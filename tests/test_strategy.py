"""Composable search strategies: Pipeline / Portfolio combinators, the
string-spec parser, Autotuning wiring, and strategy provenance on persisted
records.  Everything here is deterministic (seeded optimizers, analytic
costs)."""
import json

import numpy as np
import pytest

from repro.core import (
    CSA,
    Autotuning,
    GridSearch,
    IntDim,
    NelderMead,
    Pipeline,
    Portfolio,
    RandomSearch,
    SearchSpace,
    TunedStep,
    make_strategy,
    strategy_label,
)
from repro.core.measure import NoiseEstimate
from repro.tuning import TuningDB, TuningRecord, make_key


def sphere(z):
    return float(np.sum(np.asarray(z) ** 2))


def drive(opt, fn):
    """Run a strategy to completion via ask/tell; returns total tells."""
    n = 0
    while not opt.is_end():
        batch = opt.ask()
        if not batch:
            break
        opt.tell([fn(z) for z in batch])
        n += len(batch)
    return n


# ------------------------------------------------------------------ pipeline
def test_pipeline_budget_split_is_exact():
    """Total tells == budget, split across stages by budget_fracs; the last
    batch is truncated to the remaining allowance."""
    for budget, fracs in [(40, (0.5, 0.5)), (37, (0.7, 0.3)), (23, None)]:
        p = Pipeline(
            [CSA(2, num_opt=4, max_iter=100, seed=0),
             NelderMead(2, error=0.0, max_iter=1000, seed=0)],
            fracs, budget=budget,
        )
        assert drive(p, sphere) == budget
        assert p.spent == budget
        assert p.is_end()


def test_pipeline_second_stage_seeded_at_first_stage_best():
    """The NM stage starts from a simplex built around CSA's best (the
    paper's hybrid handoff), so its first asked vertex IS the incumbent."""
    csa = CSA(2, num_opt=4, max_iter=5, seed=3)
    nm = NelderMead(2, error=0.0, max_iter=50, seed=3)
    p = Pipeline([csa, nm], (0.5, 0.5), budget=40)
    incumbent = None
    while not p.is_end():
        batch = p.ask()
        if not batch:
            break
        if p.stage_index == 1 and incumbent is None:
            incumbent = batch[0]  # first NM vertex
            np.testing.assert_allclose(incumbent, p.best_solution)
        p.tell([sphere(z) for z in batch])
    assert incumbent is not None


def test_pipeline_stage_budget_rolls_forward_on_early_convergence():
    """A stage that converges early donates its unspent share downstream."""
    # grid of 4 points finishes long before its 0.8 share of 40
    p = Pipeline(
        [GridSearch(1, points_per_dim=4),
         NelderMead(1, error=0.0, max_iter=1000, seed=0)],
        (0.8, 0.2), budget=40,
    )
    assert drive(p, sphere) == 40  # 4 grid tells + 36 NM tells
    assert p.stage_index == 1


def test_pipeline_truncated_round_not_fed_to_stage():
    """A truncated boundary batch updates the pipeline incumbent but is not
    delivered to the stage optimizer (its round contract stays whole)."""
    csa = CSA(2, num_opt=4, max_iter=100, seed=0)
    nm = NelderMead(2, error=0.0, max_iter=1000, seed=0)
    p = Pipeline([csa, nm], (0.5, 0.5), budget=22)  # stage-1 boundary at 11
    seen = []
    while not p.is_end():
        batch = p.ask()
        if not batch:
            break
        seen.append((p.stage_index, len(batch)))
        p.tell([sphere(z) for z in batch])
    # CSA emits rounds of 4; its 11-tell allowance ends in a truncated 3-batch
    stage0 = [n for si, n in seen if si == 0]
    assert stage0 == [4, 4, 3]
    # the stage optimizer only consumed the two full rounds
    assert csa.iteration == 3
    assert p.spent == 22


def test_pipeline_best_includes_truncated_measurements():
    p = Pipeline(
        [RandomSearch(1, max_iter=100, seed=0),
         NelderMead(1, error=0.0, max_iter=100, seed=0)],
        (0.5, 0.5), budget=10,
    )
    costs = iter([5.0, 4.0, 3.0, 2.0, 1.0, 0.5, 0.4, 0.3, 0.2, -7.0])
    drive(p, lambda z: next(costs))
    assert p.best_cost == -7.0


def test_pipeline_reset_level0_restarts_current_stage_only():
    p = Pipeline(
        [CSA(1, num_opt=4, max_iter=4, seed=0),
         NelderMead(1, error=0.0, max_iter=100, seed=0)],
        (0.5, 0.5), budget=32,
    )
    drive(p, lambda z: sphere(z) + 1.0)
    assert p.is_end() and p.stage_index == 1
    best = p.best_cost
    p.reset(0)
    assert not p.is_end()
    assert p.stage_index == 1  # the *current* stage restarts, not the pipeline
    assert p.best_cost == best  # level 0 retains found solutions
    assert drive(p, lambda z: sphere(z) + 1.0) > 0  # fresh stage allowance


def test_pipeline_reset_level1_restarts_warm_at_incumbent():
    csa = CSA(2, num_opt=4, max_iter=4, seed=1)
    nm = NelderMead(2, error=0.0, max_iter=100, seed=1)
    p = Pipeline([csa, nm], (0.5, 0.5), budget=32)
    drive(p, sphere)
    incumbent = p.best_solution
    p.reset(1)
    assert not p.is_end()
    assert p.stage_index == 0  # the whole pipeline restarts...
    assert not np.isfinite(p.best_cost)  # ...with the stale energy dropped
    first = p.ask()
    # ...warm: CSA solver 0 sits exactly at the incumbent's coordinates
    np.testing.assert_allclose(first[0], incumbent)
    assert drive(p, sphere) == 32  # full cold budget restored


def test_pipeline_reset_level2_is_cold():
    p = make_strategy("csa+nm", 2, num_opt=4, max_iter=8, seed=5)
    drive(p, sphere)
    p.reset(2)
    assert not np.isfinite(p.best_cost)
    assert p.stage_index == 0
    assert drive(p, sphere) == 32  # full cold budget again


def test_pipeline_enter_refinement_runs_final_stage_alone():
    csa = CSA(2, num_opt=4, max_iter=10, seed=2)
    nm = NelderMead(2, error=0.0, max_iter=1000, seed=2)
    p = Pipeline([csa, nm], (0.7, 0.3), budget=40)
    drive(p, sphere)
    assert p.is_end()
    assert p.enter_refinement()
    assert p.refining
    assert p.stage_index == 1
    assert not p.is_end()
    assert not np.isfinite(p.best_cost)  # energy re-proves post-drift
    # the refinement episode gets the final stage's nominal share: 0.3 * 40
    spent = drive(p, sphere)
    assert spent == 12
    # a later level-1 reset leaves refinement mode and restores the budget
    p.reset(1)
    assert not p.refining
    assert drive(p, sphere) == 40


def test_pipeline_seed_targets_current_stage():
    """A DB warm start seeds the *first* stage; after enter_refinement the
    same call seeds the refinement stage."""
    p = make_strategy("csa+nm", 1, num_opt=4, max_iter=8, seed=0)
    z0 = np.array([0.25])
    assert p.seed(z0, spread=0.1)
    first = p.ask()
    np.testing.assert_allclose(first[0], z0)  # CSA solver 0 == seed
    p.tell([sphere(z) for z in first])
    drive(p, sphere)
    p.enter_refinement()
    z1 = np.array([-0.5])
    assert p.seed(z1, spread=0.1)
    batch = p.ask()
    np.testing.assert_allclose(batch[0], z1)  # NM base vertex == seed


def test_pipeline_shrink_budget_scales_total():
    p = make_strategy("csa+nm", 1, num_opt=4, max_iter=10, seed=0)  # budget 40
    assert p.shrink_budget(0.5)
    assert drive(p, sphere) == 20


def test_pipeline_validates():
    with pytest.raises(ValueError):
        Pipeline([])
    with pytest.raises(ValueError):
        Pipeline([CSA(1, num_opt=2, max_iter=2), CSA(2, num_opt=2, max_iter=2)])
    with pytest.raises(ValueError):
        Pipeline([CSA(1, num_opt=2, max_iter=2)], (0.5, 0.5), budget=10)
    with pytest.raises(ValueError):  # fracs without a budget
        Pipeline([CSA(1, num_opt=2, max_iter=2)], (1.0,))


# ----------------------------------------------------------------- portfolio
def test_portfolio_interleaves_and_respects_budget():
    pf = Portfolio(
        [CSA(1, num_opt=4, max_iter=100, seed=0),
         NelderMead(1, error=0.0, max_iter=1000, seed=0)],
        budget=30,
    )
    assert drive(pf, sphere) == 30
    assert pf.is_end()


def test_portfolio_culls_separated_laggard_toward_leader():
    """The member whose best is statistically separated from the leader's is
    halved away; the survivor inherits the remaining budget."""
    good = NelderMead(1, error=0.0, max_iter=1000, seed=0)
    bad = RandomSearch(1, max_iter=1000, seed=0)
    pf = Portfolio([good, bad], budget=60, noise=NoiseEstimate(0.0, 0.0))
    drive(pf, sphere)
    assert len(pf.active) == 1  # exactly one arm survived successive halving
    winner = pf.active[0]
    bests = pf.member_bests
    assert bests[winner] == min(bests)  # ...and it is the leader
    assert pf.spent == 60  # the culled arm's allowance flowed to the leader
    assert pf.best_cost == bests[winner]


def test_portfolio_drip_feeds_oversized_member_rounds():
    """A member whose natural round exceeds one rung (a random sweep asks
    everything at once) is drip-fed across turns instead of monopolizing the
    budget — the other member still gets its interleaved share."""
    nm = NelderMead(1, error=0.0, max_iter=1000, seed=0)
    rs = RandomSearch(1, max_iter=1000, seed=0)
    pf = Portfolio([nm, rs], budget=20, noise=NoiseEstimate(1e9, 0.0), rung=2)
    drive(pf, sphere)
    assert pf.spent == 20
    # with a giant noise floor nothing is culled, so both arms consumed
    # interleaved rungs: NM must have advanced several tells, not just one
    assert nm.evaluations >= 6
    assert pf.active == [0, 1]


def test_portfolio_never_culls_inside_noise_floor():
    a = RandomSearch(1, max_iter=1000, seed=1)
    b = RandomSearch(1, max_iter=1000, seed=2)
    # a giant noise floor: no lead is ever statistically separated
    pf = Portfolio([a, b], budget=24, noise=NoiseEstimate(1e9, 0.0))
    drive(pf, sphere)
    assert pf.active == [0, 1]


def test_portfolio_set_noise_tightens_separation():
    a = RandomSearch(1, max_iter=1000, seed=1)
    b = RandomSearch(1, max_iter=1000, seed=2)
    pf = Portfolio([a, b], budget=24, noise=NoiseEstimate(1e9, 0.0))
    pf.set_noise(NoiseEstimate(0.0, 1e-6))
    costs = iter(range(100))
    drive(pf, lambda z: float(next(costs)))
    assert len(pf.active) == 1  # now the laggard separates and is culled


def test_portfolio_default_rung_caps_sweep_members():
    """A sweep-style member (grid: its 'round' is the whole sweep) must not
    swallow the shared budget in its first chunk — the default rung is
    capped at a fair share, so the other member still races and the cull
    checks fire."""
    pf = make_strategy("grid|csa", 2, num_opt=4, max_iter=20, seed=0)  # budget 80
    grid, csa = pf.members
    drive(pf, sphere)
    assert pf.spent == 80
    # both members actually consumed budget (pre-fix: grid took all 80)
    assert csa.iteration > 1  # CSA completed at least one told round
    assert grid.get_num_points() > pf._rung  # the cap engaged for the sweep


def test_portfolio_reset_reactivates_members():
    pf = make_strategy("csa|nm", 1, num_opt=4, max_iter=10, seed=0)
    drive(pf, sphere)
    assert len(pf.active) <= 2
    pf.reset(1)
    assert pf.active == [0, 1]
    assert not np.isfinite(pf.best_cost)
    assert drive(pf, sphere) == 40  # cold budget restored


def test_portfolio_validates():
    with pytest.raises(ValueError):
        Portfolio([CSA(1, num_opt=2, max_iter=2)])
    with pytest.raises(ValueError):
        Portfolio(
            [CSA(1, num_opt=2, max_iter=2), CSA(2, num_opt=2, max_iter=2)]
        )


# -------------------------------------------------------------------- parser
def test_make_strategy_bare_names_return_raw_optimizers():
    assert isinstance(make_strategy("csa", 2, num_opt=4, max_iter=5), CSA)
    assert isinstance(make_strategy("nm", 2), NelderMead)
    assert isinstance(make_strategy("random", 2), RandomSearch)
    assert isinstance(make_strategy("grid", 2), GridSearch)


def test_make_strategy_bare_csa_is_trajectory_identical_to_default():
    """strategy='csa' must be the default search bit-for-bit."""
    a = make_strategy("csa", 2, num_opt=3, max_iter=5, seed=7)
    b = CSA(2, num_opt=3, max_iter=5, seed=7)
    fa = fb = np.nan
    while not a.is_end():
        za, zb = a.run(fa), b.run(fb)
        np.testing.assert_array_equal(za, zb)
        fa = fb = sphere(za)
    assert b.is_end()


def test_make_strategy_budget_matches_default_csa():
    """Every spec consumes num_opt * max_iter tells — the Eq.1 product."""
    for spec in ("csa", "nm", "random", "csa+nm", "csa:0.6+nm:0.4", "csa|nm"):
        opt = make_strategy(spec, 2, num_opt=4, max_iter=6, seed=0)
        assert drive(opt, sphere) == 24, spec


def test_make_strategy_structures_and_spec_attr():
    p = make_strategy("csa+nm", 2, num_opt=4, max_iter=5)
    assert isinstance(p, Pipeline)
    assert [type(s) for s in p.stages] == [CSA, NelderMead]
    assert p.spec == "csa+nm"
    pf = make_strategy("csa|nm", 2, num_opt=4, max_iter=5)
    assert isinstance(pf, Portfolio)
    assert [type(m) for m in pf.members] == [CSA, NelderMead]
    assert pf.spec == "csa|nm"
    mixed = make_strategy("csa+nm|random", 2, num_opt=4, max_iter=5)
    assert isinstance(mixed, Portfolio)
    assert isinstance(mixed.members[0], Pipeline)
    assert isinstance(mixed.members[1], RandomSearch)


def test_make_strategy_default_split_is_exploration_heavy():
    p = make_strategy("csa+nm", 1, num_opt=4, max_iter=10)  # budget 40
    assert p._fracs == pytest.approx([0.7, 0.3])


def test_make_strategy_rejects_bad_specs():
    for bad in ("", "csa+", "|nm", "warp", "csa:1.4+nm", "csa:x+nm",
                "csa:0.9+nm:0.9+grid"):
        with pytest.raises(ValueError):
            make_strategy(bad, 2)


def test_strategy_label_round_trips():
    assert strategy_label(make_strategy("csa+nm", 2)) == "csa+nm"
    assert strategy_label(make_strategy("csa|nm", 2)) == "csa|nm"
    assert strategy_label(CSA(1, num_opt=2, max_iter=2)) == "csa"
    assert strategy_label(NelderMead(2)) == "nm"
    lbl = strategy_label(
        Pipeline(
            [CSA(1, num_opt=2, max_iter=4), NelderMead(1, max_iter=8)],
            (0.75, 0.25), budget=16,
        )
    )
    assert lbl == "csa:0.75+nm:0.25"
    # a non-default split is never elided: the recorded provenance must
    # re-parse to the SAME budget shares that produced the record
    uniform = Pipeline(
        [CSA(1, num_opt=2, max_iter=4), NelderMead(1, max_iter=8)],
        budget=16,  # Pipeline's own default split is uniform, not 0.7/0.3
    )
    assert strategy_label(uniform) == "csa:0.5+nm:0.5"
    rebuilt = make_strategy(strategy_label(uniform), 1, budget=16)
    assert rebuilt._fracs == pytest.approx([0.5, 0.5])


# --------------------------------------------------------- Autotuning wiring
def test_autotuning_strategy_spec_and_exclusivity():
    at = Autotuning(-10, 10, ignore=0, dim=2, strategy="csa+nm",
                    num_opt=4, max_iter=20, seed=2)
    at.entire_exec(lambda a, b: float((a - 4) ** 2 + (b + 6) ** 2))
    assert at.best_point == {"p0": 4, "p1": -6}
    assert at.strategy == "csa+nm"
    assert at.num_measurements == 80  # same Eq.1 budget as the default CSA
    with pytest.raises(ValueError):
        Autotuning(dim=1, strategy="csa", optimizer=CSA(1, num_opt=2, max_iter=2))


def test_autotuning_single_optimizer_trajectory_pinned():
    """Regression pin: optimizer=CSA construction is bit-for-bit identical to
    the pre-strategy-layer driver (visited points and costs hard-coded)."""
    at = Autotuning(1, 64, ignore=0, optimizer=CSA(2, num_opt=3, max_iter=5, seed=7),
                    dim=2)
    at.entire_exec(lambda a, b: float((a - 37) ** 2 + (b - 5) ** 2))
    pin = [
        (40, 58, 2818.0), (50, 15, 269.0), (20, 56, 2890.0), (33, 20, 241.0),
        (8, 14, 922.0), (9, 43, 2228.0), (31, 20, 261.0), (52, 2, 234.0),
        (22, 47, 1989.0), (24, 5, 169.0), (55, 8, 333.0), (16, 47, 2205.0),
        (26, 6, 122.0), (52, 59, 3141.0), (35, 38, 1093.0),
    ]
    assert [(p["p0"], p["p1"], c) for p, c in at.history] == pin
    assert at.best_point == {"p0": 26, "p1": 6}
    # ... and the batch driver walks the identical trajectory
    at2 = Autotuning(1, 64, ignore=0,
                     optimizer=CSA(2, num_opt=3, max_iter=5, seed=7), dim=2)
    at2.entire_exec_batch(
        lambda pts: [float((p["p0"] - 37) ** 2 + (p["p1"] - 5) ** 2) for p in pts]
    )
    assert [(p["p0"], p["p1"], c) for p, c in at2.history] == pin


def test_pipeline_not_worse_than_csa_on_shootout_models():
    """Acceptance: Pipeline([CSA, NM]) with a shared budget finds a best
    <= pure CSA's on the deterministic strategy_shootout cost models, at the
    same total tell count."""
    from benchmarks.strategy_shootout import COST_MODELS

    budget = 120
    for fname, fn in COST_MODELS.items():
        pipe_bests, csa_bests = [], []
        for seed in range(3):
            pipe = make_strategy("csa+nm", 2, num_opt=4, max_iter=budget // 4,
                                 seed=seed)
            csa = make_strategy("csa", 2, num_opt=4, max_iter=budget // 4,
                                seed=seed)
            assert drive(pipe, fn) == budget
            assert drive(csa, fn) == budget
            pipe_bests.append(pipe.best_cost)
            csa_bests.append(csa.best_cost)
        assert np.median(pipe_bests) <= np.median(csa_bests), fname


def test_autotuning_warm_start_seeds_first_stage_only(tmp_path):
    """A DB near-miss seeds the pipeline's *first* stage around the stored
    point (and shrinks the total budget); the NM stage still gets its seed
    from the CSA handoff, not from the DB."""
    db = TuningDB(str(tmp_path / "db.json"))
    sp = SearchSpace([IntDim("p", 1, 64)])
    stored = make_key("unit", args=(np.zeros((64, 64), np.float32),), space=sp)
    db.put(TuningRecord(key=stored, point={"p": 48}, cost=1.0, evals=8))
    near = make_key("unit", args=(np.zeros((128, 128), np.float32),), space=sp)
    at = Autotuning(space=sp, ignore=0, strategy="csa+nm",
                    num_opt=4, max_iter=10, seed=0, db=db, key=near)
    assert at.warm_started
    assert at.point == {"p": 48}  # first candidate: CSA solver 0 == seed
    pipe = at.optimizer
    assert isinstance(pipe, Pipeline)
    at.entire_exec_batch(lambda pts: [float((p["p"] - 40) ** 2) for p in pts])
    assert pipe.spent <= 20  # budget halved (cold: 40)
    assert abs(at.best_point["p"] - 40) <= 1  # half-budget refinement lands


def test_tuned_step_accepts_strategy():
    space = SearchSpace([IntDim("n", 1, 6)])
    calls = []

    def factory(n):
        calls.append(n)
        return lambda: n

    ts = TunedStep(factory, space, ignore=0, strategy="csa+nm",
                   num_opt=3, max_iter=4, seed=1)
    assert isinstance(ts.at.optimizer, Pipeline)
    for _ in range(40):
        if ts.finished:
            break
        ts()
    assert ts.finished


# --------------------------------------------------------------- provenance
def test_record_strategy_round_trips_and_old_records_load_none(tmp_path):
    sp = SearchSpace([IntDim("p", 1, 32)])
    key = make_key("unit", space=sp)
    db = TuningDB(str(tmp_path / "db.json"))
    at = Autotuning(space=sp, ignore=0, strategy="csa+nm", num_opt=3,
                    max_iter=4, seed=0, db=db, key=key)
    at.entire_exec(lambda p: float((p - 9) ** 2))
    rec = db.get(key)
    assert rec is not None and rec.strategy == "csa+nm"
    # JSON round trip preserves the spec
    rec2 = TuningRecord.from_json(json.loads(json.dumps(rec.to_json())))
    assert rec2.strategy == "csa+nm"
    # a pre-strategy record (no field at all) loads as None...
    blob = rec.to_json()
    del blob["strategy"]
    old = TuningRecord.from_json(blob)
    assert old.strategy is None
    # ...and still replays as an exact hit
    db2 = TuningDB(str(tmp_path / "db2.json"))
    db2.put(old)
    replay = Autotuning(space=sp, ignore=0, db=db2, key=key)
    assert replay.finished
    assert replay.best_point == old.point
    assert replay.num_measurements == 0


def test_default_optimizer_records_csa_strategy(tmp_path):
    sp = SearchSpace([IntDim("p", 1, 16)])
    key = make_key("unit2", space=sp)
    db = TuningDB(str(tmp_path / "db.json"))
    at = Autotuning(space=sp, ignore=0, num_opt=3, max_iter=3, seed=0,
                    db=db, key=key)
    at.entire_exec(lambda p: float(p))
    rec = db.get(key)
    assert rec is not None and rec.strategy == "csa"


def test_pretune_list_shows_strategy_column(tmp_path, capsys):
    """pretune --list prints the stored record's strategy on exact hits."""
    pytest.importorskip("jax")
    from repro.tuning import pretune

    db_path = str(tmp_path / "db.json")
    rc = pretune.main(
        ["--db", db_path, "--smoke", "--only", "lru_scan/*",
         "--strategy", "csa+nm", "--max-iter", "2", "--jobs", "1"]
    )
    assert rc == 0
    out = capsys.readouterr().out
    assert "strategy=csa+nm" in out
    rc = pretune.main(["--db", db_path, "--smoke", "--only", "lru_scan/*", "--list"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "HIT" in out and "strategy=csa+nm" in out
