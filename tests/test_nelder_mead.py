"""Nelder–Mead unit + property tests (paper §2.1/§2.3, Eq. 2)."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import NelderMead


def drive(opt, fn, cap=100_000):
    z = opt.run(np.nan)
    n = 0
    while not opt.is_end() and n < cap:
        z = opt.run(fn(z))
        n += 1
    return n


def test_converges_on_quadratic():
    opt = NelderMead(dim=4, error=1e-12, max_iter=500, seed=2)
    drive(opt, lambda z: float(np.sum((z - 0.3) ** 2)))
    assert opt.best_cost < 1e-8


def test_rosenbrock():
    def rosen(z):
        x, y = z * 2
        return float((1 - x) ** 2 + 100 * (y - x * x) ** 2)

    opt = NelderMead(dim=2, error=1e-13, max_iter=800, seed=0)
    drive(opt, rosen)
    assert opt.best_cost < 1e-4


def test_max_iter_caps_evaluations():
    """Paper Eq. 2: max_iter counts cost evaluations for NM."""
    opt = NelderMead(dim=3, error=0.0, max_iter=37, seed=1)
    n = drive(opt, lambda z: float(np.sum(z**2)) + 1.0)
    assert n == 37


def test_error_stopping():
    opt = NelderMead(dim=2, error=1e-3, max_iter=0, seed=1)  # unbounded evals
    n = drive(opt, lambda z: float(np.sum(z**2)))
    assert opt.is_end()
    assert n < 500  # converged long before the cap


def test_reset_levels():
    opt = NelderMead(dim=2, error=1e-12, max_iter=100, seed=4)
    drive(opt, lambda z: float(np.sum((z + 0.4) ** 2)))
    best = opt.best_cost
    opt.reset(0)
    assert not opt.is_end()
    assert opt.best_cost == best  # simplex rebuilt around the best
    opt.reset(1)
    assert not np.isfinite(opt.best_cost) or opt.evaluations == 0


def test_final_solution_returned_after_end():
    opt = NelderMead(dim=2, error=1e-9, max_iter=200, seed=5)
    drive(opt, lambda z: float(np.sum(z**2)))
    out = opt.run(123.0)
    assert np.allclose(out, opt.best_solution)


@settings(max_examples=25, deadline=None)
@given(dim=st.integers(1, 6), cap=st.integers(5, 200), seed=st.integers(0, 999))
def test_property_bounds_and_cap(dim, cap, seed):
    opt = NelderMead(dim=dim, error=0.0, max_iter=cap, seed=seed)
    z = opt.run(np.nan)
    n = 0
    while not opt.is_end():
        assert z.shape == (dim,)
        assert np.all(z >= -1.0) and np.all(z <= 1.0)
        z = opt.run(float(np.sum((z - 0.1) ** 2)) + 1.0)
        n += 1
    assert n <= cap
    assert np.isfinite(opt.best_cost)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_monotone_best(seed):
    """best_cost is non-increasing over the run."""
    opt = NelderMead(dim=3, error=0.0, max_iter=150, seed=seed)
    z = opt.run(np.nan)
    prev = np.inf
    while not opt.is_end():
        assert opt.best_cost <= prev + 1e-15
        prev = opt.best_cost
        z = opt.run(float(np.sum(np.abs(z - 0.25))))
