"""Autotuning driver tests — execution modes (paper Fig. 1, §2.3/§2.4)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    CSA,
    Autotuning,
    GridSearch,
    IntDim,
    LogIntDim,
    NelderMead,
    RandomSearch,
    SearchSpace,
    TunedStep,
)


def test_eq1_measurement_count():
    """num_eval = max_iter * (ignore + 1) * num_opt (paper Eq. 1)."""
    for ignore, m, it in [(0, 4, 10), (1, 3, 7), (2, 5, 4)]:
        at = Autotuning(1, 32, ignore=ignore, dim=1, num_opt=m, max_iter=it)
        at.entire_exec(lambda p: (p - 9) ** 2)
        assert at.num_measurements == it * (ignore + 1) * m


def test_eq2_measurement_count():
    """num_eval = max_iter * (ignore + 1) (paper Eq. 2, Nelder–Mead)."""
    for ignore, it in [(0, 25), (1, 12), (3, 6)]:
        nm = NelderMead(dim=1, error=0.0, max_iter=it)
        at = Autotuning(1, 64, ignore=ignore, optimizer=nm)
        at.entire_exec(lambda p: abs(p - 20))
        assert at.num_measurements == it * (ignore + 1)


def test_entire_exec_finds_optimum():
    at = Autotuning(1, 16, ignore=0, dim=1, num_opt=4, max_iter=30, seed=0)
    at.entire_exec(lambda p: (p - 7) ** 2 + 1.0)
    assert at.finished
    assert at.best_point == {"p0": 7}
    assert at.point == {"p0": 7}  # final solution exposed as current point


def test_single_mode_rides_the_loop():
    """Single Iteration mode: tuning completes inside the natural loop, then
    the final solution is used for the remaining iterations (Fig. 1a)."""
    at = Autotuning(1, 8, ignore=0, dim=1, num_opt=3, max_iter=8, seed=1)
    used_after_end = set()
    for _ in range(200):
        cost = at.single_exec(lambda p: (p - 3) ** 2)
        if at.finished:
            used_after_end.add(at.point["p0"])
    assert at.finished
    assert used_after_end == {3}


def test_single_vs_entire_equivalence():
    """On a deterministic cost, both modes see identical cost sequences and
    reach the same final point."""
    def cost(p):
        return (p - 11) ** 2 * 0.5 + 2.0

    a = Autotuning(1, 32, ignore=0, dim=1, num_opt=4, max_iter=15, seed=5)
    a.entire_exec(cost)
    b = Autotuning(1, 32, ignore=0, dim=1, num_opt=4, max_iter=15, seed=5)
    while not b.finished:
        b.single_exec(cost)
    assert a.point == b.point
    assert [c for _, c in a.history] == [c for _, c in b.history]


def test_ignore_discards_stabilization_iters():
    """With ignore=k the first k costs per candidate are discarded; the
    delivered cost is the (k+1)-th measurement (compile-absorption in JAX)."""
    seen = []

    class SpyOpt(CSA):
        def run(self, cost):
            if np.isfinite(cost):
                seen.append(cost)
            return super().run(cost)

    at = Autotuning(1, 4, ignore=2, optimizer=SpyOpt(1, num_opt=2, max_iter=3))
    calls = {"n": 0}

    def cost(p):
        calls["n"] += 1
        # first two calls per candidate return garbage; third the true cost
        return 1000.0 if calls["n"] % 3 != 0 else float(p)

    at.entire_exec(cost)
    assert all(c != 1000.0 for c in seen)


def test_runtime_mode_measures_wall_time():
    """start()/end() brackets measure real elapsed time -> tuner finds the
    faster branch."""
    at = Autotuning(0, 1, ignore=0, dim=1, num_opt=4, max_iter=12, seed=3)
    while not at.finished:
        p = at.start()
        if p["p0"] == 1:
            time.sleep(0.004)  # slow configuration
        time.sleep(0.0005)
        at.end()
    assert at.best_point["p0"] == 0


def test_runtime_mode_blocks_on_jax():
    """end(result) must block on async JAX work before timing."""
    x = jnp.ones((256, 256))

    @jax.jit
    def heavy(x):
        for _ in range(8):
            x = x @ x.T / 256.0
        return x

    @jax.jit
    def light(x):
        return x + 1.0

    heavy(x).block_until_ready()
    light(x).block_until_ready()
    at = Autotuning(0, 1, ignore=1, dim=1, num_opt=4, max_iter=10, seed=0)
    while not at.finished:
        p = at.start()
        out = heavy(x) if p["p0"] == 1 else light(x)
        at.end(out)
    assert at.best_point["p0"] == 0


def test_exec_user_cost_mode():
    """exec(point, cost) — the library as a plain staged optimizer (§2.4)."""
    at = Autotuning(-10, 10, ignore=0, dim=2, num_opt=4, max_iter=25, seed=2)
    p = at.point
    while not at.finished:
        cost = (p["p0"] - 4) ** 2 + (p["p1"] + 6) ** 2
        p = at.exec(cost)
    assert at.best_point == {"p0": 4, "p1": -6}


def test_cache_skips_repeat_measurements():
    calls = {"n": 0}

    def cost(p):
        calls["n"] += 1
        return (p - 2) ** 2

    at = Autotuning(1, 4, ignore=0, dim=1, num_opt=4, max_iter=50, seed=0, cache=True)
    at.entire_exec(cost)
    assert calls["n"] <= 4  # only 4 distinct candidates exist
    assert at.best_point["p0"] == 2


def test_reset_reenters_tuning():
    at = Autotuning(1, 16, ignore=0, dim=1, num_opt=3, max_iter=6, seed=0)
    at.entire_exec(lambda p: (p - 5) ** 2)
    assert at.finished
    at.reset(0)
    assert not at.finished
    at.entire_exec(lambda p: (p - 12) ** 2)  # environment changed
    assert at.best_point["p0"] in (5, 12)  # best over both phases retained at level 0
    at.reset(2)
    at.entire_exec(lambda p: (p - 12) ** 2)
    assert at.best_point["p0"] == 12


def test_grid_search_through_autotuning():
    at = Autotuning(0, 9, ignore=0, optimizer=GridSearch(1, points_per_dim=10))
    at.entire_exec(lambda p: abs(p - 6))
    assert at.best_point["p0"] == 6


# ---------------------------------------- grid/random reset-contract parity
def test_grid_search_reset_levels_match_csa_contract():
    """GridSearch reset parity: level 1 keeps the best *coordinates* but
    drops the stale energy (CSA's drift-reset contract); level >= 2 is
    complete."""
    import numpy as np

    gs = GridSearch(1, points_per_dim=8)
    while not gs.is_end():
        gs.tell([float((z[0] - 0.5) ** 2) for z in gs.ask()])
    best = gs.best_solution.copy()
    assert np.isfinite(gs.best_cost)
    gs.reset(1)
    assert not gs.is_end()
    np.testing.assert_array_equal(gs.best_solution, best)  # coordinates kept
    assert not np.isfinite(gs.best_cost)  # stale energy dropped
    # the point re-proves itself against post-drift costs
    while not gs.is_end():
        gs.tell([float((z[0] + 0.5) ** 2) for z in gs.ask()])
    assert abs(gs.best_solution[0] + 0.5) < 0.2
    gs.reset(2)
    assert not np.isfinite(gs.best_cost)


def test_random_search_reset_restores_cold_budget():
    """RandomSearch reset parity: a warm-start-shrunk budget never compounds
    across resets (every level restores the cold sample count), and level 1
    keeps coordinates / drops energy."""
    import numpy as np

    rs = RandomSearch(1, max_iter=16, seed=0)
    assert rs.shrink_budget(0.5)
    n = 0
    while not rs.is_end():
        b = rs.ask()
        if not b:
            break
        rs.tell([float(z[0] ** 2) for z in b])
        n += len(b)
    assert n == 8  # shrunk budget honored
    best = rs.best_solution.copy()
    rs.reset(1)
    np.testing.assert_array_equal(rs.best_solution, best)
    assert not np.isfinite(rs.best_cost)
    n = 0
    while not rs.is_end():
        b = rs.ask()
        if not b:
            break
        rs.tell([float(z[0] ** 2) for z in b])
        n += len(b)
    assert n == 16  # cold budget restored at level >= 1
    # level 2 replays the seed's stream: same points as a fresh instance
    rs.reset(2)
    fresh = RandomSearch(1, max_iter=16, seed=0)
    np.testing.assert_array_equal(
        np.asarray(rs.ask()), np.asarray(fresh.ask())
    )


def test_random_search_level0_reset_keeps_found_solution():
    import numpy as np

    rs = RandomSearch(1, max_iter=4, seed=3)
    while not rs.is_end():
        rs.tell([0.25 for _ in rs.ask()])
    rs.reset(0)
    assert rs.best_cost == 0.25  # level 0 retains found solutions (§2.2)
    assert not rs.is_end()


@settings(max_examples=20, deadline=None)
@given(
    lo=st.integers(-20, 0),
    width=st.integers(1, 40),
    seed=st.integers(0, 500),
    ignore=st.integers(0, 2),
)
def test_property_points_always_within_user_bounds(lo, width, seed, ignore):
    hi = lo + width
    at = Autotuning(lo, hi, ignore=ignore, dim=2, num_opt=3, max_iter=6, seed=seed)

    def cost(a, b):
        assert lo <= a <= hi and lo <= b <= hi
        return float(a * a + b * b)

    at.entire_exec(cost)
    assert lo <= at.best_point["p0"] <= hi


# ---------------------------------------------------------------- TunedStep
def test_tuned_step_single_iteration_mode():
    """TunedStep tunes a static knob of a jitted step during the loop."""
    space = SearchSpace([LogIntDim("block", 32, 256)])
    compiles = []

    def factory(block):
        compiles.append(block)

        @jax.jit
        def step(x):
            # emulate: smaller blocks do redundant work
            reps = 256 // block
            acc = x
            for _ in range(reps):
                acc = acc + jnp.tanh(x)
            return acc

        return step

    ts = TunedStep(factory, space, ignore=1, num_opt=3, max_iter=6, seed=0)
    x = jnp.ones((64, 64))
    for _ in range(100):
        out = ts(x)
        if ts.finished:
            break
    assert ts.finished
    # executable cache: at most one compile per distinct candidate
    assert len(compiles) == len(set(compiles))


def test_tuned_step_entire_mode_returns_best():
    space = SearchSpace([IntDim("n", 1, 6)])

    def factory(n):
        @jax.jit
        def step(x):
            acc = x
            for _ in range(n * 3):
                acc = acc @ x
            return acc

        return step

    ts = TunedStep(factory, space, ignore=1, num_opt=4, max_iter=8, seed=1)
    best = ts.tune(jnp.eye(128))
    assert ts.finished
    assert 1 <= best["n"] <= 6
