"""Fault tolerance: crash/resume bit-identical trajectories, straggler
watchdog -> PATSMA reset, elastic re-mesh restore."""
import time

import jax
import numpy as np
import pytest

from repro.runtime import TrainJob, Watchdog

from helpers import run_py


@pytest.mark.slow
def test_resume_identical_trajectory(tmp_path):
    """Uninterrupted run vs (crash at step 14 -> resume) must produce the
    same losses at the same steps (data is pure(seed, step); checkpoint
    restores params+opt exactly)."""
    base = dict(arch="qwen2_7b", tiny=True, steps=24, global_batch=4, seq_len=32,
                ckpt_every=8, ckpt_async=False, seed=3)
    full = TrainJob(**base, ckpt_dir=str(tmp_path / "a")).run()

    class Crash(Exception):
        pass

    def bomb(step):
        if step == 14:
            raise Crash()

    job_b = TrainJob(**base, ckpt_dir=str(tmp_path / "b"), delay_hook=bomb)
    with pytest.raises(Crash):
        job_b.run()
    # resume (fresh driver object — simulates a new process)
    resumed = TrainJob(**base, ckpt_dir=str(tmp_path / "b")).run()
    # the resumed run restarts after the last checkpoint (step 7) -> steps 8..23
    assert resumed["steps"][0] == 8
    full_by_step = dict(zip(full["steps"], full["loss"]))
    for s, l in zip(resumed["steps"], resumed["loss"]):
        np.testing.assert_allclose(l, full_by_step[s], rtol=1e-6)


def test_watchdog_detects_stragglers():
    wd = Watchdog(factor=1.5, warmup=2)
    for i in range(8):
        assert wd.check(0.10, i) == 0
    assert wd.check(0.18, 8) >= 1  # 1.8x EWMA -> flagged
    assert wd.events and wd.events[-1]["step"] == 8
    # EWMA not polluted by the outlier
    assert abs(wd.ewma - 0.10) < 0.01


@pytest.mark.slow
def test_driver_tunes_and_resets_on_straggler(tmp_path):
    """Single-Iteration tuning rides the loop; an injected slowdown after
    tuning completes triggers reset() and re-tuning (paper §2.2 reset)."""
    slow = {"on": False}

    def delay(step):
        if 30 <= step < 33:
            slow["on"] = True
            time.sleep(0.25)
        else:
            slow["on"] = False

    job = TrainJob(
        arch="qwen2_7b", tiny=True, steps=40, global_batch=4, seq_len=32,
        tune=True, tune_microbatches=(1, 2), tune_max_iter=3, tune_num_opt=2,
        ignore=1, delay_hook=delay, watchdog_factor=1.6,
    )
    hist = job.run()
    assert np.isfinite(hist["loss"]).all()
    assert hist["final_knobs"].get("microbatches") in (1, 2)
    assert len(hist["watchdog_events"]) >= 1  # straggler seen
    assert len(hist["resets"]) >= 1  # tuning re-entered


@pytest.mark.slow
@pytest.mark.multidevice
def test_elastic_restore_across_device_counts(tmp_path):
    """Save on a (2,2) mesh (4 devices), restore+reshard on (4,2) (8 devices):
    params must be bit-identical after the round-trip."""
    code = f"""
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.models import Model
from repro.launch.mesh import make_mesh, default_rules
from repro.parallel.sharding import tree_shardings, param_wanted
from repro.checkpoint import save_checkpoint, load_checkpoint

cfg = configs.get_tiny("qwen2_72b")
m = Model(cfg)
params = m.init(jax.random.PRNGKey(0))
mesh = make_mesh((2, 2), ("data", "model"))
sh = tree_shardings(mesh, default_rules(mesh), jax.eval_shape(lambda: params), param_wanted)
params = jax.tree.map(lambda a, s: jax.device_put(a, s), params, sh)
save_checkpoint(r"{tmp_path}", 0, params)
print("SAVED", float(jax.tree.leaves(params)[0].sum()))
"""
    out1 = run_py(code, devices=4)
    saved_sum = float(out1.split("SAVED")[1].strip())

    code2 = f"""
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.models import Model
from repro.launch.mesh import make_mesh, default_rules
from repro.parallel.sharding import tree_shardings, param_wanted
from repro.checkpoint import load_checkpoint

cfg = configs.get_tiny("qwen2_72b")
m = Model(cfg)
like = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
mesh = make_mesh((4, 2), ("data", "model"))   # different device count!
sh = tree_shardings(mesh, default_rules(mesh), like, param_wanted)
params, step, _ = load_checkpoint(r"{tmp_path}", like, shardings=sh)
leaf = jax.tree.leaves(params)[0]
assert len(leaf.sharding.device_set) >= 1
print("RESTORED", float(leaf.sum()))
"""
    out2 = run_py(code2, devices=8)
    restored_sum = float(out2.split("RESTORED")[1].strip())
    np.testing.assert_allclose(saved_sum, restored_sum, rtol=1e-5)  # fp32 reduce order
