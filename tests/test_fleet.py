"""Distributed tuning fleet: shard partitioning, order-independent DB
merging, the umbrella CLI, the ShardedPortfolio race, and the unified
``search=`` surface."""
import itertools
import json
import warnings

import numpy as np
import pytest

from repro.core import CSA, Autotuning, NelderMead, Portfolio, RandomSearch
from repro.tuning import TuningDB, TuningKey, TuningRecord, make_key
from repro.tuning.fleet import (
    ShardedPortfolio,
    better_record,
    merge_dbs,
    merge_records,
    parse_shard,
    record_rank,
)


def _key(name="unit", tag="a"):
    return TuningKey(name=name, signature=f"sig-{tag}", space_hash="h",
                     backend="cpu", device_kind="cpu")


def _rec(key=None, *, cost=1.0, std=None, reps=None, created=1.0, point=None):
    return TuningRecord(
        key=key if key is not None else _key(),
        point=point if point is not None else {"p": 1},
        cost=cost, cost_std=std, repeats_spent=reps, created=created,
    )


# ------------------------------------------------------------------ sharding
def test_parse_shard():
    assert parse_shard("0/1") == (0, 1)
    assert parse_shard(" 2/8 ") == (2, 8)
    for bad in ("8/8", "-1/4", "4/0", "x/y", "3", "1/2/3"):
        with pytest.raises(ValueError):
            parse_shard(bad)


def test_shard_partition_complete_disjoint_and_stable():
    keys = [_key(name=f"k{i}", tag=str(i)) for i in range(40)]
    for n in (1, 2, 3, 8):
        shards = [k.shard(n) for k in keys]
        assert all(0 <= s < n for s in shards)
        # stable: recomputing gives the identical assignment
        assert shards == [k.shard(n) for k in keys]
    # n=1 is the degenerate single worker owning everything
    assert all(k.shard(1) == 0 for k in keys)
    # a 40-key grid into 2 shards should not collapse onto one worker
    two = [k.shard(2) for k in keys]
    assert 0 < sum(two) < len(two)


def test_shard_partition_of_pretune_grid_is_complete_and_disjoint():
    """`pretune --shard i/n` across all i covers the smoke grid exactly once
    — the zero-coordination contract a fleet of workers relies on."""
    pytest.importorskip("jax")
    from repro.tuning.pretune import _cases, _shard_filter

    cases = _cases(True, abstract=True)
    all_ids = [(name, label) for name, label, _ in cases]
    for n in (2, 3):
        shards = [
            [(name, label) for name, label, _ in
             _shard_filter(cases, True, None, None, (i, n), interpret=True)]
            for i in range(n)
        ]
        combined = [cid for s in shards for cid in s]
        assert sorted(combined) == sorted(all_ids)  # complete + disjoint
        # and stable: recomputing the same shard gives the same cases
        again = [(name, label) for name, label, _ in
                 _shard_filter(cases, True, None, None, (0, n), interpret=True)]
        assert again == shards[0]


# ------------------------------------------------------------- merge resolver
def test_merge_lower_cost_wins():
    a, b = _rec(cost=1.0), _rec(cost=2.0)
    assert better_record(a, b) is a
    assert better_record(b, a) is a


def test_merge_near_tie_prefers_lower_variance():
    """Inside the noise band the better-measured record stands — the same
    rule as Autotuning.commit()'s keep-better guard."""
    lucky = _rec(cost=0.99, std=0.5, reps=8, created=2.0)
    steady = _rec(cost=1.00, std=0.01, reps=8, created=1.0)
    assert better_record(lucky, steady) is steady
    # a *separated* win beats any variance argument
    clear = _rec(cost=0.2, std=0.5, reps=8)
    assert better_record(clear, steady) is clear


def test_merge_single_rep_std_is_unknown_not_zero():
    """A single-repetition record's std of 0.0 must not read as perfect
    confidence: the 2% relative prior penalizes it past a well-measured
    near-tie."""
    one_rep = _rec(cost=1.0, std=0.0, reps=1)
    measured = _rec(cost=1.005, std=0.001, reps=8)
    assert better_record(one_rep, measured) is measured


def test_merge_infinite_cost_always_loses():
    dead = _rec(cost=float("inf"))
    alive = _rec(cost=1e9)
    assert better_record(dead, alive) is alive
    assert merge_records([dead, dead]) is dead  # still total on all-inf


def test_merge_total_order_is_permutation_invariant():
    """The resolver must pick one winner regardless of fold order — the
    pairwise commit guard alone is not transitive, the rank linearizes it."""
    recs = [
        _rec(cost=1.2, std=0.1, reps=8, created=1.0, point={"p": 1}),
        _rec(cost=1.0, std=0.5, reps=8, created=2.0, point={"p": 2}),
        _rec(cost=0.9, std=None, reps=None, created=3.0, point={"p": 3}),
        _rec(cost=float("inf"), created=4.0, point={"p": 4}),
    ]
    ranks = set()
    for perm in itertools.permutations(recs):
        w = perm[0]
        for r in perm[1:]:
            w = better_record(w, r)
        ranks.add(record_rank(w))
    assert len(ranks) == 1
    assert record_rank(merge_records(recs)) == ranks.pop()


def test_merge_dbs_associative_across_shards(tmp_path):
    """Divergent shard DBs fold to the same destination whatever the merge
    order or grouping — and to what commit()'s guard would keep per key."""
    k1, k2, k3 = (_key(tag=t) for t in "123")
    s0 = TuningDB(str(tmp_path / "s0.json"))
    s1 = TuningDB(str(tmp_path / "s1.json"))
    s2 = TuningDB(str(tmp_path / "s2.json"))
    s0.put(_rec(k1, cost=1.0, std=0.01, reps=8, created=1.0))
    s1.put(_rec(k1, cost=0.99, std=0.5, reps=8, created=2.0))  # lucky near-tie
    s2.put(_rec(k1, cost=2.0, created=3.0))
    s1.put(_rec(k2, cost=5.0, created=1.0))
    s2.put(_rec(k2, cost=4.0, created=2.0))
    s0.put(_rec(k3, cost=7.0, created=1.0))

    def fold(order, pairwise):
        dest = TuningDB()  # in-memory
        dbs = [s0, s1, s2]
        if pairwise:
            for i in order:
                merge_dbs(dest, [dbs[i]])
        else:
            merge_dbs(dest, [dbs[i] for i in order])
        return {k: record_rank(r) for k, r in
                ((rec.key.encode(), rec) for rec in dest.records())}

    outcomes = {
        json.dumps(sorted(fold(order, pw).items()))
        for order in itertools.permutations(range(3))
        for pw in (True, False)
    }
    assert len(outcomes) == 1
    # and the per-key winners are the keep-better picks
    dest = TuningDB()
    stats = merge_dbs(dest, [s0, s1, s2])
    assert stats.seen == 6
    assert (stats.new, stats.replaced, stats.kept) == (3, 1, 2)
    assert stats.adopted == 4
    assert len(dest) == 3
    assert dest.get(k1).cost == 1.0  # steady record beats the lucky near-tie
    assert dest.get(k2).cost == 4.0
    assert dest.get(k3).cost == 7.0


def test_tuningdb_merge_uses_fleet_resolver():
    db_a, db_b = TuningDB(), TuningDB()
    k = _key()
    db_a.put(_rec(k, cost=1.0, std=0.01, reps=8, created=1.0))
    db_b.put(_rec(k, cost=0.99, std=0.5, reps=8, created=2.0))
    adopted = db_a.merge(db_b)
    assert adopted == 0  # lucky near-tie loses to the steadier record
    assert db_a.get(k).cost == 1.0


# ------------------------------------------------------------------- CLI
def test_tune_cli_db_merge_list_diff(tmp_path):
    from repro.tune import main as tune_main

    k1, k2 = _key(tag="1"), _key(tag="2")
    a = TuningDB(str(tmp_path / "a.json"))
    b = TuningDB(str(tmp_path / "b.json"))
    a.put(_rec(k1, cost=1.0, created=1.0))
    b.put(_rec(k1, cost=0.5, created=2.0))
    b.put(_rec(k2, cost=3.0, created=1.0))

    out = str(tmp_path / "merged.json")
    assert tune_main(["db", "merge", "--out", out,
                      str(tmp_path / "a.json"), str(tmp_path / "b.json")]) == 0
    merged = TuningDB(out)
    assert len(merged) == 2 and merged.get(k1).cost == 0.5

    assert tune_main(["db", "list", "--db", out]) == 0
    # diff: merged vs b differ on k1's point? identical points here, but a
    # missing key in a vs merged must exit 1
    assert tune_main(["db", "diff", out, str(tmp_path / "b.json")]) == 0
    assert tune_main(["db", "diff", out, str(tmp_path / "a.json")]) == 1
    # missing file is a usage error (2), not a crash
    assert tune_main(["db", "merge", "--out", out, str(tmp_path / "nope.json")]) == 2
    assert tune_main(["nonsense"]) == 2


def test_tune_cli_db_diff_detects_point_mismatch(tmp_path):
    from repro.tune import main as tune_main

    k = _key()
    a = TuningDB(str(tmp_path / "a.json"))
    b = TuningDB(str(tmp_path / "b.json"))
    a.put(_rec(k, point={"p": 1}))
    b.put(_rec(k, point={"p": 2}))
    assert tune_main(["db", "diff", str(tmp_path / "a.json"),
                      str(tmp_path / "b.json")]) == 1


# ------------------------------------------------------- sharded portfolio
def _cost(x):
    x = np.asarray(x, dtype=float)
    return float(np.sum((x - 0.3) ** 2) + 0.05 * np.cos(8.0 * x[0]))


def _drive_serial(portfolio):
    while not portfolio.is_end():
        batch = portfolio.ask()
        if not batch:
            break
        portfolio.tell([_cost(p) for p in batch])
    return portfolio


@pytest.mark.parametrize("budget", [80, None])
def test_sharded_portfolio_matches_serial_race(budget):
    """Deterministic costs → the concurrent rung-barrier driver makes the
    same cull decisions and finds the same member bests as the serial
    Portfolio."""

    def members():
        return [
            CSA(2, num_opt=4, max_iter=10, seed=0),
            CSA(2, num_opt=4, max_iter=10, seed=1),
            RandomSearch(2, max_iter=40, seed=3),
            NelderMead(2, error=0.0, max_iter=40, seed=2),
        ]

    serial = _drive_serial(Portfolio(members(), budget=budget, rung=4))
    fleet = ShardedPortfolio(members(), budget=budget, rung=4)
    res = fleet.run(lambda i, pts: [_cost(p) for p in pts])
    assert res.survivors == serial.active
    for a, b in zip(res.member_bests, serial.member_bests):
        assert (np.isinf(a) and np.isinf(b)) or abs(a - b) < 1e-12
    assert res.spent == serial.spent
    assert np.isfinite(res.best_cost)
    assert abs(res.best_cost - min(res.member_bests)) < 1e-12


def test_sharded_portfolio_culls_laggards():
    """A member pinned to a hopeless region is culled, and the race ends
    with the survivors' budget honestly accounted."""

    def members():
        return [CSA(2, num_opt=4, max_iter=8, seed=s) for s in range(4)]

    fleet = ShardedPortfolio(members(), budget=96, rung=4)

    def measure(i, pts):
        # member 3 is sandbagged far above everyone else's floor
        return [(_cost(p) + (100.0 if i == 3 else 0.0)) for p in pts]

    res = fleet.run(measure)
    assert 3 not in res.survivors
    assert res.member_spent[3] < max(res.member_spent)
    assert sum(res.member_spent) == res.spent


def test_sharded_portfolio_validates():
    with pytest.raises(ValueError):
        ShardedPortfolio([CSA(2, num_opt=2, max_iter=2)])
    with pytest.raises(ValueError):
        ShardedPortfolio(
            [CSA(2, num_opt=2, max_iter=2), CSA(3, num_opt=2, max_iter=2)]
        )
    with pytest.raises(ValueError):
        ShardedPortfolio(
            [CSA(2, num_opt=2, max_iter=2), CSA(2, num_opt=2, max_iter=2)],
            budget=0,
        )


def test_cache_partitions_do_not_collide():
    from repro.core import ExecutableCache

    base = ExecutableCache(maxsize=8)
    p0, p1 = base.partition("dev0"), base.partition("dev1")
    assert p0.get_or_build("k", lambda: "exe-dev0") == "exe-dev0"
    # the same key in another partition is a distinct executable
    assert p1.peek("k") is None
    assert p1.get_or_build("k", lambda: "exe-dev1") == "exe-dev1"
    assert p0.peek("k") == "exe-dev0"
    assert len(base) == 2
    # nested partitions compose tags instead of flattening into collisions
    assert p0.partition("x")._key("k") != p1.partition("x")._key("k")


def test_device_pool_and_bound_measure():
    pytest.importorskip("jax")
    from repro.core import ExecutableCache
    from repro.parallel.devices import local_device_pool
    from repro.tuning.fleet import device_bound_measure

    cache = ExecutableCache(maxsize=8)
    slots = local_device_pool(4, cache=cache)
    assert len(slots) == 4
    assert all(s.cache is not None for s in slots)
    slots[0].cache.get_or_build("k", lambda: "a")
    assert slots[0].cache.peek("k") == "a"
    seen = []
    wrapped = device_bound_measure(lambda i, pts: seen.append(i) or [0.0] * len(pts),
                                   slots)
    assert wrapped(0, [np.zeros(2)]) == [0.0]
    assert seen == [0]
    with pytest.raises(ValueError):
        local_device_pool(0)


# ----------------------------------------------------- unified search= API
def _measure_batch(points):
    """entire_exec_batch hands decoded point dicts to the measurement hook."""
    return [float(sum(float(v) ** 2 for v in p.values())) for p in points]


def test_autotuning_search_consolidation():
    from repro.core import IntDim, SearchSpace

    space = SearchSpace([IntDim("p", 1, 32)])
    # spec string, optimizer instance, and strategy object all ride search=
    for search in ("csa", CSA(1, num_opt=3, max_iter=4, seed=0)):
        at = Autotuning(space=space, search=search, num_opt=3, max_iter=4, seed=0)
        at.entire_exec_batch(_measure_batch)
        assert at.finished

    # passing more than one search method is an error, not a silent pick
    with pytest.raises(ValueError):
        Autotuning(dim=1, search="csa", optimizer=CSA(1, num_opt=2, max_iter=2))
    with pytest.raises(ValueError):
        Autotuning(dim=1, optimizer=CSA(1, num_opt=2, max_iter=2), strategy="csa")


def test_deprecated_aliases_warn_and_match_search():
    """optimizer=/strategy= still work (one DeprecationWarning) and give the
    identical trajectory to the same value passed as search=."""
    def run(**kw):
        at = Autotuning(dim=2, num_opt=3, max_iter=5, seed=7, **kw)
        history = []

        def measure(points):
            costs = _measure_batch(points)
            history.extend(costs)
            return costs

        at.entire_exec_batch(measure)
        return history, at.best_point

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        h_old, best_old = run(optimizer=CSA(2, num_opt=3, max_iter=5, seed=7))
    assert any(issubclass(x.category, DeprecationWarning) for x in w)

    h_new, best_new = run(search=CSA(2, num_opt=3, max_iter=5, seed=7))
    assert h_old == h_new and best_old == best_new

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        h_strat, _ = run(strategy="csa")
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    h_spec, _ = run(search="csa")
    assert h_strat == h_spec


def test_tuning_facade_exports():
    import repro.tuning as T

    # cross-layer facade names resolve lazily (no import cycle with kernels)
    assert T.Autotuning.__name__ == "Autotuning"
    assert callable(T.tune_call)
    assert callable(T.make_strategy)
    assert T.MeasurePolicy.__name__ == "MeasurePolicy"
    assert callable(T.local_device_pool)
    assert callable(T.merge_dbs) and callable(T.parse_shard)
    # __dir__ advertises the facade
    assert "tune_call" in dir(T)
