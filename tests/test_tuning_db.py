"""Persistent tuning DB: round-trip, cross-process fingerprint stability,
corrupt-file recovery, exact-hit replay, and near-miss warm-start budgets."""
import json
import os

import numpy as np
import pytest

from repro.core import CSA, Autotuning, IntDim, LogIntDim, NelderMead, SearchSpace
from repro.tuning import (
    SCHEMA_VERSION,
    TuningDB,
    TuningKey,
    TuningRecord,
    make_key,
    space_fingerprint,
)

from helpers import run_py


def _space():
    return SearchSpace([IntDim("p", 1, 32)])


def _key(shape=(64, 64), name="unit", space=None):
    return make_key(name, args=(np.zeros(shape, np.float32),), space=space or _space())


# ------------------------------------------------------------- persistence
def test_round_trip_persistence(tmp_path):
    path = str(tmp_path / "db.json")
    db = TuningDB(path)
    key = _key()
    rec = TuningRecord(key=key, point={"p": 9}, cost=0.125, evals=40, source="pretune")
    db.put(rec)

    db2 = TuningDB(path)  # fresh handle = fresh process's view
    got = db2.get(key)
    assert got is not None
    assert got.point == {"p": 9}
    assert got.cost == 0.125
    assert got.evals == 40
    assert got.source == "pretune"
    assert got.key == key

    blob = json.load(open(path))
    assert blob["schema"] == SCHEMA_VERSION


def test_atomic_save_leaves_no_temp_files(tmp_path):
    path = str(tmp_path / "db.json")
    db = TuningDB(path)
    for i in range(5):
        db.put(TuningRecord(key=_key(shape=(64, 64 + i)), point={"p": i + 1}, cost=float(i)))
    leftovers = [f for f in os.listdir(tmp_path) if f != "db.json"]
    assert leftovers == []
    assert len(TuningDB(path)) == 5


def test_corrupted_file_recovery(tmp_path):
    path = str(tmp_path / "db.json")
    db = TuningDB(path)
    db.put(TuningRecord(key=_key(), point={"p": 9}, cost=1.0))
    with open(path, "w") as f:
        f.write('{"schema": 1, "records": {truncated garbage')

    db2 = TuningDB(path)  # must not raise
    assert len(db2) == 0
    assert os.path.exists(path + ".corrupt")  # quarantined, not destroyed
    # and the DB is usable again
    db2.put(TuningRecord(key=_key(), point={"p": 5}, cost=2.0))
    assert TuningDB(path).get(_key()).point == {"p": 5}


def test_newer_schema_is_ignored_not_destroyed(tmp_path):
    path = str(tmp_path / "db.json")
    with open(path, "w") as f:
        json.dump({"schema": SCHEMA_VERSION + 1, "records": {"k": {}}}, f)
    db = TuningDB(path)
    assert len(db) == 0
    assert json.load(open(path))["schema"] == SCHEMA_VERSION + 1  # untouched


# ------------------------------------------------------------ fingerprints
def test_key_distinguishes_contexts():
    sp = _space()
    base = _key(space=sp)
    assert _key(space=sp) == base  # deterministic
    assert _key(shape=(64, 128), space=sp) != base  # shapes keyed
    assert _key(name="other", space=sp) != base  # name keyed
    sp2 = SearchSpace([IntDim("p", 1, 64)])  # bounds changed -> new space
    assert _key(space=sp2) != base
    assert make_key("unit", space=sp, extra={"b": 8}) != make_key(
        "unit", space=sp, extra={"b": 16}
    )


def test_space_fingerprint_ignores_nothing_structural():
    a = SearchSpace([LogIntDim("bm", 32, 256), IntDim("n", 1, 4)])
    b = SearchSpace([LogIntDim("bm", 32, 256), IntDim("n", 1, 4)])
    c = SearchSpace([LogIntDim("bm", 32, 512), IntDim("n", 1, 4)])
    assert space_fingerprint(a) == space_fingerprint(b)
    assert space_fingerprint(a) != space_fingerprint(c)


def test_fingerprint_stable_across_processes():
    """The on-disk dict key must be identical when computed in a different
    interpreter (no Python hash() anywhere in the pipeline)."""
    code = (
        "import numpy as np\n"
        "from repro.core import SearchSpace, IntDim\n"
        "from repro.tuning import make_key\n"
        "k = make_key('unit', args=(np.zeros((64, 64), np.float32),),\n"
        "             space=SearchSpace([IntDim('p', 1, 32)]))\n"
        "print(k.encode())\n"
    )
    remote = run_py(code, devices=1).strip().splitlines()[-1]
    local = _key().encode()
    # backend/device fields may legitimately differ between the processes if
    # XLA flags differ; everything else must match exactly
    r_parts, l_parts = remote.split("|"), local.split("|")
    assert r_parts[:4] == l_parts[:4]
    assert r_parts == l_parts  # same host, same backend -> full equality


def test_record_json_round_trip():
    rec = TuningRecord(key=_key(), point={"p": 7}, cost=3.5, evals=12, source="online")
    back = TuningRecord.from_json(json.loads(json.dumps(rec.to_json())))
    assert back.key == rec.key
    assert back.point == rec.point
    assert back.cost == rec.cost


# -------------------------------------------------------- warm-start paths
def _count_cost(target=9):
    calls = {"n": 0}

    def cost(p):
        calls["n"] += 1
        return (p - target) ** 2

    return calls, cost


def test_exact_hit_zero_measurements(tmp_path):
    """Tuning the same key twice: the second run replays the stored best with
    zero cost evaluations (acceptance criterion)."""
    path = str(tmp_path / "db.json")
    sp = _space()
    key = _key(space=sp)

    calls, cost = _count_cost()
    at = Autotuning(space=sp, optimizer=CSA(1, num_opt=4, max_iter=10, seed=0),
                    db=TuningDB(path), key=key)
    at.entire_exec(cost)
    assert calls["n"] > 0
    assert at.best_point == {"p": 9}

    calls2, cost2 = _count_cost()
    at2 = Autotuning(space=sp, optimizer=CSA(1, num_opt=4, max_iter=10, seed=0),
                     db=TuningDB(path), key=key)  # fresh handle = second process
    assert at2.finished
    assert at2.warm_started
    at2.entire_exec(cost2)  # no-op: already finished
    assert calls2["n"] == 0
    assert at2.point == {"p": 9}
    assert at2.best_point == {"p": 9}
    assert at2.num_measurements == 0


def test_near_miss_halves_evaluations(tmp_path):
    """A different-shape key seeded from a stored neighbor must converge in
    <= 50% of the cold-start cost evaluations (acceptance criterion)."""
    path = str(tmp_path / "db.json")
    sp = _space()

    def tuned_run(db, key):
        calls, cost = _count_cost()
        at = Autotuning(space=sp, optimizer=CSA(1, num_opt=4, max_iter=10, seed=0),
                        db=db, key=key, cache=False)
        at.entire_exec(cost)
        return calls["n"], at

    cold_key = _key(shape=(64, 64), space=sp)
    cold_evals, cold_at = tuned_run(TuningDB(path), cold_key)
    assert cold_at.best_point == {"p": 9}

    near_key = _key(shape=(128, 128), space=sp)  # same computation, new shape
    warm_evals, warm_at = tuned_run(TuningDB(path), near_key)
    assert warm_at.warm_started
    assert warm_evals <= cold_evals // 2
    assert warm_at.best_point == {"p": 9}  # still converges


def test_near_miss_seeds_nelder_mead(tmp_path):
    path = str(tmp_path / "db.json")
    sp = _space()
    db = TuningDB(path)
    db.put(TuningRecord(key=_key(shape=(64, 64), space=sp), point={"p": 9}, cost=0.0))

    calls, cost = _count_cost()
    at = Autotuning(space=sp, optimizer=NelderMead(1, error=0.0, max_iter=40, seed=0),
                    db=db, key=_key(shape=(32, 32), space=sp))
    assert at.warm_started
    at.entire_exec(cost)
    assert calls["n"] <= 20  # budget halved
    assert at.best_point == {"p": 9}


def test_reset_reenters_tuning_after_db_hit(tmp_path):
    """Watchdog reset semantics survive DB replay: a reset after an exact hit
    re-enters real tuning and re-commits the fresh result."""
    path = str(tmp_path / "db.json")
    sp = _space()
    key = _key(space=sp)
    db = TuningDB(path)
    db.put(TuningRecord(key=key, point={"p": 3}, cost=1.0))

    at = Autotuning(space=sp, optimizer=CSA(1, num_opt=4, max_iter=10, seed=0),
                    db=db, key=key)
    assert at.finished and at.point == {"p": 3}
    at.reset(2)  # environment drifted
    assert not at.finished
    calls, cost = _count_cost(target=20)
    at.entire_exec(cost)
    assert calls["n"] > 0
    assert abs(at.best_point["p"] - 20) <= 1  # moved off the stale optimum
    assert TuningDB(path).get(key).point == at.best_point  # re-committed


def test_commit_happens_automatically_on_finish(tmp_path):
    path = str(tmp_path / "db.json")
    sp = _space()
    key = _key(space=sp)
    at = Autotuning(space=sp, optimizer=CSA(1, num_opt=3, max_iter=4, seed=1),
                    db=TuningDB(path), key=key)
    _, cost = _count_cost()
    at.entire_exec(cost)
    rec = TuningDB(path).get(key)
    assert rec is not None
    assert rec.point == at.best_point
    assert rec.evals == at.num_evals


# -------------------------------------------------- kernel dispatch layer
def test_autotuned_kernel_exact_hit_and_correctness(tmp_path):
    import jax

    from repro.kernels import autotuned, ref
    from repro.kernels.autotuned import get_spec, tune_call

    path = str(tmp_path / "k.json")
    db = TuningDB(path)
    a = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    b = jax.random.normal(jax.random.PRNGKey(1), (64, 64))

    # cold: registered defaults, still correct
    o = autotuned("matmul", a, b, db=db, interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref.matmul_ref(a, b)), atol=1e-4)
    assert len(db) == 0

    rec = tune_call("matmul", a, b, db=db, interpret=True, max_iter=2)
    assert set(rec.point) == {"bm", "bn", "bk"}
    assert len(TuningDB(path)) == 1  # persisted

    # warm: dispatch uses the stored point (exact fingerprint hit; interpret
    # mode is part of the fingerprint — interpreter timings never leak into
    # compiled dispatch)
    spec = get_spec("matmul")
    key = make_key("matmul", args=(a, b), space=spec.space(a, b),
                   extra={"interpret": True})
    assert TuningDB(path).get(key) is not None
    assert TuningDB(path).get(
        make_key("matmul", args=(a, b), space=spec.space(a, b),
                 extra={"interpret": False})) is None
    o = autotuned("matmul", a, b, db=TuningDB(path), interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref.matmul_ref(a, b)), atol=1e-4)


def test_autotuned_neighbor_point_clamped_into_smaller_space(tmp_path):
    import jax

    from repro.kernels import autotuned, ref
    from repro.kernels.autotuned import get_spec

    db = TuningDB(str(tmp_path / "k.json"))
    big_a = jax.random.normal(jax.random.PRNGKey(0), (256, 256))
    big_b = jax.random.normal(jax.random.PRNGKey(1), (256, 256))
    spec = get_spec("matmul")
    db.put(
        TuningRecord(
            key=make_key("matmul", args=(big_a, big_b), space=spec.space(big_a, big_b)),
            point={"bm": 256, "bn": 256, "bk": 256},
            cost=0.001,
        )
    )
    # smaller problem: neighbor's 256-tiles must clamp to the 64-space
    a = jax.random.normal(jax.random.PRNGKey(2), (64, 64))
    b = jax.random.normal(jax.random.PRNGKey(3), (64, 64))
    o = autotuned("matmul", a, b, db=db, interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref.matmul_ref(a, b)), atol=1e-4)


def test_committed_snapshot_replays(tmp_path):
    """The repo's tuned/cpu.json snapshot must load under the current schema
    and yield an exact fingerprint hit for a pretune grid entry — this guards
    fingerprint stability across code changes."""
    import jax

    from repro.kernels.autotuned import get_spec

    snap = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                        "tuned", "cpu.json")
    if not os.path.exists(snap):
        pytest.skip("no committed snapshot")
    db = TuningDB(snap)
    assert len(db) > 0
    # cpu-backend records only apply on a cpu host
    if db.records()[0].key.backend != "cpu" or jax.default_backend() != "cpu":
        pytest.skip("snapshot is for a different backend")
    a = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    b = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    spec = get_spec("matmul")
    key = make_key("matmul", args=(a, b), space=spec.space(a, b),
                   extra={"interpret": True})
    rec = db.get(key)
    assert rec is not None, "fingerprint drifted: snapshot key no longer matches"
    assert set(rec.point) == {"bm", "bn", "bk"}


def test_tuned_step_warm_start(tmp_path):
    """TunedStep with a DB: second construction replays without tuning."""
    import jax.numpy as jnp

    from repro.core import TunedStep

    path = str(tmp_path / "step.json")
    sp = SearchSpace([IntDim("n", 1, 4)])

    def factory(n):
        def step(x):
            for _ in range(n):
                x = x + 1.0
            return x

        return step

    ts = TunedStep(factory, sp, ignore=0, num_opt=3, max_iter=3, seed=0,
                   db=TuningDB(path), name="unit_step", key_extra={"b": 8})
    ts.tune(jnp.zeros((4,)))
    assert ts.finished

    ts2 = TunedStep(factory, sp, ignore=0, num_opt=3, max_iter=3, seed=0,
                    db=TuningDB(path), name="unit_step", key_extra={"b": 8})
    assert ts2.finished  # replayed before any step ran
    assert ts2.best_knobs == ts.best_knobs
    out = ts2(jnp.zeros((4,)))  # runs the stored-best step directly
    assert out.shape == (4,)
