"""repro.runtime: online adaptive tuning.

Deterministic throughout — costs come through the cost seam (no wall
clock), the ε-scheduler is a credit counter (no RNG), and where background
builds are involved the tests drain the pool between serving calls so
readiness is reproducible.
"""
import threading

import numpy as np
import pytest

from repro.core import (
    CSA,
    Autotuning,
    ChoiceDim,
    ExecutableCache,
    IntDim,
    LogIntDim,
    SearchSpace,
    TunedStep,
)
from repro.runtime import (
    EXPLOIT,
    EXPLORE,
    ContextRouter,
    DriftDetector,
    OnlineTuner,
    bucket_args,
    pow2_bucket,
)
from repro.tuning import TuningDB, make_key


def _space(hi=32):
    return SearchSpace([IntDim("p", 1, hi)])


def _at(space=None, num_opt=3, max_iter=4, seed=0, **kw):
    space = space or _space()
    return Autotuning(
        space=space, ignore=0,
        optimizer=CSA(len(space), num_opt=num_opt, max_iter=max_iter, seed=seed),
        cache=True, **kw,
    )


def _drive_search(tuner, cost_of, n=500, exploit_cost=None):
    """Serve requests until the tuner's search finishes; returns decisions."""
    decisions = []
    for _ in range(n):
        if tuner.finished:
            break
        d = tuner.begin()
        decisions.append(d)
        if d.kind == EXPLORE:
            tuner.observe(d, cost_of(d.point))
        else:
            tuner.observe(d, exploit_cost if exploit_cost is not None
                          else cost_of(d.point))
    return decisions


# ------------------------------------------------------------ drift detector
def test_drift_detector_levels_and_rebaseline():
    dd = DriftDetector(window=4, min_samples=2, factor=1.5, severe_factor=3.0)
    for _ in range(4):
        assert dd.observe(1.0) == 0  # baseline fills, no detection yet
    assert dd.ready
    assert dd.observe(1.2) == 0  # recent below min_samples
    assert dd.observe(1.2) == 0  # median 1.2 < 1.5
    assert dd.observe(2.0) == 0  # median(1.2,1.2,2.0) = 1.2
    assert dd.observe(2.0) == 1  # median -> 1.6 > 1.5
    # the trigger cleared the recent window: no immediate re-trigger
    assert dd.observe(2.0) == 0  # recent below min_samples again
    # severe drift
    assert dd.observe(9.0) == 2  # median(2.0, 9.0) = 5.5 > 3.0 x baseline
    assert [e["level"] for e in dd.events] == [1, 2]
    assert dd.events[-1]["recent"] == 5.5  # freshest min_samples' median
    dd.rebaseline()
    assert not dd.ready
    assert dd.observed == 0


def test_drift_detector_ignores_nonfinite_and_single_spikes():
    dd = DriftDetector(window=6, min_samples=3, factor=1.5)
    for _ in range(6):
        dd.observe(1.0)
    assert dd.observe(float("inf")) == 0  # crashed request: excluded
    # a single straggler cannot flip the median
    assert dd.observe(100.0) == 0
    assert dd.observe(1.0) == 0
    assert dd.observe(1.0) == 0
    assert dd.events == []


def test_drift_detector_validates():
    with pytest.raises(ValueError):
        DriftDetector(window=0)
    with pytest.raises(ValueError):
        DriftDetector(window=4, min_samples=9)
    with pytest.raises(ValueError):
        DriftDetector(factor=1.0)


# --------------------------------------------------------------- ε schedule
def test_epsilon_exploration_accounting():
    """The credit scheduler holds explored/calls <= ε exactly, with explores
    landing on the deterministic schedule (every 1/ε-th call)."""
    at = _at(max_iter=10)
    t = OnlineTuner(at, epsilon=0.25)
    kinds = []
    for i in range(40):
        if t.finished:
            break
        d = t.begin()
        kinds.append(d.kind)
        t.observe(d, float((d.point["p"] - 9) ** 2) if d.kind == EXPLORE else 1.0)
    explores = kinds.count(EXPLORE)
    # every 4th call explores while the search is live
    assert kinds[:8] == [EXPLOIT, EXPLOIT, EXPLOIT, EXPLORE] * 2
    assert explores == len(kinds) // 4
    assert t.stats_["explores"] == explores
    assert t.stats_["exploits"] == len(kinds) - explores
    # ... and the search only ever advances on explore calls
    assert at.num_measurements == explores


def test_epsilon_zero_never_explores_and_one_always_does():
    t0 = OnlineTuner(_at(), epsilon=0.0, default_point={"p": 5})
    for _ in range(10):
        d = t0.begin()
        assert d.kind == EXPLOIT
        t0.observe(d, 1.0)
    assert not t0.finished  # replay-only: the search never advances

    t1 = OnlineTuner(_at(), epsilon=1.0)
    d = t1.begin()
    assert d.kind == EXPLORE


def test_exploit_point_prefers_default_until_measured():
    t = OnlineTuner(_at(), epsilon=0.25, default_point={"p": 7})
    d = t.begin()
    assert d.kind == EXPLOIT and d.point == {"p": 7}
    # after a measurement the best-known point takes over
    while True:
        d = t.begin()
        if d.kind == EXPLORE:
            t.observe(d, 0.5)
            break
        t.observe(d, 1.0)
    assert np.isfinite(t.at.best_cost)
    assert t.exploit_point() == t.at.best_point


# ------------------------------------------------------- drift-driven resets
def test_drift_triggers_warm_reset_and_recommits(tmp_path):
    """End-to-end episode: converge -> commit -> drift -> warm half-budget
    re-search with fresh measurements -> recommit with source='online'."""
    path = str(tmp_path / "db.json")
    db = TuningDB(path)
    sp = _space()
    key = make_key("unit", args=(np.zeros((64, 64), np.float32),), space=sp)
    at = _at(space=sp, db=db, key=key)
    t = OnlineTuner(at, epsilon=0.5, drift=DriftDetector(window=4, min_samples=2),
                    warm_frac=0.5)

    phase1 = {"n": 0}

    def cost1(p):
        phase1["n"] += 1
        return (p["p"] - 9) ** 2 * 0.01 + 1.0

    _drive_search(t, cost1, exploit_cost=1.0)
    assert t.finished
    assert t.stats_["searches_completed"] == 1
    rec1 = db.get(key)
    assert rec1 is not None and rec1.point == {"p": 9}

    # healthy steady state establishes the detector's baseline
    for _ in range(6):
        d = t.begin()
        assert d.kind == EXPLOIT
        assert t.observe(d, 1.0) == 0

    # environment drifts: exploit costs triple -> detector fires
    level = 0
    for _ in range(50):
        d = t.begin()
        assert d.kind == EXPLOIT
        level = t.observe(d, 3.0)
        if level:
            break
    assert level == 1
    assert t.stats_["drift_resets"] == 1
    assert not t.finished  # re-entered tuning
    # the incumbent's fresh cost was noted, so the driver's view is current
    assert any(p == {"p": 9} and c == 3.0 for p, c in at.history)

    phase2 = {"n": 0}

    def cost2(p):
        phase2["n"] += 1
        return (p["p"] - 20) ** 2 * 0.01 + 3.0

    _drive_search(t, cost2, exploit_cost=3.0)
    assert t.finished
    assert phase2["n"] > 0  # the re-search measured fresh costs
    # half budget: the warm re-search spent fewer evaluations than cold
    assert phase2["n"] < phase1["n"]
    rec2 = db.get(key)
    assert rec2 is not None
    assert rec2.source == "online"
    assert rec2.cost >= 3.0  # refreshed to post-drift reality
    assert rec2.point == at.best_point


def test_exploit_costs_do_not_feed_drift_while_search_is_live():
    t = OnlineTuner(_at(), epsilon=0.25,
                    drift=DriftDetector(window=2, min_samples=1, factor=1.1))
    for _ in range(6):
        d = t.begin()
        assert t.observe(d, 100.0 if d.kind == EXPLOIT else 1.0) == 0
    assert t.drift.observed == 0  # nothing armed before convergence


# ----------------------------------------------- background builds / no-block
def test_background_builds_never_run_on_serving_thread():
    main_thread = threading.get_ident()
    build_threads = []

    def build(point, *args):
        build_threads.append(threading.get_ident())
        return ("exe", point["p"])

    cache = ExecutableCache()
    t = OnlineTuner(_at(), build=build, cache=cache, jobs=2, epsilon=1.0,
                    default_point={"p": 4})
    explored_with_exec = 0
    for _ in range(300):
        if t.finished:
            break
        d = t.begin()
        if d.kind == EXPLORE:
            assert d.executable == ("exe", d.point["p"])
            explored_with_exec += 1
            t.observe(d, abs(d.point["p"] - 5) + 1.0)
        else:
            t.observe(d, 1.0)
            t.wait_pending()  # deterministic readiness between requests
    assert t.finished
    assert explored_with_exec == t.stats_["explores"] > 0
    assert t.stats_["inband_builds"] == 0
    assert build_threads and all(th != main_thread for th in build_threads)
    assert cache.stats()["recompiles"] == 0


def test_scheduled_explore_defers_while_compile_in_flight():
    import time as _time

    def slow_build(point, *args):
        _time.sleep(0.05)
        return ("exe", point["p"])

    t = OnlineTuner(_at(), build=slow_build, cache=ExecutableCache(), jobs=1,
                    epsilon=1.0, default_point={"p": 4})
    d = t.begin()  # wants to explore; the build was only just submitted
    assert d.kind == EXPLOIT
    assert t.stats_["deferred_explores"] == 1
    t.observe(d, 1.0)
    t.wait_pending()
    d = t.begin()  # ready now
    assert d.kind == EXPLORE and d.executable is not None


def test_failed_candidate_builds_absorbed_without_serving_requests():
    fails = {2, 3}

    def build(point, *args):
        if point["p"] in fails:
            raise RuntimeError("illegal block config for this shape")
        return ("exe", point["p"])

    t = OnlineTuner(_at(space=_space(hi=8)), build=build, cache=ExecutableCache(),
                    jobs=1, epsilon=1.0, default_point={"p": 4})
    explored = set()
    for _ in range(300):
        if t.finished:
            break
        d = t.begin()
        t.wait_pending()
        if d.kind == EXPLORE:
            explored.add(d.point["p"])
            t.observe(d, abs(d.point["p"] - 5) + 1.0)
        else:
            t.observe(d, 1.0)
    assert t.finished
    assert not (explored & fails)  # never served at a failed candidate
    assert t.stats_["candidate_failures"] > 0
    crashed = {p["p"] for p, c in t.at.history if not np.isfinite(c)}
    assert crashed and crashed <= fails


def test_one_off_shapes_never_trigger_background_builds():
    """Admission control: long-tail exact shapes (each request a new seq
    len) are served by fallback dispatch — no AOT compile per request."""
    built = []

    def build(point, *args):
        built.append(tuple(args[0].shape))
        return "exe"

    t = OnlineTuner(_at(), build=build, jobs=1, epsilon=1.0, default_point={"p": 4})
    for n in range(20):
        d = t.begin(np.zeros((100 + n, 8), np.float32))  # every shape unique
        t.observe(d, 1.0)
        t.wait_pending()
    assert built == []
    assert t.stats_["compiles_submitted"] == 0
    # ... while a shape that returns earns its builds from the second sight
    x = np.zeros((64, 8), np.float32)
    d = t.begin(x)
    t.wait_pending()
    assert built == []  # first sight: still fallback-served
    t.observe(d, 1.0)
    d = t.begin(x)
    t.wait_pending()
    assert built  # second sight admitted the compile


def test_transient_build_failure_is_retried_not_poisoned():
    """The default cache never memoizes failures: a transient compile error
    (RESOURCE_EXHAUSTED under load) must not disqualify the candidate for
    the process lifetime — a revisit rebuilds."""
    calls = {"n": 0}

    def build(point, *args):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient resource exhaustion")
        return "ok"

    t = OnlineTuner(_at(), build=build, jobs=1, epsilon=1.0)
    pt = {"p": 5}
    assert t.executable_for(pt) is None  # build submitted
    t.wait_pending()
    assert t.executable_for(pt) is None  # failed -> memo dropped, resubmitted
    t.wait_pending()
    assert t.executable_for(pt) == "ok"  # the retry succeeded
    assert calls["n"] == 2


def test_kernel_router_rejects_conflicting_singleton_config():
    from repro.kernels.autotuned import kernel_router

    r1 = kernel_router(interpret=True, epsilon=0.1)
    assert kernel_router(interpret=True) is r1  # default args: same singleton
    with pytest.raises(ValueError):
        kernel_router(interpret=True, epsilon=0.5)
    with pytest.raises(ValueError):
        kernel_router(interpret=True, db=TuningDB(None))
    assert kernel_router(interpret=True, epsilon=0.5, fresh=True) is not r1


def test_prewarm_and_executable_for():
    built = []

    def build(point, *args):
        built.append(point["p"])
        return ("exe", point["p"])

    t = OnlineTuner(_at(space=_space(hi=4)), build=build, cache=ExecutableCache(),
                    jobs=2, epsilon=0.5)
    t.prewarm([{"p": k} for k in (1, 2, 3, 4)], wait=True)
    assert sorted(built) == [1, 2, 3, 4]
    assert t.executable_for({"p": 3}) == ("exe", 3)
    d = t.begin()
    assert d.executable is not None  # whatever it picked was prewarmed


# --------------------------------------------------------------- the router
def test_pow2_bucket_and_bucket_args():
    assert [pow2_bucket(n) for n in (1, 2, 3, 48, 64, 65, 1000)] == [
        1, 2, 4, 64, 64, 128, 1024]
    args, kwargs = bucket_args(
        (np.zeros((60, 17), np.float32), 3), {"v": np.zeros((5,), np.int32)}
    )
    assert args[0].shape == (64, 32) and args[1] == 3
    assert kwargs["v"].shape == (8,)


def test_router_buckets_nearby_shapes_into_one_context():
    r = ContextRouter(db=TuningDB(None))
    r.register("k", space=lambda x: _space(), defaults=lambda x: {"p": 4})
    t60 = r.tuner("k", np.zeros((60, 16), np.float32))
    t64 = r.tuner("k", np.zeros((64, 16), np.float32))
    t65 = r.tuner("k", np.zeros((65, 16), np.float32))
    assert t60 is t64  # both bucket to (64, 16)
    assert t64 is not t65  # 65 -> 128
    assert len(r.contexts()) == 2


def test_router_space_comes_from_bucketed_shapes():
    """Exact shapes in one bucket must share a single context whose knob
    domain is derived from the bucket — not from whichever exact shape
    arrived first — so pretuned pow2 records exact-hit non-pow2 traffic."""
    seen_shapes = []

    def space(x):
        seen_shapes.append(tuple(x.shape))
        return SearchSpace([LogIntDim("t", 8, int(x.shape[0]))])

    r = ContextRouter(db=TuningDB(None))
    r.register("k", space=space)
    t1000 = r.tuner("k", np.zeros((1000, 16), np.float32))
    t1024 = r.tuner("k", np.zeros((1024, 16), np.float32))
    assert t1000 is t1024
    # the space saw the bucketed 1024, never the exact 1000
    assert (1024, 16) in seen_shapes and (1000, 16) not in seen_shapes
    k_a = r.context_key("k", (np.zeros((1000, 16), np.float32),))
    k_b = r.context_key("k", (np.zeros((1024, 16), np.float32),))
    assert k_a.encode() == k_b.encode()


def test_router_separates_contexts_by_extra_and_dtype():
    r = ContextRouter(db=TuningDB(None))
    r.register("k", space=lambda x: _space())
    x = np.zeros((64, 16), np.float32)
    assert r.tuner("k", x, extra={"batch": 8}) is not r.tuner("k", x, extra={"batch": 16})
    assert r.tuner("k", x) is not r.tuner("k", x.astype(np.float16))


def test_router_observe_routes_to_owning_tuner():
    r = ContextRouter(db=TuningDB(None))
    r.register("k", space=lambda x: _space(), epsilon=1.0)
    a = np.zeros((64, 16), np.float32)
    b = np.zeros((256, 16), np.float32)
    da = r.begin("k", a)
    db_ = r.begin("k", b)
    r.observe(da, 1.0)
    r.observe(db_, 2.0)
    assert r.tuner("k", a).stats_["calls"] == 1
    assert r.tuner("k", b).stats_["calls"] == 1
    assert r.stats()["calls"] == 2


def test_router_new_context_warm_starts_from_committed_neighbor():
    db = TuningDB(None)
    r = ContextRouter(db=db)
    r.register("k", space=lambda x: _space(), epsilon=1.0, max_iter=4)
    a = np.zeros((64, 16), np.float32)
    for _ in range(100):
        t = r.tuner("k", a)
        if t.finished:
            break
        d = r.begin("k", a)
        r.observe(d, (d.point["p"] - 9) ** 2 * 0.01 + 1.0)
    assert r.tuner("k", a).finished
    assert len(db) == 1
    # a new shape bucket opens warm-started from the committed neighbor
    t_new = r.tuner("k", np.zeros((256, 16), np.float32))
    assert t_new.at.warm_started
    assert not t_new.finished  # near miss, not an exact hit


def test_router_exact_hit_serves_stored_best_from_first_request():
    db = TuningDB(None)
    r1 = ContextRouter(db=db)
    r1.register("k", space=lambda x: _space(), epsilon=1.0, max_iter=4)
    a = np.zeros((64, 16), np.float32)
    for _ in range(100):
        if r1.tuner("k", a).finished:
            break
        d = r1.begin("k", a)
        r1.observe(d, (d.point["p"] - 9) ** 2 * 0.01 + 1.0)
    best = r1.tuner("k", a).best_point

    r2 = ContextRouter(db=db)  # "second process"
    r2.register("k", space=lambda x: _space(), epsilon=1.0, max_iter=4)
    d = r2.begin("k", a)
    assert d.kind == EXPLOIT and d.point == best
    assert r2.tuner("k", a).finished


def test_router_rejects_unknown_route_and_detached_decision():
    r = ContextRouter(db=TuningDB(None))
    with pytest.raises(KeyError):
        r.begin("nope", np.zeros((4,), np.float32))
    from repro.runtime import Decision

    with pytest.raises(ValueError):
        r.observe(Decision(EXPLOIT, {"p": 1}), 1.0)


# ---------------------------------------------- Autotuning reset x DB seams
def test_level1_reset_after_commit_remeasures(tmp_path):
    """Satellite: a level-1 reset after a committed record must re-measure,
    not replay the cost cache."""
    db = TuningDB(str(tmp_path / "db.json"))
    sp = _space()
    key = make_key("unit", args=(np.zeros((64, 64), np.float32),), space=sp)
    at = _at(space=sp, db=db, key=key)

    calls1 = {"n": 0}

    def cost1(p):
        calls1["n"] += 1
        return (p - 9) ** 2

    at.entire_exec(cost1)
    assert db.get(key) is not None
    visited_before = {p["p"] for p, _ in at.history}

    at.reset(1)
    assert not at.finished
    assert at.history == []  # stale-environment measurements dropped
    calls2 = {"n": 0}

    def cost2(p):
        calls2["n"] += 1
        return (p - 9) ** 2 + 2.0

    at.entire_exec(cost2)
    # revisited candidates were re-measured, not answered from the cache
    assert calls2["n"] > 0
    revisited = {p["p"] for p, _ in at.history} & visited_before
    assert revisited  # level 1 keeps the best coordinates -> overlap exists
    assert all(c >= 2.0 for _, c in at.history)  # every cost is fresh


def test_commit_does_not_clobber_better_unvisited_record(tmp_path):
    """Satellite: a worse drifted re-search must not overwrite a strictly
    better stored record whose point it never re-measured."""
    db = TuningDB(str(tmp_path / "db.json"))
    sp = _space(hi=1000)
    key = make_key("unit", args=(np.zeros((64, 64), np.float32),), space=sp)
    from repro.tuning import TuningRecord

    db.put(TuningRecord(key=key, point={"p": 9}, cost=0.001, source="pretune"))

    at = _at(space=sp, db=db, key=key, warm_start=False, num_opt=3, max_iter=2)
    at.entire_exec(lambda p: 1.0 + abs(p - 500))
    # seed 0 on this space never lands on p=9 (pinned by the determinism of
    # CSA's RNG stream); re-check so a future optimizer change fails loudly
    assert not at._visited({"p": 9})
    rec = db.get(key)
    assert rec.point == {"p": 9} and rec.cost == 0.001  # stored best kept
    assert at._committed  # idempotent: the run will not retry the write


def test_commit_refreshes_record_when_stored_point_remeasured(tmp_path):
    """...but a run that DID re-measure the stored point always commits —
    that is a refresh under current conditions, not a clobber."""
    db = TuningDB(str(tmp_path / "db.json"))
    sp = _space()
    key = make_key("unit", args=(np.zeros((64, 64), np.float32),), space=sp)
    from repro.tuning import TuningRecord

    db.put(TuningRecord(key=key, point={"p": 9}, cost=0.001, source="pretune"))

    at = _at(space=sp, db=db, key=key, warm_start=False, num_opt=3, max_iter=2)
    while not at.finished:
        at.exec(1.0 + abs(at.point["p"] - 20))
        if at.finished:
            break
    at._committed = False  # simulate: commit raced before the note landed
    at.note({"p": 9}, 5.0)  # fresh measurement of the stored point
    assert at.commit()
    rec = db.get(key)
    assert rec.cost >= 1.0  # refreshed to current-environment reality
    assert rec.point == at.best_point


def test_note_validates_and_feeds_best():
    at = _at()
    with pytest.raises(ValueError):
        at.note({"wrong": 1}, 1.0)
    at.note({"p": 3}, 0.25)
    assert at.best_point == {"p": 3}
    assert at.best_cost == 0.25


def test_skip_bypasses_ignore_stabilization():
    at = Autotuning(space=_space(), ignore=2,
                    optimizer=CSA(1, num_opt=3, max_iter=2, seed=0), cache=True)
    before = at.point
    at.skip()  # one call, no ignore rounds burned
    assert at.num_evals == 1
    assert at.history[0] == (before, np.inf)


def test_warm_reset_seeds_and_halves_budget():
    at = _at(max_iter=8)
    at.entire_exec(lambda p: (p - 9) ** 2)
    evals_cold = at.num_evals
    at.reset(1, warm_point={"p": 9}, budget_frac=0.5)
    n = {"n": 0}

    def cost(p):
        n["n"] += 1
        return (p - 9) ** 2 + 1.0

    at.entire_exec(cost)
    assert n["n"] > 0
    assert n["n"] <= evals_cold // 2 + 1
    assert at.best_point == {"p": 9}


def test_drift_level1_retunes_through_nm_refinement_stage(tmp_path):
    """With a staged strategy, environment drift (level 1) re-tunes through
    the pipeline's final NM refinement stage alone, warm-seeded at the
    deployed point — and commits the refreshed result with the strategy's
    provenance.  Deterministic: analytic costs, seeded search."""
    from repro.core import Pipeline

    db = TuningDB(str(tmp_path / "db.json"))
    sp = _space()
    key = make_key("unit", args=(np.zeros((64, 64), np.float32),), space=sp)
    at = Autotuning(space=sp, ignore=0, strategy="csa+nm", num_opt=3,
                    max_iter=8, seed=0, cache=True, db=db, key=key)
    pipe = at.optimizer
    assert isinstance(pipe, Pipeline)
    t = OnlineTuner(at, epsilon=0.5, warm_frac=1.0,
                    drift=DriftDetector(window=4, min_samples=2))

    _drive_search(t, lambda p: (p["p"] - 9) ** 2 * 0.01 + 1.0, exploit_cost=1.0)
    assert t.finished
    deployed = at.best_point
    assert deployed == {"p": 9}
    assert db.get(key).strategy == "csa+nm"

    # healthy steady state -> baseline; then the environment degrades
    for _ in range(6):
        t.observe(t.begin(), 1.0)
    level = 0
    for _ in range(50):
        level = t.observe(t.begin(), 2.0)
        if level:
            break
    assert level == 1
    # the pipeline re-entered through its final (NM) refinement stage...
    assert pipe.refining
    assert pipe.stage_index == len(pipe.stages) - 1
    assert t.events[-1]["refined"] is True
    # ...warm-seeded at the deployed point: it is the first candidate retried
    assert at.point == deployed

    # the optimum moved two steps within the same basin; the NM-only
    # re-search finds it without a global re-exploration
    retune = {"n": 0}

    def cost2(p):
        retune["n"] += 1
        return (p["p"] - 11) ** 2 * 0.01 + 2.0

    _drive_search(t, cost2, exploit_cost=2.0)
    assert t.finished
    assert retune["n"] > 0
    # the refinement episode is a fraction of the cold budget (24 tells)
    assert retune["n"] <= pipe.stages[-1].get_num_points() + 8
    assert at.best_point == {"p": 11}
    rec = db.get(key)
    assert rec is not None and rec.point == {"p": 11}
    assert rec.source == "online" and rec.strategy == "csa+nm"

    # a severe (level 2) drift restarts the FULL pipeline instead
    for _ in range(6):
        t.observe(t.begin(), 2.0)
    level = 0
    for _ in range(50):
        level = t.observe(t.begin(), 50.0)
        if level:
            break
    assert level == 2
    assert not pipe.refining  # workload shift: back to the global stage
    assert pipe.stage_index == 0


# -------------------------------------------------------- TunedStep adaptive
def test_tuned_step_adaptive_mode_wiring():
    calls = []

    def factory(mb=1):
        def step(x):
            calls.append(mb)
            return x + mb

        return step

    space = SearchSpace([ChoiceDim("mb", (1, 2, 4))])
    ts = TunedStep(factory, space, ignore=0, num_opt=2, max_iter=2,
                   runtime="adaptive", epsilon=1.0,
                   drift={"window": 4, "min_samples": 2})
    assert ts.online is not None
    x = np.zeros((2,))
    for _ in range(30):
        x = ts(x)
        if ts.finished:
            break
    assert ts.finished
    assert ts.best_knobs["mb"] in (1, 2, 4)
    assert ts.online.stats_["explores"] > 0
    assert ts.drift_events == []

    with pytest.raises(ValueError):
        TunedStep(factory, space, runtime="bogus")


# ----------------------------------------------------------- kernel routing
def test_routed_kernel_dispatch_matches_reference():
    import jax

    from repro.kernels import ref
    from repro.kernels.autotuned import kernel_router, routed

    router = kernel_router(interpret=True, db=TuningDB(None), epsilon=0.0,
                           fresh=True)
    a = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    b = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    out = routed("matmul", a, b, router=router, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.matmul_ref(a, b)), atol=1e-4
    )
    st = router.stats()
    assert st["contexts"] == 1 and st["calls"] == 1
    assert st["inband_builds"] == 0


# ------------------------------------------------------------- serve replay
def test_serve_no_tune_replays_stored_decode_k(tmp_path):
    """Satellite: --no-tune --db must replay the stored-best decode k."""
    from repro.launch.serve import DECODE_KS, replay_decode_k
    from repro.tuning import TuningRecord

    space = SearchSpace([ChoiceDim("k", DECODE_KS)])
    db = TuningDB(str(tmp_path / "serve.json"))
    key = make_key("serve/decode_k", space=space,
                   extra={"arch": "qwen2_7b", "tiny": True, "batch": 8})
    assert replay_decode_k(db, key, gen=64) == 1  # no record: untuned default
    db.put(TuningRecord(key=key, point={"k": 8}, cost=0.001))
    assert replay_decode_k(db, key, gen=64) == 8
    assert replay_decode_k(db, key, gen=4) == 4  # clamped to the stream
    other = make_key("serve/decode_k", space=space,
                     extra={"arch": "qwen2_7b", "tiny": True, "batch": 16})
    assert replay_decode_k(db, other, gen=64) == 1  # per-batch-size context


# ------------------------------------------------------------ pretune CLI
def test_pretune_list_and_only(tmp_path, capsys):
    from repro.tuning.pretune import main as pretune_main

    db_path = str(tmp_path / "db.json")
    rc = pretune_main(["--db", db_path, "--smoke", "--list"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "matmul/64x64x64" in out and "lru_scan/b2t64d32" in out
    assert "cold" in out

    rc = pretune_main(["--db", db_path, "--smoke", "--list", "--only", "matmul/64*"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "matmul/64x64x64" in out
    assert "matmul/128x128x128" not in out and "lru_scan" not in out

    rc = pretune_main(["--db", db_path, "--smoke", "--list", "--only", "nomatch*"])
    assert rc == 2

    # committed snapshot shows up as HIT on the next --list
    snap = "tuned/cpu.json"
    import os

    if os.path.exists(snap):
        rc = pretune_main(["--db", snap, "--smoke", "--list", "--only", "matmul*"])
        assert rc == 0
        assert "HIT" in capsys.readouterr().out


@pytest.mark.slow
def test_pretune_only_tunes_single_case(tmp_path):
    from repro.tuning.pretune import main as pretune_main
    from repro.tuning import TuningDB as DB

    db_path = str(tmp_path / "db.json")
    rc = pretune_main([
        "--db", db_path, "--smoke", "--only", "matmul/64*",
        "--num-opt", "2", "--max-iter", "1",
    ])
    assert rc == 0
    db = DB(db_path)
    assert len(db) == 1
    assert db.records()[0].key.name == "matmul"
