"""Optimizer substrate: AdamW math, schedules, clipping, state dtypes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamW, clip_by_global_norm, cosine_schedule, global_norm


def quad_params():
    return {"w": jnp.array([3.0, -2.0]), "b": jnp.array(5.0)}


def loss(p):
    return jnp.sum(p["w"] ** 2) + p["b"] ** 2


def test_adamw_converges():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    p = quad_params()
    s = opt.init(p)
    for _ in range(200):
        g = jax.grad(loss)(p)
        p, s, m = opt.update(g, s, p)
    assert float(loss(p)) < 1e-3
    assert int(s["step"]) == 200


def test_weight_decay_pulls_to_zero():
    opt = AdamW(lr=0.05, weight_decay=1.0)
    p = {"w": jnp.array([10.0])}
    s = opt.init(p)
    for _ in range(100):
        g = {"w": jnp.zeros(1)}  # no gradient signal: only decay acts
        p, s, _ = opt.update(g, s, p)
    assert abs(float(p["w"][0])) < 1.0


def test_state_dtype_bf16_halves_memory():
    p = {"w": jnp.zeros((128,), jnp.float32)}
    s32 = AdamW(lr=1e-3).init(p)
    s16 = AdamW(lr=1e-3, state_dtype="bfloat16").init(p)
    assert s32["m"]["w"].dtype == jnp.float32
    assert s16["m"]["w"].dtype == jnp.bfloat16


def test_grad_clip():
    t = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(t, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert abs(float(norm) - 20.0) < 1e-4
    # under the threshold: untouched
    small = {"a": jnp.full((4,), 0.01)}
    c2, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), np.asarray(small["a"]), rtol=1e-6)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=110, floor=0.1)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(60)) < 1.0
    assert abs(float(lr(110)) - 0.1) < 1e-2  # decays to the floor
    # monotone decay after warmup
    vals = [float(lr(s)) for s in range(10, 111, 10)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


def test_metrics_emitted():
    opt = AdamW(lr=1e-2)
    p = quad_params()
    s = opt.init(p)
    g = jax.grad(loss)(p)
    _, _, m = opt.update(g, s, p)
    assert "grad_norm" in m and "lr" in m
    assert float(m["grad_norm"]) > 0
