"""Training substrate: chunked CE == full CE, microbatch equivalence,
label masking, metrics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import ExecConfig, Model
from repro.optim import AdamW
from repro.train import make_loss_fn, make_train_step, xent_chunked, xent_full


def setup():
    cfg = configs.get_tiny("qwen2_7b")
    model = Model(cfg, ExecConfig(rec_chunk=4))
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 17), 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    return cfg, model, params, batch


def test_chunked_equals_full():
    cfg, model, params, batch = setup()
    lf = make_loss_fn(model)
    lc = make_loss_fn(model, logits_chunk=16)
    a, _ = lf(params, batch)
    b, _ = lc(params, batch)
    assert abs(float(a) - float(b)) / float(a) < 1e-4


def test_chunked_grads_match():
    cfg, model, params, batch = setup()
    g1 = jax.grad(lambda p: make_loss_fn(model)(p, batch)[0])(params)
    g2 = jax.grad(lambda p: make_loss_fn(model, logits_chunk=16)(p, batch)[0])(params)

    def rel(a, b):
        d = float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        return d / (float(jnp.max(jnp.abs(a.astype(jnp.float32)))) + 1e-9)

    assert max(jax.tree.leaves(jax.tree.map(rel, g1, g2))) < 5e-2  # bf16 matmul noise


def test_xent_direct():
    """Against a hand-rolled softmax CE on small tensors."""
    rng = jax.random.PRNGKey(2)
    h = jax.random.normal(rng, (2, 3, 8))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (8, 32))
    labels = jax.random.randint(jax.random.fold_in(rng, 2), (2, 3), 0, 32)
    want_logits = h @ w
    want = -jax.nn.log_softmax(want_logits, -1)
    want = jnp.take_along_axis(want, labels[..., None], -1).mean()
    got_full, _ = xent_full(h, w, labels)
    got_chunk, _ = xent_chunked(h, w, labels, chunk=8)
    np.testing.assert_allclose(float(want), float(got_full), rtol=1e-5)
    np.testing.assert_allclose(float(want), float(got_chunk), rtol=1e-5)


def test_pad_labels_masked():
    cfg, model, params, batch = setup()
    lf = make_loss_fn(model)
    base, m0 = lf(params, batch)
    # point some labels at the padded vocab region -> they must be ignored
    bad = dict(batch, labels=batch["labels"].at[:, -3:].set(cfg.vocab_size + 1))
    loss, m1 = lf(params, bad)
    assert float(m1["tokens"]) == float(m0["tokens"]) - 4 * 3
    assert np.isfinite(float(loss))


@pytest.mark.slow
def test_microbatch_grad_equivalence():
    """Accumulated microbatch gradients == single-shot gradients on the same
    global batch.  (Updated *params* can differ on near-zero-grad leaves:
    Adam normalizes noise-scale gradients to ±lr steps — expected.)"""
    cfg, model, params, batch = setup()
    lf = make_loss_fn_for(model)
    g_full = jax.grad(lambda p: lf(p, batch)[0])(params)
    half = jax.tree.map(lambda x: (x[:2], x[2:]), batch)
    h1 = jax.tree.map(lambda t: t[0], half, is_leaf=lambda t: isinstance(t, tuple))
    h2 = jax.tree.map(lambda t: t[1], half, is_leaf=lambda t: isinstance(t, tuple))
    g1 = jax.grad(lambda p: lf(p, h1)[0])(params)
    g2 = jax.grad(lambda p: lf(p, h2)[0])(params)
    g_acc = jax.tree.map(lambda a, b: (a + b) / 2, g1, g2)

    def close(a, b):
        a = a.astype(jnp.float32)
        b = b.astype(jnp.float32)
        return float(jnp.max(jnp.abs(a - b))) <= 1e-4 + 2e-2 * float(jnp.max(jnp.abs(a)))

    assert all(jax.tree.leaves(jax.tree.map(close, g_full, g_acc)))
    # and the step-level path runs + losses agree
    opt = AdamW(lr=1e-3)
    ost = opt.init(params)
    _, _, m1 = make_train_step(model, opt, microbatches=1)(params, ost, batch)
    _, _, m2 = make_train_step(model, opt, microbatches=2)(params, ost, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2


def make_loss_fn_for(model):
    return make_loss_fn(model)
