"""Pallas kernel validation: interpret=True vs ref.py oracles, shape/dtype
sweeps (per-kernel allclose contract) + hypothesis property sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref

TOL = {jnp.float32: 2e-4, jnp.bfloat16: 2e-2}


def _tol(dt):
    return TOL[jnp.bfloat16 if dt == jnp.bfloat16 else jnp.float32]


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "B,H,Kh,Sq,hd,bq,bkv",
    [
        (1, 2, 2, 32, 16, 16, 16),  # MHA
        (2, 4, 2, 64, 32, 32, 16),  # GQA g=2
        (1, 8, 1, 64, 16, 16, 64),  # MQA
        (1, 2, 1, 128, 64, 64, 32),
    ],
)
def test_flash_attention_sweep(dtype, causal, B, H, Kh, Sq, hd, bq, bkv):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Kh, Sq, hd), dtype)
    v = jax.random.normal(ks[2], (B, Kh, Sq, hd), dtype)
    o = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_kv=bkv, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(want, np.float32), atol=_tol(dtype), rtol=_tol(dtype)
    )


@settings(max_examples=8, deadline=None)
@given(
    logsq=st.integers(5, 7),
    bq=st.sampled_from([16, 32, 64]),
    bkv=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 100),
)
def test_flash_attention_property(logsq, bq, bkv, seed):
    """Block shape must never change the result (tuning-safety property)."""
    Sq = 2**logsq
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, Sq, 2, 16))
    k = jax.random.normal(ks[1], (1, 2, Sq, 16))
    v = jax.random.normal(ks[2], (1, 2, Sq, 16))
    o = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_kv=bkv, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want), atol=2e-4, rtol=2e-4)


def test_flash_attention_grad_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 16))
    k = jax.random.normal(ks[1], (1, 2, 32, 16))
    v = jax.random.normal(ks[2], (1, 2, 32, 16))

    def f(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, interpret=True, block_q=16, block_kv=16) ** 2)

    def fr(q, k, v):
        return jnp.sum(ref.flash_attention_ref(q, k, v) ** 2)

    g = jax.grad(f, (0, 1, 2))(q, k, v)
    gr = jax.grad(fr, (0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ------------------------------------------------------------ decode attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bkv", [16, 64, 128])
@pytest.mark.parametrize("length", [1, 37, 128])
def test_decode_attention_sweep(dtype, bkv, length):
    B, H, Kh, S, hd = 2, 4, 2, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Kh, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, Kh, S, hd), dtype)
    valid = (jnp.arange(S) < length).astype(jnp.int32)[None].repeat(B, 0)
    o = ops.decode_attention(q, k, v, valid, block_kv=bkv, interpret=True)
    want = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(want, np.float32), atol=_tol(dtype), rtol=_tol(dtype)
    )


def test_decode_attention_ring_validity():
    """Scattered validity (ring buffers) must be honoured, not just prefixes."""
    B, H, Kh, S, hd = 1, 2, 1, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, Kh, S, hd))
    v = jax.random.normal(ks[2], (B, Kh, S, hd))
    valid = jax.random.bernoulli(ks[3], 0.5, (B, S)).astype(jnp.int32)
    o = ops.decode_attention(q, k, v, valid, block_kv=16, interpret=True)
    # dense oracle with the same mask
    s = jnp.einsum("bkgh,bksh->bkgs", q.reshape(B, Kh, 2, hd), k) / np.sqrt(hd)
    s = jnp.where(valid[:, None, None] > 0, s, -1e30)
    want = jnp.einsum("bkgs,bksh->bkgh", jax.nn.softmax(s, -1), v).reshape(B, H, hd)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want), atol=1e-4)


# ------------------------------------------------------------------ rwkv scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("chunk", [8, 16, 32])
@pytest.mark.parametrize("B,T,H,hd", [(1, 32, 2, 8), (2, 64, 2, 16), (1, 64, 1, 32)])
def test_rwkv_scan_sweep(dtype, chunk, B, T, H, hd):
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, hd), dtype) for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, hd))).astype(dtype)
    u = jax.random.normal(ks[4], (H, hd), dtype)
    s0 = jax.random.normal(ks[5], (B, H, hd, hd), jnp.float32)
    y, sT = ops.rwkv_scan(r, k, v, lw, u, s0, chunk=chunk, interpret=True)
    yw, sw = ref.rwkv_scan_ref(r, k, v, lw, u, s0)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yw, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sw), atol=tol, rtol=tol)


def test_rwkv_scan_strong_decay_stable():
    B, T, H, hd = 1, 32, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, hd)) for i in range(3))
    lw = jnp.full((B, T, H, hd), -14.0)
    u = jax.random.normal(ks[3], (H, hd))
    s0 = jnp.zeros((B, H, hd, hd))
    y, _ = ops.rwkv_scan(r, k, v, lw, u, s0, chunk=16, interpret=True)
    yw, _ = ref.rwkv_scan_ref(r, k, v, lw, u, s0)
    assert bool(jnp.isfinite(y).all())
    np.testing.assert_allclose(np.asarray(y), np.asarray(yw), atol=1e-4)


# ------------------------------------------------------------------- lru scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("chunk,block_d", [(16, 16), (32, 32), (64, 16)])
def test_lru_scan_sweep(dtype, chunk, block_d):
    B, T, D = 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, D))).astype(dtype)
    b = jax.random.normal(ks[1], (B, T, D), dtype)
    h0 = jax.random.normal(ks[2], (B, D), jnp.float32)
    hs, hT = ops.lru_scan(a, b, h0, chunk=chunk, interpret=True)
    hw, hTw = ref.lru_scan_ref(a, b, h0)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(hs, np.float32), np.asarray(hw, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTw), atol=tol, rtol=tol)


# --------------------------------------------------------------------- matmul
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (64, 32, 96), (128, 64, 48)])
def test_matmul_sweep(dtype, bm, bn, bk):
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    a = jax.random.normal(ks[0], (128, 96), dtype)
    b = jax.random.normal(ks[1], (96, 64), dtype)
    o = ops.matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(want, np.float32), atol=_tol(dtype), rtol=_tol(dtype)
    )


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([16, 32, 64]),
    bn=st.sampled_from([16, 32, 64]),
    bk=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 50),
)
def test_matmul_property_tile_invariance(bm, bn, bk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    a = jax.random.normal(ks[0], (64, 64))
    b = jax.random.normal(ks[1], (64, 64))
    o = ops.matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref.matmul_ref(a, b)), atol=1e-4)


def test_model_uses_pallas_attention_path():
    """End-to-end: a tiny model with attn_impl='pallas' matches the xla path."""
    from repro import configs
    from repro.models import ExecConfig, Model

    cfg = configs.get_tiny("qwen2_7b")
    mx = Model(cfg, ExecConfig(attn_impl="xla"))
    mp = Model(cfg, ExecConfig(attn_impl="pallas", interpret=True, block_q=16, block_kv=16))
    params = mx.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    hx, _ = mx.forward(params, {"tokens": tokens})
    hp, _ = mp.forward(params, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(hx, np.float32), np.asarray(hp, np.float32), atol=3e-2, rtol=3e-2)


def test_model_uses_pallas_rwkv_path():
    from repro import configs
    from repro.models import ExecConfig, Model

    cfg = configs.get_tiny("rwkv6_7b")
    mx = Model(cfg, ExecConfig(rec_chunk=8))
    mp = Model(cfg, ExecConfig(attn_impl="pallas", interpret=True, rec_chunk=8))
    params = mx.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    hx, _ = mx.forward(params, {"tokens": tokens})
    hp, _ = mp.forward(params, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(hx, np.float32), np.asarray(hp, np.float32), atol=3e-2, rtol=3e-2)
