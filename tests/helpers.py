"""Subprocess helper for multi-device tests (fake host devices via XLA_FLAGS
must be set before jax import, so each multi-device test runs in its own
interpreter)."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 600, env_extra=None):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.update(env_extra or {})
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
        cwd=REPO,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
        )
    return proc.stdout
