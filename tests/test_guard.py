"""Resilience layer: guarded calls, fault injection, circuit breaking,
quarantine, and crash/resume of the pretune sweep.

The fault-injection tests (marked ``chaos``) run the *real* execution paths
under deterministic fault plans — hangs, transient storms, hard crashes,
mid-run kills — and assert the recovery, not the injection.  The CI chaos
lane re-runs them with a straggler plan injected through ``REPRO_FAULT_PLAN``
on top.
"""
import math
import os
import time

import numpy as np
import pytest

from repro.core import (
    CircuitBreaker,
    FaultPolicy,
    GuardTimeout,
    MeasureEngine,
    MeasurePolicy,
    Quarantine,
    SandboxCrash,
    compile_fanout,
    deterministic_backoff,
    guarded_call,
    is_transient_failure,
    sandboxed_probe,
)
from repro.testing import FaultPlan, FaultSpec, InjectedCrash, parse_plan, tear_file


@pytest.fixture(autouse=True)
def _fresh_fault_plans():
    """Env-configured plans are cached per env value with live counters;
    tests must not inherit a sibling's exhausted plan."""
    from repro.testing import faults

    faults._active.clear()
    yield
    faults._active.clear()


# ----------------------------------------------------------- guarded_call
def test_guarded_call_timeout_fires_on_hang():
    with pytest.raises(GuardTimeout):
        guarded_call(lambda: time.sleep(0.5), timeout=0.05, label="hang")


def test_guarded_call_timeout_never_retried_in_band():
    calls = {"n": 0}

    def hang():
        calls["n"] += 1
        time.sleep(0.5)

    with pytest.raises(GuardTimeout):
        guarded_call(hang, timeout=0.05, retries=5, backoff=0.0)
    assert calls["n"] == 1  # each retry would cost another full deadline


def test_guarded_call_transient_retried_exactly_with_backoff():
    calls = {"n": 0}
    sleeps: list = []
    retries_seen: list = []

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 2:
            raise RuntimeError("RESOURCE_EXHAUSTED: injected")
        return 42

    out = guarded_call(
        flaky,
        retries=2,
        backoff=0.01,
        backoff_mult=2.0,
        jitter=0.25,
        label="tok",
        on_retry=lambda a, e, d: retries_seen.append((a, d)),
        sleep=sleeps.append,
    )
    assert out == 42
    assert calls["n"] == 3  # transient-twice-then-succeed: exactly 2 retries
    # the backoff schedule is exponential and deterministically jittered
    expect = [deterministic_backoff(a, 0.01, 2.0, 0.25, "tok") for a in (0, 1)]
    assert sleeps == expect
    assert expect[1] > expect[0]
    assert [a for a, _ in retries_seen] == [0, 1]


def test_guarded_call_permanent_failure_not_retried():
    calls = {"n": 0}

    def bug():
        calls["n"] += 1
        raise ValueError("not a resource problem")

    with pytest.raises(ValueError):
        guarded_call(bug, retries=5, backoff=0.0)
    assert calls["n"] == 1


def test_guarded_call_retries_exhausted_raises_last():
    with pytest.raises(RuntimeError, match="RESOURCE_EXHAUSTED"):
        guarded_call(
            lambda: (_ for _ in ()).throw(RuntimeError("RESOURCE_EXHAUSTED: x")),
            retries=2,
            backoff=0.0,
            sleep=lambda d: None,
        )


def test_deterministic_backoff_reproducible_and_desynchronized():
    a1 = deterministic_backoff(1, 0.05, 2.0, 0.25, "shard0")
    assert a1 == deterministic_backoff(1, 0.05, 2.0, 0.25, "shard0")
    assert a1 != deterministic_backoff(1, 0.05, 2.0, 0.25, "shard1")
    base = 0.05 * 2.0**1
    assert base <= a1 <= base * 1.25  # jitter only ever stretches


def test_is_transient_failure_classes():
    assert is_transient_failure(RuntimeError("RESOURCE_EXHAUSTED: oom"))
    assert is_transient_failure(GuardTimeout("deadline"))
    assert not is_transient_failure(SandboxCrash("died", exitcode=-11))
    assert not is_transient_failure(ValueError("block size mismatch"))


# -------------------------------------------------------- sandboxed_probe
def test_sandboxed_probe_contains_hard_crash():
    # a clean callable survives its probe
    assert sandboxed_probe(lambda: 1 + 1, timeout=30.0)
    # an ordinary Python exception is NOT a crash: the real in-process
    # build must get to raise (and classify) it
    assert sandboxed_probe(lambda: 1 / 0, timeout=30.0)

    # a hard exit is contained in the child and surfaces as SandboxCrash
    def die():
        os._exit(3)

    with pytest.raises(SandboxCrash) as ei:
        sandboxed_probe(die, timeout=30.0)
    assert ei.value.exitcode == 3


# -------------------------------------------------------------- quarantine
def test_quarantine_threshold_and_recovery():
    q = Quarantine(max_failures=2)
    assert not q.note_failure("k")
    assert "k" not in q
    assert q.note_failure("k")
    assert "k" in q
    q.note_success("k")  # a success clears the strike count
    assert "k" not in q
    assert q.stats()["max_failures"] == 2


# --------------------------------------------------------- circuit breaker
def test_circuit_breaker_walk():
    b = CircuitBreaker(threshold=2, cooldown=3)
    assert b.state == CircuitBreaker.CLOSED and b.allow()
    b.record_failure()
    assert b.state == CircuitBreaker.CLOSED  # one below threshold
    b.record_failure()
    assert b.state == CircuitBreaker.OPEN and b.opens == 1
    # cooldown is counted in denied calls, not wall time
    assert not b.allow()
    assert not b.allow()
    assert b.denied == 2
    assert b.allow()  # third tick: half-open, probe granted
    assert b.state == CircuitBreaker.HALF_OPEN and b.probes == 1
    b.record_failure()  # failed probe re-trips immediately
    assert b.state == CircuitBreaker.OPEN and b.opens == 2
    assert not b.allow() and not b.allow()
    assert b.allow()  # probe again
    b.record_success()
    assert b.state == CircuitBreaker.CLOSED
    assert b.allow() and b.stats()["opens"] == 2


def test_circuit_breaker_success_resets_strike_count():
    b = CircuitBreaker(threshold=2, cooldown=2)
    b.record_failure()
    b.record_success()
    b.record_failure()
    assert b.state == CircuitBreaker.CLOSED  # never two consecutive


# ------------------------------------------------------------- fault plans
@pytest.mark.chaos
def test_fault_plan_matching_and_counters():
    plan = FaultPlan(
        [
            FaultSpec(kind="transient", site="cost", match={"bm": 32}, times=2),
            FaultSpec(kind="crash", site="build", calls=(3,)),
        ]
    )
    plan.fire("cost", key={"bm": 64, "bn": 32})  # no match: bm differs
    with pytest.raises(RuntimeError, match="RESOURCE_EXHAUSTED"):
        plan.fire("cost", key={"bm": 32, "bn": 64})  # dict-subset match
    with pytest.raises(RuntimeError):
        plan.fire("cost", key={"bm": 32, "bn": 32})
    plan.fire("cost", key={"bm": 32})  # times=2 exhausted: passes through
    plan.fire("build")
    plan.fire("build")
    with pytest.raises(InjectedCrash):
        plan.fire("build")  # 3rd build call
    assert plan.count("cost") == 2 and plan.count("build") == 1
    assert plan.count() == 3


@pytest.mark.chaos
def test_fault_plan_parse_and_env(monkeypatch):
    from repro.testing import active_plan
    from repro.testing.faults import ENV_FAULT_PLAN

    plan = parse_plan('[{"site": "tune", "kind": "kill", "calls": [2]}]')
    assert plan.specs[0].kind == "kill" and plan.specs[0].calls == (2,)
    monkeypatch.setenv(ENV_FAULT_PLAN, '[{"kind": "slow", "seconds": 0.0}]')
    p1 = active_plan()
    assert p1 is active_plan()  # cached: counters persist across tune_calls
    monkeypatch.delenv(ENV_FAULT_PLAN)
    assert active_plan() is None


def test_fault_plan_string_match_and_kill():
    plan = FaultPlan([FaultSpec(kind="kill", site="tune", match="matmul")])
    plan.fire("tune", key="flash_attention")  # substring miss
    with pytest.raises(SystemExit):
        plan.fire("tune", key="matmul")


# --------------------------------------------------------- measure engine
def test_measure_engine_timeout_charges_inf_run_survives():
    policy = MeasurePolicy(mode="fixed", warmup=0, repeats=1)
    eng = MeasureEngine(policy, guard=FaultPolicy(measure_timeout=0.05))
    out = eng.measure_round([lambda: time.sleep(0.5), lambda: 0.010])
    assert math.isinf(out[0].cost)
    assert out[1].cost == pytest.approx(0.010)
    assert eng.stats["timeouts"] == 1 and eng.stats["failed"] == 1


def test_measure_engine_retries_transients_in_place():
    calls = {"n": 0}

    def flaky_rep():
        calls["n"] += 1
        if calls["n"] <= 2:
            raise RuntimeError("RESOURCE_EXHAUSTED: injected")
        return 0.010

    policy = MeasurePolicy(mode="fixed", warmup=0, repeats=1)
    eng = MeasureEngine(policy, guard=FaultPolicy(retries=2, backoff=0.0))
    out = eng.measure_round([flaky_rep])
    assert out[0].cost == pytest.approx(0.010)
    assert eng.stats["retried"] == 2 and eng.stats["failed"] == 0


# --------------------------------------------------------- compile fanout
def test_compile_fanout_deadline_charges_unfinished():
    def quick():
        return "ok"

    def slow():
        time.sleep(1.0)
        return "late"

    out = compile_fanout(
        [("a", quick), ("b", slow), ("c", slow)], jobs=2, deadline=0.2
    )
    assert out[0] == "ok"
    assert isinstance(out[1], GuardTimeout) and isinstance(out[2], GuardTimeout)


def test_compile_fanout_fatal_raises_first_poison():
    def poison():
        raise TypeError("unexpected kwarg 'bm'")

    def fine():
        return "ok"

    with pytest.raises(TypeError):
        compile_fanout(
            [("a", poison), ("b", fine)],
            jobs=2,
            fatal=lambda e: isinstance(e, TypeError),
        )
    # without the predicate the classic returned-not-raised contract holds
    out = compile_fanout([("a", poison), ("b", fine)], jobs=2)
    assert isinstance(out[0], TypeError) and out[1] == "ok"


# -------------------------------------------- tune_call under a fault plan
def _matmul_args(n=192):
    import jax.numpy as jnp

    # 192 keeps this grid (bm/bn/bk in {32, 64}) off every other test's
    # shapes, so the process executable cache is cold and build-site
    # faults actually reach the builds
    return jnp.ones((n, n), jnp.float32), jnp.ones((n, n), jnp.float32)


@pytest.mark.chaos
@pytest.mark.slow
def test_tune_call_completes_under_fault_plan():
    """The acceptance scenario: a hang + two transients + one hard crash
    across candidates must not kill the run, and with deterministic costs
    the faulted search converges to the fault-free best point."""
    from repro.tuning import TuningDB
    from repro.tuning.pretune import _analytic_cost_fn
    from repro.kernels.autotuned import tune_call

    a, b = _matmul_args()
    cost_fn = _analytic_cost_fn()
    plan = FaultPlan(
        [
            FaultSpec(kind="hang", site="cost",
                      match={"bm": 32, "bn": 32, "bk": 64}, seconds=0.3),
            FaultSpec(kind="transient", site="cost",
                      match={"bm": 64, "bn": 32, "bk": 32}, times=2),
            FaultSpec(kind="crash", site="build",
                      match={"bm": 32, "bn": 64, "bk": 32}, times=1),
        ]
    )
    # the faulted run goes FIRST: its builds are cache-cold, so the
    # build-site crash genuinely reaches a build
    ms: dict = {}
    rec_faulted = tune_call(
        "matmul", a, b, db=TuningDB(path=None), interpret=True,
        strategy="grid", cost_fn=cost_fn, warm_start=False, jobs=1,
        measure_stats=ms,
        fault_policy=FaultPolicy(measure_timeout=0.05, retries=2, backoff=0.001),
        fault_plan=plan,
    )
    rec_clean = tune_call(
        "matmul", a, b, db=TuningDB(path=None), interpret=True,
        strategy="grid", cost_fn=cost_fn, warm_start=False, jobs=1,
        fault_plan=FaultPlan([]),  # isolate from any env-injected plan
    )
    assert plan.count() >= 4  # hang + 2 transients + crash all fired
    assert ms["timeouts"] == 1  # the hang was charged, not waited out
    assert ms["retried"] >= 2  # the transient candidate was retried in place
    assert rec_faulted is not None and rec_clean is not None
    assert rec_faulted.point == rec_clean.point  # same best despite the storm
    assert rec_faulted.cost == pytest.approx(rec_clean.cost)


@pytest.mark.chaos
def test_tune_call_quarantine_skips_repeat_offender():
    """A candidate that keeps failing stops being offered builds at all:
    with max_failures=1 the first failure quarantines it, and later rounds
    (the grid revisits nothing, so force revisits via two tune_calls on the
    same Quarantine-scoped search) charge it inf without a measurement."""
    from repro.tuning import TuningDB
    from repro.kernels.autotuned import tune_call

    a, b = _matmul_args(96)  # pow2_floor(96)=32: single-point grid elsewhere
    costs = {"calls": 0}

    def cost_fn(ex, *args):
        costs["calls"] += 1
        raise RuntimeError("vmem exceeded: always-illegal candidate")

    ms: dict = {}
    rec = tune_call(
        "matmul", a, b, db=TuningDB(path=None), interpret=True,
        strategy="grid", cost_fn=cost_fn, warm_start=False, jobs=1,
        measure_stats=ms, fault_plan=FaultPlan([]),
        fault_policy=FaultPolicy(max_failures=1, retries=0),
    )
    assert rec is None  # every candidate failed: nothing stored
    assert ms["quarantined"] >= 1


# ----------------------------------------------- breaker in the OnlineTuner
def test_online_tuner_breaker_gates_and_recovers():
    from repro.core import Autotuning, IntDim, SearchSpace
    from repro.runtime.online import EXPLOIT, EXPLORE, OnlineTuner

    space = SearchSpace([IntDim("x", 0, 7)])
    at = Autotuning(space=space, num_opt=2, max_iter=4, seed=0, cache=False)
    t = OnlineTuner(
        at, epsilon=1.0, default_point={"x": 0},
        breaker={"threshold": 2, "cooldown": 3},
    )
    # two failing explores trip the breaker
    for _ in range(2):
        d = t.begin()
        assert d.kind == EXPLORE
        t.observe(d, np.inf)
    assert t.breaker.state == CircuitBreaker.OPEN
    # while open: incumbent served, no e-credits burned, cooldown ticks
    for _ in range(2):
        d = t.begin()
        assert d.kind == EXPLOIT
    assert t.stats_["breaker_denied"] == 2
    # cooldown lapsed: half-open probe explores again
    d = t.begin()
    assert d.kind == EXPLORE and t.breaker.state == CircuitBreaker.HALF_OPEN
    t.observe(d, 1.0)  # healthy probe closes the breaker
    assert t.breaker.state == CircuitBreaker.CLOSED
    d = t.begin()
    assert d.kind == EXPLORE  # exploration resumed
    t.observe(d, 1.0)
    assert t.stats()["breaker"]["opens"] == 1


def test_online_tuner_breaker_failed_probe_reopens():
    from repro.core import Autotuning, IntDim, SearchSpace
    from repro.runtime.online import EXPLOIT, EXPLORE, OnlineTuner

    space = SearchSpace([IntDim("x", 0, 7)])
    at = Autotuning(space=space, num_opt=2, max_iter=4, seed=0, cache=False)
    t = OnlineTuner(
        at, epsilon=1.0, default_point={"x": 0},
        breaker={"threshold": 1, "cooldown": 2},
    )
    d = t.begin()
    t.observe(d, np.inf)  # threshold=1: open immediately
    assert t.breaker.state == CircuitBreaker.OPEN
    assert t.begin().kind == EXPLOIT
    d = t.begin()  # second tick: half-open probe
    assert d.kind == EXPLORE
    t.observe(d, np.inf)  # probe fails: re-open for another cooldown
    assert t.breaker.state == CircuitBreaker.OPEN and t.breaker.opens == 2
    assert t.begin().kind == EXPLOIT


def test_autotuning_skip_reasons_tagged():
    from repro.core import Autotuning, IntDim, SearchSpace

    at = Autotuning(
        space=SearchSpace([IntDim("x", 0, 7)]), num_opt=2, max_iter=4, cache=False
    )
    at.skip(np.inf, reason="build-failed")
    at.skip(np.inf, reason="build-failed")
    at.skip(np.inf, reason="quarantined")
    assert at.skip_reasons == {"build-failed": 2, "quarantined": 1}


# ------------------------------------------------------------- run journal
def test_run_journal_roundtrip_and_torn_write(tmp_path):
    from repro.tuning import RunJournal, TuningDB
    from repro.tuning.records import TuningRecord
    from repro.tuning import make_key
    from repro.core import IntDim, SearchSpace

    space = SearchSpace([IntDim("x", 0, 7)])
    k1 = make_key("demo", args=(), space=space, extra={"case": 1})
    k2 = make_key("demo", args=(), space=space, extra={"case": 2})
    k3 = make_key("demo", args=(), space=space, extra={"case": 3})
    rec = TuningRecord(key=k1, point={"x": 3}, cost=1.25, evals=8, source="test")

    j = RunJournal(str(tmp_path / "db.json.journal"))
    j.start(k1)
    j.commit(k1, rec)
    j.start(k2)
    j.failed(k2, RuntimeError("every candidate failed"))
    j.start(k3)  # interrupted: no verdict before the "kill"
    s = j.summary()
    assert set(s["committed"]) == {k1.encode()}
    assert s["failed"] == {k2.encode()}
    assert s["interrupted"] == {k3.encode()}

    # the journal alone reconstructs a DB of the committed work
    db = j.to_db()
    assert len(db) == 1 and db.get(k1).point == {"x": 3}
    assert RunJournal.is_journal(j.path)
    assert not RunJournal.is_journal(__file__)

    # a torn trailing line (power loss mid-append) loses only the tail
    j2 = RunJournal(str(tmp_path / "torn.journal"))
    j2.start(k1)
    j2.commit(k1, rec)
    size_before_tail = os.path.getsize(j2.path)
    j2.start(k2)
    tear_file(j2.path, keep_bytes=size_before_tail + 10)
    s2 = j2.summary()
    assert set(s2["committed"]) == {k1.encode()}
    assert s2["interrupted"] == set()  # the torn start never happened


@pytest.mark.chaos
@pytest.mark.slow
def test_pretune_kill_resume_matches_uninterrupted(tmp_path, monkeypatch):
    """A shard killed mid-sweep, resumed with --resume, must (a) re-measure
    zero completed cases and (b) end with a DB that ``db diff --costs``
    reports identical to an uninterrupted run's."""
    from repro.testing.faults import ENV_FAULT_PLAN
    from repro.tune import main as tune_main
    from repro.tuning import RunJournal

    monkeypatch.chdir(tmp_path)
    common = [
        "pretune", "--smoke", "--cost", "analytic", "--no-warm-start",
        "--kernel", "matmul", "--jobs", "1",
    ]
    monkeypatch.delenv(ENV_FAULT_PLAN, raising=False)
    assert tune_main(common + ["--db", "ref.json"]) == 0

    # kill the worker at its second tune_call (mid-sweep)
    monkeypatch.setenv(
        ENV_FAULT_PLAN, '[{"site": "tune", "kind": "kill", "calls": [2]}]'
    )
    with pytest.raises(SystemExit):
        tune_main(common + ["--db", "k.json"])
    monkeypatch.delenv(ENV_FAULT_PLAN)

    j = RunJournal("k.json.journal")
    s = j.summary()
    assert len(s["committed"]) == 1 and len(s["interrupted"]) == 1
    committed_before = set(s["committed"])

    # the journal's committed records already merge like a shard DB
    assert tune_main(["db", "merge", "--out", "partial.json", "k.json.journal"]) == 0
    assert tune_main(["db", "diff", "partial.json", "k.json"]) == 0

    assert tune_main(common + ["--db", "k.json", "--resume"]) == 0

    # zero re-measurement: after the resume marker, no completed case starts
    events = j.events()
    resume_at = max(i for i, ev in enumerate(events) if ev["event"] == "resume")
    restarted = {
        ev["key"] for ev in events[resume_at:] if ev["event"] == "start"
    }
    assert restarted.isdisjoint(committed_before)
    # and the resumed DB is byte-equivalent to the uninterrupted one
    assert tune_main(["db", "diff", "--costs", "ref.json", "k.json"]) == 0


@pytest.mark.chaos
def test_chaos_lane_env_plan_reaches_tune_call(monkeypatch):
    """With REPRO_FAULT_PLAN set (how the CI chaos lane injects), a plain
    tune_call picks the plan up with zero plumbing."""
    from repro.testing.faults import ENV_FAULT_PLAN, active_plan
    from repro.tuning import TuningDB
    from repro.tuning.pretune import _analytic_cost_fn
    from repro.kernels.autotuned import tune_call

    monkeypatch.setenv(
        ENV_FAULT_PLAN,
        '[{"site": "cost", "kind": "slow", "seconds": 0.0001, "times": 1000}]',
    )
    a, b = _matmul_args(64)
    rec = tune_call(
        "matmul", a, b, db=TuningDB(path=None), interpret=True,
        strategy="grid", cost_fn=_analytic_cost_fn(), warm_start=False, jobs=1,
    )
    assert rec is not None
    assert active_plan().count("cost") > 0  # the stragglers actually fired
