"""Adaptive measurement engine: racing, noise floor, roofline prefilter,
record confidence, and the online fractional explore credits."""
from __future__ import annotations

import math

import numpy as np
import pytest

from repro.core import (
    CSA,
    Autotuning,
    IntDim,
    LogIntDim,
    MeasureEngine,
    MeasurePolicy,
    MeasureResult,
    RuntimeCost,
    SearchSpace,
    resolve_measure_policy,
)


def det_reps(costs, jitter=0.0):
    """Deterministic rep callables: candidate i returns costs[i] with an
    optional seeded pseudo-jitter per repetition."""
    state: dict = {}

    def rep_for(i):
        def rep():
            k = state.get(i, 0)
            state[i] = k + 1
            j = jitter * ((((i * 31 + k * 17) % 7) - 3) / 3.0)
            return costs[i] * (1.0 + j)

        return rep

    return [rep_for(i) for i in range(len(costs))]


# ---------------------------------------------------------------- the policy
def test_resolve_policy_from_env_and_values(monkeypatch):
    assert resolve_measure_policy("fixed").mode == "fixed"
    assert resolve_measure_policy("adaptive").mode == "adaptive"
    monkeypatch.setenv("REPRO_TUNE_MEASURE", "fixed")
    assert resolve_measure_policy(None).mode == "fixed"
    monkeypatch.delenv("REPRO_TUNE_MEASURE")
    assert resolve_measure_policy(None).mode == "adaptive"
    p = MeasurePolicy(mode="fixed", repeats=5)
    assert resolve_measure_policy(p) is p
    # warmup/repeats override named modes, never explicit policies
    assert resolve_measure_policy("fixed", warmup=0, repeats=9).repeats == 9
    with pytest.raises(ValueError):
        MeasurePolicy(mode="nope")
    with pytest.raises(ValueError):
        MeasurePolicy(ladder=(3, 1))


# ---------------------------------------------------------------- the engine
def test_racing_culls_dominated_candidate_after_one_rep():
    eng = MeasureEngine(MeasurePolicy(warmup=0, calibrate_reps=3))
    out = eng.measure_round(det_reps([1.0, 40.0, 5.0], jitter=1e-4))
    assert out[1].culled and out[1].repeats_spent == 1
    assert out[2].culled and out[2].repeats_spent == 1
    # culled candidates are charged their real single-rep cost, never inf
    assert out[1].cost == pytest.approx(40.0, rel=1e-3)
    assert np.isfinite(out[2].cost)
    # the winner survives un-culled
    assert not out[0].culled and out[0].cost == pytest.approx(1.0, rel=1e-3)
    assert eng.stats["culled"] == 2


def test_racing_never_culls_within_noise_floor():
    """Two candidates whose true costs sit inside the calibrated noise floor
    must both climb the full ladder — neither is raced out."""
    eng = MeasureEngine(MeasurePolicy(warmup=0, calibrate_reps=5))
    # 0.3% apart, jitter 0.5% -> calibrated floor covers the gap
    out = eng.measure_round(det_reps([1.0, 1.003, 30.0], jitter=5e-3))
    assert not out[0].culled and not out[1].culled
    assert out[0].repeats_spent == out[1].repeats_spent == 7  # ladder top
    assert out[2].culled and out[2].repeats_spent == 1
    assert eng.noise is not None and eng.noise.floor(1.0) >= 0.003


def test_racing_stops_early_when_separated():
    """Clearly distinct survivors do not climb past the first rung."""
    eng = MeasureEngine(MeasurePolicy(warmup=0, calibrate_reps=3))
    out = eng.measure_round(det_reps([1.0, 2.0], jitter=1e-4))
    # 2.0 is culled at rung 1; the singleton winner needs no more reps
    assert out[0].repeats_spent == 1
    assert out[1].culled


def test_racing_culls_regressive_round_against_incumbent():
    """A later round whose candidates all lose to an earlier round's best
    is decided at one rep each — mutual CI overlap must not escalate the
    ladder when the cross-round incumbent already dominates everyone."""
    eng = MeasureEngine(MeasurePolicy(warmup=0, calibrate_reps=3))
    eng.measure_round(det_reps([1.0], jitter=1e-4))
    out = eng.measure_round(det_reps([8.0, 8.001, 8.002], jitter=1e-4))
    assert all(r.culled and r.repeats_spent == 1 for r in out)
    assert eng.best_measured == pytest.approx(1.0, rel=1e-3)


def test_failed_and_missing_candidates_are_inf():
    eng = MeasureEngine(MeasurePolicy(warmup=0, calibrate_reps=2))

    def boom():
        raise ValueError("tile does not divide")

    errs = []
    eng.on_error = lambda i, e: errs.append((i, e))
    reps = det_reps([1.0, 1.0, 1.0])
    reps[1] = None  # executable never built
    reps[2] = boom
    out = eng.measure_round(reps)
    assert np.isfinite(out[0].cost)
    assert out[1].cost == math.inf and out[1].repeats_spent == 0
    assert out[2].cost == math.inf
    assert errs and errs[0][0] == 2
    assert eng.stats["failed"] == 2


def test_engine_reraises_interrupts():
    """A Ctrl-C mid-measurement is control flow, never a candidate cost."""
    eng = MeasureEngine(MeasurePolicy(warmup=0, calibrate_reps=2))

    def interrupted():
        raise KeyboardInterrupt

    with pytest.raises(KeyboardInterrupt):
        eng.measure_round(det_reps([1.0]) + [interrupted])


def test_roofline_prefilter_skips_and_charges_bound():
    eng = MeasureEngine(MeasurePolicy(warmup=0, calibrate_reps=2))
    eng.measure_round(det_reps([1.0]))  # establishes the incumbent
    out = eng.measure_round(det_reps([3.0, 0.5]), bounds=[2.7, 0.45])
    assert out[0].pruned == "roofline" and out[0].repeats_spent == 0
    assert out[0].cost == pytest.approx(2.7)
    assert out[1].pruned is None and np.isfinite(out[1].cost)
    # a pruned bound never becomes the incumbent
    assert eng.best_measured == pytest.approx(0.5, rel=1e-2)


def test_roofline_prefilter_never_fires_without_incumbent():
    eng = MeasureEngine(MeasurePolicy(warmup=0, calibrate_reps=2))
    out = eng.measure_round(det_reps([1.0, 2.0]), bounds=[0.9, 1.8])
    assert all(r.pruned is None for r in out)


def test_fixed_mode_spends_exact_schedule():
    eng = MeasureEngine(MeasurePolicy(mode="fixed", warmup=1, repeats=3))
    out = eng.measure_round(det_reps([1.0, 40.0], jitter=1e-4))
    assert [r.repeats_spent for r in out] == [3, 3]
    assert not any(r.culled for r in out)
    assert eng.stats["reps"] == 6 and eng.stats["warmup_reps"] == 2


# ------------------------------------------------- driver (entire_exec_batch)
def _bowl_space():
    return SearchSpace([LogIntDim("t", 4, 64)])


def _bowl_cost(point):
    return 1.0 + (math.log2(point["t"] / 16.0)) ** 2


def test_batch_driver_records_measure_meta_and_revisits_after_reset():
    """A roofline-pruned candidate is flagged in the driver's measurement
    meta; reset(level>=1) clears the flag and the re-search measures it."""
    space = _bowl_space()
    at = Autotuning(space=space, ignore=0,
                    optimizer=CSA(1, num_opt=4, max_iter=4, seed=0), cache=True)
    eng = MeasureEngine(MeasurePolicy(warmup=0, calibrate_reps=2))
    measured_points: list = []

    def measure_batch(points):
        measured_points.extend(tuple(sorted(p.items())) for p in points)
        reps = det_reps([_bowl_cost(p) for p in points])
        bounds = [0.9 * _bowl_cost(p) for p in points]
        return eng.measure_round(reps, bounds=bounds)

    at.entire_exec_batch(measure_batch)
    assert at.best_point == {"t": 16}
    pruned = [
        (p, at.measurement_meta(p)) for p, _ in at.history
        if (at.measurement_meta(p) or {}).get("pruned") == "roofline"
    ]
    assert eng.stats["pruned_roofline"] > 0 and pruned
    victim = pruned[0][0]
    # the meta survives a level-0 reset (history is kept)...
    # ...and a level-1 reset clears it so the point is re-measured
    at.reset(1)
    assert at.measurement_meta(victim) is None
    eng2 = MeasureEngine(MeasurePolicy(warmup=0, calibrate_reps=2))
    before = len(measured_points)

    def measure_batch2(points):
        measured_points.extend(tuple(sorted(p.items())) for p in points)
        return eng2.measure_round(det_reps([_bowl_cost(p) for p in points]))

    at.entire_exec_batch(measure_batch2)
    revisited = measured_points[before:]
    assert tuple(sorted(victim.items())) in revisited
    meta = at.measurement_meta(victim)
    assert meta is not None and meta["pruned"] is None
    assert meta["repeats_spent"] >= 1


def test_measure_meta_survives_pipeline_stage_transition():
    """A point really measured by an earlier pipeline stage must keep its
    measurement meta — and its measured cost — when a later stage revisits
    it and the engine's roofline prefilter answers with the optimistic
    analytic bound (NM must not 'improve' on CSA's real measurement)."""
    from repro.core import NelderMead, Pipeline

    space = SearchSpace([IntDim("k", 0, 31)])
    pipe = Pipeline(
        [CSA(1, num_opt=4, max_iter=3, seed=0),
         NelderMead(1, error=0.0, max_iter=100, seed=0)],
        (0.5, 0.5), budget=24,
    )
    # cache=False: revisits genuinely reach the measurement layer, which is
    # exactly when a stale prune could clobber a real measurement
    at = Autotuning(space=space, ignore=0, optimizer=pipe, cache=False)
    seen: set = set()

    def true_cost(p):
        return 1.0 + abs(p["k"] - 7) * 0.1

    def measure_batch(points):
        out = []
        for p in points:
            key = tuple(sorted(p.items()))
            if key in seen:
                # revisit: the engine prunes against its (better) incumbent,
                # charging an optimistic lower bound with zero reps
                out.append(MeasureResult(cost=0.5 * true_cost(p), pruned="roofline"))
            else:
                seen.add(key)
                out.append(
                    MeasureResult(cost=true_cost(p), cost_std=0.01, repeats_spent=3)
                )
        return out

    at.entire_exec_batch(measure_batch)
    keys = [space.key(p) for p, _ in at.history]
    revisited = {k for k in keys if keys.count(k) > 1}
    assert revisited  # the NM stage revisited a CSA-measured point
    # every delivered cost is the *measured* one — the optimistic half-price
    # bound never reached the optimizer or the history
    for p, c in at.history:
        assert c == pytest.approx(true_cost(p))
    # ...and the measured meta survived the stage transition
    for p, _ in at.history:
        if space.key(p) in revisited:
            meta = at.measurement_meta(p)
            assert meta is not None
            assert meta["pruned"] is None
            assert meta["repeats_spent"] == 3


def test_measurements_count_reps_actually_spent():
    space = SearchSpace([IntDim("k", 0, 3)])
    at = Autotuning(space=space, ignore=0,
                    optimizer=CSA(1, num_opt=3, max_iter=2, seed=0), cache=True)
    eng = MeasureEngine(MeasurePolicy(warmup=0, calibrate_reps=2))

    def measure_batch(points):
        return eng.measure_round(det_reps([1.0 + p["k"] for p in points]))

    at.entire_exec_batch(measure_batch)
    assert at.num_measurements == eng.stats["reps"]


# ------------------------------------------------------- RuntimeCost + record
def test_runtime_cost_records_raw_times():
    cost = RuntimeCost(warmup=1, repeats=3)
    c = cost(lambda: sum(range(200)))
    assert len(cost.last_times) == 3
    assert c == sorted(cost.last_times)[1]
    assert cost.last_std >= 0.0


def test_runtime_cost_reraises_interrupts():
    cost = RuntimeCost(warmup=0, repeats=2)
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        if calls["n"] == 2:
            raise KeyboardInterrupt
        return 1

    with pytest.raises(KeyboardInterrupt):
        cost(fn)
    with pytest.raises(SystemExit):
        cost(lambda: (_ for _ in ()).throw(SystemExit(1)))


def test_tuning_record_confidence_roundtrip():
    from repro.tuning import make_key
    from repro.tuning.records import TuningRecord

    key = make_key("k", extra={"x": 1})
    rec = TuningRecord(key=key, point={"a": 1}, cost=0.5, cost_std=0.01,
                       repeats_spent=7)
    back = TuningRecord.from_json(rec.to_json())
    assert back.cost_std == pytest.approx(0.01)
    assert back.repeats_spent == 7
    # old records (fields absent) load with None
    blob = rec.to_json()
    del blob["cost_std"]
    del blob["repeats_spent"]
    old = TuningRecord.from_json(blob)
    assert old.cost_std is None and old.repeats_spent is None


def test_commit_near_tie_prefers_lower_variance():
    """A marginally 'better' new best inside the noise band must not clobber
    a lower-variance stored record it never re-measured."""
    from repro.tuning import TuningDB, make_key
    from repro.tuning.records import TuningRecord

    space = SearchSpace([IntDim("k", 0, 63)])
    key = make_key("near_tie", space=space, extra={"case": 1})
    db = TuningDB(None)
    stored = {"k": 50}
    db.put(TuningRecord(key=key, point=dict(stored), cost=1.000, cost_std=0.002,
                        repeats_spent=7))
    at = Autotuning(space=space, ignore=0,
                    optimizer=CSA(1, num_opt=3, max_iter=2, seed=0),
                    cache=True, db=db, key=key, warm_start=False)

    def measure_batch(points):
        # every visited point "measures" 0.999 with high variance: a lucky
        # near-tie one noise-width under the stored best
        return [MeasureResult(cost=0.999, cost_std=0.05, repeats_spent=1)
                for _ in points]

    at.entire_exec_batch(measure_batch)
    # the guard only applies to a stored point this run never re-measured
    assert all(p != stored for p, _ in at.history)
    kept = db.get(key)
    assert kept.point == stored and kept.cost == pytest.approx(1.000)
    # a decisive improvement (beyond the noise band) still wins
    at2 = Autotuning(space=space, ignore=0,
                     optimizer=CSA(1, num_opt=3, max_iter=2, seed=1),
                     cache=True, db=db, key=key, warm_start=False)

    def measure_batch2(points):
        return [MeasureResult(cost=0.5, cost_std=0.05, repeats_spent=3)
                for _ in points]

    at2.entire_exec_batch(measure_batch2)
    assert db.get(key).cost == pytest.approx(0.5)


def test_commit_single_rep_record_never_blocks_refresh():
    """A stored single-rep record's std of 0.0 is *unknown* confidence, not
    perfect confidence — it must not survive as 'lower variance' against a
    fully-measured near-tie."""
    from repro.tuning import TuningDB, make_key
    from repro.tuning.records import TuningRecord

    space = SearchSpace([IntDim("k", 0, 63)])
    key = make_key("near_tie", space=space, extra={"case": "single_rep"})
    db = TuningDB(None)
    db.put(TuningRecord(key=key, point={"k": 50}, cost=1.000, cost_std=0.0,
                        repeats_spent=1))
    at = Autotuning(space=space, ignore=0,
                    optimizer=CSA(1, num_opt=3, max_iter=2, seed=0),
                    cache=True, db=db, key=key, warm_start=False)

    def measure_batch(points):
        return [MeasureResult(cost=0.999, cost_std=0.01, repeats_spent=7)
                for _ in points]

    at.entire_exec_batch(measure_batch)
    assert all(p != {"k": 50} for p, _ in at.history)
    assert db.get(key).cost == pytest.approx(0.999)  # the fluke is replaced


def test_record_from_carries_measurement_confidence():
    from repro.tuning import make_key
    from repro.tuning.warm_start import record_from

    space = _bowl_space()
    at = Autotuning(space=space, ignore=0,
                    optimizer=CSA(1, num_opt=4, max_iter=3, seed=0), cache=True)
    eng = MeasureEngine(MeasurePolicy(warmup=0, calibrate_reps=2))

    def measure_batch(points):
        return eng.measure_round(det_reps([_bowl_cost(p) for p in points],
                                          jitter=1e-4))

    at.entire_exec_batch(measure_batch)
    rec = record_from(at, make_key("conf", space=space))
    assert rec.repeats_spent is not None and rec.repeats_spent >= 1
    assert rec.cost_std is not None and rec.cost_std >= 0.0


# --------------------------------------------------------------- online mode
def _online_tuner(measure, epsilon=1.0, seed=0):
    from repro.runtime.online import OnlineTuner

    space = SearchSpace([IntDim("k", 0, 5)])
    at = Autotuning(space=space, ignore=0,
                    optimizer=CSA(1, num_opt=3, max_iter=3, seed=seed),
                    cache=True)
    return OnlineTuner(at, epsilon=epsilon, measure=measure)


def _drive_online(tuner, cost_of, max_requests=10_000):
    """Serve synthetic explore traffic until the search converges; returns
    the number of requests spent."""
    n = 0
    while not tuner.finished and n < max_requests:
        d = tuner.begin(_force_explore=True)
        assert d.kind == "explore"
        tuner.observe(d, cost_of(d.point))
        n += 1
    assert tuner.finished
    return n


def test_online_adaptive_culls_and_converges_in_fewer_requests():
    """Dominated candidates are decided after one live request; the same
    search under a fixed 3-rep policy pays the full schedule every time."""
    cost_of = lambda p: 1.0 + p["k"]  # k=0 dominates, others dominated

    adaptive = _online_tuner(MeasurePolicy(warmup=0))
    n_adaptive = _drive_online(adaptive, cost_of)
    fixed = _online_tuner(MeasurePolicy(mode="fixed", repeats=3))
    n_fixed = _drive_online(fixed, cost_of)

    assert adaptive.at.best_point == fixed.at.best_point == {"k": 0}
    assert n_adaptive < n_fixed
    assert adaptive.stats_["culled_explores"] > 0
    # requests = repetitions: every explore request was charged to exactly
    # one candidate's measurement (cache-answered revisits are free, so
    # num_evals can exceed the candidates actually served)
    assert adaptive.stats_["explores"] == n_adaptive
    assert adaptive.stats_["explore_candidates"] <= adaptive.at.num_evals
    assert fixed.stats_["explores"] == n_fixed
    # the fixed schedule pays repeats per decided candidate
    assert n_fixed >= 3 * fixed.stats_["explore_candidates"]


def test_online_epsilon_accounting_with_fractional_explores():
    """ε rations explore *requests* (repetitions), so culled candidates
    consume a fraction of the budget a full ladder evaluation would."""
    tuner = _online_tuner(MeasurePolicy(warmup=0), epsilon=0.25)
    cost_of = lambda p: 1.0 + p["k"]
    calls = 0
    while not tuner.finished and calls < 4000:
        d = tuner.begin()
        tuner.observe(d, cost_of(d.point) if d.kind == "explore" else 1.0)
        calls += 1
    assert tuner.finished
    s = tuner.stats_
    assert s["explores"] + s["exploits"] == calls
    # the ε-credit ledger holds at every prefix by construction; check the
    # aggregate explicitly
    assert s["explores"] <= 0.25 * calls + 1
    assert s["culled_explores"] > 0


def test_online_legacy_single_rep_unchanged():
    """measure=None keeps the classic one-request-per-candidate protocol."""
    tuner = _online_tuner(None)
    n = _drive_online(tuner, lambda p: 1.0 + p["k"])
    # one request == one decided candidate (cache-answered revisits aside)
    assert tuner.stats_["explore_candidates"] == n
    assert tuner.stats_["culled_explores"] == 0


# ----------------------------------------------------------- tune_call wiring
@pytest.fixture
def measure_probe_kernel():
    import jax.numpy as jnp

    from repro.kernels.autotuned import _REGISTRY, KernelSpec, register

    def probe(x, *, t1, t2, interpret=False):
        val = (jnp.log2(t1 / 16.0)) ** 2 + (jnp.log2(t2 / 64.0)) ** 2
        return x.sum() * 0.0 + val + 0.5

    name = "_measure_probe"
    register(
        KernelSpec(
            name=name,
            fn=probe,
            space=lambda x: SearchSpace(
                [LogIntDim("t1", 4, 64), LogIntDim("t2", 16, 256)]
            ),
            defaults=lambda x: {"t1": 16, "t2": 64},
        )
    )
    yield name
    _REGISTRY.pop(name, None)


def det_cost(ex, *args):
    return float(np.asarray(ex(*args)))


def test_tune_call_fixed_reproduces_sequential_best(measure_probe_kernel):
    """--measure fixed is the trajectory-pinned policy: same committed best
    point as the pre-engine sequential reference on a deterministic cost."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.autotuned import exec_cache, get_spec, tune_call
    from repro.tuning import TuningDB, make_key

    x = jnp.ones((4, 4))
    spec = get_spec(measure_probe_kernel)
    space = spec.space(x)
    key = make_key(measure_probe_kernel, args=(x,), space=space,
                   extra={"interpret": True})
    db_s = TuningDB(None)

    def measure(*knob_values):
        knobs = dict(zip(space.names, knob_values))
        fn = jax.jit(lambda *xs: spec.fn(*xs, **knobs, interpret=True))
        return det_cost(fn, x)

    at = Autotuning(space=space, ignore=0,
                    optimizer=CSA(2, num_opt=3, max_iter=3, seed=0),
                    cache=True, db=db_s, key=key)
    at.entire_exec(measure)
    at.commit()
    rec_seq = db_s.get(key)

    exec_cache().clear()
    stats: dict = {}
    rec_fixed = tune_call(measure_probe_kernel, x, db=TuningDB(None),
                          interpret=True, num_opt=3, max_iter=3, seed=0,
                          jobs=2, cost_fn=det_cost, measure="fixed",
                          measure_stats=stats)
    rec_adaptive = tune_call(measure_probe_kernel, x, db=TuningDB(None),
                             interpret=True, num_opt=3, max_iter=3, seed=0,
                             jobs=2, cost_fn=det_cost, measure="adaptive")
    assert rec_seq is not None
    assert rec_fixed.point == rec_seq.point and rec_fixed.cost == rec_seq.cost
    assert stats["mode"] == "fixed"
    # the adaptive policy finds the same best on a deterministic cost
    assert rec_adaptive.point == rec_seq.point


def test_tune_call_adaptive_reports_stats(measure_probe_kernel):
    import jax.numpy as jnp

    from repro.kernels.autotuned import tune_call
    from repro.tuning import TuningDB

    x = jnp.ones((4, 4))
    stats: dict = {}
    rec = tune_call(measure_probe_kernel, x, db=TuningDB(None), interpret=True,
                    num_opt=4, max_iter=3, seed=0, jobs=2, cost_fn=det_cost,
                    measure="adaptive", measure_stats=stats)
    assert rec is not None
    assert stats["mode"] == "adaptive"
    assert stats["reps"] >= stats["measured"] >= 1
    assert stats["culled"] >= 1  # dominated knobs raced out
    assert rec.repeats_spent is not None and rec.repeats_spent >= 1


def test_pretune_measure_fixed_flag(tmp_path, capsys):
    """pretune --measure fixed runs the classic schedule end to end on one
    tiny grid case and commits a record."""
    from repro.tuning import TuningDB
    from repro.tuning.pretune import main as pretune_main

    db_path = str(tmp_path / "fixed.json")
    rc = pretune_main([
        "--db", db_path, "--smoke", "--only", "matmul/64*",
        "--measure", "fixed", "--num-opt", "2", "--max-iter", "1",
    ])
    out = capsys.readouterr().out
    assert rc == 0
    assert "matmul/64x64x64: best=" in out
    db = TuningDB(db_path)
    assert len(db) == 1
    rec = next(iter(db.records()))
    assert rec.cost_std is not None  # fixed RuntimeCost carries confidence
