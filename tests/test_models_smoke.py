"""Per-architecture smoke tests (reduced configs, CPU): one forward + one
train step, shape and finiteness assertions; decode == full-forward exactness;
window ring-buffer correctness; MoE/aux behaviours.  Full configs are only
exercised via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import ExecConfig, Model
from repro.optim import AdamW
from repro.train import make_train_step

EC = ExecConfig(rec_chunk=4)

# tiny configs of these archs are still the suite's heaviest (recurrence /
# vision towers / enc-dec); they run in the full lane only
_HEAVY = {"recurrentgemma_2b", "llama_3_2_vision_11b", "seamless_m4t_large_v2"}
ARCH_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
    for a in configs.ARCH_IDS
]


def make_batch(cfg, B=2, S=12, seed=1, with_labels=False):
    rng = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, :-1]}
    if with_labels:
        batch["labels"] = tokens[:, 1:]
    else:
        batch["tokens"] = tokens[:, :S]
    if cfg.is_encdec:
        batch["frames"] = 0.1 * jax.random.normal(rng, (B, cfg.ctx_tokens, cfg.d_model))
    if cfg.family == "vlm":
        batch["ctx_embeds"] = 0.1 * jax.random.normal(rng, (B, cfg.ctx_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_forward_shapes_finite(arch):
    cfg = configs.get_tiny(arch)
    m = Model(cfg, EC)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    h, aux = m.forward(params, make_batch(cfg, B, S))
    assert h.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(h).all())
    logits = m.logits(params, h)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    if cfg.ffn == "moe":
        assert bool(jnp.isfinite(aux)) and float(aux) > 0.0


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_train_step_decreases_loss(arch):
    cfg = configs.get_tiny(arch)
    m = Model(cfg, EC)
    params = m.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    ost = opt.init(params)
    batch = make_batch(cfg, B=2, S=12, with_labels=True)
    step = jax.jit(make_train_step(m, opt))
    p, o, met = step(params, ost, batch)
    l0 = float(met["loss"])
    assert np.isfinite(l0)
    for _ in range(8):
        p, o, met = step(p, o, batch)
    assert float(met["loss"]) < l0


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_decode_matches_forward(arch):
    """Prefill + decode_step must reproduce the full-forward logits exactly
    (same compute path discipline across all 4 block kinds)."""
    cfg = configs.get_tiny(arch)
    m = Model(cfg, EC)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = make_batch(cfg, B, S)
    tokens = batch["tokens"]
    h, _ = m.forward(params, batch)
    want = m.logits(params, h)[:, -1]
    pb = dict(batch, tokens=tokens[:, : S - 1], max_len=S)
    _, states = m.prefill(params, pb)
    got, _ = m.decode_step(params, tokens[:, S - 1 : S], states, jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(want), np.asarray(got), atol=1e-3)


@pytest.mark.slow
def test_multi_step_decode_chain():
    cfg = configs.get_tiny("recurrentgemma_2b")  # covers ring buffer + rglru state
    m = Model(cfg, EC)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 24  # window = 8 << S
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    h, _ = m.forward(params, {"tokens": tokens})
    want = m.logits(params, h)[:, -1]
    _, states = m.prefill(params, {"tokens": tokens[:, : S - 4], "max_len": S})
    for i in range(S - 4, S):
        got, states = m.decode_step(params, tokens[:, i : i + 1], states, jnp.int32(i))
    np.testing.assert_allclose(np.asarray(want), np.asarray(got), atol=1e-3)


def test_rwkv_chunked_equals_scan():
    from repro.models.rwkv6 import wkv_chunked, wkv_scan_ref

    rng = jax.random.PRNGKey(0)
    B, T, H, hd = 2, 32, 3, 8
    ks = jax.random.split(rng, 6)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, hd)) for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, hd)))  # log decay <= 0
    u = jax.random.normal(ks[4], (H, hd))
    s0 = jax.random.normal(ks[5], (B, H, hd, hd))
    y1, s1 = wkv_scan_ref(r, k, v, lw, u, s0)
    for chunk in (4, 8, 16, 32):
        y2, s2 = wkv_chunked(r, k, v, lw, u, s0, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4, rtol=1e-4)


def test_rwkv_chunked_stability_strong_decay():
    """Strong decays (w -> 0) must not overflow the chunked form."""
    from repro.models.rwkv6 import wkv_chunked, wkv_scan_ref

    B, T, H, hd = 1, 64, 2, 8
    rng = jax.random.PRNGKey(3)
    ks = jax.random.split(rng, 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, hd)) for i in range(3))
    lw = jnp.full((B, T, H, hd), -12.0)  # near-total per-step decay
    u = jax.random.normal(ks[3], (H, hd))
    s0 = jnp.zeros((B, H, hd, hd))
    y1, _ = wkv_scan_ref(r, k, v, lw, u, s0)
    y2, _ = wkv_chunked(r, k, v, lw, u, s0, chunk=16)
    assert bool(jnp.isfinite(y2).all())
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)


def test_lru_scan_matches_ref():
    from repro.models.rglru import lru_scan, lru_scan_ref

    B, T, D = 3, 40, 16
    rng = jax.random.PRNGKey(1)
    a = jax.nn.sigmoid(jax.random.normal(rng, (B, T, D)))
    b = jax.random.normal(jax.random.fold_in(rng, 1), (B, T, D))
    h0 = jax.random.normal(jax.random.fold_in(rng, 2), (B, D))
    y1, h1 = lru_scan_ref(a, b, h0)
    y2, h2 = lru_scan(a, b, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5, rtol=1e-5)


def test_moe_capacity_drops_tokens():
    """Above-capacity tokens are dropped (train regime) but never in the
    decode regime (drop-free small-T path)."""
    cfg = configs.get_tiny("arctic_480b")
    from repro.models.moe import moe_apply, moe_init

    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    y, aux = moe_apply(cfg, p, x)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())


def test_param_count_sane():
    # spot check: llama3-405b analytic count is ~405B (±10%)
    cfg = configs.get("llama3_405b")
    n = cfg.param_count()
    assert 3.6e11 < n < 4.6e11, n
    # MoE active < total
    moe = configs.get("arctic_480b")
    assert moe.active_param_count() < moe.param_count()
    assert 3.9e11 < moe.param_count() < 5.6e11, moe.param_count()


def test_vocab_padding():
    cfg = configs.get("seamless_m4t_large_v2")
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab_size
