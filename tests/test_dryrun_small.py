"""Dry-run machinery tests (subprocess: fake devices, small meshes).

Covers: mesh construction, lower+compile for each model family and shape
kind on a reduced mesh, multi-pod lowering, and validation of the
while-loop cost-correction (probe method vs fully-unrolled ground truth).
"""
import json

import pytest

from helpers import run_py

pytestmark = [pytest.mark.slow, pytest.mark.multidevice]


def _dryrun(arch, shape, devices=16, mesh="4,4", extra=""):
    code = f"""
import os
os.environ["REPRO_DRYRUN_DEVICES"] = "{devices}"
import sys
sys.argv = ["dryrun", "--arch", "{arch}", "--shape", "{shape}", "--tiny",
            "--mesh", "{mesh}", "--out", "/tmp/dr_test.jsonl"] + {extra!r}.split()
import runpy
runpy.run_module("repro.launch.dryrun", run_name="__main__")
"""
    return run_py(code, devices=devices, timeout=900)


@pytest.mark.parametrize(
    "arch,shape",
    [
        ("qwen2_7b", "train_4k"),
        ("arctic_480b", "train_4k"),  # MoE dispatch collectives
        ("rwkv6_7b", "train_4k"),  # recurrence, no attention
        ("recurrentgemma_2b", "train_4k"),  # hybrid + window
        ("seamless_m4t_large_v2", "train_4k"),  # encoder-decoder
        ("llama_3_2_vision_11b", "train_4k"),  # cross-attn
        ("qwen2_7b", "prefill_32k"),
        ("qwen2_7b", "decode_32k"),
        ("rwkv6_7b", "long_500k"),
    ],
)
def test_dryrun_cell_compiles(arch, shape):
    out = _dryrun(arch, shape)
    assert "compile OK" in out
    assert "1 ok, 0 skipped, 0 errors" in out


def test_dryrun_multipod():
    out = _dryrun("qwen2_7b", "train_4k", devices=16, mesh="2,2,4")
    assert "compile OK" in out


def test_long500k_skipped_for_full_attention():
    """Full-attention archs skip long_500k with the documented reason —
    exercised on the real (non-tiny) config path via configs.cells()."""
    code = """
from repro import configs
cells = configs.cells(include_skips=True)
runnable = {(a, s): r for a, s, r in cells}
assert runnable[("rwkv6_7b", "long_500k")] is True
assert runnable[("recurrentgemma_2b", "long_500k")] is True
assert runnable[("qwen2_7b", "long_500k")] is False
assert runnable[("arctic_480b", "long_500k")] is False
assert sum(1 for (_, s), r in runnable.items() if s == "long_500k" and r) == 2
assert len(cells) == 40
print("OK")
"""
    assert "OK" in run_py(code, devices=1)


def test_probe_correction_matches_full_unroll():
    """The while-loop cost correction (stage probes) must agree with a
    fully-unrolled lowering of the same model (ground truth) within 2%."""
    code = """
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import jax, jax.numpy as jnp
from repro import configs
from repro.models import Model, ExecConfig
from repro.launch.mesh import make_mesh, default_rules
from repro.launch import costing
from repro.parallel.api import sharding_context
from repro.parallel.sharding import tree_shardings, param_wanted, batch_wanted
from repro.train import make_train_step
from repro.optim import AdamW
import dataclasses

cfg = dataclasses.replace(configs.get_tiny("qwen2_7b"), n_layers=6, n_groups=6)
mesh = make_mesh((2, 4), ("data", "model"))
rules = default_rules(mesh)
B, S = 8, 64

def lower_cost(scan_unroll):
    ec = ExecConfig(scan_layers=True, scan_unroll=scan_unroll, remat="full", rec_unroll=True)
    model = Model(cfg, ec)
    opt = AdamW(lr=1e-3)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_s = jax.eval_shape(opt.init, params)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    fn = make_train_step(model, opt)
    p_sh = tree_shardings(mesh, rules, params, param_wanted)
    o_sh = tree_shardings(mesh, rules, opt_s, lambda p, n: param_wanted(p[2:], n) if p[0] in "mv" else ())
    b_sh = tree_shardings(mesh, rules, batch, lambda p, n: batch_wanted(p.split("/")[-1], n))
    with sharding_context(mesh, rules):
        c = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh)).lower(params, opt_s, batch).compile()
        step = costing.measure(c)
        if scan_unroll == 1:
            model2 = Model(cfg, ec)
            probe = costing.stage_probe(model2, 0, mesh, rules, B=B, S=S, mode="train", train=True)
            return costing.corrected_cost(model2, step, {0: probe})
        return step

corrected = lower_cost(1)
truth = lower_cost(6)   # full unroll: every layer in the HLO
rel_f = abs(corrected.flops - truth.flops) / truth.flops
rel_c = abs(corrected.coll_bytes - truth.coll_bytes) / max(truth.coll_bytes, 1)
print(f"flops corrected={corrected.flops:.3e} truth={truth.flops:.3e} rel={rel_f:.4f}")
print(f"coll  corrected={corrected.coll_bytes:.3e} truth={truth.coll_bytes:.3e} rel={rel_c:.4f}")
# probe method documented accuracy is 10%; jax 0.4.x HLO cost analysis
# attributes scan overheads differently, so grant it a wider band there
tol_f = 0.10 if tuple(int(x) for x in jax.__version__.split(".")[:2]) >= (0, 5) else 0.20
assert rel_f < tol_f, rel_f
assert rel_c < 0.25, rel_c  # collectives: probe double-counts some FSDP gathers
print("OK")
"""
    out = run_py(code, devices=8, timeout=900)
    assert "OK" in out
