"""Checkpoint store: atomicity, integrity, keep-k, async, resume."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.store import latest_step


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (16, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    t = tree()
    save_checkpoint(str(tmp_path), 7, t, extra={"note": "x"})
    loaded, step, extra = load_checkpoint(str(tmp_path), t)
    assert step == 7 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_keep_k(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, tree(s))
    assert m.latest_step() == 4
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_0000000003", "step_0000000004"]


def test_corruption_detected(tmp_path):
    t = tree()
    d = save_checkpoint(str(tmp_path), 1, t)
    # flip a byte in the first array file
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    f = os.path.join(d, next(iter(manifest["arrays"].values()))["file"])
    data = bytearray(open(f, "rb").read())
    data[-1] ^= 0xFF
    open(f, "wb").write(bytes(data))
    with pytest.raises(IOError, match="checksum"):
        load_checkpoint(str(tmp_path), t)


def test_incomplete_save_ignored(tmp_path):
    """A tmp dir (crash mid-save) must not be visible as a checkpoint."""
    t = tree()
    save_checkpoint(str(tmp_path), 1, t)
    os.makedirs(tmp_path / "tmp.2")  # simulated crash leftovers
    os.makedirs(tmp_path / "step_0000000003")  # no manifest -> incomplete
    assert latest_step(str(tmp_path)) == 1


def test_async_save(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save_async(5, tree(5))
    m.wait()
    assert m.latest_step() == 5
    loaded, step, _ = m.restore(tree(0))
    assert step == 5
    np.testing.assert_array_equal(
        np.asarray(loaded["a"]), np.asarray(tree(5)["a"])
    )


def test_dtype_cast_on_restore(tmp_path):
    t = {"w": jnp.ones((4,), jnp.float32)}
    save_checkpoint(str(tmp_path), 0, t)
    like = {"w": jnp.zeros((4,), jnp.bfloat16)}
    loaded, _, _ = load_checkpoint(str(tmp_path), like)
    assert loaded["w"].dtype == jnp.bfloat16
