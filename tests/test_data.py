"""Data pipeline: determinism, resume purity, shape/feature contracts."""
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import SyntheticLM, make_batch_for


def test_batches_deterministic():
    a = SyntheticLM(1024, 32, 4, seed=7).batch(13)
    b = SyntheticLM(1024, 32, 4, seed=7).batch(13)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    np.testing.assert_array_equal(np.asarray(a["labels"]), np.asarray(b["labels"]))


def test_batches_differ_across_steps_and_seeds():
    d = SyntheticLM(1024, 32, 4, seed=0)
    assert not np.array_equal(np.asarray(d.batch(0)["tokens"]), np.asarray(d.batch(1)["tokens"]))
    d2 = SyntheticLM(1024, 32, 4, seed=1)
    assert not np.array_equal(np.asarray(d.batch(0)["tokens"]), np.asarray(d2.batch(0)["tokens"]))


def test_labels_are_shifted_tokens():
    b = SyntheticLM(512, 16, 2, seed=3).batch(0)
    # labels[t] is the next token of tokens[t] in the underlying stream
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)
    np.testing.assert_array_equal(
        np.asarray(b["tokens"][:, 1:]), np.asarray(b["labels"][:, :-1])
    )


def test_tokens_in_vocab():
    b = SyntheticLM(100, 64, 8, seed=0).batch(5)
    assert int(b["tokens"].max()) < 100 and int(b["tokens"].min()) >= 0


def test_make_batch_for_families():
    for arch, extra in [
        ("seamless_m4t_large_v2", "frames"),
        ("llama_3_2_vision_11b", "ctx_embeds"),
        ("qwen2_7b", None),
    ]:
        cfg = configs.get_tiny(arch)
        b = make_batch_for(cfg, 2, 16, step=1, seed=0)
        assert b["tokens"].shape == (2, 16)
        if extra:
            assert b[extra].shape == (2, cfg.ctx_tokens, cfg.d_model)


def test_iterator_protocol():
    it = iter(SyntheticLM(64, 8, 2, seed=0))
    b0, b1 = next(it), next(it)
    assert not np.array_equal(np.asarray(b0["tokens"]), np.asarray(b1["tokens"]))
