"""SearchSpace codec tests (incl. hypothesis round-trip properties)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ChoiceDim, FloatDim, IntDim, LogIntDim, SearchSpace


def test_uniform_matches_paper_ctor():
    sp = SearchSpace.uniform(1, 512, dim=2, integer=True)
    lo = sp.decode(np.array([-1.0, -1.0]))
    hi = sp.decode(np.array([1.0, 1.0]))
    assert lo == {"p0": 1, "p1": 1}
    assert hi == {"p0": 512, "p1": 512}


def test_uniform_per_dim_bounds():
    sp = SearchSpace.uniform([1, 10], [4, 20], dim=2)
    assert sp.decode(np.array([-1, -1.0])) == {"p0": 1, "p1": 10}
    assert sp.decode(np.array([1, 1.0])) == {"p0": 4, "p1": 20}


def test_logint_grid():
    d = LogIntDim("blk", 16, 512)
    vals = {d.decode(z) for z in np.linspace(-1, 1, 101)}
    assert vals == {16, 32, 64, 128, 256, 512}


def test_choice_dim():
    d = ChoiceDim("policy", ("none", "dots", "full"))
    assert d.decode(-1.0) == "none"
    assert d.decode(0.0) == "dots"
    assert d.decode(1.0) == "full"


def test_duplicate_names_rejected():
    with pytest.raises(ValueError):
        SearchSpace([IntDim("a", 0, 1), IntDim("a", 0, 1)])


def test_empty_space_rejected():
    with pytest.raises(ValueError):
        SearchSpace([])


def test_dim_mismatch_rejected():
    sp = SearchSpace([IntDim("a", 0, 3)])
    with pytest.raises(ValueError):
        sp.decode(np.zeros(2))


@settings(max_examples=100, deadline=None)
@given(z=st.lists(st.floats(-1.0, 1.0), min_size=4, max_size=4))
def test_property_decode_encode_fixpoint(z):
    """decode -> encode -> decode is a fixpoint (idempotent codec)."""
    sp = SearchSpace(
        [
            IntDim("a", -5, 17),
            FloatDim("b", 0.0, 2.5),
            LogIntDim("c", 8, 1024),
            ChoiceDim("d", ("x", "y", "z", "w")),
        ]
    )
    v1 = sp.decode(np.array(z))
    v2 = sp.decode(sp.encode(v1))
    assert v1["a"] == v2["a"]
    assert v1["c"] == v2["c"]
    assert v1["d"] == v2["d"]
    assert abs(v1["b"] - v2["b"]) < 1e-9


@settings(max_examples=100, deadline=None)
@given(
    z=st.floats(-1.0, 1.0),
    lo=st.integers(-100, 50),
    width=st.integers(0, 200),
)
def test_property_int_in_bounds(z, lo, width):
    d = IntDim("a", lo, lo + width)
    v = d.decode(z)
    assert lo <= v <= lo + width
    assert isinstance(v, int)


@settings(max_examples=50, deadline=None)
@given(z=st.floats(-1.0, 1.0), k=st.integers(0, 6))
def test_property_logint_power_of_two(z, k):
    d = LogIntDim("a", 8, 8 * 2**k)
    v = d.decode(z)
    assert v % 8 == 0 and (v // 8) & (v // 8 - 1) == 0  # 8 * power of two
    assert 8 <= v <= 8 * 2**k


def test_key_hashable_and_stable():
    sp = SearchSpace([IntDim("a", 0, 9), ChoiceDim("b", ("u", "v"))])
    p = sp.decode(np.array([0.3, -1.0]))
    assert sp.key(p) == sp.key(dict(reversed(list(p.items()))))
    assert hash(sp.key(p)) is not None
