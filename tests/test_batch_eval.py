"""Batch evaluation pipeline tests: ask/tell ⇔ run parity, batch dedup,
the executable cache, and tune_call's concurrent compile path."""
import threading
import time

import numpy as np
import pytest

from repro.core import (
    CSA,
    Autotuning,
    ExecutableCache,
    GridSearch,
    NelderMead,
    RandomSearch,
    compile_fanout,
)


# ---------------------------------------------------------------- cost fns
def sphere(z):
    return float(np.sum(z**2))


def shifted_abs(z):
    return float(np.sum(np.abs(z - 0.25)))


def cliff(z):
    """Half the domain 'crashes' (inf cost) — exercises the nonfinite path."""
    return np.inf if z[0] > 0.3 else float(np.sum((z + 0.2) ** 2))


def rastrigin(z):
    x = z * 2.0
    return float(10 * x.size + np.sum(x**2 - 10 * np.cos(2 * np.pi * x)))


# ----------------------------------------------------------- parity helpers
def drive_run(opt, fn):
    """Sequential staging; returns the emitted candidate list."""
    z = opt.run(np.nan)
    pts = []
    while not opt.is_end():
        pts.append(z.copy())
        z = opt.run(fn(z))
    return pts


def drive_ask_tell(opt, fn):
    """Batch staging; returns the emitted candidate list (flattened)."""
    pts = []
    guard = 0
    while True:
        batch = opt.ask()
        if not batch:
            break
        pts.extend(p.copy() for p in batch)
        opt.tell([fn(z) for z in batch])
        guard += 1
        assert guard < 100_000
    return pts


def assert_same_trajectory(make_opt, fn):
    a, b = make_opt(), make_opt()
    pts_a = drive_run(a, fn)
    pts_b = drive_ask_tell(b, fn)
    assert len(pts_a) == len(pts_b)
    assert all(np.array_equal(x, y) for x, y in zip(pts_a, pts_b))
    assert a.best_cost == b.best_cost
    assert np.array_equal(a.best_solution, b.best_solution)
    assert a.is_end() and b.is_end()
    return pts_a


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("fn", [sphere, cliff], ids=["sphere", "cliff"])
@pytest.mark.parametrize("seed", [0, 3, 11])
def test_csa_ask_tell_matches_run(fn, seed):
    pts = assert_same_trajectory(
        lambda: CSA(dim=2, num_opt=3, max_iter=8, seed=seed), fn
    )
    assert len(pts) == 3 * 8  # paper Eq. 1 (ignore applied by the driver)


def test_csa_ask_is_idempotent_and_batched_by_round():
    opt = CSA(dim=2, num_opt=4, max_iter=5, seed=1)
    b1 = opt.ask()
    b2 = opt.ask()
    assert len(b1) == 4  # the full INIT population in one round
    assert all(np.array_equal(x, y) for x, y in zip(b1, b2))
    opt.tell([sphere(z) for z in b1])
    b3 = opt.ask()
    assert len(b3) == 4  # m probes per CSA iteration
    assert not all(np.array_equal(x, y) for x, y in zip(b1, b3))


def test_tell_validates():
    opt = CSA(dim=1, num_opt=2, max_iter=3, seed=0)
    with pytest.raises(RuntimeError):
        opt.tell([1.0, 2.0])  # no batch asked yet
    batch = opt.ask()
    with pytest.raises(ValueError):
        opt.tell([1.0] * (len(batch) + 1))
    opt.tell([1.0] * len(batch))  # still consumable after the failed tell


@pytest.mark.parametrize(
    "fn", [sphere, shifted_abs, cliff, rastrigin],
    ids=["sphere", "abs", "cliff", "rastrigin"],
)
@pytest.mark.parametrize("seed", [0, 7])
def test_nm_ask_tell_matches_run(fn, seed):
    assert_same_trajectory(
        lambda: NelderMead(dim=3, error=0.0, max_iter=40, seed=seed), fn
    )


def test_nm_ask_tell_matches_run_error_stop():
    assert_same_trajectory(
        lambda: NelderMead(dim=2, error=1e-3, max_iter=0, seed=2), sphere
    )


def test_nm_budget_truncates_batches():
    """max_iter smaller than the simplex: only max_iter candidates emitted."""
    for cap in (2, 3, 5):
        opt = NelderMead(dim=3, error=0.0, max_iter=cap, seed=0)
        pts = drive_ask_tell(opt, sphere)
        assert len(pts) == cap
        assert opt.evaluations == cap
        # sequential agrees
        opt2 = NelderMead(dim=3, error=0.0, max_iter=cap, seed=0)
        assert len(drive_run(opt2, sphere)) == cap


@pytest.mark.parametrize("fn", [sphere, shifted_abs, rastrigin],
                         ids=["sphere", "abs", "rastrigin"])
def test_nm_speculative_same_outcome(fn):
    """Speculative batches measure extra points but consume identical costs:
    same best, same consumed-eval budget, same simplex trajectory."""
    plain = NelderMead(dim=2, error=0.0, max_iter=30, seed=4)
    spec = NelderMead(dim=2, error=0.0, max_iter=30, seed=4, speculative=True)
    pts_plain = drive_ask_tell(plain, fn)
    pts_spec = drive_ask_tell(spec, fn)
    assert spec.speculative
    assert plain.best_cost == spec.best_cost
    assert np.array_equal(plain.best_solution, spec.best_solution)
    assert plain.evaluations == spec.evaluations  # budget counts consumed only
    assert len(pts_spec) >= len(pts_plain)  # extras are the overlap fuel
    # the consumed (sequential) candidates are a subsequence of the asked ones
    keys = {tuple(np.round(p, 12)) for p in pts_spec}
    assert all(tuple(np.round(p, 12)) in keys for p in pts_plain)


def test_grid_and_random_ask_tell_match_run():
    assert_same_trajectory(lambda: GridSearch(2, points_per_dim=4), sphere)
    assert_same_trajectory(lambda: RandomSearch(2, max_iter=17, seed=3), sphere)


def test_grid_asks_whole_sweep():
    opt = GridSearch(1, points_per_dim=9)
    assert len(opt.ask()) == 9


# -------------------------------------------------------- Autotuning driver
def _cost1d(p):
    return (p - 9) ** 2 * 0.25 + 1.0


@pytest.mark.parametrize("ignore", [0, 2])
def test_entire_exec_batch_matches_sequential(ignore):
    a = Autotuning(1, 32, ignore=ignore, dim=1, num_opt=4, max_iter=12, seed=5,
                   cache=True)
    a.entire_exec(_cost1d)

    b = Autotuning(1, 32, ignore=ignore, dim=1, num_opt=4, max_iter=12, seed=5,
                   cache=True)
    calls = []

    def measure_batch(points):
        calls.append([dict(p) for p in points])
        return [_cost1d(p["p0"]) for p in points]

    b.entire_exec_batch(measure_batch)

    assert a.history == b.history
    assert a.best_point == b.best_point
    assert a.point == b.point
    assert a.num_evals == b.num_evals
    assert a.num_measurements == b.num_measurements
    assert b.finished
    # each batch call carried only deduplicated, not-yet-cached points
    # (with ignore=k the same batch repeats k+1 times for stabilization)
    seen = set()
    prev = None
    for batch in calls:
        keys = [p["p0"] for p in batch]
        assert len(keys) == len(set(keys))  # no dupes within a round
        if keys == prev:
            continue  # stabilization repeat of the same round
        assert not (set(keys) & seen)  # no re-measurement across rounds
        seen |= set(keys)
        prev = keys


def test_entire_exec_batch_dedups_within_round():
    """A tiny space forces duplicate decoded points inside one CSA round —
    they must be measured once."""
    measured = []

    def measure_batch(points):
        measured.append(len(points))
        return [float(p["p0"]) for p in points]

    at = Autotuning(0, 1, ignore=0, dim=1, num_opt=6, max_iter=4, seed=0,
                    cache=True)
    at.entire_exec_batch(measure_batch)
    assert at.finished
    # the whole search sees only 2 decodable points: measured at most twice
    assert sum(measured) <= 2
    assert at.num_evals == 6 * 4  # the optimizer still got every cost


def test_entire_exec_batch_without_cache_dedups_round_only():
    counts = {}

    def measure_batch(points):
        for p in points:
            counts[p["p0"]] = counts.get(p["p0"], 0) + 1
        return [float(p["p0"] == 0) for p in points]

    at = Autotuning(0, 1, ignore=0, dim=1, num_opt=5, max_iter=3, seed=1,
                    cache=False)
    at.entire_exec_batch(measure_batch)
    # within a round each point once; across rounds re-measured (cache off)
    assert max(counts.values()) <= 3  # bounded by number of rounds


def test_entire_exec_batch_ignore_counts_measurements():
    at = Autotuning(1, 8, ignore=2, dim=1, num_opt=3, max_iter=4, seed=0,
                    cache=True)
    calls = {"n": 0}

    def measure_batch(points):
        calls["n"] += 1
        return [_cost1d(p["p0"]) for p in points]

    at.entire_exec_batch(measure_batch)
    assert at.finished
    # stabilization rounds: each measuring round ran (ignore + 1) times
    assert calls["n"] % 3 == 0


def test_num_crashed_counts_distinct_inf_points():
    def measure_batch(points):
        return [np.inf if p["p0"] > 4 else float(p["p0"]) for p in points]

    at = Autotuning(1, 8, ignore=0, dim=1, num_opt=4, max_iter=6, seed=2,
                    cache=True)
    at.entire_exec_batch(measure_batch)
    visited = {p["p0"] for p, _ in at.history}
    assert at.num_crashed == sum(1 for v in visited if v > 4)
    assert at.best_point["p0"] <= 4


# --------------------------------------------------------- executable cache
def test_executable_cache_hits_and_failures():
    cache = ExecutableCache(maxsize=8)
    builds = {"n": 0}

    def build_ok():
        builds["n"] += 1
        return "exe"

    def build_bad():
        raise ValueError("tile does not divide shape")

    assert cache.get_or_build("a", build_ok) == "exe"
    assert cache.get_or_build("a", build_ok) == "exe"
    assert builds["n"] == 1
    err = cache.get_or_build("bad", build_bad)
    assert isinstance(err, ValueError)
    # the failure is cached too: no rebuild on revisit
    assert cache.get_or_build("bad", lambda: "never") is err
    s = cache.stats()
    assert s["hits"] == 2 and s["misses"] == 2 and s["recompiles"] == 0


def test_executable_cache_recompile_accounting_after_eviction():
    cache = ExecutableCache(maxsize=2)
    for k in ("a", "b", "c"):  # evicts "a"
        cache.get_or_build(k, lambda k=k: k)
    assert cache.stats()["evictions"] == 1
    cache.get_or_build("a", lambda: "a2")  # rebuilt → recompile
    assert cache.stats()["recompiles"] == 1


def test_executable_cache_concurrent_single_build():
    cache = ExecutableCache()
    builds = {"n": 0}
    lock = threading.Lock()

    def slow_build():
        with lock:
            builds["n"] += 1
        time.sleep(0.05)
        return object()

    results = []

    def worker():
        results.append(cache.get_or_build("k", slow_build))

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert builds["n"] == 1
    assert all(r is results[0] for r in results)
    assert cache.stats()["hits"] == 7


def test_executable_cache_failure_predicate_skips_transient():
    calls = {"n": 0}

    def build_transient():
        calls["n"] += 1
        raise RuntimeError("RESOURCE_EXHAUSTED: compile ran out of memory")

    cache = ExecutableCache(
        maxsize=8,
        cache_failures=lambda e: "resource_exhausted" not in str(e).lower(),
    )
    err = cache.get_or_build("t", build_transient)
    assert isinstance(err, RuntimeError)
    err2 = cache.get_or_build("t", build_transient)  # retried, not replayed
    assert isinstance(err2, RuntimeError) and err2 is not err
    assert calls["n"] == 2
    # an intentional retry is a plain miss, not a recompile
    assert cache.stats()["recompiles"] == 0

    def build_deterministic():
        raise ValueError("tile does not divide shape")

    det = cache.get_or_build("d", build_deterministic)
    assert cache.get_or_build("d", lambda: "never") is det  # cached


def test_executable_cache_base_exception_not_cached():
    """A KeyboardInterrupt mid-compile must not poison the key."""
    cache = ExecutableCache(maxsize=8)

    def interrupt():
        raise KeyboardInterrupt

    with pytest.raises(KeyboardInterrupt):
        cache.get_or_build("k", interrupt)
    assert cache.get_or_build("k", lambda: "exe") == "exe"  # rebuilt
    assert cache.stats()["recompiles"] == 0


def test_partial_round_best_visible_to_driver():
    """The run() adapter buffers costs until a full ask/tell round completes;
    a driver that stops mid-round (a short serving stream) must still see the
    best of the costs it already delivered."""
    at = Autotuning(1, 8, ignore=0, dim=1, num_opt=4, max_iter=8, seed=0)
    first = at.point
    at.exec(1.25)  # one cost into a 4-probe CSA round
    assert at.best_cost == 1.25
    assert at.best_point == first


def test_compile_fanout_preserves_order():
    cache = ExecutableCache()
    items = [(i, lambda i=i: i * 10) for i in range(20)]
    out = compile_fanout(items, cache=cache, jobs=4)
    assert out == [i * 10 for i in range(20)]
    # duplicate keys share one build
    out2 = compile_fanout([(0, lambda: "other")], cache=cache, jobs=2)
    assert out2 == [0]


# ------------------------------------------------- tune_call (kernels layer)
@pytest.fixture
def probe_kernel():
    """A registered kernel whose output deterministically encodes its knobs
    (so costs are noise-free) with optional failure modes."""
    import jax.numpy as jnp

    from repro.core import ChoiceDim, SearchSpace
    from repro.kernels.autotuned import _REGISTRY, KernelSpec, register

    def fn(x, *, mode, interpret=False):
        if mode == 91:
            raise ValueError("tile 91 does not evenly divide shape")  # expected
        if mode in (92, 93):
            raise RuntimeError("boom: unexpected bug")  # unexpected
        return x.sum() * 0.0 + (1.0 + mode)

    name = "_batch_eval_probe"
    register(
        KernelSpec(
            name=name,
            fn=fn,
            space=lambda x: SearchSpace([ChoiceDim("mode", (0, 1, 2, 91, 92, 93))]),
            defaults=lambda x: {"mode": 0},
        )
    )
    yield name
    _REGISTRY.pop(name, None)


def det_cost(ex, *args):
    return float(np.asarray(ex(*args)))


def test_tune_call_batched_matches_sequential_record(probe_kernel):
    """Concurrency smoke: jobs=4 and jobs=1 commit the same DB record as the
    sequential reference driver for a deterministic cost."""
    import jax
    import jax.numpy as jnp

    from repro.core import CSA, RuntimeCost  # noqa: F401
    from repro.kernels.autotuned import exec_cache, get_spec, tune_call
    from repro.tuning import TuningDB, make_key

    x = jnp.ones((4, 4))

    # sequential reference: per-candidate jit dispatch through entire_exec
    spec = get_spec(probe_kernel)
    space = spec.space(x)
    key = make_key(probe_kernel, args=(x,), space=space, extra={"interpret": True})
    db_s = TuningDB(None)

    def measure(*knob_values):
        knobs = dict(zip(space.names, knob_values))
        try:
            fn = jax.jit(lambda *xs: spec.fn(*xs, **knobs, interpret=True))
            return det_cost(fn, x)
        except Exception:
            return np.inf

    at = Autotuning(space=space, ignore=0,
                    optimizer=CSA(1, num_opt=4, max_iter=4, seed=0),
                    cache=True, db=db_s, key=key)
    at.entire_exec(measure)
    at.commit()
    rec_s = db_s.get(key)

    exec_cache().clear()
    recs = {}
    for jobs in (1, 4):
        db = TuningDB(None)
        recs[jobs] = tune_call(probe_kernel, x, db=db, interpret=True,
                               num_opt=4, max_iter=4, seed=0, jobs=jobs,
                               cost_fn=det_cost)
    assert rec_s is not None
    for jobs, rec in recs.items():
        assert rec is not None, f"jobs={jobs}"
        assert rec.point == rec_s.point
        assert rec.cost == rec_s.cost
        assert rec.evals == rec_s.evals
    assert recs[1].crashed == recs[4].crashed == rec_s.crashed


def test_tune_call_classifies_and_logs_failures_once(probe_kernel, capsys):
    import jax.numpy as jnp

    from repro.kernels.autotuned import exec_cache, tune_call
    from repro.tuning import TuningDB

    exec_cache().clear()
    x = jnp.ones((4, 4))
    # wide search: visits every mode incl. both crash flavors
    rec = tune_call(probe_kernel, x, db=TuningDB(None), interpret=True,
                    num_opt=6, max_iter=6, seed=0, jobs=2, cost_fn=det_cost)
    err = capsys.readouterr().err
    assert rec is not None
    assert rec.point == {"mode": 0}  # lowest deterministic cost
    # the unexpected error is logged exactly once per search (modes 92 and 93
    # share one signature), the expected illegal-tile failure not at all
    assert err.count("boom: unexpected bug") <= 1
    assert "does not evenly divide" not in err
    assert rec.crashed >= 1


def test_classify_failure_programmer_errors_never_illegal():
    """Knob names ('block_q', 'tile'...) appear in TypeError messages about
    bad signatures — those are real bugs, not illegal-tile candidates."""
    from repro.kernels.autotuned import _failure_is_deterministic, classify_failure

    bad_kwarg = TypeError("got an unexpected keyword argument 'block_q'")
    assert classify_failure(bad_kwarg) == "unexpected"
    assert classify_failure(AttributeError("module has no attribute 'tile'")) == "unexpected"
    illegal = ValueError("block size does not evenly divide the shape")
    assert classify_failure(illegal) == "illegal"
    # deterministic illegal failures cache; resource exhaustion does not
    assert _failure_is_deterministic(illegal)
    assert not _failure_is_deterministic(RuntimeError("RESOURCE_EXHAUSTED: oom"))
    assert not _failure_is_deterministic(bad_kwarg)


def test_tuning_record_crashed_roundtrip():
    from repro.tuning import TuningDB, make_key
    from repro.tuning.records import TuningRecord

    key = make_key("k", extra={"x": 1})
    rec = TuningRecord(key=key, point={"a": 1}, cost=0.5, evals=3, crashed=2)
    back = TuningRecord.from_json(rec.to_json())
    assert back.crashed == 2
    # old records (no field) default to 0
    blob = rec.to_json()
    del blob["crashed"]
    assert TuningRecord.from_json(blob).crashed == 0


def test_exec_cache_zero_recompiles_across_searches(probe_kernel):
    """Re-tuning the same context (fresh DB) revisits candidates: every
    executable must come from the cache, zero recompiles."""
    import jax.numpy as jnp

    from repro.kernels.autotuned import exec_cache, tune_call
    from repro.tuning import TuningDB

    cache = exec_cache()
    cache.clear()
    x = jnp.ones((4, 4))
    tune_call(probe_kernel, x, db=TuningDB(None), interpret=True,
              num_opt=4, max_iter=3, seed=0, jobs=2, cost_fn=det_cost)
    first = cache.stats()
    tune_call(probe_kernel, x, db=TuningDB(None), interpret=True,
              num_opt=4, max_iter=3, seed=0, jobs=2, cost_fn=det_cost)
    second = cache.stats()
    assert second["recompiles"] == first["recompiles"] == 0
    assert second["misses"] == first["misses"]  # nothing new compiled
    assert second["hits"] > first["hits"]
