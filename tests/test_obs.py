"""The observability layer: spans, metrics, events, snapshots, report.

The load-bearing guarantees tested here:

* span nesting survives thread pools (``compile_fanout`` workers and
  ``ShardedPortfolio`` members attach to the submitting thread's span — no
  orphan or crossed spans) and the export is valid Chrome-trace JSON;
* the event stream accounts for **every** candidate of a ``tune_call`` run
  exactly once (committed + culled + pruned + skipped + quarantined =
  asked);
* the sink shares the run journal's durability discipline (a torn trailing
  line never poisons the readable prefix);
* ``Quarantine``/``CircuitBreaker``/``OnlineTuner`` expose cheap
  ``snapshot()`` views so denials and strikes are visible *between* summary
  dumps;
* ``repro.tune report`` renders the artifacts and exits nonzero on broken
  accounting.
"""
from __future__ import annotations

import json
import os
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro import obs
from repro.obs import events as obs_events
from repro.obs import metrics as obs_metrics
from repro.obs.trace import Tracer


@pytest.fixture
def obs_dir(tmp_path):
    """Obs enabled into a temp dir; global state restored afterwards."""
    d = tmp_path / "obs"
    obs.configure(str(d))
    obs_metrics.registry().reset()
    try:
        yield str(d)
    finally:
        obs.shutdown()
        obs_metrics.registry().reset()


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts and ends with observability disabled."""
    obs.shutdown()
    yield
    obs.shutdown()


# ------------------------------------------------------------------- tracing
def test_span_nesting_and_chrome_export(tmp_path):
    t = Tracer()
    t.enable()
    with t.span("search", ctx="k"):
        with t.span("round", round=1):
            with t.span("compile"):
                pass
            with t.span("measure", candidates=3):
                pass
    path = str(tmp_path / "trace.json")
    n = t.export_chrome(path)
    assert n == 4
    blob = json.loads(open(path).read())
    xs = [e for e in blob["traceEvents"] if e["ph"] == "X"]
    by_name = {e["name"]: e for e in xs}
    assert set(by_name) == {"search", "round", "compile", "measure"}
    assert by_name["search"]["args"].get("parent_id") is None
    assert by_name["round"]["args"]["parent_id"] == by_name["search"]["args"]["span_id"]
    for leaf in ("compile", "measure"):
        assert by_name[leaf]["args"]["parent_id"] == by_name["round"]["args"]["span_id"]
    # every span's interval nests inside its parent's
    spans = {e["args"]["span_id"]: e for e in xs}
    for e in xs:
        pid = e["args"].get("parent_id")
        if pid is not None:
            p = spans[pid]
            assert p["ts"] <= e["ts"]
            assert e["ts"] + e["dur"] <= p["ts"] + p["dur"] + 1  # µs rounding


def test_wrap_attaches_pool_workers_to_submitting_span():
    t = Tracer()
    t.enable()
    with ThreadPoolExecutor(max_workers=4) as pool:
        with t.span("round", round=1):
            work = t.wrap(lambda i: i * i, "compile")
            futs = [pool.submit(work, i) for i in range(8)]
            assert [f.result() for f in futs] == [i * i for i in range(8)]
    spans = t.finished()
    round_span = next(s for s in spans if s.name == "round")
    compiles = [s for s in spans if s.name == "compile"]
    assert len(compiles) == 8
    # no orphans, no crossed parents: every worker span hangs off the round
    assert all(s.parent_id == round_span.span_id for s in compiles)


def test_compile_fanout_pool_spans_nest_under_round():
    from repro.core.costs import ExecutableCache, compile_fanout
    from repro.obs.trace import tracer

    t = tracer()
    t.reset()
    t.enable()
    try:
        cache = ExecutableCache()
        items = [((i,), (lambda i=i: i * 10)) for i in range(6)]
        with t.span("round", round=1):
            out = compile_fanout(items, cache=cache, jobs=3)
        assert out == [i * 10 for i in range(6)]
        spans = t.finished()
        round_span = next(s for s in spans if s.name == "round")
        compiles = [s for s in spans if s.name == "compile"]
        assert len(compiles) == 6
        assert all(s.parent_id == round_span.span_id for s in compiles)
        # worker spans ran on pool threads yet none leaked onto a stack
        assert t.current() is None
    finally:
        t.disable()
        t.reset()


def test_sharded_portfolio_member_turns_attach_to_parent_span():
    from repro.core.csa import CSA
    from repro.obs.trace import tracer
    from repro.tuning.fleet import ShardedPortfolio

    t = tracer()
    t.reset()
    t.enable()
    try:
        fleet = ShardedPortfolio(
            [CSA(2, num_opt=2, max_iter=3, seed=0),
             CSA(2, num_opt=2, max_iter=3, seed=1)],
            budget=24, rung=2,
        )
        with t.span("search", ctx="fleet"):
            fleet.run(lambda i, pts: [float(np.sum(p * p)) for p in pts],
                      max_workers=2)
        spans = t.finished()
        search = next(s for s in spans if s.name == "search")
        turns = [s for s in spans if s.name == "member_turn"]
        assert turns, "fleet run produced no member_turn spans"
        assert all(s.parent_id == search.span_id for s in turns)
        members = {s.args.get("member") for s in turns}
        assert members == {0, 1}
    finally:
        t.disable()
        t.reset()


def test_disabled_tracer_is_null_and_threadsafe():
    t = Tracer()
    assert not t.enabled
    s = t.span("anything")
    with s:
        assert t.current() is None
    assert t.wrap(abs, "compile") is abs
    assert t.finished() == []


# ------------------------------------------------------------------- metrics
def test_metrics_primitives_and_registry():
    r = obs_metrics.MetricsRegistry()
    c = r.counter("a.b")
    c.inc()
    c.inc(4)
    assert r.counter("a.b") is c and c.value == 5
    g = r.gauge("g")
    g.set(7)
    g.dec(2)
    assert g.value == 5
    h = r.histogram("h")
    for x in (1e-5, 2e-3, 0.5, 2.0):
        h.observe(x)
    snap = r.snapshot()
    assert snap["a.b"] == 5 and snap["g"] == 5
    assert snap["h"]["count"] == 4
    assert abs(snap["h"]["sum"] - (1e-5 + 2e-3 + 0.5 + 2.0)) < 1e-12
    with pytest.raises(TypeError):
        r.gauge("a.b")  # type clash must not silently shadow


def test_mirrored_stats_mirror_growth_only():
    obs_metrics.registry().reset()
    s = obs_metrics.MirroredStats("t", {"n": 0, "mode": "x"})
    s["n"] += 3
    s["n"] += 2
    s["mode"] = "adaptive"  # non-numeric: dict-only
    s["n"] = 0  # reset: not mirrored (counters are monotonic)
    assert obs_metrics.counter("t.n").value == 5
    assert s["n"] == 0 and s["mode"] == "adaptive"
    obs_metrics.registry().reset()


def test_existing_stats_are_backed_by_metrics():
    """The cache/breaker counters are the metric primitives themselves, not
    parallel ints (the 're-implemented on top' contract)."""
    from repro.core.costs import ExecutableCache
    from repro.core.guard import CircuitBreaker

    cache = ExecutableCache()
    assert isinstance(cache.hits, obs_metrics.Counter)
    cache.get_or_build("k", lambda: 1)
    cache.get_or_build("k", lambda: 1)
    assert cache.stats()["hits"] == 1 and cache.stats()["misses"] == 1
    br = CircuitBreaker(threshold=1, cooldown=2)
    assert isinstance(br.denied, obs_metrics.Counter)


# -------------------------------------------------------------------- events
def test_event_sink_fsync_roundtrip_and_torn_line(tmp_path):
    path = str(tmp_path / "events.jsonl")
    sink = obs_events.EventSink(path)
    sink.emit("search_start", name="k")
    sink.emit("candidate_asked", name="k", point={"t": 1}, round=1)
    sink.emit("candidate_committed", name="k", point={"t": 1}, cost=0.5)
    assert sink.emitted == 3
    sink.close()  # non-milestone events may buffer until flush/close
    with open(path, "a") as f:
        f.write('{"type": "candidate_cul')  # the crash-torn trailing line
    evs = obs_events.read_events(path)
    assert [e["type"] for e in evs] == [
        "search_start", "candidate_asked", "candidate_committed"]
    assert obs_events.validate_events(evs) == []
    acc = obs_events.completeness(evs)
    assert acc["k"]["asked"] == 1 and acc["k"]["balanced"]


def test_event_schema_rejects_missing_fields(tmp_path):
    sink = obs_events.EventSink(str(tmp_path / "e.jsonl"))
    with pytest.raises(ValueError, match="missing fields"):
        sink.emit("candidate_committed", name="k")  # no point/cost
    problems = obs_events.validate_events([{"type": "bogus", "ts": 0, "pid": 1}])
    assert problems and "unknown type" in problems[0]
    assert obs_events.validate_events(
        [{"type": "bogus", "ts": 0, "pid": 1}], strict_types=False) == []


def test_emit_is_noop_without_sink():
    obs_events.set_sink(None)
    obs_events.emit("candidate_committed", name="k")  # invalid, but no sink


def test_completeness_flags_imbalance():
    evs = [
        {"type": "candidate_asked", "name": "k", "point": {}, "round": 1,
         "ts": 0, "pid": 1},
        {"type": "candidate_asked", "name": "k", "point": {}, "round": 1,
         "ts": 0, "pid": 1},
        {"type": "candidate_committed", "name": "k", "point": {}, "cost": 1.0,
         "ts": 0, "pid": 1},
    ]
    acc = obs_events.completeness(evs)
    assert acc["k"]["asked"] == 2 and acc["k"]["terminal"] == 1
    assert not acc["k"]["balanced"]


# ---------------------------------------------------------------- snapshots
def test_quarantine_snapshot_exposes_strikes_between_dumps():
    from repro.core.guard import Quarantine

    q = Quarantine(max_failures=2)
    q.note_failure("bad")
    snap = q.snapshot()
    assert snap["strikes"] == 1 and snap["quarantined"] == []
    assert snap["failing"] == {"bad": 1}
    q.note_failure("bad")
    snap = q.snapshot()
    assert snap["strikes"] == 2 and snap["quarantined"] == ["bad"]


def test_breaker_and_online_tuner_snapshot():
    from repro.core import Autotuning, CircuitBreaker
    from repro.runtime.online import OnlineTuner

    at = Autotuning(min=1, max=8, dim=1, num_opt=2, max_iter=4, seed=0)
    br = CircuitBreaker(threshold=1, cooldown=3)
    tuner = OnlineTuner(at, epsilon=1.0, breaker=br, name="snap-test")
    br.record_failure()  # trips immediately at threshold=1
    for _ in range(2):
        d = tuner.begin()
        tuner.observe(d, 1.0)
    snap = tuner.snapshot()
    assert snap["name"] == "snap-test"
    assert snap["calls"] == 2
    assert snap["breaker_denied"] >= 1  # visible without a stats() dump
    assert snap["breaker"]["state"] == "open"
    assert "cache" not in snap  # cheap: no cache walk in the snapshot


def test_breaker_transitions_counted():
    from repro.core.guard import CircuitBreaker

    obs_metrics.registry().reset()
    br = CircuitBreaker(threshold=1, cooldown=1)
    br.record_failure()  # closed -> open
    assert br.snapshot()["state"] == "open"
    assert br.allow()  # cooldown elapsed: open -> half_open probe
    br.record_success()  # half_open -> closed
    assert obs_metrics.counter("guard.breaker_transitions").value >= 3
    obs_metrics.registry().reset()


# --------------------------------------------------- end-to-end (tune_call)
@pytest.fixture
def obs_probe_kernel():
    import jax.numpy as jnp

    from repro.core import LogIntDim, SearchSpace
    from repro.kernels.autotuned import _REGISTRY, KernelSpec, register

    def probe(x, *, t1, t2, interpret=False):
        val = (jnp.log2(t1 / 16.0)) ** 2 + (jnp.log2(t2 / 64.0)) ** 2
        return x.sum() * 0.0 + val + 0.5

    name = "_obs_probe"
    register(
        KernelSpec(
            name=name,
            fn=probe,
            space=lambda x: SearchSpace(
                [LogIntDim("t1", 4, 64), LogIntDim("t2", 16, 256)]
            ),
            defaults=lambda x: {"t1": 16, "t2": 64},
        )
    )
    yield name
    _REGISTRY.pop(name, None)


def _det_cost(ex, *args):
    return float(np.asarray(ex(*args)))


@pytest.mark.parametrize("measure", ["fixed", "adaptive"])
def test_tune_call_event_stream_accounts_for_every_candidate(
    obs_dir, obs_probe_kernel, measure
):
    import jax.numpy as jnp

    from repro.kernels.autotuned import exec_cache, tune_call
    from repro.tuning import TuningDB

    exec_cache().clear()  # compile spans record real builds, not cache hits
    x = jnp.ones((4, 4))
    rec = tune_call(obs_probe_kernel, x, db=TuningDB(None), interpret=True,
                    num_opt=3, max_iter=3, seed=0, jobs=2, cost_fn=_det_cost,
                    measure=measure)
    assert rec is not None
    d = obs.shutdown()
    evs = obs_events.read_events(os.path.join(d, "events.jsonl"))
    assert obs_events.validate_events(evs) == []
    types = [e["type"] for e in evs]
    assert "search_start" in types and "search_end" in types
    assert "db_commit" in types
    acc = obs_events.completeness(evs)
    assert len(acc) == 1
    (a,) = acc.values()
    assert a["asked"] >= 1
    assert a["balanced"], f"candidate accounting imbalanced: {a}"
    # spans made it out as loadable Chrome JSON with the full hierarchy
    blob = json.loads(open(os.path.join(d, "trace.json")).read())
    xs = [e for e in blob["traceEvents"] if e["ph"] == "X"]
    names = {e["name"] for e in xs}
    assert {"search", "round", "compile"} <= names
    ids = {e["args"]["span_id"] for e in xs}
    for e in xs:
        pid = e["args"].get("parent_id")
        assert pid is None or pid in ids  # no orphan spans


def test_quarantined_candidates_appear_in_stream(obs_dir, obs_probe_kernel):
    import jax.numpy as jnp

    from repro.kernels.autotuned import tune_call
    from repro.tuning import TuningDB

    x = jnp.ones((4, 4))

    def flaky_cost(ex, *args):
        c = _det_cost(ex, *args)
        if c > 1.5:  # every non-near-optimal candidate "crashes"
            raise RuntimeError("block size misfit")
        return c

    from repro.core import FaultPolicy

    rec = tune_call(obs_probe_kernel, x, db=TuningDB(None), interpret=True,
                    num_opt=3, max_iter=4, seed=0, cost_fn=flaky_cost,
                    measure="fixed",
                    fault_policy=FaultPolicy(max_failures=1, retries=0))
    d = obs.shutdown()
    evs = obs_events.read_events(os.path.join(d, "events.jsonl"))
    acc = obs_events.completeness(evs)
    (a,) = acc.values()
    assert a["balanced"], f"imbalanced with failures in play: {a}"
    assert a["skipped"] + a["quarantined"] >= 1
    assert rec is None or np.isfinite(rec.cost)


# -------------------------------------------------------------------- report
def test_report_renders_and_gates(obs_dir, obs_probe_kernel, capsys):
    import time as _time

    import jax.numpy as jnp

    from repro.kernels.autotuned import tune_call
    from repro.tune import main as tune_main
    from repro.tuning import TuningDB

    x = jnp.ones((4, 4))
    t0 = _time.perf_counter()
    tune_call(obs_probe_kernel, x, db=TuningDB(None), interpret=True,
              num_opt=3, max_iter=3, seed=0, cost_fn=_det_cost,
              measure="adaptive")
    wall = _time.perf_counter() - t0
    d = obs.shutdown()

    assert tune_main(["report", d]) == 0
    out = capsys.readouterr().out
    assert "schema: ok" in out
    assert "candidate accounting" in out and "IMBALANCED" not in out
    assert "phase breakdown" in out

    from repro.obs.report import load_trace_spans, phase_breakdown

    br = phase_breakdown(load_trace_spans(os.path.join(d, "trace.json")))
    # per-phase accounting reconstructs the run's wall clock (±5%, plus a
    # small absolute floor for sub-second smoke runs)
    assert br["total_s"] <= wall * 1.05 + 0.05
    covered = sum(br["phases"].values()) + br["other_s"]
    assert covered <= br["total_s"] + 1e-6

    # a corrupted stream must fail the gate
    with open(os.path.join(d, "events.jsonl"), "a") as f:
        f.write(json.dumps({"type": "candidate_asked", "name": "ghost",
                            "point": {}, "round": 1, "ts": 0.0, "pid": 1})
                + "\n")
    assert tune_main(["report", d]) == 1
    capsys.readouterr()


def test_report_missing_dir_is_usage_error(capsys):
    from repro.tune import main as tune_main

    assert tune_main(["report", "/nonexistent/obs-dir"]) == 2
    capsys.readouterr()


# ----------------------------------------------------------- configure/env
def test_configure_from_env_and_idempotency(tmp_path, monkeypatch):
    d = str(tmp_path / "envobs")
    monkeypatch.setenv("REPRO_OBS", d)
    assert obs.configure_from_env()
    assert obs.enabled() and obs.obs_dir() == os.path.abspath(d)
    assert obs.configure(d)  # same dir: no-op, still enabled
    obs.emit("search_start", name="k")
    out = obs.shutdown()
    assert out == os.path.abspath(d)
    assert not obs.enabled()
    assert os.path.exists(os.path.join(out, "trace.json"))
    assert os.path.exists(os.path.join(out, "metrics.json"))
    assert len(obs.read_events(os.path.join(out, "events.jsonl"))) == 1


def test_log_levels(monkeypatch, capsys):
    from repro.obs.log import get_logger, set_level

    log = get_logger("repro.test_obs")
    set_level("quiet")
    log.info("should not appear")
    set_level("debug")
    log.debug("dbg visible")
    err = capsys.readouterr().err
    assert "should not appear" not in err
    assert "dbg visible" in err
    set_level("info")


def test_drift_reset_event_emitted(obs_dir):
    from repro.core import Autotuning
    from repro.runtime.drift import DriftDetector
    from repro.runtime.online import OnlineTuner

    at = Autotuning(min=1, max=8, dim=1, num_opt=2, max_iter=4, seed=0)
    tuner = OnlineTuner(
        at, epsilon=1.0, name="drift-test",
        drift=DriftDetector(window=2, min_samples=1, factor=1.2),
    )
    tuner.drive(lambda p: float(p["p0"]))
    assert at.finished
    # baseline 2 cheap samples, then a 50x degradation fires the detector
    for c in (1.0, 1.0, 50.0):
        d = tuner.begin()
        tuner.observe(d, c)
    d = obs.shutdown()
    evs = obs.read_events(os.path.join(d, "events.jsonl"))
    drifts = [e for e in evs if e["type"] == "drift_reset"]
    assert len(drifts) == 1
    assert drifts[0]["name"] == "drift-test" and drifts[0]["level"] >= 1
    assert tuner.stats_["drift_resets"] == 1
