"""Multi-threaded serving-runtime stress tests (the ``concurrency`` marker).

Threads are released together through a :class:`threading.Barrier` so every
test maximizes real interleaving, and every assertion is on an *accounting
identity* rather than a trajectory — under true concurrency the interleaving
is non-deterministic, but the books must balance at every consistent read
point:

* ``calls == explores + exploits`` (per tuner, and per aggregate),
* ``explores == explore_reps_decided + stale_explore_reps + buffered``,
* per-tenant ε-credit: no tenant's explores exceed ε of its own calls +1,
* one build per (point, signature): racing streams never duplicate an
  in-flight compile,
* the router's dispatch snapshot yields exactly one tuner per context no
  matter how many threads race the first sight of a signature.
"""
import threading

import pytest

from repro.core import CSA, Autotuning, ExecutableCache, IntDim, SearchSpace
from repro.core.measure import MeasurePolicy
from repro.runtime import EXPLORE, ContextRouter, OnlineTuner

pytestmark = pytest.mark.concurrency

THREADS = 8


def _space(hi=32):
    return SearchSpace([IntDim("p", 1, hi)])


def _at(space=None, num_opt=3, max_iter=4, seed=0, **kw):
    space = space or _space()
    return Autotuning(
        space=space, ignore=0,
        search=CSA(len(space), num_opt=num_opt, max_iter=max_iter, seed=seed),
        cache=True, **kw,
    )


def _hammer(fn, n_threads=THREADS, reps=60):
    """Run ``fn(thread_index, rep_index)`` from ``n_threads`` threads released
    simultaneously; re-raises the first worker exception."""
    barrier = threading.Barrier(n_threads)
    errors = []

    def work(i):
        barrier.wait()
        try:
            for r in range(reps):
                fn(i, r)
        except BaseException as e:  # noqa: BLE001 - reported to the test
            errors.append(e)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]


# ----------------------------------------------------------- ε / accounting
def test_tenant_epsilon_accounting_under_contention():
    """Concurrent tenants each stay within their own ε budget, and the
    global identities hold after the storm."""
    t = OnlineTuner(_at(max_iter=50), epsilon=0.25)
    eps = t.epsilon

    def serve(i, r):
        d = t.begin(tenant=f"tenant-{i}")
        cost = float((d.point["p"] - 9) ** 2) if d.kind == EXPLORE else 1.0
        t.observe(d, cost)

    _hammer(serve, reps=80)
    s = t.stats()
    assert s["calls"] == THREADS * 80
    assert s["calls"] == s["explores"] + s["exploits"]
    assert s["explores"] == (
        s["explore_reps_decided"] + s["stale_explore_reps"]
        + s["explore_reps_buffered"]
    )
    # the search converged at some point mid-storm, clearing the per-tenant
    # episode counters — only tenants still live in the table are checkable,
    # but for those the credit rule must hold exactly
    for tenant, ts in s.get("tenants", {}).items():
        assert ts["explores"] <= eps * ts["calls"] + 1, (tenant, ts)


def test_snapshot_identities_hold_mid_update():
    """A reader thread polling ``snapshot()`` mid-storm must never see torn
    counters: the identities hold at every single read."""
    t = OnlineTuner(_at(max_iter=200), epsilon=0.5)
    stop = threading.Event()
    bad = []

    def reader():
        while not stop.is_set():
            snap = t.snapshot()
            if snap["calls"] != snap["explores"] + snap["exploits"]:
                bad.append(("calls", snap))
            reps = (snap["explore_reps_decided"] + snap["stale_explore_reps"]
                    + snap["explore_reps_buffered"] + snap["explore_inflight"])
            if snap["explores"] != reps:
                bad.append(("reps", snap))

    poller = threading.Thread(target=reader)
    poller.start()
    try:
        def serve(i, r):
            d = t.begin()
            t.observe(d, float(d.point["p"]) if d.kind == EXPLORE else 1.0)

        _hammer(serve, reps=100)
    finally:
        stop.set()
        poller.join()
    assert not bad, bad[:3]


def test_rung_accounting_under_cross_stream_racing():
    """With a measurement policy, racing streams share one candidate rung;
    every explore request resolves to exactly one of decided/stale/buffered."""
    policy = MeasurePolicy(mode="fixed", repeats=3)
    t = OnlineTuner(_at(max_iter=30), epsilon=1.0, measure=policy)

    def serve(i, r):
        d = t.begin()
        t.observe(d, float((d.point["p"] - 5) ** 2) if d.kind == EXPLORE else 1.0)

    _hammer(serve, reps=60)
    s = t.stats()
    assert s["calls"] == s["explores"] + s["exploits"]
    assert s["explores"] == (
        s["explore_reps_decided"] + s["stale_explore_reps"]
        + s["explore_reps_buffered"]
    )
    # fixed repeats=3: every decided candidate consumed at most 3 reps
    if s["explore_candidates"]:
        assert s["explore_reps_decided"] <= 3 * s["explore_candidates"]


# ------------------------------------------------------------------- builds
def test_no_duplicate_inflight_builds_per_signature():
    """Racing threads asking for the same (point, signature) executable get
    one build, not one per thread — the cache's future is the dedup point."""
    calls = []
    lock = threading.Lock()
    started = threading.Barrier(THREADS, timeout=10)

    def build(key):
        with lock:
            calls.append(key)
        return f"exe-{key}"

    cache = ExecutableCache(maxsize=64)

    def hit(i, r):
        if r == 0:
            started.wait()  # all threads reach the first build together
        key = ("point", r % 4)
        got = cache.get_or_build(key, lambda k=key: build(k))
        assert got == f"exe-{key}"

    _hammer(hit, reps=40)
    assert len(calls) == 4  # one build per distinct key, ever
    st = cache.stats()
    assert st["misses"] == 4
    assert st["hits"] == THREADS * 40 - 4


def test_cache_eviction_caps_under_concurrent_build():
    """LRU caps hold under concurrent insertion and evictions are counted."""
    cache = ExecutableCache(maxsize=256, max_entries=8)

    def hit(i, r):
        key = (i, r)
        cache.get_or_build(key, lambda: b"x" * 64)

    _hammer(hit, reps=50)
    st = cache.stats()
    assert st["size"] <= 8
    assert st["misses"] == THREADS * 50  # distinct keys: no dedup expected
    assert st["evictions"] == st["misses"] - st["size"]


# ------------------------------------------------------------------- router
def test_router_creates_one_context_per_signature_under_racing():
    """All threads hitting a cold router converge on the same tuner objects;
    the dispatch snapshot never yields duplicates or loses contexts."""
    router = ContextRouter()
    router.register("ctx", space=lambda *a, **k: _space(), epsilon=0.25,
                    max_iter=10)
    seen = [set() for _ in range(4)]
    lock = threading.Lock()

    def serve(i, r):
        shape = r % 4  # four distinct contexts, all racing
        t = router.tuner("ctx", extra={"shape": shape})
        with lock:
            seen[shape].add(id(t))
        d = router.begin("ctx", extra={"shape": shape}, tenant=f"t{i}")
        router.observe(d, float(d.point["p"]) if d.kind == EXPLORE else 1.0)

    _hammer(serve, reps=40)
    for shape, ids in enumerate(seen):
        assert len(ids) == 1, f"context {shape} duplicated: {ids}"
    s = router.stats()
    assert s["contexts"] == 4
    assert s["calls"] == THREADS * 40
    assert s["calls"] == s["explores"] + s["exploits"]


def test_router_fast_path_is_stable_across_snapshot_swaps():
    """Threads creating new contexts (snapshot swaps) never disturb threads
    riding the fast path of an existing context."""
    router = ContextRouter()
    router.register("hot", space=lambda *a, **k: _space(), epsilon=0.0)
    router.register("cold", space=lambda *a, **k: _space(), epsilon=0.0)
    hot = router.tuner("hot", extra={"k": 0})

    def serve(i, r):
        if i % 2 == 0:
            # fast-path rider: must always resolve to the same tuner
            assert router.tuner("hot", extra={"k": 0}) is hot
        else:
            # snapshot churner: a fresh context every few reps
            router.tuner("cold", extra={"k": (i, r)})

    _hammer(serve, reps=50)
    assert router.tuner("hot", extra={"k": 0}) is hot
    # half the threads created 50 contexts each, plus "hot"
    assert router.stats()["contexts"] == (THREADS // 2) * 50 + 1


def test_wait_pending_does_not_deadlock_with_serving_threads():
    """``wait_pending`` waits outside the tuner lock, so serving threads and
    background builds make progress while another thread drains."""
    space = _space(8)

    def build(point, *args, **kwargs):
        return ("exe", point["p"])

    t = OnlineTuner(_at(space, max_iter=10), epsilon=0.5, build=build, jobs=2)
    done = threading.Event()

    def drainer():
        while not done.is_set():
            t.wait_pending(timeout=0.05)

    dr = threading.Thread(target=drainer)
    dr.start()
    try:
        def serve(i, r):
            d = t.begin(1, r % 4)
            t.observe(d, float(d.point["p"]) if d.kind == EXPLORE else 1.0)

        _hammer(serve, n_threads=4, reps=40)
    finally:
        done.set()
        dr.join(timeout=10)
    assert not dr.is_alive()
    assert t.stats()["inband_builds"] == 0  # builds never ran on a server thread
