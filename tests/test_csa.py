"""CSA unit + property tests (paper §2.1/§2.2, Eq. 1)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import CSA


def drive(opt, fn):
    z = opt.run(np.nan)
    n = 0
    while not opt.is_end():
        z = opt.run(fn(z))
        n += 1
    return n


def test_eval_count_matches_eq1():
    """num_eval = max_iter * num_opt (ignore handled by Autotuning)."""
    for m, it in [(2, 5), (5, 60), (8, 3)]:
        opt = CSA(dim=2, num_opt=m, max_iter=it, seed=0)
        n = drive(opt, lambda z: float(np.sum(z**2)))
        assert n == m * it


def test_converges_on_sphere():
    opt = CSA(dim=3, num_opt=5, max_iter=80, seed=1)
    drive(opt, lambda z: float(np.sum(z**2)))
    assert opt.best_cost < 0.05


def test_escapes_local_minima_rastrigin():
    """CSA's selling point (paper §2.1): multimodal robustness."""
    def rastrigin(z):
        x = z * 2.0
        return float(10 * x.size + np.sum(x**2 - 10 * np.cos(2 * np.pi * x)))

    opt = CSA(dim=2, num_opt=8, max_iter=150, seed=3)
    drive(opt, rastrigin)
    # global optimum is 0 at origin; local minima are at integer lattice ≈ >= 1
    assert opt.best_cost < 2.0


def test_final_solution_is_best_seen():
    costs = {}

    def fn(z):
        c = float(np.sum((z - 0.2) ** 2))
        costs[tuple(np.round(z, 12))] = c
        return c

    opt = CSA(dim=2, num_opt=4, max_iter=30, seed=7)
    drive(opt, fn)
    assert np.isclose(opt.best_cost, min(costs.values()))
    final = opt.run(0.0)  # post-end calls keep returning the final solution
    assert np.allclose(final, opt.best_solution)
    assert opt.is_end()


def test_reset_levels():
    opt = CSA(dim=2, num_opt=4, max_iter=10, seed=0)
    drive(opt, lambda z: float(np.sum(z**2)))
    best = opt.best_cost
    opt.reset(0)  # keeps solutions, re-anneals
    assert not opt.is_end()
    assert opt.best_cost == best  # best retained
    drive(opt, lambda z: float(np.sum(z**2)))
    opt.reset(2)  # full reset
    assert not opt.is_end()
    assert not np.isfinite(opt.best_cost)


def test_nonfinite_cost_never_adopted():
    opt = CSA(dim=1, num_opt=3, max_iter=20, seed=0)
    z = opt.run(np.nan)
    while not opt.is_end():
        # crash half the configurations
        c = np.inf if z[0] > 0 else float(z[0] ** 2)
        z = opt.run(c)
    assert np.isfinite(opt.best_cost)
    assert opt.best_solution[0] <= 0


def test_validates_args():
    with pytest.raises(ValueError):
        CSA(dim=0)
    with pytest.raises(ValueError):
        CSA(dim=1, num_opt=1)
    with pytest.raises(ValueError):
        CSA(dim=1, max_iter=0)


@settings(max_examples=25, deadline=None)
@given(
    dim=st.integers(1, 6),
    m=st.integers(2, 8),
    it=st.integers(1, 25),
    seed=st.integers(0, 1000),
)
def test_property_candidates_in_bounds(dim, m, it, seed):
    """Every candidate CSA ever emits lies in [-1, 1]^dim (property)."""
    opt = CSA(dim=dim, num_opt=m, max_iter=it, seed=seed)
    z = opt.run(np.nan)
    count = 0
    while not opt.is_end():
        assert z.shape == (dim,)
        assert np.all(z >= -1.0) and np.all(z <= 1.0)
        z = opt.run(float(np.sum(z**2)))
        count += 1
    assert count == m * it
    assert np.all(opt.best_solution >= -1.0) and np.all(opt.best_solution <= 1.0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_coupled_acceptance_matches_reference_loop(seed):
    """Regression pin for the vectorized coupled-acceptance step: identical
    accept/reject decisions (and RNG stream) to the per-solver reference loop
    for a fixed seed, including crashed (inf) probes."""
    m, dim = 5, 2
    opt = CSA(dim=dim, num_opt=m, max_iter=10, seed=seed)
    cost_rng = np.random.default_rng(seed + 1)

    def costs_for(batch):
        c = cost_rng.uniform(0.1, 2.0, size=len(batch))
        if cost_rng.uniform() < 0.4:
            c[int(cost_rng.integers(len(batch)))] = np.inf  # crashed candidate
        return list(c)

    opt.tell(costs_for(opt.ask()))  # INIT round
    for _ in range(4):
        batch = opt.ask()
        if not batch:
            break
        costs = costs_for(batch)
        # snapshot pre-acceptance state + RNG position
        x, e = opt._x.copy(), opt._e.copy()
        probes = opt._probes.copy()
        probe_e = np.array([c if np.isfinite(c) else np.inf for c in costs])
        tac = opt._tac
        rng_state = opt._rng.bit_generator.state

        opt.tell(costs)

        # reference: the historical per-solver loop with short-circuit draws
        ref = np.random.default_rng(0)
        ref.bit_generator.state = rng_state
        emax = float(np.max(e[np.isfinite(e)])) if np.any(np.isfinite(e)) else 0.0
        ex = np.exp((np.where(np.isfinite(e), e, emax) - emax) / max(tac, 1e-300))
        probs = ex / float(np.sum(ex))
        for i in range(m):
            if not np.isfinite(probe_e[i]):
                continue
            if probe_e[i] < e[i] or ref.uniform() < probs[i]:
                x[i] = probes[i]
                e[i] = probe_e[i]
        assert np.array_equal(opt._x, x)
        assert np.array_equal(opt._e, e)
        assert opt._rng.bit_generator.state["state"] == ref.bit_generator.state["state"]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_deterministic_given_seed(seed):
    def run_once():
        opt = CSA(dim=2, num_opt=3, max_iter=15, seed=seed)
        z = opt.run(np.nan)
        trace = []
        while not opt.is_end():
            trace.append(tuple(z))
            z = opt.run(float(np.sum(z**2)))
        return trace, opt.best_cost

    t1, b1 = run_once()
    t2, b2 = run_once()
    assert t1 == t2 and b1 == b2
