"""Launch-level tuning tests (ISSUE 10): declarative constraints in the
driver, launch search spaces, tuned-launch DB round-trips, chunked psum."""
import json

import pytest

from repro.core import Autotuning, Constraint, IntDim, SearchSpace
from repro.tuning.records import space_fingerprint

from helpers import run_py


def _toy_space(constrained: bool = True) -> SearchSpace:
    cons = (
        [Constraint("prod-4", lambda p: p["a"] * p["b"] == 4,
                    describe="a*b must equal 4")]
        if constrained else []
    )
    return SearchSpace([IntDim("a", 1, 4), IntDim("b", 1, 4)],
                       constraints=cons)


# ------------------------------------------------------- constraint basics
def test_constraint_check_reports_first_violation():
    sp = SearchSpace(
        [IntDim("a", 1, 4)],
        constraints=[
            Constraint("even", lambda p: p["a"] % 2 == 0),
            Constraint("big", lambda p: p["a"] >= 3),
        ],
    )
    assert sp.check({"a": 4}) is None
    assert sp.check({"a": 3}) == "even"
    assert sp.check({"a": 2}) == "big"
    assert sp.check({"a": 1}) == "even"  # first violated name wins


def test_constraint_predicate_exception_counts_as_violation():
    sp = SearchSpace(
        [IntDim("a", 0, 3)],
        constraints=[Constraint("div", lambda p: 6 % p["a"] == 0)],
    )
    assert sp.check({"a": 0}) == "div"  # ZeroDivisionError -> invalid


def test_size_and_constrained_size():
    sp = _toy_space()
    assert sp.size() == 16
    # (1,4), (2,2), (4,1) are the only products equal to 4
    assert sp.constrained_size() == 3
    assert _toy_space(constrained=False).constrained_size() == 16


def test_fingerprint_stable_for_unconstrained_spaces():
    """Adding the constraints feature must not move existing kernel
    fingerprints; attaching constraints must."""
    dims = lambda: [IntDim("a", 1, 4), IntDim("b", 1, 4)]  # noqa: E731
    plain = space_fingerprint(SearchSpace(dims()))
    assert plain == space_fingerprint(SearchSpace(dims(), constraints=[]))
    assert plain != space_fingerprint(_toy_space())


# --------------------------------------------------- driver-level pruning
def _grid(sp: SearchSpace) -> Autotuning:
    """Exhaustive deterministic scan: visits all 16 grid points, so exactly
    the 13 invalid ones get pruned and the true optimum must surface."""
    from repro.core import GridSearch

    return Autotuning(space=sp, search=GridSearch(2, points_per_dim=4),
                      cache=True)


def test_sequential_search_never_presents_invalid_points():
    sp = _toy_space()
    at = _grid(sp)
    presented = []
    p = at.start()
    while not at.finished:
        assert sp.check(p) is None, f"driver presented invalid point {p}"
        presented.append(dict(p))
        p = at.exec(float((p["a"] - 2) ** 2 + (p["b"] - 2) ** 2))
    assert presented, "search presented no points at all"
    assert at.best_point == {"a": 2, "b": 2}
    assert at.skip_reasons.get("constraint", 0) == 13  # 16 grid - 3 valid
    assert sum(at.constraint_violations.values()) == at.skip_reasons["constraint"]
    # constraint prunes are bookkeeping, not failures
    assert at.num_crashed == 0


def test_batch_search_prunes_before_measurement():
    sp = _toy_space()
    at = _grid(sp)

    def measure(points):
        for p in points:
            assert sp.check(p) is None, f"measure_batch saw invalid {p}"
        return [float((p["a"] - 2) ** 2 + (p["b"] - 2) ** 2) for p in points]

    at.entire_exec_batch(measure)
    assert at.best_point == {"a": 2, "b": 2}
    assert at.skip_reasons.get("constraint", 0) == 13
    assert "prod-4" in at.constraint_violations


def test_pruned_points_revisitable_after_reset():
    sp = _toy_space()
    at = Autotuning(space=sp, num_opt=3, max_iter=4, seed=0, cache=True)
    at.entire_exec_batch(lambda pts: [1.0] * len(pts))
    n0 = sum(at.constraint_violations.values())
    assert n0 > 0
    at.reset(1)  # level>=1 clears the pruned-key memory
    at.entire_exec_batch(lambda pts: [1.0] * len(pts))
    assert sum(at.constraint_violations.values()) > n0


def test_constraint_events_balance(tmp_path):
    """asked == committed + culled + pruned + skipped + quarantined must
    keep holding when the driver charges constraint prunes."""
    from repro.obs import completeness
    from repro.obs.events import EventSink, set_sink

    sp = _toy_space()
    epath = str(tmp_path / "events.jsonl")
    sink = EventSink(epath)
    set_sink(sink)
    try:
        at = Autotuning(space=sp, num_opt=3, max_iter=5, seed=0, cache=True)
        at.entire_exec_batch(
            lambda pts: [float(p["a"] + p["b"]) for p in pts]
        )
    finally:
        set_sink(None)
        sink.close()
    acc = completeness(epath)
    name = at.ctx_name()
    assert acc[name]["balanced"], acc[name]
    assert acc[name]["skipped"] >= at.skip_reasons.get("constraint", 0) > 0


# --------------------------------------------------------- launch spaces
ZOO = ["qwen2_7b", "recurrentgemma_2b", "moonshot_v1_16b_a3b"]


def test_launch_space_default_point_is_valid():
    from repro import configs
    from repro.launch.spaces import default_launch_point, launch_space

    for arch in ZOO:
        cfg = configs.get(arch)
        shape = configs.SHAPES["train_4k"]
        sp = launch_space(cfg, shape, 8)
        pt = default_launch_point(cfg, shape, 8, sp)
        assert sp.check(pt) is None, (arch, pt)
        assert pt["dp"] * pt["tp"] == 8


def test_launch_space_constraints_collapse_raw_space():
    from repro import configs
    from repro.launch.spaces import launch_space

    cfg = configs.get("qwen2_7b")
    sp = launch_space(cfg, configs.SHAPES["train_4k"], 8)
    raw, feas = sp.size(), sp.constrained_size()
    assert raw is not None and feas is not None
    assert 0 < feas < raw
    # every grid survivor factorizes the device count
    for pt in sp.grid_points():
        if sp.check(pt) is None:
            assert pt["dp"] * pt["tp"] == 8


def test_launch_cost_model_deterministic_and_monotone():
    from repro import configs
    from repro.launch.spaces import default_launch_point, launch_cost_model, launch_space

    cfg = configs.get("qwen2_7b")
    shape = configs.SHAPES["train_4k"]
    cost = launch_cost_model(cfg, shape, 8)
    sp = launch_space(cfg, shape, 8)
    pt = default_launch_point(cfg, shape, 8, sp)
    assert cost(pt) == cost(dict(pt))  # pure function of the point
    # remat="full" recomputes more than "none", all else equal
    lean, fat = dict(pt, remat="full"), dict(pt, remat="none")
    assert cost(lean) != cost(fat)


def test_tune_launch_commits_and_replays(tmp_path):
    from repro.launch.spaces import tune_launch
    from repro.tuning import TuningDB

    db = TuningDB(str(tmp_path / "launch.json"))
    s1: dict = {}
    rec = tune_launch("qwen2_7b", "train_4k", 8, db=db, mode="model",
                      max_iter=3, warm_start=False, stats=s1)
    assert rec is not None and rec.source == "pretune"
    assert rec.cost <= s1["default_cost"] * (1 + 1e-9)
    assert rec.key.shapes() is None  # no array args: context lives in extra
    assert json.loads(rec.key.extra)["shape"] == "train_4k"
    db.save()

    s2: dict = {}
    rec2 = tune_launch("qwen2_7b", "train_4k", 8, db=db, mode="model",
                       max_iter=3, stats=s2)
    assert s2["replayed"] and s2["measured"] == 0
    assert rec2.point == rec.point and rec2.cost == rec.cost


def test_launch_keys_roundtrip_db_cli(tmp_path, capsys):
    """Satellite 6: knobs-only launch keys survive db merge/diff/list."""
    from repro.launch.spaces import tune_launch
    from repro.tune import main as tune_main
    from repro.tuning import TuningDB

    a, b = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    dba, dbb = TuningDB(a), TuningDB(b)
    tune_launch("qwen2_7b", "train_4k", 8, db=dba, mode="model",
                max_iter=2, warm_start=False)
    tune_launch("recurrentgemma_2b", "train_4k", 8, db=dbb, mode="model",
                max_iter=2, warm_start=False)
    dba.save(), dbb.save()

    merged = str(tmp_path / "m.json")
    assert tune_main(["db", "merge", "--out", merged, a, b]) == 0
    assert len(TuningDB(merged)) == 2
    # a merged db agrees with each source on the records it contributed
    assert tune_main(["db", "diff", a, a]) == 0
    rc_diff = tune_main(["db", "diff", merged, a])
    assert rc_diff == 1  # b's record is missing from a -> reported, not crash

    assert tune_main(["db", "list", "--db", merged]) == 0
    out = capsys.readouterr().out
    assert "launch/qwen2_7b" in out and "launch/recurrentgemma_2b" in out
    assert "shape=train_4k" in out and "None" not in out


# ----------------------------------------------------- chunked collectives
@pytest.mark.multidevice
def test_chunked_psum_matches_dense():
    code = """
import jax, jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.parallel.collectives import chunked_psum

mesh = make_mesh((4,), ("data",))
g = jax.random.normal(jax.random.PRNGKey(0), (4, 37, 5))  # non-divisible size

def red(chunk_bytes):
    def f(gl):
        return chunked_psum(gl[0], "data", chunk_bytes)
    return shard_map(f, mesh=mesh, in_specs=(P("data", None, None),),
                     out_specs=P(), check_rep=False)(g)

exact = jnp.sum(g, axis=0)
for cb in (64, 256, 10**9):  # many chunks, a few, and one monolithic psum
    out = red(cb)
    assert out.shape == exact.shape
    assert jnp.allclose(out, exact, atol=1e-5), cb
try:
    red(0)
    raise SystemExit("chunk_bytes=0 must raise")
except ValueError:
    pass
print("OK")
"""
    assert "OK" in run_py(code, devices=4)


# --------------------------------------------------------- dryrun hygiene
@pytest.mark.multidevice
def test_dryrun_preserves_existing_xla_flags():
    """Satellite 1: importing launch.dryrun must keep caller XLA flags and
    honor REPRO_DRYRUN_DEVICES instead of clobbering the whole variable."""
    code = """
import os
import repro.launch.dryrun  # noqa: F401  (import applies the device-count flag)
flags = os.environ["XLA_FLAGS"].split()
assert "--xla_cpu_enable_fast_math=false" in flags, flags
assert "--xla_force_host_platform_device_count=4" in flags, flags
assert sum(f.startswith("--xla_force_host_platform_device_count") for f in flags) == 1
import jax
assert jax.device_count() == 4
print("OK")
"""
    out = run_py(
        code,
        devices=2,  # helpers sets ...device_count=2; dryrun must replace it
        env_extra={
            "XLA_FLAGS": "--xla_cpu_enable_fast_math=false "
                         "--xla_force_host_platform_device_count=2",
            "REPRO_DRYRUN_DEVICES": "4",
        },
    )
    assert "OK" in out
