"""Test-suite bootstrap.

Provides a deterministic fallback implementation of the small `hypothesis`
surface these tests use (`given`, `settings`, `strategies.integers/floats/
lists/sampled_from`) when the real package is not installed.  CI installs real
hypothesis from pyproject's dev extra; hermetic containers without it still
collect and run the property tests against a fixed, boundary-first example
stream (example 0 pins lower bounds, example 1 upper bounds, the rest are
seeded pseudo-random draws).
"""
from __future__ import annotations

import sys
import types

import numpy as np


def _install_hypothesis_fallback() -> None:
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng, i):
            return self._draw(rng, i)

    def integers(min_value, max_value):
        def draw(rng, i):
            if i == 0:
                return int(min_value)
            if i == 1:
                return int(max_value)
            return int(rng.integers(min_value, max_value + 1))

        return _Strategy(draw)

    def floats(min_value, max_value, **_kw):
        def draw(rng, i):
            if i == 0:
                return float(min_value)
            if i == 1:
                return float(max_value)
            return float(rng.uniform(min_value, max_value))

        return _Strategy(draw)

    def sampled_from(seq):
        seq = list(seq)

        def draw(rng, i):
            if i < len(seq):
                return seq[i]
            return seq[int(rng.integers(0, len(seq)))]

        return _Strategy(draw)

    def lists(elem, min_size=0, max_size=None):
        def draw(rng, i):
            hi = max_size if max_size is not None else min_size + 8
            size = min_size if i == 0 else int(rng.integers(min_size, hi + 1))
            return [elem.example(rng, 2 + j) for j in range(size)]

        return _Strategy(draw)

    def given(*g_args, **g_kw):
        if g_args:
            raise TypeError("fallback @given supports keyword strategies only")

        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_max_examples", 10)
                for i in range(n):
                    rng = np.random.default_rng(0xC0FFEE + 7919 * i)
                    kwargs = {k: s.example(rng, i) for k, s in g_kw.items()}
                    try:
                        fn(**kwargs)
                    except Exception:
                        print(f"Falsifying example ({fn.__name__}): {kwargs}")
                        raise

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._max_examples = 10
            wrapper.hypothesis_fallback = True
            return wrapper

        return deco

    def settings(*s_args, **s_kw):
        def deco(fn):
            if "max_examples" in s_kw and hasattr(fn, "_max_examples"):
                fn._max_examples = int(s_kw["max_examples"])
            return fn

        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.lists = lists
    st_mod.sampled_from = sampled_from

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st_mod
    hyp.__is_fallback__ = True

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401  (real package wins when available)
except ModuleNotFoundError:
    _install_hypothesis_fallback()
