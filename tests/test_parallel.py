"""Distribution tests: sharding rules, logical specs, collectives, pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.api import ShardingRules, logical_spec
from repro.parallel.sharding import param_wanted, state_wanted

from helpers import run_py


# ------------------------------------------------------ sharding rule units
def test_param_wanted_attention():
    assert param_wanted("stages/0/pos0/attn/wq/w", 3) == (None, "fsdp", "tp")
    assert param_wanted("stages/0/pos0/attn/wo/w", 3) == (None, "tp", "fsdp")
    assert param_wanted("stages/0/pos0/attn/wq/b", 2) == (None, "tp")
    assert param_wanted("embed/table", 2) == ("tp", "fsdp")
    assert param_wanted("lm_head/w", 2) == ("fsdp", "tp")


def test_param_wanted_moe_vs_dense():
    # expert weights (ng, E, D, F) -> EP on experts
    assert param_wanted("stages/0/pos0/ffn/wi", 4) == (None, "ep", "fsdp", None)
    assert param_wanted("stages/0/pos0/ffn/wo", 4) == (None, "ep", None, "fsdp")
    # dense ffn (ng, D, F)
    assert param_wanted("stages/0/pos0/ffn/wi", 3) == (None, "fsdp", "tp")
    assert param_wanted("stages/0/pos0/ffn/dense/wi", 3) == (None, "fsdp", "tp")
    assert param_wanted("stages/0/pos0/ffn/router", 3) == (None, "fsdp", None)


def test_param_wanted_norms_replicated():
    assert param_wanted("stages/0/pos0/norm1/scale", 2) == (None, None)
    assert param_wanted("final_norm/scale", 1) == (None,)


def test_state_wanted():
    assert state_wanted("0/pos0/kv/k", 5) == (None, "dp", "tp", None, None)
    # GQA kv=8 on 16-way model axis: prefer the sharded-sequence KV layout
    assert state_wanted("0/pos0/kv/k", (126, 128, 8, 32768, 128), tp_size=16) == (
        None, "dp", None, "tp", None)
    assert state_wanted("0/pos0/kv/k", (126, 128, 16, 32768, 128), tp_size=16) == (
        None, "dp", "tp", None, None)
    assert state_wanted("0/pos0/kv/pos", 2) == (None, None)
    assert state_wanted("0/pos0/wkv", 5) == (None, "dp", "tp", None, None)
    assert state_wanted("0/pos0/h", 3) == (None, "dp", "tp")
    assert state_wanted("0/pos0/conv", 4) == (None, "dp", None, "tp")


@pytest.mark.multidevice
def test_logical_spec_divisibility_guard():
    """Dims that don't divide the axis product must replicate, not crash."""
    code = """
import jax
from repro.launch.mesh import make_mesh
from repro.parallel.api import ShardingRules, logical_spec
from jax.sharding import PartitionSpec as P

mesh = make_mesh((2, 4), ("data", "model"))
rules = ShardingRules(dp=("data",), tp="model", fsdp=("data",))
# 28 heads on a 4-way model axis -> sharded; 30 -> replicated
assert logical_spec(mesh, rules, (28, 64), ("tp", None)) == P("model", None)
assert logical_spec(mesh, rules, (30, 64), ("tp", None)) == P(None, None)
# batch 1 cannot shard over dp
assert logical_spec(mesh, rules, (1, 5), ("dp", None)) == P(None, None)
print("OK")
"""
    assert "OK" in run_py(code, devices=8)


# ------------------------------------------------------------- collectives
@pytest.mark.multidevice
def test_int8_psum_and_topk():
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.parallel.collectives import int8_psum, topk_psum

mesh = make_mesh((4,), ("data",))
g = jax.random.normal(jax.random.PRNGKey(0), (4, 64))

def f8(gl):
    return int8_psum(gl[0], "data")
out = shard_map(f8, mesh=mesh, in_specs=(P("data", None),), out_specs=P(), check_rep=False)(g)
exact = jnp.sum(g, axis=0)
rel = float(jnp.max(jnp.abs(out - exact)) / (jnp.max(jnp.abs(exact)) + 1e-9))
assert rel < 0.05, rel

def ftk(gl, el):
    r, ne = topk_psum(gl[0], "data", 0.25, el[0])
    return r, ne[None]
err0 = jnp.zeros((4, 64))
out, ne = shard_map(ftk, mesh=mesh, in_specs=(P("data", None), P("data", None)),
                    out_specs=(P(), P("data", None)), check_rep=False)(g, err0)
# error feedback: sparse + residual == original (per shard)
recon = out  # sum of sparse parts
# after two rounds with error feedback the cumulative reduction approaches exact
r2, ne2 = shard_map(ftk, mesh=mesh, in_specs=(P("data", None), P("data", None)),
                    out_specs=(P(), P("data", None)), check_rep=False)(jnp.zeros_like(g), ne)
total = out + r2
gap1 = float(jnp.linalg.norm(out - exact))
gap2 = float(jnp.linalg.norm(total - exact))
assert gap2 < gap1, (gap1, gap2)   # residual shrinks with error feedback
print("OK")
"""
    assert "OK" in run_py(code, devices=4)


def test_wire_bytes_model():
    from repro.parallel.collectives import wire_bytes

    tree = {"a": jnp.zeros((1000,)), "b": jnp.zeros((24,))}
    assert wire_bytes(tree, "fp32") == 2 * 4 * 1024
    assert wire_bytes(tree, "int8") < wire_bytes(tree, "bf16") < wire_bytes(tree, "fp32")
    assert wire_bytes(tree, "topk", 0.01) < wire_bytes(tree, "int8")


# ---------------------------------------------------------------- pipeline
@pytest.mark.multidevice
def test_gpipe_matches_sequential():
    """4-stage pipeline over a 4-device axis == sequential application."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.parallel.pipeline import gpipe_apply

mesh = make_mesh((4,), ("pod",))
S, M, mb, d = 4, 6, 3, 16
ks = jax.random.split(jax.random.PRNGKey(0), 2)
w = jax.random.normal(ks[0], (S, d, d)) / np.sqrt(d)
x = jax.random.normal(ks[1], (M, mb, d))

def stage_fn(wp, xmb):
    return jnp.tanh(xmb @ wp)

y = gpipe_apply(mesh, "pod", stage_fn, w, x)
# sequential oracle
want = x
for s in range(S):
    want = jnp.tanh(want @ w[s])
np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5)

# grads flow through ppermute
def loss(w):
    return jnp.sum(gpipe_apply(mesh, "pod", stage_fn, w, x) ** 2)
g = jax.grad(loss)(w)
def loss_seq(w):
    h = x
    for s in range(4):
        h = jnp.tanh(h @ w[s])
    return jnp.sum(h ** 2)
gs = jax.grad(loss_seq)(w)
np.testing.assert_allclose(np.asarray(g), np.asarray(gs), atol=1e-4)
print("OK")
"""
    assert "OK" in run_py(code, devices=4, timeout=900)
