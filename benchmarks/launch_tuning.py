"""Launch-tuning benchmark: tuned vs default launch across the zoo (ISSUE 10).

The claim under test: registering launch-level knobs (mesh dp×tp
factorization, microbatches, remat, collective chunking, XLA preset) as
PATSMA search spaces finds launches at least as fast as the untuned default
on every zoo config — and the declarative validity predicates collapse the
raw product space *before* any candidate is scored, at zero compile/measure
cost.

Three gates per config (SystemExit on any failure):

  1. ``tuned step time <= default step time`` — the default point is noted
     as the search incumbent, so this must hold by construction; the
     benchmark re-checks the committed record against an independently
     evaluated default.
  2. the constraints prune a nonzero fraction of the raw space (statically,
     ``1 - constrained/raw``) and a nonzero number of search candidates
     (dynamically, ``skip(reason="constraint")`` charges).
  3. zero scoring cost for pruned points, proven from the event stream:
     every ``candidate_committed`` point satisfies every predicate, every
     constraint-skipped point violates one, and the obs completeness
     identity (``asked == committed+culled+pruned+skipped+quarantined``)
     balances for each launch search.

Default mode is the deterministic analytic cost model (``mode="model"`` —
pure arithmetic, no devices, byte-stable for CI); ``--full`` switches to
``mode="dryrun"``, compiling each surviving candidate on the host-platform
mesh and charging its roofline bound.
"""
from __future__ import annotations

import math
import os
import sys
import tempfile

# script-mode support (same shim as benchmarks/run.py)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

#: dynamic prunes must be nonzero summed over the sweep (each config's count
#: depends on the search trajectory; the static fraction gates per-config)
MIN_TOTAL_PRUNED = 1


def _check_events(path: str, space_by_name: dict) -> dict:
    """Event-stream gate: committed points valid, constraint-skips invalid,
    completeness balanced.  Returns the per-search completeness table."""
    from repro.obs import completeness, read_events

    events = read_events(path)
    for ev in events:
        name = ev.get("name")
        space = space_by_name.get(name)
        if space is None:
            continue
        t = ev.get("type")
        if t == "candidate_committed":
            violated = space.check(ev["point"])
            assert violated is None, (
                f"{name}: committed point {ev['point']} violates "
                f"constraint {violated!r} — an illegal launch was scored"
            )
        elif t == "candidate_skipped" and ev.get("reason") == "constraint":
            assert space.check(ev["point"]) is not None, (
                f"{name}: point {ev['point']} charged as constraint-pruned "
                f"but satisfies every predicate"
            )
    acc = completeness(events)
    for name in space_by_name:
        a = acc.get(name)
        if a is None:
            continue
        assert a["balanced"], (
            f"{name}: candidate accounting does not balance: {a}"
        )
    return {k: v for k, v in acc.items() if k in space_by_name}


def run(*, mode: str = "model", n_devices: int = 8, num_opt: int = 3,
        max_iter: int = 6, seed: int = 0, tiny: bool = False,
        verbose: bool = True) -> dict:
    from repro import configs
    from repro.launch.spaces import launch_cases, launch_space, tune_launch
    from repro.obs.events import EventSink, set_sink
    from repro.tuning import TuningDB

    if mode == "dryrun":
        import jax

        if jax.device_count() < n_devices:
            raise SystemExit(
                f"dryrun mode factorizes {n_devices} devices but the host "
                f"exposes {jax.device_count()}; set REPRO_DRYRUN_DEVICES="
                f"{n_devices} (before jax initializes) or run --smoke"
            )

    cases = launch_cases(smoke=True)
    out: dict = {"mode": mode, "devices": n_devices}
    total_pruned = 0
    total_measured = 0
    space_by_name: dict = {}

    with tempfile.TemporaryDirectory() as td:
        db = TuningDB(os.path.join(td, "launch.json"))
        epath = os.path.join(td, "events.jsonl")
        sink = EventSink(epath)
        set_sink(sink)
        try:
            for arch, shape_name in cases:
                cfg = configs.get(arch) if not tiny else configs.get_tiny(arch)
                shape = configs.SHAPES[shape_name]
                space = launch_space(cfg, shape, n_devices)
                space_by_name[f"launch/{arch}"] = space

                stats: dict = {}
                rec = tune_launch(
                    arch, shape_name, n_devices, db=db, mode=mode,
                    num_opt=num_opt, max_iter=max_iter, seed=seed,
                    warm_start=False, source="benchmark", tiny=tiny,
                    stats=stats,
                )
                assert rec is not None, f"{arch}: no launch record committed"

                raw = stats["raw_size"]
                feas = stats["constrained_size"]
                static_frac = 1.0 - feas / raw
                default_cost = stats["default_cost"]
                ratio = rec.cost / default_cost if default_cost > 0 else 1.0
                total_pruned += stats.get("pruned", 0)
                total_measured += stats.get("measured", 0)

                out[f"{arch}_default_s"] = round(float(default_cost), 4)
                out[f"{arch}_tuned_s"] = round(float(rec.cost), 4)
                out[f"{arch}_ratio"] = round(float(ratio), 4)
                out[f"{arch}_static_prune_frac"] = round(static_frac, 4)
                out[f"{arch}_pruned"] = int(stats.get("pruned", 0))
                out[f"{arch}_measured"] = int(stats.get("measured", 0))
                if verbose:
                    print(
                        f"launch_{arch},{rec.cost * 1e6:.0f},"
                        f"default={default_cost:.4g}s ratio={ratio:.3f} "
                        f"space={raw}->{feas} (-{static_frac:.0%}) "
                        f"pruned={stats.get('pruned', 0)} "
                        f"measured={stats.get('measured', 0)} "
                        f"best={rec.point}"
                    )

                # gate 1: tuned never loses to the untuned default
                assert rec.cost <= default_cost * (1 + 1e-9), (
                    f"{arch}: tuned launch {rec.cost:.4g}s is slower than the "
                    f"default {default_cost:.4g}s"
                )
                assert math.isfinite(rec.cost), f"{arch}: non-finite tuned cost"
                # gate 2a: the predicates statically collapse the raw space
                assert 0.0 < static_frac < 1.0, (
                    f"{arch}: constraints prune {static_frac:.0%} of the raw "
                    f"space — expected a nonzero fraction with survivors"
                )
        finally:
            set_sink(None)
            sink.close()

        # gate 2b: the search dynamically charged constraint prunes
        assert total_pruned >= MIN_TOTAL_PRUNED, (
            f"search charged only {total_pruned} constraint prunes over "
            f"{len(cases)} configs — the predicates never fired"
        )
        # gate 3: event-stream audit (valid commits, invalid skips, balance)
        acc = _check_events(epath, space_by_name)

    out["total_pruned"] = int(total_pruned)
    out["total_measured"] = int(total_measured)
    out["searches_balanced"] = all(a["balanced"] for a in acc.values())
    if verbose:
        print(
            f"launch_tuning_total,{total_measured},"
            f"pruned={total_pruned} balanced={out['searches_balanced']}"
        )
    return out


def smoke() -> dict:
    """CI lane: analytic cost model — deterministic, no devices, seconds."""
    return run(mode="model", max_iter=4)


def main(argv=None) -> dict:
    argv = list(argv or sys.argv[1:])
    if "--full" in argv:
        # compile-and-measure mode on the host-platform mesh: tiny configs
        # keep per-candidate compiles tractable off-TPU.  The device-count
        # flag must land before jax initializes its backends — a no-op if
        # something already did (run.py sweeps), in which case the guard in
        # run() reports what to export instead of a mesh-shape crash.
        from repro.launch.dryrun import _ensure_host_platform_devices

        _ensure_host_platform_devices(8)
        return run(mode="dryrun", tiny=True, max_iter=2, num_opt=2)
    return run(mode="model")


if __name__ == "__main__":
    main()
