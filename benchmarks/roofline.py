"""Roofline report generator: reads the dry-run JSONL and emits the
EXPERIMENTS.md §Dry-run + §Roofline tables (markdown) and CSV lines.

Terms (per §Roofline of the brief, TPU v5e constants):
    compute_s    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory_s     = HLO_bytes / HBM_bw               (per chip)
    collective_s = collective_bytes / (links * bw)  (per chip)
plus MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (inference) and the
useful-flops ratio MODEL_FLOPS / HLO_FLOPs.  The "roofline fraction"
column is (MODEL_FLOPS / peak) / max(term) — the share of the bound time
doing useful model math (the §Perf score)."""
from __future__ import annotations

import json
import os
import sys

BASELINE = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun_baseline.jsonl")


def load(path=BASELINE):
    rows = []
    if not os.path.exists(path):
        return rows
    seen = {}
    for line in open(path):
        r = json.loads(line)
        key = (r["arch"], r["shape"], r.get("multi_pod", False))
        seen[key] = r  # last write wins (reruns supersede)
    return list(seen.values())


def fraction(r) -> float:
    rt = r["roofline"]
    useful_s = r["model_flops_per_chip"] / 197e12
    bound = max(rt["compute_s"], rt["memory_s"], rt["collective_s"])
    return useful_s / bound if bound > 0 else 0.0


def markdown(rows) -> str:
    out = []
    out.append("### §Dry-run — per-chip memory + compile status\n")
    out.append(
        "| arch | shape | mesh | status | args GB/chip | temp GB/chip | peak GB/chip |"
    )
    out.append("|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r.get("multi_pod", False))):
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | {r['status']}: "
                f"{r.get('reason', r.get('error', ''))[:60]} | — | — | — |"
            )
            continue
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | ok | "
            f"{m['argument_bytes']/1e9:.2f} | {m['temp_bytes']/1e9:.2f} | "
            f"{m['peak_bytes']/1e9:.2f} |"
        )

    out.append("\n### §Roofline — per-chip terms (single-pod 16x16 unless noted)\n")
    out.append(
        "| arch | shape | compute ms | memory ms | collective ms | dominant | "
        "useful-flops ratio | roofline fraction |"
    )
    out.append("|---|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok" or r.get("multi_pod"):
            continue
        rt = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rt['compute_s']*1e3:.1f} | "
            f"{rt['memory_s']*1e3:.1f} | {rt['collective_s']*1e3:.1f} | "
            f"{rt['dominant']} | {r['useful_flops_ratio']:.2f} | {fraction(r):.3f} |"
        )
    return "\n".join(out)


def main(argv=None):
    path = argv[0] if argv else BASELINE
    rows = load(path)
    if not rows:
        print("roofline_report,0,no dryrun results yet (run repro.launch.dryrun --all)")
        return {}
    md = markdown(rows)
    out_md = os.path.join(os.path.dirname(path), "roofline.md")
    with open(out_md, "w") as f:
        f.write(md + "\n")
    ok = [r for r in rows if r["status"] == "ok" and not r.get("multi_pod")]
    for r in sorted(ok, key=fraction):
        print(
            f"roofline_{r['arch']}_{r['shape']},"
            f"{max(r['roofline']['compute_s'], r['roofline']['memory_s'], r['roofline']['collective_s'])*1e6:.0f},"
            f"dominant={r['roofline']['dominant']} fraction={fraction(r):.3f}"
        )
    print(f"roofline_report,{len(ok)},written={out_md}")
    return {"rows": rows, "markdown": md}


if __name__ == "__main__":
    main(sys.argv[1:])
