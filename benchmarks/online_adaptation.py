"""Online-adaptation benchmark: what `repro.runtime` buys on a shifting
workload.

Simulates a serving trace through the real machinery — a
:class:`repro.runtime.ContextRouter` with per-shape-bucket contexts, an
ε-rationed :class:`OnlineTuner` per context whose candidate "executables"
are built through an :class:`ExecutableCache` on a background pool, a
:class:`DriftDetector` on the exploit stream, and a shared in-memory
:class:`TuningDB` — against a deterministic analytic cost model, so the
numbers measure *adaptation*, not host noise:

* **phase A**: requests at one shape; the context tunes from cold.
* **phase B** (workload shift): the request shape distribution changes →
  a new shape-bucket context spins up mid-run, warm-started from phase A's
  committed record at half budget.
* **phase C** (environment drift): same shapes, but the cost surface moves
  (contention/thermal analogue) → the DriftDetector fires and the context
  re-tunes in the background while serving continues.

Reported per shift: **adaptation latency** (requests until the deployed
knobs are within 10% of the oracle-retuned cost) and **regret** (total
excess cost vs an oracle that retunes instantly), for the online tuner vs
frozen-static knobs (tuned once on phase A, never adapted).  Also reported:
in-band builds and executable-cache recompiles, both of which must be zero
— the serving thread never blocks on a compile.
"""
from __future__ import annotations

import math
import os
import sys
import time

import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


# ------------------------------------------------------------- cost model
class Phase:
    """One regime of the workload: request shape + true cost surface."""

    def __init__(self, name, n, shape, opt_t, base, scale=0.25):
        self.name = name
        self.n = n
        self.shape = shape
        self.opt_t = opt_t
        self.base = base
        self.scale = scale

    def cost(self, point: dict) -> float:
        return self.base + self.scale * (math.log2(point["t"] / self.opt_t)) ** 2

    @property
    def oracle(self) -> float:
        return self.base  # cost at the true optimum


def _phases(n_a, n_b, n_c):
    return [
        Phase("A", n_a, (64, 32), opt_t=32, base=1.0),
        # workload shift: new shape bucket (64 -> 256) => new context
        Phase("B", n_b, (256, 32), opt_t=128, base=1.2),
        # environment drift: same context, cost surface moves
        Phase("C", n_c, (256, 32), opt_t=256, base=2.0),
    ]


def run(
    n_a=140, n_b=170, n_c=170, epsilon=0.35, seed=0,
    request_work_s=2e-4, verbose=True,
) -> dict:
    """``request_work_s`` simulates the serving work of one request (the
    model execution between routing decisions); it is what background
    candidate builds overlap with, exactly as compiles overlap decode chunks
    in real serving.  Without it the trace would be a GIL-tight Python loop
    that never yields to the build pool — a serving pattern that doesn't
    exist."""
    from repro.core import ExecutableCache, LogIntDim, SearchSpace
    from repro.runtime import ContextRouter
    from repro.tuning import TuningDB

    def build(point, *args):  # background "compile" of a candidate
        return ("exe", point["t"])

    cache = ExecutableCache()
    router = ContextRouter(db=TuningDB(None), cache=cache, jobs=2)
    router.register(
        "sim_kernel",
        space=lambda x: SearchSpace([LogIntDim("t", 8, 512)]),
        defaults=lambda x: {"t": 64},
        build=build,
        epsilon=epsilon,
        num_opt=3,
        max_iter=3,
        seed=seed,
        drift={"window": 10, "min_samples": 5, "factor": 1.3},
    )

    phases = _phases(n_a, n_b, n_c)
    requests = [(p, i) for p in phases for i in range(p.n)]
    shift_b = phases[0].n                 # first request of phase B
    shift_c = phases[0].n + phases[1].n   # first request of phase C

    deployed_costs = []  # cost of the knobs the tuner would exploit, per request
    online_costs = []    # cost actually served (exploration included)
    oracle_costs = []
    frozen_point = None  # phase A's converged knobs, frozen at the boundary
    frozen_costs = []
    b_warm_started = False

    for r, (phase, _) in enumerate(requests):
        x = np.zeros(phase.shape, np.float32)
        if r == shift_b:
            # snapshot what a non-adaptive system would keep serving with
            a_tuner = router.tuner("sim_kernel", np.zeros(phases[0].shape, np.float32))
            frozen_point = dict(a_tuner.exploit_point())
        decision = router.begin("sim_kernel", x)
        if request_work_s:
            time.sleep(request_work_s)  # the request's serving work
        cost = phase.cost(decision.point)
        router.observe(decision, cost)
        tuner = decision.tuner
        if r == shift_b:
            b_warm_started = tuner.at.warm_started
        online_costs.append(cost)
        deployed_costs.append(phase.cost(tuner.exploit_point()))
        oracle_costs.append(phase.oracle)
        frozen_costs.append(
            phase.cost(frozen_point) if frozen_point is not None else cost
        )

    def adapt_latency(shift: int, end: int) -> int:
        """Requests after `shift` until the deployed knobs' cost is within
        10% of the oracle (and the end of the phase if never)."""
        for j in range(shift, end):
            if deployed_costs[j] <= 1.1 * oracle_costs[j]:
                return j - shift
        return end - shift

    n_total = len(requests)
    regret_online = sum(c - o for c, o in zip(online_costs, oracle_costs))
    regret_frozen = sum(
        c - o for c, o in zip(frozen_costs[shift_b:], oracle_costs[shift_b:])
    )
    regret_online_post = sum(
        c - o for c, o in zip(online_costs[shift_b:], oracle_costs[shift_b:])
    )
    stats = router.stats()
    tail = 10  # end-of-phase window for the recovery / regression checks
    recovered = all(
        np.mean(deployed_costs[end - tail:end]) <= 1.1 * np.mean(oracle_costs[end - tail:end])
        for end in (shift_b, shift_c, n_total)
    )
    frozen_regressed = (
        np.mean(frozen_costs[n_total - tail:]) > 1.1 * np.mean(oracle_costs[n_total - tail:])
    )

    out = {
        "requests": n_total,
        "contexts": stats["contexts"],
        "adapt_latency_shift": adapt_latency(shift_b, shift_c),
        "adapt_latency_drift": adapt_latency(shift_c, n_total),
        "regret_online": round(regret_online, 3),
        "regret_online_post_shift": round(regret_online_post, 3),
        "regret_frozen_post_shift": round(regret_frozen, 3),
        "regret_ratio": round(regret_online_post / max(regret_frozen, 1e-9), 3),
        "recovered_within_10pct": bool(recovered),
        "frozen_regressed": bool(frozen_regressed),
        "shift_warm_started": bool(b_warm_started),
        "drift_resets": stats["drift_resets"],
        "explores": stats["explores"],
        "deferred_explores": stats["deferred_explores"],
        "inband_builds": stats["inband_builds"],
        "recompiles": stats["cache"]["recompiles"],
        "compiles": stats["cache"]["misses"],
    }
    if verbose:
        print(
            f"online_adaptation: shift latency {out['adapt_latency_shift']} req "
            f"(warm={out['shift_warm_started']}), drift latency "
            f"{out['adapt_latency_drift']} req ({out['drift_resets']} resets) | "
            f"post-shift regret {out['regret_online_post_shift']} vs frozen "
            f"{out['regret_frozen_post_shift']} (ratio {out['regret_ratio']}) | "
            f"recovered<=10%: {out['recovered_within_10pct']}, frozen regressed: "
            f"{out['frozen_regressed']} | {out['compiles']} compiles, "
            f"{out['inband_builds']} in-band, {out['recompiles']} recompiles"
        )
    return out


def _print_csv(out: dict) -> None:
    print(
        f"online_adaptation_shift_latency,{out['adapt_latency_shift']},"
        f"warm={out['shift_warm_started']}"
    )
    print(
        f"online_adaptation_drift_latency,{out['adapt_latency_drift']},"
        f"resets={out['drift_resets']}"
    )
    print(
        f"online_adaptation_regret,{out['regret_online_post_shift'] * 1e3:.0f},"
        f"ratio_vs_frozen={out['regret_ratio']};frozen_regressed={out['frozen_regressed']}"
    )
    print(
        f"online_adaptation_noblock,{out['inband_builds']},"
        f"recompiles={out['recompiles']};recovered={out['recovered_within_10pct']}"
    )


def smoke():
    out = run(verbose=True)
    _print_csv(out)
    if not out["recovered_within_10pct"] or out["inband_builds"] or out["recompiles"]:
        raise SystemExit(f"online adaptation acceptance failed: {out}")
    return out


def main(argv=None):
    out = run(n_a=300, n_b=400, n_c=400, verbose=True)
    _print_csv(out)
    return out


if __name__ == "__main__":
    main()
