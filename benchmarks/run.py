"""Benchmark harness — one benchmark per paper table/figure (DESIGN §9).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run strategy_shootout  # one
    PYTHONPATH=src python benchmarks/run.py --smoke --out BENCH_ci.json

Each benchmark prints ``name,us_per_call,derived`` CSV lines.  ``--smoke``
runs the reduced CI lane (each module's ``smoke()``) and ``--out`` writes a
machine-readable JSON result so CI accumulates per-PR perf data points.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time
import traceback

# support `python benchmarks/run.py` (script mode puts benchmarks/ on the
# path, not the repo root the package imports need)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

BENCHES = [
    "strategy_shootout",  # §2.1 via the strategy layer: csa vs nm vs hybrid; Eq.1/Eq.2
    "rb_gauss_seidel",  # §3: the paper's illustrative example (Fig. 1a/1b)
    "kernel_autotune",  # §2.3: block-size tuning on Pallas kernels
    "tuning_warmstart",  # tuning DB: cold vs near-miss vs exact-replay cost
    "tuning_throughput",  # batched (ask/tell + AOT fan-out) vs sequential tuning
    "measurement_overhead",  # adaptive racing vs fixed repeats (deterministic)
    "fleet_sharding",  # fleet: ShardedPortfolio wall-clock vs serial Portfolio
    "online_adaptation",  # runtime: adaptation latency/regret on a workload shift
    "traffic_replay",  # serving: multi-tenant dispatch/racing/objectives under threads
    "fault_recovery",  # resilience: search under injected faults; guard overhead
    "obs_overhead",  # observability: tuning throughput obs off vs on (gate 1.05)
    "step_autotune",  # §2.4: exec modes on a real train step
    "grad_compression",  # DESIGN §7: compressed DP reduction
    "launch_tuning",  # launch-level knobs: tuned vs default across the zoo
    "roofline",  # §Roofline report from the dry-run JSONL
]


def _run_one(name: str, smoke: bool) -> dict:
    print(f"\n=== benchmarks.{name} ===")
    t0 = time.time()
    entry: dict = {"bench": name, "mode": "smoke" if smoke else "full"}
    try:
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        if smoke:
            fn = getattr(mod, "smoke", None)
            if fn is None:
                entry.update(status="skipped", reason="no smoke() entry")
                print(f"bench_{name},0,SKIPPED:no-smoke-entry")
                return entry
            out = fn()
        else:
            out = mod.main([])
        entry.update(status="ok", wall_s=time.time() - t0)
        if isinstance(out, dict):
            entry["result"] = {
                k: v for k, v in out.items() if isinstance(v, (int, float, str, bool))
            }
        print(f"bench_{name}_wall,{entry['wall_s'] * 1e6:.0f},ok")
    except (Exception, SystemExit) as e:
        # SystemExit is how a bench's smoke() reports a failed acceptance
        # gate — record it and keep sweeping so --out still captures every
        # other bench (the driver re-raises a summary SystemExit at the end)
        if not isinstance(e, SystemExit):
            traceback.print_exc()
        entry.update(status="failed", wall_s=time.time() - t0, error=repr(e))
        print(f"bench_{name}_wall,{entry['wall_s'] * 1e6:.0f},FAILED:{e!r}")
    return entry


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.run")
    ap.add_argument("benches", nargs="*", default=None, help="subset to run")
    ap.add_argument("--smoke", action="store_true", help="reduced CI lane")
    ap.add_argument("--out", type=str, default=None, help="write JSON results here")
    ap.add_argument(
        "--jobs", type=int, default=None,
        help="concurrent AOT compiles for tuning benches (sets REPRO_TUNE_JOBS)",
    )
    args = ap.parse_args(argv)
    if args.jobs is not None:
        os.environ["REPRO_TUNE_JOBS"] = str(args.jobs)

    which = args.benches or BENCHES
    results = [_run_one(name, args.smoke) for name in which]

    if args.out:
        blob = {
            "created": time.time(),
            "python": platform.python_version(),
            "platform": platform.platform(),
            "results": results,
        }
        try:
            import jax

            blob["jax"] = jax.__version__
            blob["backend"] = jax.default_backend()
        except Exception:
            pass
        with open(args.out, "w") as f:
            json.dump(blob, f, indent=1)
        print(f"\nwrote {args.out}")

    failures = [r["bench"] for r in results if r["status"] == "failed"]
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main(sys.argv[1:])
