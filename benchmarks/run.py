"""Benchmark harness — one benchmark per paper table/figure (DESIGN §9).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run csa_vs_nm  # one

Each benchmark prints ``name,us_per_call,derived`` CSV lines.
"""
from __future__ import annotations

import sys
import time
import traceback

BENCHES = [
    "csa_vs_nm",  # §2.1: CSA vs NM vs random; Eq.1/Eq.2
    "rb_gauss_seidel",  # §3: the paper's illustrative example (Fig. 1a/1b)
    "kernel_autotune",  # §2.3: block-size tuning on Pallas kernels
    "step_autotune",  # §2.4: exec modes on a real train step
    "grad_compression",  # DESIGN §7: compressed DP reduction
    "roofline",  # §Roofline report from the dry-run JSONL
]


def main() -> None:
    which = sys.argv[1:] or BENCHES
    failures = []
    for name in which:
        print(f"\n=== benchmarks.{name} ===")
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main([])
            print(f"bench_{name}_wall,{(time.time()-t0)*1e6:.0f},ok")
        except Exception as e:
            traceback.print_exc()
            failures.append(name)
            print(f"bench_{name}_wall,{(time.time()-t0)*1e6:.0f},FAILED:{e!r}")
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
