"""Observability overhead benchmark: tuning throughput with obs off vs on.

The observability layer's contract is that it is a **sidecar**: disabled, an
instrumentation site costs one attribute check (the tracer's ``enabled``
flag, the event module's ``_SINK is None`` early-out); enabled, the fsynced
event stream and span bookkeeping ride along without distorting the search.
This benchmark measures both prices on ``tuning_throughput``'s contexts,
but with a **deterministic** ``cost_fn`` instead of wall-clock costs: a
measured cost is noisy, so CSA trajectories diverge between passes and an
occasional never-seen candidate triggers a cold XLA compile (~100ms) that
swamps the few-ms signal.  The cost function still *executes* each
candidate (the loop does the real, GIL-releasing work a measured search
does) but returns a constant, so every pass asks the exact same candidates
and the warm-up pass compiles all of them once.

Measurement is paired to survive CI-runner load drift: each round times
off → on → off phases and contributes one paired ratio
``on / mean(off, off)``; the reported ``on_ratio`` is the **median** over
rounds (an unpaired min-vs-min estimate flaps by ±15% on a busy machine,
far above the effect being measured).  Within a round each phase is the
**min of ``reps`` back-to-back sweeps**, shedding one-off scheduler or
writer-drain interference before the ratio is formed.  ``off_ratio`` —
the same pairing applied to two disabled phases — is the self-noise
floor, reported but not gated.

**Gate: on_ratio ≤ 1.05** (the CI smoke lane asserts this), above the
< 2% design target so CI noise does not flake the lane.

Prints ``obs_overhead_{off,on},us,ratio=...`` CSV lines for the CI artifact.
"""
from __future__ import annotations

import os
import shutil
import sys
import tempfile
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

#: CI gate: obs-on tuning throughput may cost at most 5% over obs-off
GATE_RATIO = 1.05


def _contexts(n_ctx: int = 2):
    """(kernel, args) pairs at production-ish sizes.  The obs cost per
    candidate is fixed (a handful of span/event calls); what the ratio
    means depends on how much real work a candidate does.  Tuning a
    64x64 toy would overstate the relative overhead of any workload a
    search is actually pointed at, so these shapes are sized to the
    pretune grid's upper end."""
    import jax

    def rnd(seed, shape):
        return jax.random.normal(jax.random.PRNGKey(seed), shape)

    ctxs = [
        ("matmul", (rnd(0, (128, 128)), rnd(1, (128, 128)))),
        ("matmul", (rnd(2, (192, 192)), rnd(3, (192, 192)))),
        ("matmul", (rnd(4, (256, 256)), rnd(5, (256, 256)))),
    ]
    return ctxs[:n_ctx]


def _det_cost(executable, *args) -> float:
    """Run the candidate like a measured search would (``RuntimeCost``'s
    warmup + 2 repeats), but return a constant: identical trajectories
    every pass."""
    import jax

    for _ in range(3):
        jax.block_until_ready(executable(*args))
    return 1.0


def _sweep(ctxs, *, num_opt, max_iter) -> float:
    """One timed pass: tune every context against a throwaway DB (no
    exact-hit replay) with the deterministic cost function — after the
    warm-up pass every candidate build is an executable-cache hit."""
    from repro.kernels.autotuned import tune_call
    from repro.tuning import TuningDB

    t0 = time.perf_counter()
    for name, args in ctxs:
        tune_call(name, *args, db=TuningDB(None), interpret=True,
                  num_opt=num_opt, max_iter=max_iter, measure="fixed",
                  cost_fn=_det_cost)
    return time.perf_counter() - t0


def _median(xs):
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else (s[n // 2 - 1] + s[n // 2]) / 2.0


def run(n_ctx=2, num_opt=4, max_iter=2, rounds=7, reps=3, verbose=True) -> dict:
    from repro import obs
    from repro.kernels.autotuned import exec_cache

    ctxs = _contexts(n_ctx)
    obs.shutdown()  # make sure a stray REPRO_OBS doesn't skew the baseline

    def phase():
        # min over back-to-back sweeps: one slow sweep (scheduler hiccup,
        # writer drain landing mid-loop) must not poison the round's ratio
        return min(_sweep(ctxs, num_opt=num_opt, max_iter=max_iter)
                   for _ in range(reps))

    # warm: backend init + every candidate executable into the process
    # cache, once per mode so neither pass pays first-time costs
    obs_tmp = tempfile.mkdtemp(prefix="obs-overhead-")
    _sweep(ctxs, num_opt=num_opt, max_iter=max_iter)
    obs.configure(obs_tmp)
    _sweep(ctxs, num_opt=num_opt, max_iter=max_iter)
    obs.shutdown()

    on_ratios: list = []
    off_ratios: list = []
    offs: list = []
    ons: list = []
    try:
        for _ in range(rounds):
            off_a = phase()
            off_b = phase()
            obs.configure(obs_tmp)
            on = phase()
            obs.shutdown()
            off_c = phase()
            on_ratios.append(on / ((off_b + off_c) / 2.0))
            off_ratios.append(off_b / ((off_a + off_c) / 2.0))
            offs += [off_a, off_b, off_c]
            ons.append(on)
    finally:
        obs.shutdown()
        shutil.rmtree(obs_tmp, ignore_errors=True)

    on_ratio = _median(on_ratios)
    res = {
        "contexts": len(ctxs),
        "rounds": rounds,
        "reps": reps,
        "off_s": _median(offs),
        "on_s": _median(ons),
        "off_ratio": _median(off_ratios),  # self-noise floor
        "on_ratio": on_ratio,
        "gate_ratio": GATE_RATIO,
        "gate_ok": on_ratio <= GATE_RATIO,
        "cache_hits": exec_cache().stats()["hits"],
    }
    if verbose:
        print(
            f"obs overhead over {len(ctxs)} contexts x {rounds} rounds: "
            f"off={res['off_s'] * 1e3:.1f}ms on={res['on_s'] * 1e3:.1f}ms "
            f"ratio={on_ratio:.3f} (gate {GATE_RATIO}, "
            f"self-noise {res['off_ratio']:.3f})"
        )
    return res


def _print_csv(out: dict) -> None:
    print(f"obs_overhead_off,{out['off_s'] * 1e6:.0f},ratio={out['off_ratio']:.3f}")
    print(f"obs_overhead_on,{out['on_s'] * 1e6:.0f},ratio={out['on_ratio']:.3f}")


def smoke():
    out = run(n_ctx=2, num_opt=4, max_iter=2, rounds=7, verbose=True)
    _print_csv(out)
    assert out["gate_ok"], (
        f"obs-on tuning throughput ratio {out['on_ratio']:.3f} "
        f"exceeds the {GATE_RATIO} gate"
    )
    return out


def main(argv=None):
    out = run(n_ctx=3, num_opt=4, max_iter=3, rounds=7, verbose=True)
    _print_csv(out)
    if not out["gate_ok"]:
        raise SystemExit(
            f"obs-on tuning throughput ratio {out['on_ratio']:.3f} "
            f"exceeds the {GATE_RATIO} gate"
        )
    return out


if __name__ == "__main__":
    main()
