"""Tuning-throughput benchmark: what the batched evaluation pipeline buys.

Runs the same PATSMA searches (same kernels, shapes, seed, budget) two ways:

  * sequential — the pre-batching hot path: one candidate at a time through
    ``Autotuning.entire_exec``, a fresh ``jax.jit`` dispatch per candidate,
    nothing cached across searches;
  * batched    — ``tune_call``'s pipeline: per-round dedup via
    ``entire_exec_batch``, concurrent AOT ``lower().compile()`` fan-out
    through the process executable cache, serial measurement overlapping the
    remaining compiles.

Three comparisons:

  * ``best_match`` — with a deterministic cost (a probe kernel whose output
    encodes its knobs) both paths must commit identical best points per
    context: same seed ⇒ same trajectory, timing noise excluded by design.
  * ``cold_ratio`` — wall time over the smoke contexts, both caches cold.
    Bounded by compile parallelism (cores), so it is hardware-dependent.
  * ``retune_ratio`` — the steady state of a long-lived process (drift
    resets, serving re-tunes, repeated pretune refreshes): tuning the same
    grid again.  The batched path answers every candidate from the
    executable cache with **zero recompiles**; the sequential path re-pays
    every trace+compile.  This is the headline ``≤ 0.5x`` number.

Every ``tune_call`` here pins ``measure="fixed"``: this benchmark isolates
the *batching/compile* layers against the fixed-repeat sequential reference,
so the adaptive measurement engine (benchmarked separately in
``measurement_overhead``) must not change the repetition schedule under it.

Prints ``tuning_throughput_{mode},us,...`` CSV lines for the CI artifact.
"""
from __future__ import annotations

import os
import sys
import tempfile
import time

import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def _contexts(n_ctx: int = 2):
    """(kernel, args) pairs — the pretune smoke grid's first contexts."""
    import jax
    import jax.numpy as jnp

    def rnd(seed, shape):
        return jax.random.normal(jax.random.PRNGKey(seed), shape)

    ctxs = [
        ("matmul", (rnd(0, (64, 64)), rnd(1, (64, 64)))),
        ("matmul", (rnd(2, (128, 128)), rnd(3, (128, 128)))),
        ("lru_scan", (0.9 * jnp.ones((2, 64, 32)), rnd(4, (2, 64, 32)), rnd(5, (2, 32)))),
    ]
    return ctxs[:n_ctx]


def sequential_tune(name, *args, db, interpret=True, num_opt=3, max_iter=3,
                    seed=0, warmup=1, repeats=2, cost_fn=None):
    """Reference pre-batching path: per-candidate ``jax.jit`` dispatch, one
    cost at a time through the sequential ``run(cost)`` staging."""
    import jax

    from repro.core import CSA, Autotuning, RuntimeCost
    from repro.kernels.autotuned import get_spec
    from repro.tuning import make_key

    spec = get_spec(name)
    space = spec.space(*args)
    key = make_key(name, args=args, space=space, extra={"interpret": bool(interpret)})
    cost = cost_fn if cost_fn is not None else RuntimeCost(warmup=warmup, repeats=repeats)

    def measure(*knob_values):
        knobs = dict(zip(space.names, knob_values))
        try:
            fn = jax.jit(lambda *xs: spec.fn(*xs, **knobs, interpret=interpret))
            return cost(fn, *args)
        except Exception:
            return np.inf

    at = Autotuning(
        space=space,
        ignore=0,
        search=CSA(len(space), num_opt=num_opt, max_iter=max_iter, seed=seed),
        cache=True,
        db=db,
        key=key,
    )
    at.entire_exec(measure)
    at.commit()
    return db.get(key)


def _register_probe():
    """A kernel whose *output* deterministically encodes its knobs, so a
    cost reading the output is noise-free and knob-dependent — the
    best-point parity check can't be flipped by wall-clock jitter."""
    import jax.numpy as jnp

    from repro.core import LogIntDim, SearchSpace
    from repro.kernels.autotuned import KernelSpec, register

    def probe(x, *, t1, t2, interpret=False):
        # minimum at (t1=16, t2=64) with distinct costs everywhere else
        val = (jnp.log2(t1 / 16.0)) ** 2 + (jnp.log2(t2 / 64.0)) ** 2
        return x.sum() * 0.0 + val

    register(
        KernelSpec(
            name="_throughput_probe",
            fn=probe,
            space=lambda x: SearchSpace([LogIntDim("t1", 4, 64), LogIntDim("t2", 16, 256)]),
            defaults=lambda x: {"t1": 16, "t2": 64},
        )
    )


def _parity_check(num_opt, max_iter, jobs):
    """Deterministic-cost tune through both paths; returns point equality."""
    import jax.numpy as jnp

    from repro.kernels.autotuned import tune_call
    from repro.tuning import TuningDB

    _register_probe()
    x = jnp.ones((4, 4))

    def det_cost(ex, *args):
        return float(np.asarray(ex(*args)))

    rec_b = tune_call("_throughput_probe", x, db=TuningDB(None), interpret=True,
                      num_opt=num_opt, max_iter=max_iter, jobs=jobs, cost_fn=det_cost,
                      measure="fixed")
    rec_s = sequential_tune("_throughput_probe", x, db=TuningDB(None),
                            num_opt=num_opt, max_iter=max_iter, cost_fn=det_cost)
    ok = rec_b is not None and rec_s is not None and rec_b.point == rec_s.point
    return ok, (rec_b.point if rec_b else None)


def run(n_ctx=2, num_opt=4, max_iter=3, jobs=None, verbose=True) -> dict:
    from repro.kernels.autotuned import exec_cache, tune_call
    from repro.tuning import TuningDB

    tmp = tempfile.mkdtemp(prefix="tuning-throughput-")
    ctxs = _contexts(n_ctx)
    cache = exec_cache()

    # jax/pallas warmup so neither timed pass pays backend initialization
    name0, args0 = ctxs[0]
    tune_call(name0, *args0, db=TuningDB(None), interpret=True, num_opt=2,
              max_iter=1, jobs=jobs, measure="fixed")
    cache.clear()

    best_match, probe_point = _parity_check(num_opt, max_iter, jobs)
    cache.clear()

    # --- batched, cold executable cache
    db_b = TuningDB(os.path.join(tmp, "batched.json"))
    t0 = time.perf_counter()
    recs_b = [
        tune_call(name, *args, db=db_b, interpret=True, num_opt=num_opt,
                  max_iter=max_iter, jobs=jobs, measure="fixed")
        for name, args in ctxs
    ]
    batched_cold_s = time.perf_counter() - t0
    cold_stats = cache.stats()

    # --- batched re-tune: same contexts, fresh DB (no exact-hit replay) —
    #     every revisited candidate must come from the executable cache
    db_r = TuningDB(os.path.join(tmp, "retune.json"))
    t0 = time.perf_counter()
    recs_r = [
        tune_call(name, *args, db=db_r, interpret=True, num_opt=num_opt,
                  max_iter=max_iter, jobs=jobs, measure="fixed")
        for name, args in ctxs
    ]
    batched_retune_s = time.perf_counter() - t0
    warm_stats = cache.stats()
    retune_recompiles = warm_stats["recompiles"] - cold_stats["recompiles"]
    retune_compiles = warm_stats["misses"] - cold_stats["misses"]

    # --- sequential cold + re-tune (no cross-search caching exists there)
    t0 = time.perf_counter()
    recs_s = [
        sequential_tune(name, *args, db=TuningDB(os.path.join(tmp, "seq.json")),
                        num_opt=num_opt, max_iter=max_iter)
        for name, args in ctxs
    ]
    sequential_cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for name, args in ctxs:
        sequential_tune(name, *args, db=TuningDB(os.path.join(tmp, "seq2.json")),
                        num_opt=num_opt, max_iter=max_iter)
    sequential_retune_s = time.perf_counter() - t0

    res = {
        "contexts": len(ctxs),
        "best_match": best_match,
        "batched_cold_s": batched_cold_s,
        "sequential_cold_s": sequential_cold_s,
        "cold_ratio": batched_cold_s / max(sequential_cold_s, 1e-9),
        "batched_retune_s": batched_retune_s,
        "sequential_retune_s": sequential_retune_s,
        "retune_ratio": batched_retune_s / max(sequential_retune_s, 1e-9),
        "compiles": cold_stats["misses"],
        "retune_compiles": retune_compiles,
        "retune_recompiles": retune_recompiles,
        "cache_hits": warm_stats["hits"],
        "wall_best_match": all(
            rb is not None and rs is not None and rb.point == rs.point
            for rb, rs in zip(recs_b, recs_s)
        ),
        "retune_best_match": all(
            rb is not None and rr is not None and rb.point == rr.point
            for rb, rr in zip(recs_b, recs_r)
        ),
    }
    if verbose:
        print(
            f"tuning_throughput: cold {batched_cold_s:.2f}s vs {sequential_cold_s:.2f}s "
            f"(ratio {res['cold_ratio']:.2f}) | retune {batched_retune_s:.2f}s vs "
            f"{sequential_retune_s:.2f}s (ratio {res['retune_ratio']:.2f}, "
            f"{retune_compiles} compiles, {retune_recompiles} recompiles) | "
            f"deterministic best match: {best_match} (probe best {probe_point})"
        )
    return res


def _print_csv(out: dict) -> None:
    print(
        f"tuning_throughput_cold,{out['batched_cold_s'] * 1e6:.0f},"
        f"ratio={out['cold_ratio']:.2f}"
    )
    print(
        f"tuning_throughput_retune,{out['batched_retune_s'] * 1e6:.0f},"
        f"ratio={out['retune_ratio']:.2f}"
    )
    print(
        f"tuning_throughput_parity,0,best_match={out['best_match']}"
        f";recompiles={out['retune_recompiles']}"
    )


def smoke():
    out = run(n_ctx=2, num_opt=4, max_iter=2, verbose=True)
    _print_csv(out)
    return out


def main(argv=None):
    out = run(n_ctx=3, num_opt=4, max_iter=3, verbose=True)
    _print_csv(out)
    return out


if __name__ == "__main__":
    main()
