"""Paper §2.3 use-case: "block size (or loop granularity)" — PATSMA over
Pallas kernel tile shapes.

On CPU the kernels run in interpret mode, so wall-time tuning here
demonstrates the mechanism end-to-end (measured cost -> CSA -> tile choice);
on a real TPU the same code tunes MXU tile shapes (the `ops.py` wrappers
take the block sizes as arguments).  We also tune the XLA-path matmul wrapper
where block shape maps to a real CPU-side effect (loop count in interpret
mode still orders candidates consistently)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CSA, Autotuning, LogIntDim, RuntimeCost, SearchSpace
from repro.kernels import ops


def run(m=256, n=256, k=256, verbose=True) -> dict:
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    a = jax.random.normal(ks[0], (m, k), jnp.float32)
    b = jax.random.normal(ks[1], (k, n), jnp.float32)
    space = SearchSpace(
        [LogIntDim("bm", 32, m), LogIntDim("bn", 32, n), LogIntDim("bk", 32, k)]
    )
    cost = RuntimeCost(warmup=1, repeats=2)

    measured = {}

    def measure(bm, bn, bk):
        # the kernel clamps tiles to the problem dims; key on the clamped
        # values so equivalent computations share one measurement
        bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
        key = (bm, bn, bk)
        if key not in measured:
            fn = jax.jit(
                lambda a, b: ops.matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
            )
            measured[key] = cost(fn, a, b)
        return measured[key]

    at = Autotuning(
        space=space, ignore=0,
        search=CSA(3, num_opt=4, max_iter=6, seed=0), cache=True,
    )
    t0 = time.perf_counter()
    at.entire_exec(lambda bm, bn, bk: measure(bm, bn, bk))
    tune_s = time.perf_counter() - t0

    # exhaustive truth over the grid for the quality metric
    def tiles(lim):
        return [t for t in (32, 64, 128, 256) if t <= lim] or [min(32, lim)]

    grid = [(bm, bn, bk) for bm in tiles(m) for bn in tiles(n) for bk in tiles(k)]
    best = min(grid, key=lambda t: measure(*t))
    tuned = (min(at.best_point["bm"], m), min(at.best_point["bn"], n),
             min(at.best_point["bk"], k))
    res = {
        "tuned": tuned,
        "tuned_s": measured[tuned],
        "best": best,
        "best_s": measured[best],
        "worst_s": max(measured.values()),
        "tune_time_s": tune_s,
        "n_measured": len(measured),
    }
    if verbose:
        print(
            f"kernel_autotune: tuned {tuned} = {res['tuned_s']*1e3:.1f} ms | "
            f"best {best} = {res['best_s']*1e3:.1f} ms | worst {res['worst_s']*1e3:.1f} ms"
        )
    return res


def smoke():
    """CI lane: tiny matmul, tiny budget."""
    out = run(m=64, n=64, k=64, verbose=True)
    return {
        "tuned_vs_best": out["tuned_s"] / out["best_s"],
        "n_measured": out["n_measured"],
        "tune_time_s": out["tune_time_s"],
    }


def main(argv=None):
    out = run()
    print(
        f"kernel_autotune_matmul,{out['tuned_s']*1e6:.0f},"
        f"vs_best={out['tuned_s']/out['best_s']:.2f} vs_worst={out['tuned_s']/out['worst_s']:.2f}"
    )
    return out


if __name__ == "__main__":
    main()
