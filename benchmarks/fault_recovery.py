"""Fault-recovery benchmark: what the resilience layer costs, and what it
survives.

Three runs of the *same* deterministic search (matmul block-size grid,
interpret mode, analytic cost model — no timer noise in the search itself):

  * **faulted** — guarded (`FaultPolicy`) under a deterministic
    :class:`~repro.testing.faults.FaultPlan` throwing the acceptance
    scenario at it: one candidate hangs (watchdog changes it to ``inf``),
    one fails transiently twice then succeeds (retried in place), one
    hard-crashes its build (charged ``inf``).  The run must complete and
    converge to the same best point as the fault-free run.
  * **clean** — the classic unguarded run: the reference best point and
    the wall-clock baseline.
  * **guarded** — same `FaultPolicy` armed, zero faults: the pure overhead
    of the guard machinery (watchdog threads, quarantine bookkeeping) on a
    healthy run.  Reported as ``overhead_ratio`` = guarded / clean wall
    (compile cache warm for both, so this is search+measure overhead, not
    compile variance).

Prints ``fault_recovery_*,us,...`` CSV lines for the CI artifact.
"""
from __future__ import annotations

import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

#: 320 = 64*5: a shape no test suite tunes, so the faulted run's compiles
#: are genuinely cold and the injected build crash reaches a real build
N = 320
TIMING_ROUNDS = 5  # median-of-N for the (tiny) warm-cache wall clocks


def _args():
    import jax.numpy as jnp

    return (jnp.ones((N, N), jnp.float32), jnp.ones((N, N), jnp.float32))


def _tune(a, b, *, fault_policy=None, fault_plan=None, measure_stats=None):
    from repro.kernels.autotuned import tune_call
    from repro.tuning import TuningDB
    from repro.tuning.pretune import _analytic_cost_fn

    return tune_call(
        "matmul", a, b,
        db=TuningDB(path=None), interpret=True, strategy="grid",
        cost_fn=_analytic_cost_fn(), warm_start=False, jobs=1,
        fault_policy=fault_policy, fault_plan=fault_plan,
        measure_stats=measure_stats,
    )


def _fault_plan():
    from repro.testing import FaultPlan, FaultSpec

    return FaultPlan([
        FaultSpec(kind="hang", site="cost",
                  match={"bm": 32, "bn": 32, "bk": 64}, seconds=0.3),
        FaultSpec(kind="transient", site="cost",
                  match={"bm": 64, "bn": 32, "bk": 32}, times=2),
        FaultSpec(kind="crash", site="build",
                  match={"bm": 32, "bn": 64, "bk": 32}, times=1),
    ])


def _timed(fn) -> float:
    best = float("inf")
    for _ in range(TIMING_ROUNDS):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(csv: bool = True) -> dict:
    from repro.core import FaultPolicy
    from repro.testing import FaultPlan

    a, b = _args()
    policy = FaultPolicy(measure_timeout=0.05, retries=2, backoff=0.001)

    # faulted first: its compiles are cache-cold, so the injected build
    # crash hits an actual build (later runs reuse the surviving artifacts)
    plan = _fault_plan()
    stats: dict = {}
    t0 = time.perf_counter()
    rec_faulted = _tune(a, b, fault_policy=policy, fault_plan=plan,
                        measure_stats=stats)
    faulted_s = time.perf_counter() - t0
    completed = rec_faulted is not None

    rec_clean = _tune(a, b, fault_plan=FaultPlan([]))
    best_match = (
        completed and rec_clean is not None
        and rec_faulted.point == rec_clean.point
    )

    # warm-cache wall clocks: guard machinery overhead on a healthy run
    clean_s = _timed(lambda: _tune(a, b, fault_plan=FaultPlan([])))
    guarded_s = _timed(
        lambda: _tune(a, b, fault_policy=policy, fault_plan=FaultPlan([]))
    )
    overhead_ratio = guarded_s / clean_s if clean_s > 0 else float("inf")

    out = {
        "completed": completed,
        "best_match": bool(best_match),
        "faults_fired": int(plan.count()),
        "timeouts": int(stats.get("timeouts", 0)),
        "retried": int(stats.get("retried", 0)),
        "faulted_s": faulted_s,
        "clean_s": clean_s,
        "guarded_s": guarded_s,
        "overhead_ratio": overhead_ratio,
        "best_point": str(rec_clean.point if rec_clean is not None else None),
    }
    if csv:
        print(f"fault_recovery_clean,{clean_s * 1e6:.1f},baseline")
        print(f"fault_recovery_guarded,{guarded_s * 1e6:.1f},"
              f"overhead_ratio={overhead_ratio:.3f}")
        print(f"fault_recovery_faulted,{faulted_s * 1e6:.1f},"
              f"completed={completed},best_match={best_match},"
              f"faults_fired={plan.count()}")
    return out


def smoke() -> dict:
    return run()


def main(argv=None) -> dict:
    return run()


if __name__ == "__main__":
    out = main(sys.argv[1:])
    ok = out["completed"] and out["best_match"]
    print(f"fault_recovery: {'OK' if ok else 'FAILED'} {out}")
    sys.exit(0 if ok else 1)
