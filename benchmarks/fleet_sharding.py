"""Fleet benchmark: ShardedPortfolio wall-clock vs the serial Portfolio.

The claim under test (ISSUE 6 acceptance): running a Portfolio race with one
concurrent worker per member turns its wall-clock from the *sum* of every
member's measurements into (roughly) the slowest surviving member's own
time, while the race itself — surviving members and their best points —
stays identical to the serial driver.

The cost model is deterministic-with-simulated-work: each evaluation charges
a fixed ``time.sleep`` (standing in for a kernel measurement pinned to one
device of a multi-chip host) and returns an analytic multimodal landscape
value, so (a) wall-clock honestly reflects the drivers' scheduling and
(b) both drivers see bit-identical costs and must make bit-identical
decisions.  The benchmark asserts both properties: identical surviving
members + member bests, and fleet wall ≤ 0.6× serial wall.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import CSA, NelderMead, Portfolio, RandomSearch
from repro.tuning.fleet import ShardedPortfolio

#: fleet wall-clock must come in under this fraction of the serial race
#: (4 members → ideal is ~0.25–0.3×; 0.6 leaves slack for barrier overhead)
WALL_RATIO_GATE = 0.6


def _cost(x) -> float:
    """Deterministic multimodal landscape (min near 0.3 per dim)."""
    x = np.asarray(x, dtype=float)
    return float(np.sum((x - 0.3) ** 2) + 0.05 * np.cos(8.0 * x[0]))


def _members(rounds: int):
    """A diverse 8-member field (5 CSA restarts, 2 random streams, one
    Nelder–Mead simplex), each with a ``rounds``-round intrinsic budget and
    no shared cap: the race ends when every member finished or was culled,
    so the fleet's wall-clock is literally the slowest surviving member's.
    The simplex is listed last: its check quota accrues over several turns,
    and trailing the field keeps the serial mid-pass check cadence aligned
    with the fleet's pass-boundary one (see the ShardedPortfolio docstring)."""
    per = 4 * rounds  # tells a CSA member consumes (num_opt probes x rounds)
    return [
        *(CSA(2, num_opt=4, max_iter=rounds, seed=s) for s in range(5)),
        RandomSearch(2, max_iter=per, seed=7),
        RandomSearch(2, max_iter=per, seed=8),
        NelderMead(2, error=0.0, max_iter=per, seed=9),
    ]


def _warmup() -> None:
    """Pay one throwaway threaded race before timing anything: thread
    creation and scheduler warm-up otherwise land on the first timed fleet
    pass and skew the ratio on a cold process."""
    sp = ShardedPortfolio(
        [CSA(2, num_opt=2, max_iter=1, seed=0), CSA(2, num_opt=2, max_iter=1, seed=1)],
        rung=2,
    )
    sp.run(lambda i, pts: [_cost(p) for p in pts])


def run(*, rounds: int = 8, rung: int = 4, eval_s: float = 0.005,
        verbose: bool = True) -> dict:
    def measure_point(p) -> float:
        time.sleep(eval_s)  # simulated per-candidate measurement
        return _cost(p)

    _warmup()
    # --- serial reference: the classic single-thread round-robin race
    serial = Portfolio(_members(rounds), rung=rung)
    t0 = time.perf_counter()
    while not serial.is_end():
        batch = serial.ask()
        if not batch:
            break
        serial.tell([measure_point(p) for p in batch])
    serial_wall = time.perf_counter() - t0

    # --- fleet driver: one worker per member, rung-barrier culls
    fleet = ShardedPortfolio(_members(rounds), rung=rung)
    res = fleet.run(lambda i, pts: [measure_point(p) for p in pts])

    ratio = res.wall_s / serial_wall if serial_wall > 0 else float("inf")
    same_survivors = res.survivors == serial.active
    same_bests = all(
        (np.isinf(a) and np.isinf(b)) or abs(a - b) < 1e-12
        for a, b in zip(res.member_bests, serial.member_bests)
    )
    out = {
        "serial_wall_s": round(serial_wall, 4),
        "fleet_wall_s": round(res.wall_s, 4),
        "wall_ratio": round(ratio, 4),
        "serial_spent": serial.spent,
        "fleet_spent": res.spent,
        "survivors_match": same_survivors,
        "bests_match": same_bests,
        "survivors": ",".join(map(str, res.survivors)),
        "best_cost": round(res.best_cost, 6),
    }
    if verbose:
        print(f"fleet_serial_wall,{serial_wall * 1e6:.0f},spent={serial.spent}")
        print(f"fleet_sharded_wall,{res.wall_s * 1e6:.0f},spent={res.spent}")
        print(
            f"fleet_wall_ratio,{ratio * 1e6:.0f},gate<={WALL_RATIO_GATE}"
            f" survivors={'match' if same_survivors else 'MISMATCH'}"
            f" bests={'match' if same_bests else 'MISMATCH'}"
        )
    assert same_survivors, (
        f"fleet survivors {res.survivors} != serial {serial.active}"
    )
    assert same_bests, (
        f"fleet member bests {res.member_bests} != serial {serial.member_bests}"
    )
    assert ratio <= WALL_RATIO_GATE, (
        f"fleet wall-clock {res.wall_s:.3f}s is {ratio:.2f}x serial "
        f"{serial_wall:.3f}s (gate {WALL_RATIO_GATE}x)"
    )
    return out


def smoke() -> dict:
    """CI lane: fewer rounds, shorter simulated measurements."""
    return run(rounds=6, rung=4, eval_s=0.003)


def main(argv=None) -> dict:
    return run()


if __name__ == "__main__":
    main()
