"""Diff a smoke-bench result against the committed baseline.

    python benchmarks/compare.py --baseline benchmarks/baseline_cpu.json \
        --current BENCH_ci.json --out BENCH_diff.json [--strict]

CI's ``bench-smoke`` lane runs this after every smoke sweep so the perf
trajectory is *compared*, not just archived.  The gate is warn-only by
default: drifted metrics are listed (and written to ``--out`` as a
machine-readable diff artifact) but the exit code stays 0 unless
``--strict`` promotes the gate to a hard failure.

What is compared, per benchmark present in both files:

* ``status`` — any transition (ok/skipped/failed) is flagged.
* boolean / parity metrics (``best_match``, ``false_culls``...) — exact.
* numeric metrics — relative drift beyond ``--tolerance`` (default 0.5,
  i.e. ±50%) is flagged.  Keys carrying raw wall-clock seconds (suffix
  ``_s``, ``wall``...) are skipped: they measure the runner, not the code.
"""
from __future__ import annotations

import argparse
import json
import sys

def _is_machine_time(key: str) -> bool:
    """Keys carrying raw host seconds (ratios and counts are kept)."""
    return key.endswith("_s") or key.endswith("_secs") or key == "wall"


def compare(baseline: dict, current: dict, tolerance: float, only=None) -> list:
    """Return a list of diff entries; ``flagged`` entries exceed the gate.

    ``only`` restricts the comparison to the named benches — the partial
    lanes (``serve-replay``) diff a one-bench blob without every other
    baseline row flagging as missing."""
    base_by = {r["bench"]: r for r in baseline.get("results", [])}
    cur_by = {r["bench"]: r for r in current.get("results", [])}
    if only:
        base_by = {b: r for b, r in base_by.items() if b in only}
        cur_by = {b: r for b, r in cur_by.items() if b in only}
    diffs = []
    for bench, base in sorted(base_by.items()):
        cur = cur_by.get(bench)
        if cur is None:
            diffs.append({"bench": bench, "key": "status", "base": base.get("status"),
                          "current": "missing", "flagged": True})
            continue
        if base.get("status") != cur.get("status"):
            # any status transition is news: ok->failed is a regression,
            # skipped->failed is a benchmark starting to crash, and
            # failed->ok / skipped->ok means the baseline wants refreshing
            diffs.append({"bench": bench, "key": "status",
                          "base": base.get("status"),
                          "current": cur.get("status"), "flagged": True})
            continue
        bres, cres = base.get("result") or {}, cur.get("result") or {}
        for key in sorted(set(bres) & set(cres)):
            bv, cv = bres[key], cres[key]
            if isinstance(bv, bool) or isinstance(cv, bool) or isinstance(bv, str):
                if bv != cv:
                    diffs.append({"bench": bench, "key": key, "base": bv,
                                  "current": cv, "flagged": True})
                continue
            if not isinstance(bv, (int, float)) or not isinstance(cv, (int, float)):
                continue
            if _is_machine_time(key):
                continue
            denom = max(abs(float(bv)), 1e-12)
            rel = (float(cv) - float(bv)) / denom
            if abs(rel) > tolerance:
                diffs.append({"bench": bench, "key": key, "base": bv,
                              "current": cv, "rel": round(rel, 3), "flagged": True})
    return diffs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.compare")
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument("--current", required=True, help="fresh smoke-bench JSON")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="relative drift allowed on numeric metrics (default 0.5)")
    ap.add_argument("--out", default=None, help="write the diff JSON here")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to compare (default: all)")
    ap.add_argument("--strict", action="store_true",
                    help="promote the warn gate: exit 1 on any flagged drift")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    only = frozenset(args.only.split(",")) if args.only else None
    diffs = compare(baseline, current, args.tolerance, only=only)
    flagged = [d for d in diffs if d.get("flagged")]

    if args.out:
        with open(args.out, "w") as f:
            json.dump({"tolerance": args.tolerance, "flagged": len(flagged),
                       "diffs": diffs}, f, indent=1)
        print(f"wrote {args.out}")
    if not flagged:
        print(f"bench-compare: OK — no metric drifted beyond ±{args.tolerance:.0%}")
        return 0
    print(f"bench-compare: {len(flagged)} metric(s) drifted beyond "
          f"±{args.tolerance:.0%} of {args.baseline}:")
    for d in flagged:
        rel = f" ({d['rel']:+.0%})" if "rel" in d else ""
        print(f"  {d['bench']}.{d['key']}: {d['base']} -> {d['current']}{rel}")
    if args.strict:
        return 1
    print("bench-compare: warn-only gate — not failing the lane")
    return 0


if __name__ == "__main__":
    sys.exit(main())
