"""Tuning-DB benchmark: what persisting tuning results actually buys.

Measures, for the same kernel context:

  * cold      — full PATSMA search (the paper's Entire Execution cost)
  * near-miss — search seeded from a stored neighbor (half budget)
  * exact     — DB replay (the steady-state of a production process)

Prints ``tuning_warmstart_{mode},us,evals=N`` lines; the CI smoke artifact
tracks the ratios over time.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax

from repro.kernels.autotuned import autotuned, tune_call
from repro.tuning import TuningDB


def run(n_small=64, n_big=128, max_iter=3, verbose=True) -> dict:
    tmp = tempfile.mkdtemp(prefix="tuning-bench-")
    db = TuningDB(os.path.join(tmp, "db.json"))

    def mk(n, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        return (
            jax.random.normal(ks[0], (n, n)),
            jax.random.normal(ks[1], (n, n)),
        )

    a, b = mk(n_small, 0)

    t0 = time.perf_counter()
    rec_cold = tune_call("matmul", a, b, db=db, interpret=True, max_iter=max_iter)
    cold_s = time.perf_counter() - t0

    a2, b2 = mk(n_big, 1)  # same computation, new shape -> neighbor seed
    t0 = time.perf_counter()
    rec_near = tune_call("matmul", a2, b2, db=db, interpret=True, max_iter=max_iter)
    near_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    out = autotuned("matmul", a, b, db=db, interpret=True)  # exact replay
    jax.block_until_ready(out)
    exact_s = time.perf_counter() - t0

    res = {
        "cold_s": cold_s,
        "cold_evals": rec_cold.evals,
        "near_s": near_s,
        "near_evals": rec_near.evals,
        "exact_s": exact_s,
        "near_eval_frac": rec_near.evals / max(rec_cold.evals, 1),
    }
    if verbose:
        print(
            f"tuning_warmstart: cold {cold_s:.2f}s/{rec_cold.evals} evals | "
            f"near-miss {near_s:.2f}s/{rec_near.evals} evals | exact replay {exact_s * 1e3:.1f}ms"
        )
    return res


def smoke():
    out = run(n_small=64, n_big=128, max_iter=2, verbose=True)
    print(f"tuning_warmstart_cold,{out['cold_s'] * 1e6:.0f},evals={out['cold_evals']}")
    print(f"tuning_warmstart_near,{out['near_s'] * 1e6:.0f},evals={out['near_evals']}")
    print(f"tuning_warmstart_exact,{out['exact_s'] * 1e6:.0f},evals=0")
    return out


def main(argv=None):
    out = run()
    print(f"tuning_warmstart_cold,{out['cold_s'] * 1e6:.0f},evals={out['cold_evals']}")
    print(f"tuning_warmstart_near,{out['near_s'] * 1e6:.0f},evals={out['near_evals']}")
    print(f"tuning_warmstart_exact,{out['exact_s'] * 1e6:.0f},evals=0")
    return out


if __name__ == "__main__":
    main()
