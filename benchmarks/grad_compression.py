"""Distributed-optimization trick (DESIGN §7): compressed DP gradient
reduction.  Reports wire bytes per all-reduce and end-loss parity vs exact
fp32 reduction on a small training run (4-way data parallel, subprocess-free:
runs on however many devices are visible; with 1 device the psum is an
identity but the quantization error path is still exercised)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import make_batch_for
from repro.models import ExecConfig, Model
from repro.optim import AdamW
from repro.parallel.collectives import make_compressed_dp_step, wire_bytes
from repro.train import make_loss_fn
from repro.launch.mesh import make_mesh


def run(steps=12, verbose=True) -> dict:
    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev,), ("data",))
    cfg = configs.get_tiny("qwen2_7b")
    model = Model(cfg, ExecConfig(rec_chunk=4))
    loss_fn = make_loss_fn(model)
    opt = AdamW(lr=1e-3)
    params0 = model.init(jax.random.PRNGKey(0))
    B, S = 4 * n_dev, 32

    out = {}
    for method in ("exact", "int8", "topk"):
        step, init_err = make_compressed_dp_step(
            loss_fn, opt, mesh, method=method, k_ratio=0.05
        )
        step = jax.jit(step)
        p, o, e = params0, opt.init(params0), init_err(params0)
        losses = []
        for i in range(steps):
            p, o, e, m = step(p, o, e, make_batch_for(cfg, B, S, i))
            losses.append(float(m["loss"]))
        out[method] = {
            "final_loss": losses[-1],
            "wire_bytes": wire_bytes(params0, "fp32" if method == "exact" else method, 0.05),
        }
        if verbose:
            print(f"grad_compression {method}: final_loss={losses[-1]:.4f} "
                  f"wire={out[method]['wire_bytes']/1e6:.2f} MB/allreduce")
    # parity: compressed training must track exact within a few percent
    for m in ("int8", "topk"):
        rel = abs(out[m]["final_loss"] - out["exact"]["final_loss"]) / out["exact"]["final_loss"]
        out[m]["loss_gap_rel"] = rel
    return out


def main(argv=None):
    out = run()
    for m, v in out.items():
        gap = v.get("loss_gap_rel", 0.0)
        print(f"grad_compression_{m},{v['wire_bytes']},loss={v['final_loss']:.4f} gap={gap:.4f}")
    return out


if __name__ == "__main__":
    main()
