"""Multi-tenant traffic replay: the serving runtime under concurrent load.

A deterministic multi-threaded replay harness over the real serving stack —
:class:`repro.runtime.ContextRouter` dispatch, per-context
:class:`OnlineTuner` striped locks, cross-stream candidate racing, and
quantile objectives — with an analytic cost model so the numbers measure the
*runtime*, not host noise.  Four sections:

* **dispatch** — 16 request threads riding the exact-signature fast path
  (an immutable snapshot read, no lock) vs. the same traffic behind one
  global lock held across each request, the way a coarse-grained router
  would serialize serving.  Gate: ≥8× throughput at 16 threads, and
  per-request dispatch overhead <5% of the request's serving work.
* **racing** — a context tuned by 16 concurrent streams, each request
  contributing one repetition to the current explore candidate's rung,
  vs. the identical search driven by one serial stream.  Gates: racing
  reaches convergence within the serial request count (modulo the ≤1
  in-flight request per stream at the convergence instant) and amortizes
  exploration wall-clock across streams.
* **objectives** — a heavy-tailed candidate surface (fast-median points
  that spike every few repetitions vs. slightly-slower flat points) tuned
  once with ``objective="median"`` and once with ``objective="p99"``.
  Gates: the two objectives pick different winners, and the p99 winner's
  tail is no worse than the median winner's tail.
* **replay mix** — a realistic request trace (bursty shape changes,
  long-tail one-off shapes, diurnal drift of the cost surface) replayed by
  16 threads through one router; reports p50/p95/p99 request latency and
  the serving invariants (no in-band builds, books balanced).

Determinism: request sequences, shapes and candidate costs are all derived
from indices (no RNG, no measurement noise); only the wall-clock throughput
numbers vary with the host, and the gates on those are ratios with wide
margins (theory says ~16× and ~2-4%).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import threading
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

THREADS = 16
WORK_S = 5e-4  # simulated serving work per request (releases the GIL)


def _run_threads(n, fn):
    """Run ``fn(thread_index)`` on ``n`` barrier-released threads; returns
    wall seconds for the whole cohort."""
    barrier = threading.Barrier(n + 1)
    errors = []

    def work(i):
        barrier.wait()
        try:
            fn(i)
        except BaseException as e:  # noqa: BLE001 - surfaced below
            errors.append(e)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return wall


def _fresh_router(epsilon=0.0, drift=None, measure=None, jobs=2):
    from repro.core import IntDim, SearchSpace
    from repro.runtime import ContextRouter
    from repro.tuning import TuningDB

    router = ContextRouter(db=TuningDB(None), jobs=jobs)
    router.register(
        "replay",
        space=lambda *a, **k: SearchSpace([IntDim("p", 1, 16)]),
        defaults=lambda *a, **k: {"p": 4},
        epsilon=epsilon,
        num_opt=3,
        max_iter=3,
        measure=measure,
        drift=drift,
    )
    return router


# ------------------------------------------------------------- A: dispatch
def bench_dispatch(n_threads=THREADS, reps=40, work_s=WORK_S, verbose=True):
    """Lock-light fast-path dispatch vs. a global lock held across each
    request (begin + serving work + observe — the coarse-router model where
    one lock guards all router state for the request's duration)."""
    shapes = 4

    def make_serve(router, req_lock=None):
        def serve(i):
            for r in range(reps):
                extra = {"shape": (i + r) % shapes}
                if req_lock is None:
                    d = router.begin("replay", extra=extra, tenant=f"t{i}")
                    time.sleep(work_s)
                    router.observe(d, 1.0)
                else:
                    with req_lock:
                        d = router.begin("replay", extra=extra, tenant=f"t{i}")
                        time.sleep(work_s)
                        router.observe(d, 1.0)
        return serve

    def warm(router):
        for s in range(shapes):  # pre-create contexts: measure dispatch, not setup
            router.tuner("replay", extra={"shape": s})

    n_req = n_threads * reps

    router = _fresh_router(epsilon=0.0)
    warm(router)
    wall_free = _run_threads(n_threads, make_serve(router))

    router_g = _fresh_router(epsilon=0.0)
    warm(router_g)
    wall_global = _run_threads(n_threads, make_serve(router_g, threading.Lock()))

    # dispatch overhead: time begin+observe directly, with the serving work
    # elided — a cohort-difference measure (threaded wall with vs. without
    # dispatch) drowns the microseconds of interest in sleep() jitter.
    # Min over chunks with GC paused: in a full `benchmarks/run.py` sweep
    # this runs in a process other benches have already heated (leftover
    # executor threads, GC debt), and the min strips that contention the
    # same way repeated timer reps do in the measurement engine.
    import gc

    chunk, n_chunks = 2_500, 8
    gc.collect()
    gc_was_on = gc.isenabled()
    gc.disable()
    try:
        per_chunk = []
        for _ in range(n_chunks):
            t0 = time.perf_counter()
            for r in range(chunk):
                d = router.begin("replay", extra={"shape": r % shapes}, tenant="t0")
                router.observe(d, 1.0)
            per_chunk.append((time.perf_counter() - t0) / chunk)
    finally:
        if gc_was_on:
            gc.enable()
    dispatch_s = min(per_chunk)

    speedup = wall_global / wall_free
    overhead = dispatch_s / work_s
    out = {
        "dispatch_threads": n_threads,
        "dispatch_requests": n_req,
        "dispatch_speedup": round(speedup, 2),
        "dispatch_overhead_frac": round(overhead, 4),
        "dispatch_us_per_req": round(dispatch_s * 1e6, 2),
    }
    if verbose:
        print(
            f"dispatch@{n_threads}t: lock-light {n_req / wall_free:.0f} req/s vs "
            f"global-lock {n_req / wall_global:.0f} req/s -> {speedup:.1f}x | "
            f"overhead {overhead * 100:.2f}% ({out['dispatch_us_per_req']}us/req)"
        )
    return out


# --------------------------------------------------------------- B: racing
def _racing_cost(point):
    return 1.0 + 0.05 * (point["p"] - 11) ** 2


def bench_racing(n_threads=THREADS, work_s=WORK_S, verbose=True):
    """Cross-stream candidate racing vs. the identical serial search.

    Both tuners run the paper's Single-Iteration mode (ε=1: every call
    measures) under a fixed 3-repetition rung, against the same
    deterministic cost surface — so the search trajectory, the candidate
    sequence and the repetitions needed are identical; only who delivers
    the repetitions differs."""
    from repro.core.measure import MeasurePolicy

    policy = MeasurePolicy(mode="fixed", repeats=3)

    # serial reference: one stream feeds every rung
    router_s = _fresh_router(epsilon=1.0, measure=policy)
    tuner_s = router_s.tuner("replay", extra={"shape": 0})
    serial_calls = 0
    t0 = time.perf_counter()
    while not tuner_s.finished and serial_calls < 100_000:
        d = tuner_s.begin()
        tuner_s.observe(d, _racing_cost(d.point))
        time.sleep(work_s)
        serial_calls += 1
    serial_wall = time.perf_counter() - t0

    # racing: n streams share the rungs
    router_r = _fresh_router(epsilon=1.0, measure=policy)
    tuner_r = router_r.tuner("replay", extra={"shape": 0})
    counts = [0] * n_threads

    def serve(i):
        while not tuner_r.finished:
            d = tuner_r.begin(tenant=f"t{i}")
            tuner_r.observe(d, _racing_cost(d.point))
            counts[i] += 1
            time.sleep(work_s)

    racing_wall = _run_threads(n_threads, serve)
    racing_calls = sum(counts)
    s = tuner_r.stats()

    # the convergence instant is only observable after a stream's next
    # begin(): up to one request per stream is already in flight when the
    # finishing repetition lands, so that frontier is the only allowed gap
    converged_le_serial = racing_calls <= serial_calls + n_threads
    amortization = serial_wall / max(racing_wall, 1e-9)
    same_best = tuner_r.best_point == tuner_s.best_point
    out = {
        "racing_threads": n_threads,
        "serial_requests": serial_calls,
        "racing_requests": racing_calls,
        "racing_stale_reps": s["stale_explore_reps"],
        "racing_le_serial": bool(converged_le_serial),
        "racing_same_best": bool(same_best),
        "racing_amortization": round(amortization, 2),
    }
    if verbose:
        print(
            f"racing@{n_threads}t: serial {serial_calls} req / "
            f"{serial_wall * 1e3:.0f}ms vs racing {racing_calls} req / "
            f"{racing_wall * 1e3:.0f}ms (stale {s['stale_explore_reps']}) | "
            f"amortization {amortization:.1f}x, same best: {same_best}"
        )
    return out


# ----------------------------------------------------------- C: objectives
def _tail_cost(point, k):
    """Deterministic heavy-tailed surface: small ``p`` has the best median
    but spikes every 4th repetition; large ``p`` is slightly slower and
    flat.  ``k`` is the point's repetition index."""
    p = point["p"]
    if p <= 4:
        base = 1.0 + 0.03 * abs(p - 3)  # median optimum: p=3
        return base * 4.0 if k % 4 == 3 else base  # 25% tail spikes
    return 1.06 + 0.03 * abs(p - 6)  # flat; tail optimum: p=6


def _tune_with_objective(objective, verbose=False):
    from repro.core import CSA, Autotuning, IntDim, SearchSpace
    from repro.core.measure import MeasurePolicy, quantile
    from repro.runtime import EXPLORE, OnlineTuner

    space = SearchSpace([IntDim("p", 1, 8)])
    at = Autotuning(
        space=space, ignore=0,
        search=CSA(len(space), num_opt=3, max_iter=4, seed=0),
        cache=True, objective=objective,
    )
    policy = MeasurePolicy(mode="fixed", repeats=16, objective=objective)
    tuner = OnlineTuner(at, epsilon=1.0, measure=policy)
    reps_of: dict = {}  # point key -> repetitions served so far
    for _ in range(20_000):
        if tuner.finished:
            break
        d = tuner.begin()
        if d.kind == EXPLORE:
            k = reps_of.get(d.point["p"], 0)
            reps_of[d.point["p"]] = k + 1
            tuner.observe(d, _tail_cost(d.point, k))
        else:
            tuner.observe(d, 1.0)
    best = dict(tuner.best_point)
    # the chosen point's true tail, from its deterministic rep stream
    stream = [_tail_cost(best, k) for k in range(64)]
    return best, quantile(stream, 0.99), quantile(stream, 0.5)


def bench_objectives(verbose=True):
    med_best, med_p99, med_p50 = _tune_with_objective("median")
    p99_best, p99_p99, p99_p50 = _tune_with_objective("p99")
    out = {
        "objective_median_winner": med_best["p"],
        "objective_p99_winner": p99_best["p"],
        "objective_winners_differ": bool(med_best != p99_best),
        "objective_median_winner_p99": round(med_p99, 4),
        "objective_p99_winner_p99": round(p99_p99, 4),
        "objective_p99_no_worse_tail": bool(p99_p99 <= med_p99),
    }
    if verbose:
        print(
            f"objectives: median picks p={med_best['p']} "
            f"(p50 {med_p50:.3f}, p99 {med_p99:.3f}); p99 picks "
            f"p={p99_best['p']} (p99 {p99_p99:.3f}) | winners differ: "
            f"{out['objective_winners_differ']}, tail no worse: "
            f"{out['objective_p99_no_worse_tail']}"
        )
    return out


# ---------------------------------------------------------- D: replay mix
def bench_replay_mix(n_threads=THREADS, reps=80, work_s=2e-4, verbose=True):
    """Realistic multi-tenant trace through one router: bursty shape
    changes (the hot bucket rotates every 16 requests), long-tail one-off
    shapes (every 23rd request is a never-seen context), diurnal drift (the
    cost surface swells and shrinks sinusoidally with trace position)."""
    router = _fresh_router(
        epsilon=0.25,
        drift={"window": 8, "min_samples": 4, "factor": 1.5},
        jobs=2,
    )
    lat_lock = threading.Lock()
    latencies: list = []

    def cost_of(point, r):
        diurnal = 1.0 + 0.4 * math.sin(2 * math.pi * r / (reps / 2))
        return diurnal * (1.0 + 0.05 * (point["p"] - 9) ** 2)

    def serve(i):
        mine = []
        for r in range(reps):
            if r % 23 == 11:
                extra = {"oneoff": (i, r)}  # long-tail: never seen again
            else:
                extra = {"shape": (r // 16) % 4}  # bursty hot bucket
            t0 = time.perf_counter()
            d = router.begin("replay", extra=extra, tenant=f"tenant-{i % 4}")
            time.sleep(work_s)
            router.observe(d, cost_of(d.point, r))
            mine.append(time.perf_counter() - t0)
        with lat_lock:
            latencies.extend(mine)

    wall = _run_threads(n_threads, serve)
    router.wait_pending()
    from repro.core.measure import quantile

    s = router.stats()
    books_balanced = s["calls"] == s["explores"] + s["exploits"]
    out = {
        "mix_requests": len(latencies),
        "mix_contexts": s["contexts"],
        "mix_p50_ms": round(quantile(latencies, 0.50) * 1e3, 3),
        "mix_p95_ms": round(quantile(latencies, 0.95) * 1e3, 3),
        "mix_p99_ms": round(quantile(latencies, 0.99) * 1e3, 3),
        "mix_throughput_rps": round(len(latencies) / wall, 0),
        "mix_drift_resets": s["drift_resets"],
        "mix_explores": s["explores"],
        "mix_inband_builds": s["inband_builds"],
        "mix_books_balanced": bool(books_balanced),
    }
    if verbose:
        print(
            f"mix@{n_threads}t: {out['mix_requests']} req over "
            f"{out['mix_contexts']} contexts | p50 {out['mix_p50_ms']}ms "
            f"p95 {out['mix_p95_ms']}ms p99 {out['mix_p99_ms']}ms | "
            f"{out['mix_throughput_rps']:.0f} req/s, "
            f"{out['mix_drift_resets']} drift resets, books balanced: "
            f"{books_balanced}"
        )
    return out


# ------------------------------------------------------------------ driver
def run(smoke=False, verbose=True) -> dict:
    reps = 30 if smoke else 60
    out = {}
    out.update(bench_dispatch(reps=reps, verbose=verbose))
    out.update(bench_racing(verbose=verbose))
    out.update(bench_objectives(verbose=verbose))
    out.update(bench_replay_mix(reps=60 if smoke else 150, verbose=verbose))
    return out


def _gate(out: dict) -> list:
    problems = []
    if out["dispatch_speedup"] < 8.0:
        problems.append(f"dispatch speedup {out['dispatch_speedup']} < 8x")
    if out["dispatch_overhead_frac"] >= 0.05:
        problems.append(
            f"dispatch overhead {out['dispatch_overhead_frac']} >= 5%"
        )
    if not out["racing_le_serial"]:
        problems.append(
            f"racing took {out['racing_requests']} requests vs serial "
            f"{out['serial_requests']}"
        )
    if not out["racing_same_best"]:
        problems.append("racing and serial searches disagree on the best point")
    if not out["objective_winners_differ"]:
        problems.append("median and p99 objectives picked the same winner")
    if not out["objective_p99_no_worse_tail"]:
        problems.append(
            f"p99 winner's tail {out['objective_p99_winner_p99']} worse than "
            f"median winner's {out['objective_median_winner_p99']}"
        )
    if out["mix_inband_builds"]:
        problems.append(f"{out['mix_inband_builds']} in-band builds in the mix")
    if not out["mix_books_balanced"]:
        problems.append("mix accounting identity broken")
    return problems


def _print_csv(out: dict) -> None:
    print(
        f"traffic_replay_dispatch,{out['dispatch_us_per_req']:.1f},"
        f"speedup={out['dispatch_speedup']}x;overhead={out['dispatch_overhead_frac']}"
    )
    print(
        f"traffic_replay_racing,{out['racing_requests']},"
        f"serial={out['serial_requests']};amortization={out['racing_amortization']}x"
    )
    print(
        f"traffic_replay_objectives,{out['objective_p99_winner']},"
        f"median_winner={out['objective_median_winner']};"
        f"winners_differ={out['objective_winners_differ']}"
    )
    print(
        f"traffic_replay_mix_p99,{out['mix_p99_ms'] * 1e3:.0f},"
        f"p50_ms={out['mix_p50_ms']};contexts={out['mix_contexts']}"
    )


def smoke():
    out = run(smoke=True, verbose=True)
    _print_csv(out)
    problems = _gate(out)
    if problems:
        raise SystemExit(f"traffic replay acceptance failed: {problems}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.traffic_replay")
    ap.add_argument("--smoke", action="store_true", help="reduced CI sizes")
    ap.add_argument("--out", type=str, default=None,
                    help="write a compare.py-compatible JSON blob here")
    args = ap.parse_args(argv)
    t0 = time.time()
    out = run(smoke=args.smoke, verbose=True)
    _print_csv(out)
    problems = _gate(out)
    if args.out:
        blob = {
            "created": time.time(),
            "results": [{
                "bench": "traffic_replay",
                "mode": "smoke" if args.smoke else "full",
                "status": "failed" if problems else "ok",
                "wall_s": time.time() - t0,
                "result": {
                    k: v for k, v in out.items()
                    if isinstance(v, (int, float, str, bool))
                },
            }],
        }
        with open(args.out, "w", encoding="utf-8") as f:
            json.dump(blob, f, indent=1)
        print(f"wrote {args.out}")
    if problems:
        raise SystemExit(f"traffic replay acceptance failed: {problems}")
    return out


if __name__ == "__main__":
    main()
