"""Paper §2.4 execution modes on a real jitted train step.

Knobs: gradient-accumulation microbatches × vocab-chunked-loss chunk — both
recompile the step (the `ignore` mechanism absorbs compile time, the
executable cache avoids recompiling revisited candidates).  Reports the
overhead of Single-Iteration tuning vs an oracle that always uses the best
knobs (the paper's headline trade-off), and Entire-Execution tuning cost."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import configs
from repro.core import ChoiceDim, SearchSpace, TunedStep
from repro.data import make_batch_for
from repro.models import ExecConfig, Model
from repro.optim import AdamW
from repro.train import make_train_step


def run(steps=40, verbose=True) -> dict:
    cfg = configs.get_tiny("qwen2_7b")
    model = Model(cfg, ExecConfig(rec_chunk=4))
    opt = AdamW(lr=1e-3)
    params = model.init(jax.random.PRNGKey(0))
    ost = opt.init(params)
    B, S = 8, 64
    space = SearchSpace(
        [
            ChoiceDim("microbatches", (1, 2, 4)),
            ChoiceDim("logits_chunk", (0, 64, 256)),
        ]
    )

    def factory(microbatches, logits_chunk):
        return jax.jit(
            make_train_step(model, opt, microbatches=microbatches, logits_chunk=logits_chunk)
        )

    # oracle: measure every candidate's steady-state step time
    truth = {}
    for mb in (1, 2, 4):
        for lc in (0, 64, 256):
            fn = factory(mb, lc)
            p, o, m = fn(params, ost, make_batch_for(cfg, B, S, 0))
            jax.block_until_ready(m["loss"])
            t0 = time.perf_counter()
            for i in range(3):
                p, o, m = fn(p, o, make_batch_for(cfg, B, S, i))
                jax.block_until_ready(m["loss"])
            truth[(mb, lc)] = (time.perf_counter() - t0) / 3
    best = min(truth, key=truth.get)

    # Single-Iteration mode riding a training run
    ts = TunedStep(factory, space, ignore=1, num_opt=3, max_iter=6, seed=0)
    p, o = params, ost
    t0 = time.perf_counter()
    for i in range(steps):
        p, o, m = ts(p, o, make_batch_for(cfg, B, S, i))
    jax.block_until_ready(m["loss"])
    total_single = time.perf_counter() - t0

    # oracle run (best knobs throughout, pre-compiled)
    fn = factory(*best)
    p, o = params, ost
    t0 = time.perf_counter()
    for i in range(steps):
        p, o, m = fn(p, o, make_batch_for(cfg, B, S, i))
    jax.block_until_ready(m["loss"])
    total_oracle = time.perf_counter() - t0

    # Entire-Execution mode on a replica batch
    ts2 = TunedStep(factory, space, ignore=1, num_opt=3, max_iter=6, seed=0)
    t0 = time.perf_counter()
    knobs = ts2.tune(params, ost, make_batch_for(cfg, B, S, 0))
    entire_s = time.perf_counter() - t0

    res = {
        "truth_best": best,
        "truth_best_s": truth[best],
        "truth_worst_s": max(truth.values()),
        "single_total_s": total_single,
        "oracle_total_s": total_oracle,
        "single_overhead_pct": 100 * (total_single - total_oracle) / total_oracle,
        "single_final": tuple(ts.best_knobs.values()),
        "entire_tune_s": entire_s,
        "entire_final": tuple(knobs.values()),
    }
    if verbose:
        print("step_autotune truth:", {k: f"{v*1e3:.1f}ms" for k, v in truth.items()})
        print({k: v for k, v in res.items() if k != "truth"})
    return res


def main(argv=None):
    out = run()
    print(
        f"step_autotune_single,{out['single_total_s']*1e6:.0f},"
        f"overhead_pct={out['single_overhead_pct']:.1f} final={out['single_final']}"
    )
    print(
        f"step_autotune_entire,{out['entire_tune_s']*1e6:.0f},final={out['entire_final']}"
    )
    return out


if __name__ == "__main__":
    main()
