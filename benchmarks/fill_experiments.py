"""Fill EXPERIMENTS.md's <!-- DRYRUN_TABLE --> / <!-- ROOFLINE_TABLE -->
markers from results/dryrun_baseline.jsonl (idempotent)."""
import os
import re
import sys

sys.path.insert(0, os.path.dirname(__file__))
from roofline import load, markdown, fraction  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")


def main(argv=None):
    rows = load()
    if not rows:
        print("fill_experiments,0,no results")
        return
    md = markdown(rows)
    dry, roof = md.split("### §Roofline")
    roof = "### §Roofline" + roof
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    text = re.sub(
        r"<!-- DRYRUN_TABLE -->.*?(?=\n## §Roofline)",
        "<!-- DRYRUN_TABLE -->\n" + dry.split("### §Dry-run — ")[1].split("\n", 1)[1].strip() + "\n",
        text,
        flags=re.S,
    )
    text = re.sub(
        r"<!-- ROOFLINE_TABLE -->.*?(?=\n## §Perf)",
        "<!-- ROOFLINE_TABLE -->\n" + roof.split("\n", 2)[2].strip() + "\n",
        text,
        flags=re.S,
    )
    open(path, "w").write(text)
    ok = [r for r in rows if r["status"] == "ok"]
    print(f"fill_experiments,{len(ok)},tables written")


if __name__ == "__main__":
    main()
