"""Search-strategy shootout — the paper's §2.1 CSA-vs-NM comparison rebuilt
on the composable strategy layer.

Four strategies race on the same deterministic cost models with the *same
total tell budget* (paper Eq. (1)/(2) units):

* ``csa``     — the paper's default global search;
* ``nm``      — pure local refinement;
* ``csa+nm``  — the paper's hybrid as a :class:`~repro.core.strategy.Pipeline`
  (CSA explores, NM is warm-seeded at CSA's best and polishes);
* ``csa|nm``  — a :class:`~repro.core.strategy.Portfolio`: both race,
  successive halving reallocates the budget toward the leader;
* ``random``  — the control.

The tracked claims: every strategy consumes the identical tell count
(budget accounting is exact through pipelines and portfolios), and the
hybrid's best is no worse than pure CSA's on every cost model — the
``pipeline_regret_ratio`` row lets ``benchmarks/compare.py`` watch
hybrid-vs-CSA regret across PRs.  Paper Eq. (1)/(2) evaluation counts are
re-checked through the ``Autotuning`` driver, including a strategy-built
pipeline (whose budget is the same ``max_iter * (ignore + 1) * num_opt``).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import Autotuning, NelderMead, make_strategy

STRATEGIES = ("csa", "nm", "random", "csa+nm", "csa|nm")


def sphere(z):
    return float(np.sum(z**2))


def rastrigin(z):
    x = z * 2.0
    return float(10 * x.size + np.sum(x**2 - 10 * np.cos(2 * np.pi * x)))


def rosenbrock(z):
    x = z * 2.0
    return float(np.sum(100 * (x[1:] - x[:-1] ** 2) ** 2 + (1 - x[:-1]) ** 2))


COST_MODELS = {"sphere": sphere, "rastrigin": rastrigin, "rosenbrock": rosenbrock}


def drive(opt, fn):
    """Run a strategy to its end via ask/tell; returns (best, tells, s/tell)."""
    t0 = time.perf_counter()
    n = 0
    while not opt.is_end():
        batch = opt.ask()
        if not batch:
            break
        opt.tell([fn(np.asarray(z)) for z in batch])
        n += len(batch)
    return opt.best_cost, n, (time.perf_counter() - t0) / max(n, 1)


def run(seeds=range(8), budget: int = 320, dims=(2, 4), verbose: bool = True) -> dict:
    table = {}
    tells_equal = True
    for fname, fn in COST_MODELS.items():
        for dim in dims:
            rows = {}
            for spec in STRATEGIES:
                bests, tells, us = [], set(), []
                for s in seeds:
                    opt = make_strategy(
                        spec, dim, num_opt=4, max_iter=budget // 4, seed=s
                    )
                    b, n, t = drive(opt, fn)
                    bests.append(b)
                    tells.add(n)
                    us.append(t * 1e6)
                rows[spec] = {
                    "median_best": float(np.median(bests)),
                    "tells": sorted(tells),
                    "us_per_tell": float(np.median(us)),
                }
                tells_equal &= tells == {budget}
            table[f"{fname}_d{dim}"] = rows
            if verbose:
                print(f"{fname} d={dim}: " + "  ".join(
                    f"{k}={v['median_best']:.3g}" for k, v in rows.items()
                ))

    # hybrid-vs-CSA regret (optimum is 0 for all three models, so the median
    # best IS the regret); ratio < 1 means the hybrid wins
    eps = 1e-9
    ratios = {
        spec: [
            (rows[spec]["median_best"] + eps) / (rows["csa"]["median_best"] + eps)
            for rows in table.values()
        ]
        for spec in ("csa+nm", "csa|nm")
    }
    pipeline_le_csa = all(r <= 1.0 + 1e-12 for r in ratios["csa+nm"])

    # Eq.1 / Eq.2 exact counts through the Autotuning driver — including a
    # strategy-built pipeline, whose total budget is the same Eq.1 product
    eq = {}
    for ignore in (0, 1, 2):
        at = Autotuning(0, 63, ignore=ignore, dim=1, num_opt=4, max_iter=5)
        at.entire_exec(lambda p: (p - 31) ** 2)
        eq[f"csa_ignore{ignore}"] = (at.num_measurements, 5 * (ignore + 1) * 4)
        nm = NelderMead(1, error=0.0, max_iter=12)
        at = Autotuning(0, 63, ignore=ignore, search=nm)
        at.entire_exec(lambda p: (p - 31) ** 2)
        eq[f"nm_ignore{ignore}"] = (at.num_measurements, 12 * (ignore + 1))
        at = Autotuning(
            0, 63, ignore=ignore, dim=1, search="csa+nm", num_opt=4, max_iter=5
        )
        at.entire_exec(lambda p: (p - 31) ** 2)
        eq[f"pipeline_ignore{ignore}"] = (at.num_measurements, 5 * (ignore + 1) * 4)
    assert all(a == b for a, b in eq.values()), eq
    return {
        "table": table,
        "eq_counts": eq,
        "tells_equal": tells_equal,
        "pipeline_le_csa": pipeline_le_csa,
        "pipeline_regret_ratio": float(np.median(ratios["csa+nm"])),
        "portfolio_regret_ratio": float(np.median(ratios["csa|nm"])),
    }


def smoke():
    """CI lane: reduced seed count / budget / dims, same structure."""
    out = run(seeds=range(3), budget=120, dims=(2,), verbose=False)
    eq_ok = all(a == b for a, b in out["eq_counts"].values())
    print(f"strategy_shootout_eq1_eq2,0.0,exact={eq_ok}")
    print(f"strategy_shootout_tells,0.0,equal={out['tells_equal']}")
    print(
        f"strategy_shootout_pipeline,0.0,"
        f"le_csa={out['pipeline_le_csa']} ratio={out['pipeline_regret_ratio']:.3g}"
    )
    return {
        "eq_exact": eq_ok,
        "tells_equal": out["tells_equal"],
        "pipeline_le_csa": out["pipeline_le_csa"],
        "pipeline_regret_ratio": out["pipeline_regret_ratio"],
        "portfolio_regret_ratio": out["portfolio_regret_ratio"],
    }


def main(argv=None):
    out = run()
    for case, rows in out["table"].items():
        for spec, v in rows.items():
            print(
                f"strategy_shootout_{case}_{spec},{v['us_per_tell']:.2f},"
                f"best={v['median_best']:.4g}"
            )
    eq_ok = all(a == b for a, b in out["eq_counts"].values())
    print(f"strategy_shootout_eq1_eq2,0.0,exact={eq_ok}")
    print(f"strategy_shootout_tells,0.0,equal={out['tells_equal']}")
    print(
        f"strategy_shootout_pipeline,0.0,"
        f"le_csa={out['pipeline_le_csa']} ratio={out['pipeline_regret_ratio']:.3g}"
    )
    return out
