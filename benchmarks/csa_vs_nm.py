"""Paper §2.1 claims, quantified: CSA is robust on multimodal landscapes
(escapes local minima), NM is quicker on simple ones; Eq. (1)/(2) evaluation
counts hold exactly.  Random search is the control."""
from __future__ import annotations

import time

import numpy as np

from repro.core import CSA, Autotuning, NelderMead, RandomSearch


def sphere(z):
    return float(np.sum(z**2))


def rastrigin(z):
    x = z * 2.0
    return float(10 * x.size + np.sum(x**2 - 10 * np.cos(2 * np.pi * x)))


def rosenbrock(z):
    x = z * 2.0
    return float(np.sum(100 * (x[1:] - x[:-1] ** 2) ** 2 + (1 - x[:-1]) ** 2))


def drive(opt, fn):
    t0 = time.perf_counter()
    z = opt.run(np.nan)
    n = 0
    while not opt.is_end():
        z = opt.run(fn(z))
        n += 1
    return opt.best_cost, n, (time.perf_counter() - t0) / max(n, 1)


def run(seeds=range(8), budget: int = 320, verbose: bool = True) -> dict:
    fns = {"sphere": sphere, "rastrigin": rastrigin, "rosenbrock": rosenbrock}
    table = {}
    for fname, fn in fns.items():
        for dim in (2, 4):
            rows = {}
            for oname, mk in [
                ("csa", lambda s: CSA(dim, num_opt=4, max_iter=budget // 4, seed=s)),
                ("nm", lambda s: NelderMead(dim, error=0.0, max_iter=budget, seed=s)),
                ("random", lambda s: RandomSearch(dim, max_iter=budget, seed=s)),
            ]:
                bests, evals, us = [], [], []
                for s in seeds:
                    b, n, t = drive(mk(s), fn)
                    bests.append(b)
                    evals.append(n)
                    us.append(t * 1e6)
                rows[oname] = {
                    "median_best": float(np.median(bests)),
                    "evals": int(np.median(evals)),
                    "us_per_eval": float(np.median(us)),
                }
            table[f"{fname}_d{dim}"] = rows
            if verbose:
                print(f"{fname} d={dim}: " + "  ".join(
                    f"{k}={v['median_best']:.3g}({v['evals']}ev)" for k, v in rows.items()
                ))

    # Eq.1 / Eq.2 exact counts through the Autotuning driver
    eq = {}
    for ignore in (0, 1, 2):
        at = Autotuning(0, 63, ignore=ignore, dim=1, num_opt=4, max_iter=5)
        at.entire_exec(lambda p: (p - 31) ** 2)
        eq[f"csa_ignore{ignore}"] = (at.num_measurements, 5 * (ignore + 1) * 4)
        nm = NelderMead(1, error=0.0, max_iter=12)
        at = Autotuning(0, 63, ignore=ignore, optimizer=nm)
        at.entire_exec(lambda p: (p - 31) ** 2)
        eq[f"nm_ignore{ignore}"] = (at.num_measurements, 12 * (ignore + 1))
    assert all(a == b for a, b in eq.values()), eq
    return {"table": table, "eq_counts": eq}


def smoke():
    """CI lane: reduced seed count / budget, same structure."""
    out = run(seeds=range(3), budget=120, verbose=False)
    ok = all(a == b for a, b in out["eq_counts"].values())
    print(f"csa_vs_nm_eq1_eq2,0.0,exact={ok}")
    return {"eq_exact": ok}


def main(argv=None):
    out = run()
    for case, rows in out["table"].items():
        for oname, v in rows.items():
            print(f"csa_vs_nm_{case}_{oname},{v['us_per_eval']:.2f},best={v['median_best']:.4g}")
    ok = all(a == b for a, b in out["eq_counts"].values())
    print(f"csa_vs_nm_eq1_eq2,0.0,exact={ok}")
    return out


if __name__ == "__main__":
    main()
