"""Measurement-overhead benchmark: what adaptive racing buys per search.

Runs the *same* PATSMA search (same space, optimizer, seed) under the two
measurement policies on a deterministic cost model — a synthetic kernel
whose per-repetition "wall time" is its true cost plus a tiny seeded jitter,
so every number here is reproducible and machine-independent:

  * ``fixed``    — the classic schedule: every candidate pays
    ``warmup=1 + repeats=3`` repetitions, cost is the 3-rep median.
  * ``adaptive`` — the :class:`repro.core.measure.MeasureEngine`: one rep
    per candidate, dominated candidates culled against the round best,
    survivors escalating the 1→3→7 ladder, plus the roofline prefilter
    (analytic bound = 0.9 × true cost) skipping hopeless candidates.

Reported: total repetitions spent (the acceptance gate: adaptive ≤ 50% of
fixed), the simulated wall-clock ratio, best-point parity, cull/prune
counts, and the number of *false culls* — candidates raced out whose true
cost is within the calibrated noise floor of the winner (must be zero).

Prints ``measurement_overhead_*,us,...`` CSV lines for the CI artifact.
"""
from __future__ import annotations

import math
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

BASE_S = 1e-3  # true cost scale (1 ms)
JITTER = 1e-3  # per-rep relative jitter amplitude (well inside rel_noise)


def _space():
    from repro.core import LogIntDim, SearchSpace

    return SearchSpace([LogIntDim("t1", 4, 64), LogIntDim("t2", 16, 256)])


def true_cost(point: dict) -> float:
    """Smooth bowl with its minimum at (t1=16, t2=64); distinct costs at
    every grid point, gaps far larger than the jitter."""
    t1, t2 = point["t1"], point["t2"]
    return BASE_S * (
        1.0 + (math.log2(t1 / 16.0)) ** 2 + (math.log2(t2 / 64.0)) ** 2
    )


def _jitter(point: dict, rep_idx: int) -> float:
    """Deterministic pseudo-jitter in [-1, 1] keyed by (point, rep index)."""
    k = (point["t1"] * 1009 + point["t2"]) & 0xFFFFFFFF
    v = (k * 2654435761 + rep_idx * 40503 + 12345) & 0xFFFFFFFF
    return (v / 0xFFFFFFFF) * 2.0 - 1.0


class _CostModel:
    """Counts every simulated repetition and its simulated wall time."""

    def __init__(self) -> None:
        self.reps = 0
        self.wall_s = 0.0
        self._idx: dict = {}  # point key -> next rep index

    def observe(self, point: dict) -> float:
        key = (point["t1"], point["t2"])
        i = self._idx.get(key, 0)
        self._idx[key] = i + 1
        t = true_cost(point) * (1.0 + JITTER * _jitter(point, i))
        self.reps += 1
        self.wall_s += t
        return t

    def rep_fn(self, point: dict):
        return lambda: self.observe(point)


def _driver(seed: int, num_opt: int, max_iter: int):
    from repro.core import CSA, Autotuning

    space = _space()
    return Autotuning(
        space=space,
        ignore=0,
        search=CSA(len(space), num_opt=num_opt, max_iter=max_iter, seed=seed),
        cache=True,
    )


def run_fixed(seed=0, num_opt=5, max_iter=6, warmup=1, repeats=3):
    from repro.core import MeasureEngine, MeasurePolicy

    model = _CostModel()
    engine = MeasureEngine(
        MeasurePolicy(mode="fixed", warmup=warmup, repeats=repeats)
    )
    at = _driver(seed, num_opt, max_iter)

    def measure_batch(points):
        return engine.measure_round([model.rep_fn(p) for p in points])

    at.entire_exec_batch(measure_batch)
    return at, model, engine


def run_adaptive(seed=0, num_opt=5, max_iter=6, warmup=1, roofline=True):
    from repro.core import MeasureEngine, MeasurePolicy

    model = _CostModel()
    engine = MeasureEngine(MeasurePolicy(mode="adaptive", warmup=warmup))
    at = _driver(seed, num_opt, max_iter)

    def measure_batch(points):
        reps = [model.rep_fn(p) for p in points]
        # analytic lower bound: 90% of the true cost (a roofline is always
        # an underestimate of the real wall time)
        bounds = [0.9 * true_cost(p) for p in points] if roofline else None
        return engine.measure_round(reps, bounds=bounds)

    at.entire_exec_batch(measure_batch)
    return at, model, engine


def _false_culls(at, engine) -> int:
    """Culled candidates whose *true* cost sits within the calibrated noise
    floor of the winner — racing must never kill those."""
    noise = engine._noise()
    best_true = true_cost(at.best_point)
    floor = noise.floor(best_true)
    bad = 0
    seen = set()
    for p, _ in at.history:
        k = tuple(sorted(p.items()))
        if k in seen:
            continue
        seen.add(k)
        meta = at.measurement_meta(p)
        if meta and meta.get("culled") and true_cost(p) - best_true <= floor:
            bad += 1
    return bad


def run(seed=0, num_opt=5, max_iter=6, verbose=True) -> dict:
    at_f, model_f, eng_f = run_fixed(seed=seed, num_opt=num_opt, max_iter=max_iter)
    at_a, model_a, eng_a = run_adaptive(seed=seed, num_opt=num_opt, max_iter=max_iter)

    res = {
        "reps_fixed": model_f.reps,
        "reps_adaptive": model_a.reps,
        "reps_ratio": model_a.reps / max(model_f.reps, 1),
        "wall_fixed_s": model_f.wall_s,
        "wall_adaptive_s": model_a.wall_s,
        "wall_ratio": model_a.wall_s / max(model_f.wall_s, 1e-12),
        "best_match": at_a.best_point == at_f.best_point,
        "best_point": str(at_a.best_point),
        "culled": eng_a.stats["culled"],
        "pruned_roofline": eng_a.stats["pruned_roofline"],
        "candidates_fixed": eng_f.stats["candidates"],
        "candidates_adaptive": eng_a.stats["candidates"],
        "false_culls": _false_culls(at_a, eng_a),
    }
    if verbose:
        print(
            f"measurement_overhead: reps {model_a.reps} vs {model_f.reps} "
            f"(ratio {res['reps_ratio']:.2f}) | wall {model_a.wall_s * 1e3:.2f}ms vs "
            f"{model_f.wall_s * 1e3:.2f}ms (ratio {res['wall_ratio']:.2f}) | "
            f"best match: {res['best_match']} ({at_a.best_point}) | "
            f"{res['culled']} culled, {res['pruned_roofline']} roofline-pruned, "
            f"{res['false_culls']} false culls"
        )
    return res


def _print_csv(out: dict) -> None:
    print(
        f"measurement_overhead_adaptive,{out['wall_adaptive_s'] * 1e6:.0f},"
        f"reps_ratio={out['reps_ratio']:.2f};wall_ratio={out['wall_ratio']:.2f}"
    )
    print(
        f"measurement_overhead_parity,0,best_match={out['best_match']}"
        f";false_culls={out['false_culls']};culled={out['culled']}"
        f";pruned={out['pruned_roofline']}"
    )


def smoke():
    out = run(seed=0, num_opt=5, max_iter=4, verbose=True)
    _print_csv(out)
    return out


def main(argv=None):
    out = run(seed=0, num_opt=5, max_iter=8, verbose=True)
    _print_csv(out)
    return out


if __name__ == "__main__":
    main()
