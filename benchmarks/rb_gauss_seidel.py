"""Paper §3 reproduction: auto-tuning the parallel chunk of Red-Black
Gauss-Seidel (paper Algorithms 4-6, Fig. 1a/1b).

The paper tunes OpenMP's ``schedule(dynamic, chunk)``.  The JAX/CPU analogue
with the same runtime trade-off is the row-block size of the red/black
update sweeps: small blocks -> dispatch/loop overhead; large blocks -> cache
pressure; the optimum depends on the machine — exactly the knob class PATSMA
targets.  We tune it three ways (entire-execution runtime mode, single-
iteration runtime mode, and NM instead of CSA) and report overhead + quality
vs an exhaustive sweep, mirroring the paper's comparison of its two modes.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CSA, Autotuning, GridSearch, LogIntDim, NelderMead, SearchSpace


def make_rb_step(n: int, block_rows: int):
    """One red-black Gauss-Seidel sweep on an (n, n) grid, row-blocked."""
    nblocks = n // block_rows
    red = (jnp.indices((n, n)).sum(0) % 2 == 0).astype(jnp.float32)

    @jax.jit
    def step(u, f):
        def color_sweep(u, mask):
            # vectorized neighbor average, applied block-of-rows at a time
            def block_body(i, u):
                r0 = i * block_rows
                rows = jax.lax.dynamic_slice(u, (r0, 0), (block_rows, n))
                up = jax.lax.dynamic_slice(u, (jnp.maximum(r0 - 1, 0), 0), (block_rows, n))
                dn = jax.lax.dynamic_slice(u, (jnp.minimum(r0 + 1, n - block_rows), 0), (block_rows, n))
                lf = jnp.roll(rows, 1, axis=1)
                rt = jnp.roll(rows, -1, axis=1)
                fb = jax.lax.dynamic_slice(f, (r0, 0), (block_rows, n))
                mb = jax.lax.dynamic_slice(mask, (r0, 0), (block_rows, n))
                new = 0.25 * (up + dn + lf + rt + fb)
                rows = jnp.where(mb > 0, new, rows)
                return jax.lax.dynamic_update_slice(u, rows, (r0, 0))

            return jax.lax.fori_loop(0, nblocks, block_body, u)

        u = color_sweep(u, red)
        u = color_sweep(u, 1.0 - red)
        return u

    return step


def run(n: int = 512, iters: int = 60, seed: int = 0, verbose: bool = True) -> dict:
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.normal(size=(n, n)), jnp.float32) / n
    u0 = jnp.zeros((n, n), jnp.float32)
    space = SearchSpace([LogIntDim("block_rows", 4, n // 2)])
    steps = {}

    def get_step(block_rows):
        if block_rows not in steps:
            steps[block_rows] = make_rb_step(n, block_rows)
        return steps[block_rows]

    def timed_sweep(block_rows, u, reps=1):
        st = get_step(block_rows)
        t0 = time.perf_counter()
        for _ in range(reps):
            u = st(u, f)
        jax.block_until_ready(u)
        return time.perf_counter() - t0, u

    # --- exhaustive truth (GridSearch through the same interface) ----------
    truth = {}
    for z in np.linspace(-1, 1, 8):
        br = space.dims[0].decode(z)
        if br in truth:
            continue
        timed_sweep(br, u0)  # compile+warm
        dt, _ = timed_sweep(br, u0, reps=3)
        truth[br] = dt / 3
    best_truth = min(truth, key=truth.get)

    results = {"truth": truth, "best_truth": best_truth}

    # --- Entire Execution mode (paper Alg. 5): tune on a replica up front --
    for name, opt in [
        ("csa_entire", CSA(1, num_opt=4, max_iter=6, seed=seed)),
        ("nm_entire", NelderMead(1, error=0.0, max_iter=18, seed=seed)),
    ]:
        at = Autotuning(space=space, ignore=1, search=opt, cache=True)
        t0 = time.perf_counter()
        u = u0

        def replica(block_rows):
            nonlocal u
            _, u = timed_sweep(block_rows, u)

        at.entire_exec_runtime(replica)
        tune_time = time.perf_counter() - t0
        results[name] = {
            "point": at.best_point["block_rows"],
            "tune_time_s": tune_time,
            "measurements": at.num_measurements,
            "slowdown_vs_best": truth.get(at.best_point["block_rows"], np.inf)
            / truth[best_truth],
        }

    # --- Single Iteration mode (paper Alg. 6): tuning rides the solve ------
    at = Autotuning(
        space=space, ignore=1,
        search=CSA(1, num_opt=4, max_iter=6, seed=seed), cache=True,
    )
    u = u0
    t0 = time.perf_counter()
    for it in range(iters):
        p = at.start()
        _, u = timed_sweep(p["block_rows"], u)
        at.end()
    total_single = time.perf_counter() - t0
    # reference solve at the true best block size
    u = u0
    t0 = time.perf_counter()
    for it in range(iters):
        _, u = timed_sweep(best_truth, u)
    total_best = time.perf_counter() - t0
    results["csa_single"] = {
        "point": at.best_point["block_rows"],
        "total_s": total_single,
        "oracle_total_s": total_best,
        "overhead_pct": 100.0 * (total_single - total_best) / total_best,
    }

    if verbose:
        print("rb_gauss_seidel truth (block_rows -> s/sweep):")
        for k in sorted(truth):
            mark = " <- best" if k == best_truth else ""
            print(f"  {k:6d}: {truth[k]*1e3:8.2f} ms{mark}")
        for k in ("csa_entire", "nm_entire", "csa_single"):
            print(f"  {k}: {results[k]}")
    return results


def main(argv=None):
    out = run()
    # CSV contract: name,us_per_call,derived
    t = out["truth"]
    print(f"rb_gs_best_truth,{t[out['best_truth']]*1e6:.1f},block={out['best_truth']}")
    print(
        f"rb_gs_csa_entire,{out['csa_entire']['tune_time_s']*1e6:.1f},"
        f"slowdown={out['csa_entire']['slowdown_vs_best']:.3f}"
    )
    print(
        f"rb_gs_csa_single,{out['csa_single']['total_s']*1e6:.1f},"
        f"overhead_pct={out['csa_single']['overhead_pct']:.1f}"
    )
    return out


if __name__ == "__main__":
    main()
