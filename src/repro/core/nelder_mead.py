"""Staged Nelder–Mead simplex optimizer (paper's second method).

Nelder & Mead, "A Simplex Method for Function Minimization", Comput J 1965.

Implements the standard reflection/expansion/contraction/shrink moves as a
``run(cost)`` state machine (one cost evaluation per call), matching PATSMA's
constructor ``NelderMead(int dim, double error, int max_iter = 0)``:

  * ``error``    — stop when the simplex cost spread ``max_i |E_i - E_best|``
                   falls below it;
  * ``max_iter`` — maximum number of cost evaluations (0 = unbounded), so that
                   paper Eq. (2) holds: ``num_eval = max_iter * (ignore + 1)``.

Solutions live in ``[-1, 1]^dim`` and are clipped (NM is a local method; PATSMA
wraps only CSA).
"""
from __future__ import annotations

import numpy as np

from .optimizer import NumericalOptimizer

__all__ = ["NelderMead"]

# stages
_INIT, _REFLECT, _EXPAND, _CONTRACT, _SHRINK, _DONE = range(6)


class NelderMead(NumericalOptimizer):
    def __init__(
        self,
        dim: int,
        error: float = 1e-6,
        max_iter: int = 0,
        *,
        alpha: float = 1.0,
        gamma: float = 2.0,
        beta: float = 0.5,
        sigma: float = 0.5,
        init_scale: float = 0.5,
        seed: int = 0,
    ) -> None:
        if dim < 1:
            raise ValueError(f"dim must be >= 1, got {dim}")
        self._dim = dim
        self._error = float(error)
        self._max_evals = int(max_iter)  # paper calls it max_iter; it counts evals
        self._cold_max_evals = int(max_iter)  # shrink_budget narrows the live value
        self._alpha, self._gamma, self._beta, self._sigma = alpha, gamma, beta, sigma
        self._init_scale = init_scale
        self._seed = seed
        self._rng = np.random.default_rng(seed)
        self._full_init()

    # ------------------------------------------------------------------ state
    def _full_init(self) -> None:
        n = self._dim
        x0 = self._rng.uniform(-self._init_scale, self._init_scale, size=n)
        self._simplex = np.tile(x0, (n + 1, 1))
        for i in range(n):
            self._simplex[i + 1, i] = self._clip(
                self._simplex[i + 1, i] + self._init_scale
            )[()]
        self._costs = np.full(n + 1, np.inf)
        self._stage = _INIT
        self._idx = 0  # vertex index being evaluated (INIT / SHRINK)
        self._evals = 0
        self._pending: np.ndarray | None = None  # point whose cost we await
        self._x_r: np.ndarray | None = None
        self._e_r: float = np.inf
        self._shrink_queue: list[int] = []
        self._best_x = self._simplex[0].copy()
        self._best_e = np.inf

    # ------------------------------------------------------------- interface
    def get_num_points(self) -> int:
        return self._dim + 1

    def get_dimension(self) -> int:
        return self._dim

    def is_end(self) -> bool:
        return self._stage == _DONE

    @property
    def best_solution(self) -> np.ndarray:
        return self._best_x.copy()

    @property
    def best_cost(self) -> float:
        return float(self._best_e)

    @property
    def evaluations(self) -> int:
        return self._evals

    def print(self) -> None:  # noqa: A003 - paper API name
        print(
            f"NelderMead(dim={self._dim}) evals={self._evals} stage={self._stage} "
            f"spread={self._spread():.3g} best={self._best_e:.6g}"
        )

    def seed(self, z0, spread: float = 0.2) -> bool:
        """Warm start: build the initial simplex around ``z0`` instead of a
        random point.  Only valid before the first cost is delivered."""
        if self._stage != _INIT or self._idx != 0 or self._pending is not None:
            return False
        z0 = np.asarray(z0, dtype=float).reshape(-1)
        if z0.shape[0] != self._dim:
            raise ValueError(f"seed dim {z0.shape[0]} != {self._dim}")
        self._simplex = np.tile(self._clip(z0), (self._dim + 1, 1))
        for i in range(self._dim):
            base = self._simplex[i + 1, i]
            # perturb toward the interior when the seed sits at the upper
            # bound, else the vertex collapses onto the base point and the
            # simplex has zero extent in that dimension
            step = spread if self._clip(base + spread)[()] != base else -spread
            self._simplex[i + 1, i] = self._clip(base + step)[()]
        self._best_x = self._simplex[0].copy()
        return True

    def shrink_budget(self, frac: float) -> bool:
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"frac must be in (0, 1], got {frac}")
        if self._max_evals > 0:
            # keep at least one full simplex evaluation worth of budget
            self._max_evals = max(self._dim + 2, int(np.ceil(self._max_evals * frac)))
        return True

    def reset(self, level: int = 0) -> None:
        """level 0: rebuild the simplex around the best-known solution;
        level >= 1: complete reset from a fresh random simplex."""
        if level >= 1:
            self._rng = np.random.default_rng(self._seed)
            self._max_evals = self._cold_max_evals
            self._full_init()
            return
        best_x, best_e = self._best_x.copy(), self._best_e
        self._full_init()
        self._simplex[0] = best_x
        self._best_x = best_x
        self._best_e = best_e  # level 0 retains the solutions found (§2.2)

    # ------------------------------------------------------------------- run
    def run(self, cost: float) -> np.ndarray:
        if self._stage == _DONE:
            return self.best_solution
        cost = float(cost) if np.isfinite(cost) else np.inf

        if self._pending is not None:
            self._evals += 1
            if cost < self._best_e:
                self._best_e = cost
                self._best_x = self._pending.copy()
            self._dispatch_cost(cost)
            if self._stage == _DONE:
                return self.best_solution
            if self._exhausted():
                self._stage = _DONE
                return self.best_solution

        return self._emit_next()

    def _exhausted(self) -> bool:
        return self._max_evals > 0 and self._evals >= self._max_evals

    def _spread(self) -> float:
        finite = self._costs[np.isfinite(self._costs)]
        if finite.size < 2:
            return np.inf
        return float(np.max(finite) - np.min(finite))

    # ------------------------------------------------------------ transitions
    def _emit(self, x: np.ndarray) -> np.ndarray:
        self._pending = x.copy()
        return x.copy()

    def _emit_next(self) -> np.ndarray:
        if self._pending is not None:
            # dispatch staged the next point itself (expansion / contraction)
            return self._pending.copy()
        if self._stage == _INIT:
            return self._emit(self._simplex[self._idx])
        if self._stage == _SHRINK:
            return self._emit(self._simplex[self._shrink_queue[0]])
        # start a fresh NM iteration: order simplex, reflect the worst
        self._order()
        if self._spread() < self._error:
            self._stage = _DONE
            return self.best_solution
        c = self._centroid()
        self._x_r = self._clip(c + self._alpha * (c - self._simplex[-1]))
        self._stage = _REFLECT
        return self._emit(self._x_r)

    def _dispatch_cost(self, cost: float) -> None:
        if self._stage == _INIT:
            self._costs[self._idx] = cost
            self._idx += 1
            self._pending = None
            if self._idx > self._dim:
                self._begin_iteration()  # full simplex known; next emit reflects
            return

        if self._stage == _REFLECT:
            self._e_r = cost
            c = self._centroid()
            if cost < self._costs[0]:
                # try expansion
                x_e = self._clip(c + self._gamma * (self._x_r - c))
                if np.allclose(x_e, self._x_r):
                    self._accept(self._x_r, cost)
                    self._begin_iteration()
                else:
                    self._stage = _EXPAND
                    self._pending = x_e
                return
            if cost < self._costs[-2]:
                self._accept(self._x_r, cost)
                self._begin_iteration()
                return
            # contraction (outside if reflect better than worst, else inside)
            if cost < self._costs[-1]:
                x_c = self._clip(c + self._beta * (self._x_r - c))
            else:
                x_c = self._clip(c - self._beta * (c - self._simplex[-1]))
            self._stage = _CONTRACT
            self._pending = x_c
            return

        if self._stage == _EXPAND:
            if cost < self._e_r:
                self._accept(self._pending, cost)
            else:
                self._accept(self._x_r, self._e_r)
            self._begin_iteration()
            return

        if self._stage == _CONTRACT:
            if cost < min(self._e_r, self._costs[-1]):
                self._accept(self._pending, cost)
                self._begin_iteration()
                return
            # shrink toward the best vertex
            for i in range(1, self._dim + 1):
                self._simplex[i] = self._clip(
                    self._simplex[0] + self._sigma * (self._simplex[i] - self._simplex[0])
                )
                self._costs[i] = np.inf
            self._shrink_queue = list(range(1, self._dim + 1))
            self._stage = _SHRINK
            self._pending = None
            return

        if self._stage == _SHRINK:
            i = self._shrink_queue.pop(0)
            self._costs[i] = cost
            if not self._shrink_queue:
                self._begin_iteration()
            else:
                self._pending = None
            return

    def _accept(self, x: np.ndarray, cost: float) -> None:
        """Replace the worst vertex."""
        self._simplex[-1] = x
        self._costs[-1] = cost

    def _begin_iteration(self) -> None:
        """Mark that the next emit starts a fresh order/reflect cycle."""
        self._stage = _REFLECT
        self._pending = None
        self._x_r = None
        self._e_r = np.inf
        # _emit_next() recognises a fresh cycle because _pending is None and
        # stage is _REFLECT with _x_r unset → it orders and reflects.

    def _order(self) -> None:
        order = np.argsort(self._costs, kind="stable")
        self._simplex = self._simplex[order]
        self._costs = self._costs[order]

    def _centroid(self) -> np.ndarray:
        return np.mean(self._simplex[:-1], axis=0)
