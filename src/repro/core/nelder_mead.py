"""Staged Nelder–Mead simplex optimizer (paper's second method).

Nelder & Mead, "A Simplex Method for Function Minimization", Comput J 1965.

Implements the standard reflection/expansion/contraction/shrink moves over
the batch ``ask()``/``tell()`` protocol, matching PATSMA's constructor
``NelderMead(int dim, double error, int max_iter = 0)``:

  * ``error``    — stop when the simplex cost spread ``max_i |E_i - E_best|``
                   falls below it;
  * ``max_iter`` — maximum number of cost evaluations (0 = unbounded), so that
                   paper Eq. (2) holds: ``num_eval = max_iter * (ignore + 1)``.

Natural batches: the initial simplex (``dim + 1`` vertices) and a shrink round
(``dim`` vertices) are emitted whole; reflect/expand/contract probes are
single-point batches because each depends on the previous cost.  The
sequential ``run(cost)`` staging (one cost per call) is the base-class adapter
over ask/tell and emits the identical candidate sequence.

``speculative=True`` (beyond-paper, default off) widens the reflect batch to
``[x_r, x_e, x_c_out, x_c_in]`` — all four are computable before the
reflection cost is known — so a batched driver can compile/measure them
concurrently.  ``tell`` then *consumes* only the costs the sequential
algorithm would have looked at (the rest are discarded), keeping the simplex
trajectory, best point, and the ``evaluations`` budget bit-identical to the
non-speculative run; the extra measurements are pure compile/measure overlap
paid by the driver (whose own measurement/eval counters do record them).

Solutions live in ``[-1, 1]^dim`` and are clipped (NM is a local method; PATSMA
wraps only CSA).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from .optimizer import NumericalOptimizer

__all__ = ["NelderMead"]

# stages
_INIT, _REFLECT, _EXPAND, _CONTRACT, _SHRINK, _DONE = range(6)


class NelderMead(NumericalOptimizer):
    def __init__(
        self,
        dim: int,
        error: float = 1e-6,
        max_iter: int = 0,
        *,
        alpha: float = 1.0,
        gamma: float = 2.0,
        beta: float = 0.5,
        sigma: float = 0.5,
        init_scale: float = 0.5,
        seed: int = 0,
        speculative: bool = False,
    ) -> None:
        if dim < 1:
            raise ValueError(f"dim must be >= 1, got {dim}")
        self._dim = dim
        self._error = float(error)
        self._max_evals = int(max_iter)  # paper calls it max_iter; it counts evals
        self._cold_max_evals = int(max_iter)  # shrink_budget narrows the live value
        self._alpha, self._gamma, self._beta, self._sigma = alpha, gamma, beta, sigma
        self._init_scale = init_scale
        self._seed = seed
        self._speculative = bool(speculative)
        self._rng = np.random.default_rng(seed)
        self._full_init()

    # ------------------------------------------------------------------ state
    def _full_init(self) -> None:
        n = self._dim
        x0 = self._rng.uniform(-self._init_scale, self._init_scale, size=n)
        self._simplex = np.tile(x0, (n + 1, 1))
        for i in range(n):
            self._simplex[i + 1, i] = self._clip(
                self._simplex[i + 1, i] + self._init_scale
            )[()]
        self._costs = np.full(n + 1, np.inf)
        self._stage = _INIT
        self._init_idx = 0  # vertices whose cost is known (INIT staging)
        self._evals = 0
        self._x_r: Optional[np.ndarray] = None  # reflection point in flight
        self._e_r: float = np.inf
        self._centroid_c: Optional[np.ndarray] = None  # centroid for _x_r
        self._x_e: Optional[np.ndarray] = None  # staged expansion point
        self._x_c: Optional[np.ndarray] = None  # staged contraction point
        self._shrink_queue: list = []
        self._best_x = self._simplex[0].copy()
        self._best_e = np.inf
        self._clear_batch_state()

    # ------------------------------------------------------------- interface
    def get_num_points(self) -> int:
        return self._dim + 1

    def get_dimension(self) -> int:
        return self._dim

    def is_end(self) -> bool:
        return self._stage == _DONE

    @property
    def best_solution(self) -> np.ndarray:
        return self._best_x.copy()

    @property
    def best_cost(self) -> float:
        return float(self._best_e)

    @property
    def evaluations(self) -> int:
        return self._evals

    @property
    def speculative(self) -> bool:
        return self._speculative

    def print(self) -> None:  # noqa: A003 - paper API name
        print(
            f"NelderMead(dim={self._dim}) evals={self._evals} stage={self._stage} "
            f"spread={self._spread():.3g} best={self._best_e:.6g}"
        )

    def seed(self, z0, spread: float = 0.2) -> bool:
        """Warm start: build the initial simplex around ``z0`` instead of a
        random point.  Only valid before the first candidate is emitted."""
        if self._stage != _INIT or self._init_idx != 0 or self._pending_batch is not None:
            return False
        z0 = np.asarray(z0, dtype=float).reshape(-1)
        if z0.shape[0] != self._dim:
            raise ValueError(f"seed dim {z0.shape[0]} != {self._dim}")
        self._simplex = np.tile(self._clip(z0), (self._dim + 1, 1))
        for i in range(self._dim):
            base = self._simplex[i + 1, i]
            # perturb toward the interior when the seed sits at the upper
            # bound, else the vertex collapses onto the base point and the
            # simplex has zero extent in that dimension
            step = spread if self._clip(base + spread)[()] != base else -spread
            self._simplex[i + 1, i] = self._clip(base + step)[()]
        self._best_x = self._simplex[0].copy()
        return True

    def shrink_budget(self, frac: float) -> bool:
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"frac must be in (0, 1], got {frac}")
        if self._max_evals > 0:
            # keep at least one full simplex evaluation worth of budget
            self._max_evals = max(self._dim + 2, int(np.ceil(self._max_evals * frac)))
        return True

    def reset(self, level: int = 0) -> None:
        """level 0: rebuild the simplex around the best-known solution;
        level >= 1: complete reset from a fresh random simplex.  Both levels
        restore the cold evaluation budget — a reset starts a new search
        episode, so a warm-start-shrunk budget does not compound."""
        if level >= 1:
            self._rng = np.random.default_rng(self._seed)
            self._max_evals = self._cold_max_evals
            self._full_init()
            return
        best_x, best_e = self._best_x.copy(), self._best_e
        self._full_init()
        self._max_evals = self._cold_max_evals
        self._simplex[0] = best_x
        self._best_x = best_x
        self._best_e = best_e  # level 0 retains the solutions found (§2.2)

    # -------------------------------------------------------- batch protocol
    def _remaining(self) -> Optional[int]:
        return (self._max_evals - self._evals) if self._max_evals > 0 else None

    def _exhausted(self) -> bool:
        return self._max_evals > 0 and self._evals >= self._max_evals

    def _spread(self) -> float:
        finite = self._costs[np.isfinite(self._costs)]
        if finite.size < 2:
            return np.inf
        return float(np.max(finite) - np.min(finite))

    def _next_batch(self) -> Optional[List[np.ndarray]]:
        rem = self._remaining()
        if rem is not None and rem <= 0:
            self._stage = _DONE
            return None
        if self._stage == _INIT:
            pts = [self._simplex[i].copy() for i in range(self._init_idx, self._dim + 1)]
            return pts if rem is None else pts[:rem]
        if self._stage == _SHRINK:
            pts = [self._simplex[i].copy() for i in self._shrink_queue]
            return pts if rem is None else pts[:rem]
        if self._stage == _EXPAND:
            return [self._x_e.copy()]
        if self._stage == _CONTRACT:
            return [self._x_c.copy()]
        # _REFLECT: start a fresh NM iteration — order simplex, reflect worst
        self._order()
        if self._spread() < self._error:
            self._stage = _DONE
            return None
        c = self._centroid()
        self._centroid_c = c
        self._x_r = self._clip(c + self._alpha * (c - self._simplex[-1]))
        self._e_r = np.inf
        if self._speculative and (rem is None or rem >= 2):
            # expansion and both contraction candidates depend only on the
            # simplex and x_r — compute them now so the driver can overlap
            # their compilation/measurement with the reflection's
            x_e = self._clip(c + self._gamma * (self._x_r - c))
            x_co = self._clip(c + self._beta * (self._x_r - c))
            x_ci = self._clip(c - self._beta * (c - self._simplex[-1]))
            return [self._x_r.copy(), x_e, x_co, x_ci]
        return [self._x_r.copy()]

    def _consume_batch(self, points: List[np.ndarray], costs: List[float]) -> None:
        if self._stage == _INIT:
            for x, c in zip(points, costs):
                self._consume_one(x, c)
                self._costs[self._init_idx] = c
                self._init_idx += 1
                if self._exhausted():
                    self._stage = _DONE
                    return
            if self._init_idx > self._dim:
                self._begin_iteration()
            return

        if self._stage == _SHRINK:
            for x, c in zip(points, costs):
                i = self._shrink_queue.pop(0)
                self._consume_one(x, c)
                self._costs[i] = c
                if self._exhausted():
                    self._stage = _DONE
                    return
            if not self._shrink_queue:
                self._begin_iteration()
            return

        if self._stage == _EXPAND:
            c = costs[0]
            self._consume_one(points[0], c)
            if c < self._e_r:
                self._accept(self._x_e, c)
            else:
                self._accept(self._x_r, self._e_r)
            self._begin_iteration()
            self._check_budget()
            return

        if self._stage == _CONTRACT:
            c = costs[0]
            self._consume_one(points[0], c)
            self._contract_decide(self._x_c, c)
            self._check_budget()
            return

        # _REFLECT (single or speculative batch)
        c_r = costs[0]
        self._consume_one(points[0], c_r)
        self._e_r = c_r
        if self._exhausted():
            self._stage = _DONE
            return
        spec = len(points) > 1
        c = self._centroid_c
        if c_r < self._costs[0]:
            # try expansion
            x_e = self._clip(c + self._gamma * (self._x_r - c))
            if np.allclose(x_e, self._x_r):
                self._accept(self._x_r, c_r)
                self._begin_iteration()
            elif spec:
                c_e = costs[1]
                self._consume_one(points[1], c_e)
                if c_e < self._e_r:
                    self._accept(x_e, c_e)
                else:
                    self._accept(self._x_r, self._e_r)
                self._begin_iteration()
                self._check_budget()
            else:
                self._x_e = x_e
                self._stage = _EXPAND
            return
        if c_r < self._costs[-2]:
            self._accept(self._x_r, c_r)
            self._begin_iteration()
            return
        # contraction (outside if reflect better than worst, else inside)
        outside = c_r < self._costs[-1]
        if outside:
            x_c = self._clip(c + self._beta * (self._x_r - c))
        else:
            x_c = self._clip(c - self._beta * (c - self._simplex[-1]))
        if spec:
            i = 2 if outside else 3
            c_c = costs[i]
            self._consume_one(points[i], c_c)
            self._contract_decide(x_c, c_c)
            self._check_budget()
        else:
            self._x_c = x_c
            self._stage = _CONTRACT
        return

    # ------------------------------------------------------------ transitions
    def _consume_one(self, x: np.ndarray, cost: float) -> None:
        self._evals += 1
        if cost < self._best_e:
            self._best_e = cost
            self._best_x = np.array(x, dtype=float, copy=True)

    def _check_budget(self) -> None:
        if self._exhausted():
            self._stage = _DONE

    def _contract_decide(self, x_c: np.ndarray, cost: float) -> None:
        if cost < min(self._e_r, self._costs[-1]):
            self._accept(x_c, cost)
            self._begin_iteration()
            return
        # shrink toward the best vertex
        for i in range(1, self._dim + 1):
            self._simplex[i] = self._clip(
                self._simplex[0] + self._sigma * (self._simplex[i] - self._simplex[0])
            )
            self._costs[i] = np.inf
        self._shrink_queue = list(range(1, self._dim + 1))
        self._stage = _SHRINK

    def _accept(self, x: np.ndarray, cost: float) -> None:
        """Replace the worst vertex."""
        self._simplex[-1] = x
        self._costs[-1] = cost

    def _begin_iteration(self) -> None:
        """Mark that the next batch starts a fresh order/reflect cycle."""
        self._stage = _REFLECT
        self._x_r = None
        self._e_r = np.inf
        self._x_e = None
        self._x_c = None
        self._centroid_c = None

    def _order(self) -> None:
        order = np.argsort(self._costs, kind="stable")
        self._simplex = self._simplex[order]
        self._costs = self._costs[order]

    def _centroid(self) -> np.ndarray:
        return np.mean(self._simplex[:-1], axis=0)
