"""Resilience layer — guard every compile and measurement against the ways
live hardware misbehaves.

PATSMA tunes *on the target*, where candidate configurations routinely go
wrong in ways a static legality check cannot see: a tile that hangs the
backend, a build that exhausts memory only under concurrent compile load, a
kernel that hard-crashes the process.  This module is the dynamic complement
to the illegal-candidate classifier — one bad candidate must never cost more
than its own budget:

* :class:`FaultPolicy` — the per-run knobs: per-stage watchdog timeouts,
  transient-retry counts with exponential backoff (deterministically
  jittered, so two shards never sync up their retry storms), and the
  max-failures threshold behind :class:`Quarantine`.
* :func:`guarded_call` — run a callable under a watchdog deadline, retrying
  transient failures with backoff.  Hang detection is thread-based: the
  callable runs on a daemon worker and the caller waits ``timeout``; a hung
  worker is abandoned (it cannot be killed from Python) and the candidate is
  charged ``inf`` by the classification layers above.
* :func:`sandboxed_probe` — optional subprocess sandbox for the *first touch*
  of a never-seen candidate: a hard crash (segfault, ``os._exit``) is
  contained in the child and surfaces as :class:`SandboxCrash` instead of
  killing the tuning run.
* :class:`Quarantine` — per-candidate failure counting; a candidate that
  fails ``max_failures`` times stops being offered a build at all and is
  charged ``inf`` through the existing ``Autotuning.skip()`` path.
* :class:`CircuitBreaker` — per-context explore gating for the online tuner:
  a context whose explores keep failing stops burning ε-credits and serves
  the incumbent, with half-open probes to recover.  Count-based (cooldown
  measured in denied calls, not wall time) so tests and replays are
  deterministic.

Transient-vs-permanent classification lives here (:func:`is_transient_failure`)
so both the core measurement layers and the kernel layer share one notion of
"worth retrying"; the kernel layer's ``classify_failure`` builds on it.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Any, Callable, Dict, Hashable, Optional, Set

from repro.obs import events as _events
from repro.obs import metrics as _metrics

__all__ = [
    "GuardTimeout",
    "SandboxCrash",
    "FaultPolicy",
    "is_transient_failure",
    "deterministic_backoff",
    "guarded_call",
    "sandboxed_probe",
    "Quarantine",
    "CircuitBreaker",
]


class GuardTimeout(Exception):
    """A guarded call exceeded its watchdog deadline (a hang, as far as the
    tuner is concerned).  Classified *transient* — a hang can be an artifact
    of load, so a revisited candidate gets a fresh attempt — but never
    retried in-band by :func:`guarded_call`: each retry would cost another
    full deadline, so the charge is immediate and the retry happens only if
    the search ever revisits the candidate."""


class SandboxCrash(Exception):
    """A sandboxed first-touch probe died without reporting a result (e.g.
    segfault / ``os._exit``): the candidate hard-crashes and must be charged
    ``inf`` — but thanks to the sandbox, in a child process, not ours."""

    def __init__(self, message: str, exitcode: Optional[int] = None) -> None:
        super().__init__(message)
        self.exitcode = exitcode


#: substrings marking failures that may be artifacts of the moment (memory
#: pressure from concurrent compiles, a busy allocator) rather than of the
#: candidate itself.  Shared with the kernel layer's failure classifier —
#: this is the RESOURCE_EXHAUSTED class ``classify_failure`` distinguishes.
TRANSIENT_MARKERS = ("resource_exhausted", "resource exhausted", "out of memory")


def is_transient_failure(exc: BaseException) -> bool:
    """Whether ``exc`` is worth retrying: resource exhaustion (which can be
    load-induced) and watchdog timeouts qualify; everything else — illegal
    tiles, programmer errors — is deterministic for a fixed context."""
    if isinstance(exc, GuardTimeout):
        return True  # maybe load-induced; retried on *revisit*, not in-band
    if isinstance(exc, SandboxCrash):
        return False
    msg = f"{type(exc).__name__}: {exc}".lower()
    return any(m in msg for m in TRANSIENT_MARKERS)


def deterministic_backoff(
    attempt: int,
    base: float,
    mult: float,
    jitter: float,
    token: str = "",
) -> float:
    """Exponential backoff with *deterministic* jitter.

    ``base * mult**attempt``, stretched by up to ``jitter`` fraction where
    the stretch is a hash of ``(token, attempt)`` — same token, same delays
    on every run (testable; replayable), different tokens (different
    candidates, different shards) desynchronized so a fleet's retries do not
    stampede in lockstep."""
    delay = float(base) * float(mult) ** int(attempt)
    if jitter > 0.0:
        h = hashlib.sha256(f"{token}\x00{attempt}".encode()).digest()
        frac = int.from_bytes(h[:8], "big") / float(1 << 64)  # [0, 1)
        delay *= 1.0 + float(jitter) * frac
    return delay


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Per-run resilience knobs, threaded through ``tune_call`` and the
    measurement engine.

    ``compile_timeout`` / ``measure_timeout`` are per-*stage* watchdog
    deadlines in seconds (``None`` disables the watchdog for that stage;
    ``measure_timeout`` covers one cost evaluation — one repetition under the
    adaptive engine, the whole warmup+repeats loop under a ``RuntimeCost``).
    ``compile_deadline`` bounds a whole fan-out round
    (:func:`repro.core.costs.compile_fanout`).  ``retries`` transient
    failures are retried in place with ``backoff * backoff_mult**attempt``
    seconds of deterministically-jittered sleep between attempts.
    ``max_failures`` is the :class:`Quarantine` threshold.  ``fail_fast``
    makes the compile fan-out cancel the round and raise on the first
    *non-transient unexpected* error (a poisoned executor — e.g. a TypeError
    that would hit every candidate identically) instead of draining it.
    ``sandbox_first_touch`` probes each never-seen candidate in a forked
    child first, so a hard crash is contained and charged ``inf``."""

    compile_timeout: Optional[float] = None
    measure_timeout: Optional[float] = None
    compile_deadline: Optional[float] = None
    retries: int = 2
    backoff: float = 0.05
    backoff_mult: float = 2.0
    jitter: float = 0.25
    max_failures: int = 3
    fail_fast: bool = False
    sandbox_first_touch: bool = False
    sandbox_timeout: float = 60.0

    def __post_init__(self) -> None:
        if self.retries < 0:
            raise ValueError("retries must be >= 0")
        if self.backoff < 0 or self.backoff_mult < 1.0:
            raise ValueError("backoff must be >= 0 and backoff_mult >= 1")
        if self.max_failures < 1:
            raise ValueError("max_failures must be >= 1")

    def timeout_for(self, stage: str) -> Optional[float]:
        return self.compile_timeout if stage == "compile" else self.measure_timeout

    def wrap(
        self,
        fn: Callable[[], Any],
        *,
        stage: str = "measure",
        label: str = "",
        on_retry: Optional[Callable[[int, BaseException, float], None]] = None,
        sleep: Callable[[float], None] = time.sleep,
    ) -> Callable[[], Any]:
        """A zero-arg callable running ``fn`` under this policy's guard for
        ``stage`` — drop-in wherever a build/rep thunk is expected."""
        return lambda: guarded_call(
            fn,
            timeout=self.timeout_for(stage),
            retries=self.retries,
            backoff=self.backoff,
            backoff_mult=self.backoff_mult,
            jitter=self.jitter,
            label=label or stage,
            on_retry=on_retry,
            sleep=sleep,
        )


def _call_with_deadline(fn: Callable[[], Any], timeout: float, label: str) -> Any:
    """Run ``fn`` on a watchdog-supervised daemon thread; raise
    :class:`GuardTimeout` if it has not finished within ``timeout``.

    A hung worker thread cannot be killed from Python — it is abandoned as a
    daemon (it will not block interpreter exit) and its eventual result, if
    any, is discarded.  Acceptable for the short hangs the tuner guards
    against; a candidate that wedges a thread forever is exactly what the
    quarantine then keeps from being built again."""
    box: Dict[str, Any] = {}
    done = threading.Event()

    def runner() -> None:
        try:
            box["value"] = fn()
        except BaseException as e:  # delivered to the caller below
            box["error"] = e
        finally:
            done.set()

    t = threading.Thread(
        target=runner, daemon=True, name=f"patsma-guard-{label or 'call'}"
    )
    t.start()
    if not done.wait(timeout):
        raise GuardTimeout(
            f"{label or 'guarded call'} exceeded watchdog deadline of {timeout:.3g}s"
        )
    if "error" in box:
        raise box["error"]
    return box["value"]


def guarded_call(
    fn: Callable[[], Any],
    *,
    timeout: Optional[float] = None,
    retries: int = 0,
    backoff: float = 0.05,
    backoff_mult: float = 2.0,
    jitter: float = 0.25,
    transient: Callable[[BaseException], bool] = is_transient_failure,
    label: str = "",
    on_retry: Optional[Callable[[int, BaseException, float], None]] = None,
    sleep: Callable[[float], None] = time.sleep,
) -> Any:
    """Run ``fn()`` under a watchdog deadline, retrying transient failures.

    * ``timeout`` (seconds, ``None`` = no watchdog): a call still running at
      the deadline raises :class:`GuardTimeout`; the worker thread is
      abandoned.  Timeouts are never retried in-band (each retry would cost
      another full deadline) — the layers above charge ``inf`` and move on.
    * ``retries``: failed attempts for which ``transient(exc)`` is true are
      retried up to this many times, sleeping
      ``deterministic_backoff(attempt, backoff, backoff_mult, jitter, label)``
      between attempts.  ``on_retry(attempt, exc, delay)`` observes each
      retry (tests assert the schedule; callers count them in stats).
    * Control-flow exceptions (``KeyboardInterrupt``, ``SystemExit``) always
      propagate immediately — a user interrupt is never a candidate failure.

    The final failure is raised; callers that want returned-not-raised
    failures (the executable cache) already convert at their boundary."""
    attempt = 0
    while True:
        try:
            if timeout is not None and timeout > 0:
                return _call_with_deadline(fn, float(timeout), label)
            return fn()
        except (KeyboardInterrupt, SystemExit):
            raise
        except GuardTimeout:
            _metrics.counter("guard.timeouts").inc()
            raise
        except Exception as e:
            if attempt >= retries or not transient(e):
                raise
            delay = deterministic_backoff(attempt, backoff, backoff_mult, jitter, label)
            _metrics.counter("guard.retries").inc()
            if on_retry is not None:
                on_retry(attempt, e, delay)
            if delay > 0:
                sleep(delay)
            attempt += 1


def sandboxed_probe(
    fn: Callable[[], Any],
    *,
    timeout: float = 60.0,
    label: str = "",
) -> bool:
    """Run ``fn`` once in a forked child process; return True iff it
    completed without dying.

    The probe's *result* does not cross the process boundary (executables
    are not picklable) — this is purely a crash canary for the first touch
    of a never-seen candidate: if the child survives, the real in-process
    build proceeds; if it dies, :class:`SandboxCrash` is raised here and the
    candidate is charged ``inf`` without taking the run down.  A child still
    alive at ``timeout`` is terminated and reported as :class:`GuardTimeout`.

    Uses ``fork`` (POSIX) so arbitrary closures need no pickling; on
    platforms without ``fork`` the probe is skipped (returns True) — the
    sandbox is an opt-in belt-and-braces layer, never a hard dependency."""
    import multiprocessing as mp

    try:
        ctx = mp.get_context("fork")
    except ValueError:  # pragma: no cover - non-POSIX: no fork, no sandbox
        return True

    def child(fn=fn):  # pragma: no cover - runs in the forked child
        try:
            fn()
        except BaseException:
            import os

            os._exit(17)  # ordinary failure: not a crash, let the parent build

    proc = ctx.Process(target=child, daemon=True)
    proc.start()
    proc.join(timeout)
    if proc.is_alive():
        proc.terminate()
        proc.join(5.0)
        raise GuardTimeout(
            f"sandboxed probe {label or 'candidate'} exceeded {timeout:.3g}s"
        )
    # exit 0: clean run.  exit 17: the probe raised a Python exception — the
    # real build will raise it in-process where it can be classified.  Any
    # other exit (negative = killed by signal, e.g. SIGSEGV) is a hard crash.
    if proc.exitcode not in (0, 17):
        raise SandboxCrash(
            f"sandboxed probe {label or 'candidate'} died with exit code "
            f"{proc.exitcode} (hard crash contained)",
            exitcode=proc.exitcode,
        )
    return True


class Quarantine:
    """Per-candidate failure bookkeeping: a key that fails ``max_failures``
    times is quarantined — callers stop offering it builds/measurements and
    charge it ``inf`` outright (via ``Autotuning.skip``).  A success clears
    the key's count (transient storms should not accumulate forever)."""

    def __init__(self, max_failures: int = 3) -> None:
        if max_failures < 1:
            raise ValueError("max_failures must be >= 1")
        self.max_failures = int(max_failures)
        self._failures: Dict[Hashable, int] = {}
        self._quarantined: Set[Hashable] = set()
        self.strikes = _metrics.Counter()  # lifetime note_failure calls

    def __contains__(self, key: Hashable) -> bool:
        return key in self._quarantined

    def note_failure(self, key: Hashable) -> bool:
        """Record one failure of ``key``; returns True iff it is (now)
        quarantined."""
        n = self._failures.get(key, 0) + 1
        self._failures[key] = n
        self.strikes.inc()
        if n >= self.max_failures:
            if key not in self._quarantined:
                _metrics.counter("guard.quarantined").inc()
            self._quarantined.add(key)
        return key in self._quarantined

    def note_success(self, key: Hashable) -> None:
        self._failures.pop(key, None)
        self._quarantined.discard(key)

    def stats(self) -> dict:
        return {
            "quarantined": len(self._quarantined),
            "failing": len(self._failures),
            "max_failures": self.max_failures,
        }

    def snapshot(self) -> dict:
        """The live view between summary dumps: lifetime strike count plus
        the *current* states — which keys are out, which are accumulating
        failures (and how many strikes each has).  Cheap (no measurement,
        no lock): safe to poll from serving threads and ``repro.tune
        report``."""
        return {
            "strikes": self.strikes.value,
            "quarantined": sorted(map(str, self._quarantined)),
            "failing": {str(k): n for k, n in self._failures.items()},
            "max_failures": self.max_failures,
        }


class CircuitBreaker:
    """Count-based circuit breaker for a context's exploration.

    States: **closed** (normal), **open** (explores denied), **half-open**
    (probing).  ``threshold`` consecutive recorded failures open the
    breaker; while open, each :meth:`allow` call ticks a cooldown counter
    and answers False, and after ``cooldown`` denials the breaker goes
    half-open — :meth:`allow` grants probes again, and the *next recorded
    outcome* decides: success closes the breaker (exploration resumes),
    failure re-opens it for another cooldown.  Everything is counted in
    calls, not wall time, so schedules are deterministic and testable.

    Single-threaded by contract, like ``OnlineTuner.begin``/``observe``."""

    CLOSED = "closed"
    OPEN = "open"
    HALF_OPEN = "half_open"

    def __init__(self, threshold: int = 3, cooldown: int = 8) -> None:
        if threshold < 1:
            raise ValueError("threshold must be >= 1")
        if cooldown < 1:
            raise ValueError("cooldown must be >= 1")
        self.threshold = int(threshold)
        self.cooldown = int(cooldown)
        self.state = self.CLOSED
        self._consecutive_failures = 0
        self._cooldown_ticks = 0
        # counted on the obs metric primitive; stats()/snapshot() read them
        self.opens = _metrics.Counter()  # trips (incl. re-opens from probes)
        self.denied = _metrics.Counter()  # allow() calls answered False
        self.probes = _metrics.Counter()  # allow() calls granted half-open

    def allow(self) -> bool:
        """May this call explore?  Ticks the cooldown while open."""
        if self.state == self.CLOSED:
            return True
        if self.state == self.OPEN:
            self._cooldown_ticks += 1
            if self._cooldown_ticks < self.cooldown:
                self.denied.inc()
                return False
            self._transition(self.HALF_OPEN)
        # half-open: grant the probe; the recorded outcome decides the state
        self.probes.inc()
        return True

    def record_success(self) -> None:
        self._consecutive_failures = 0
        if self.state != self.CLOSED:
            self._transition(self.CLOSED)
            self._cooldown_ticks = 0

    def record_failure(self) -> None:
        if self.state == self.HALF_OPEN:
            self._trip()
            return
        self._consecutive_failures += 1
        if self.state == self.CLOSED and self._consecutive_failures >= self.threshold:
            self._trip()

    def _trip(self) -> None:
        self._transition(self.OPEN)
        self.opens.inc()
        self._cooldown_ticks = 0
        self._consecutive_failures = 0

    def _transition(self, to_state: str) -> None:
        _metrics.counter("guard.breaker_transitions").inc()
        _events.emit(
            "breaker_transition", from_state=self.state, to_state=to_state
        )
        self.state = to_state

    def stats(self) -> dict:
        return {
            "state": self.state,
            "opens": self.opens.value,
            "denied": self.denied.value,
            "probes": self.probes.value,
        }

    def snapshot(self) -> dict:
        """Alias of :meth:`stats` under the live-introspection name the
        online tuner and quarantine share."""
        return self.stats()
