"""Cost backends for the tuner.

* :class:`RuntimeCost` — the paper's Runtime mode: wall time of a callable,
  with ``jax.block_until_ready`` so asynchronous dispatch is included.
* :class:`ExecutableCache` + :func:`aot_compile` + :func:`compile_fanout` —
  the batched measurement layer: AOT ``jit(...).lower().compile()`` fanned out
  over a thread pool (XLA compilation releases the GIL) with a process-level
  cache of compiled executables, so revisited candidates — across tuning
  rounds, optimizer resets, and pretune grid cells — never recompile.
  Wall-clock *measurement* stays strictly serial for timing fidelity; only
  compilation overlaps.
* :class:`AnalyticCost` — beyond-paper: roofline terms derived from an XLA
  ``lowered``/``compiled`` artifact.  This is what lets the *distributed
  config* search run on a CPU-only container (§Perf hillclimb): the cost of a
  candidate is its dominant roofline term on the target hardware, not a wall
  clock on the host.

Also home to :func:`collective_bytes` — the HLO-text parser used by the
roofline analysis (sums operand bytes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops).
"""
from __future__ import annotations

import dataclasses
import re
import threading
import time
from collections import OrderedDict
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor
from concurrent.futures import wait as futures_wait
from typing import Any, Callable, Hashable, List, Optional, Sequence, Tuple

from repro.obs import metrics as _metrics
from repro.obs.trace import tracer as _tracer

__all__ = [
    "HardwareSpec",
    "TPU_V5E",
    "RuntimeCost",
    "ExecutableCache",
    "CachePartition",
    "aot_compile",
    "compile_fanout",
    "roofline_terms",
    "collective_bytes",
    "hlo_flops_bytes",
]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip peak numbers for the roofline (target hardware, not host)."""

    name: str
    peak_flops: float  # FLOP/s (bf16)
    hbm_bw: float  # bytes/s
    ici_bw: float  # bytes/s per link
    hbm_bytes: float = 16e9  # HBM capacity per chip (launch-space feasibility)

    def __str__(self) -> str:
        return self.name


# Brief-mandated constants: 197 TFLOP/s bf16; 819 GB/s HBM; ~50 GB/s/link ICI;
# 16 GB HBM per chip.
TPU_V5E = HardwareSpec("tpu-v5e", 197e12, 819e9, 50e9, 16e9)


class RuntimeCost:
    """Wall time of ``fn(*args)`` over ``repeats`` runs (after ``warmup``
    discarded runs — the `ignore` idea at measurement level); the returned
    statistic is the ``objective`` over those reps (median by default,
    ``"p95"``/``"p99"`` for tail-latency tuning — see
    :data:`repro.core.measure.OBJECTIVES`).

    The per-repeat raw times of the most recent call are kept on
    :attr:`last_times` (:attr:`last_std` is their sample standard deviation),
    so callers can surface measurement confidence — ``cost_std`` /
    ``repeats_spent`` on committed :class:`~repro.tuning.TuningRecord`\\ s —
    without re-measuring.  Control-flow exceptions (``KeyboardInterrupt``,
    ``SystemExit``) raised by the measured callable always propagate; they
    must never be classified into a candidate failure cost by the layers
    above."""

    def __init__(
        self, warmup: int = 1, repeats: int = 3, objective: str = "median"
    ) -> None:
        from .measure import objective_quantile

        self.warmup = warmup
        self.repeats = repeats
        objective_quantile(objective)  # raises on unknown names
        self.objective = str(objective).strip().lower()
        self.last_times: list = []  # raw measured reps of the latest call

    def __call__(self, fn: Callable, *args, **kwargs) -> float:
        try:
            import jax

            block = jax.block_until_ready
        except Exception:  # pragma: no cover - jax always present here
            block = lambda x: x
        self.last_times = []
        try:
            for _ in range(self.warmup):
                block(fn(*args, **kwargs))
            times = []
            for _ in range(self.repeats):
                t0 = time.perf_counter()
                block(fn(*args, **kwargs))
                times.append(time.perf_counter() - t0)
        except (KeyboardInterrupt, SystemExit):
            # an interrupt mid-measurement is a user action, not a candidate
            # cost — re-raise before any classifying handler can eat it
            raise
        self.last_times = list(times)
        if self.objective not in ("median", "p50"):
            from .measure import objective_value

            return objective_value(times, self.objective)
        times.sort()
        return times[len(times) // 2]

    @property
    def last_std(self) -> float:
        """Sample standard deviation of the latest call's measured reps."""
        ts = self.last_times
        if len(ts) < 2:
            return 0.0
        mean = sum(ts) / len(ts)
        return (sum((t - mean) ** 2 for t in ts) / (len(ts) - 1)) ** 0.5


# ----------------------------------------------------------- AOT compilation
def aot_compile(fn: Callable, *args, **kwargs):
    """Ahead-of-time compile ``fn`` for the given example arguments.

    Returns the compiled executable (callable with arguments of the same
    shapes/dtypes).  Unlike first-call ``jax.jit`` dispatch, the trace +
    XLA compile happen *now*, so a driver can overlap many of these on a
    thread pool before any measurement starts.
    """
    import jax

    jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
    return jitted.lower(*args, **kwargs).compile()


class ExecutableCache:
    """Thread-safe process-level cache of compiled executables.

    Keys are caller-chosen hashables (the tuning layer uses the context
    fingerprint + decoded knobs).  Values are whatever ``build`` returns —
    or the exception it raised: an illegal tile stays illegal, so a revisited
    crashing candidate should not pay a recompile either.  ``cache_failures``
    (a predicate on the exception) can veto that for failures that may be
    transient — e.g. RESOURCE_EXHAUSTED under concurrent compile load — so a
    revisit rebuilds instead of replaying a stale error; ``None`` caches every
    failure.  Concurrent requests for the same key share one build (per-key
    future).

    Stats: ``hits`` / ``misses`` count lookups, ``recompiles`` counts builds
    of a key that had already been built once (only possible after an LRU
    eviction — the acceptance gate for the batched tuner is that this stays
    at zero on the smoke grid; an uncached transient failure counts as a
    plain miss on retry, not a recompile).

    Multi-tenant budgets (default off): ``max_entries`` caps live entries
    below ``maxsize`` and ``max_bytes`` caps the summed ``size_of(result)``
    of *completed* builds — both evict least-recently-used completed entries
    (in-flight builds are never dropped mid-compile: racing waiters hold the
    future).  Every eviction increments :attr:`evictions` and the process
    registry counter ``cache.evictions``.
    """

    def __init__(
        self,
        maxsize: int = 1024,
        *,
        max_entries: Optional[int] = None,
        max_bytes: Optional[int] = None,
        size_of: Optional[Callable[[Any], int]] = None,
        cache_failures: Optional[Callable[[BaseException], bool]] = None,
        guard: Optional[Callable[[Callable[[], Any]], Callable[[], Any]]] = None,
    ) -> None:
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        if max_bytes is not None and max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        self.maxsize = int(maxsize)
        self.max_entries = int(max_entries) if max_entries is not None else None
        self.max_bytes = int(max_bytes) if max_bytes is not None else None
        self._size_of = size_of
        self._cache_failures = cache_failures
        # optional resilience hook: wraps every owner build (e.g.
        # ``FaultPolicy.wrap`` adds a watchdog timeout + transient retries)
        # without callers having to wrap each build thunk themselves
        self._guard = guard
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Hashable, Future]" = OrderedDict()
        self._built: set = set()  # keys ever built (recompile accounting)
        self._sizes: dict = {}  # key -> size_of(result), completed builds only
        self._bytes = 0
        # lookup accounting on the obs metric primitive (repro.obs.metrics):
        # stats() below is a snapshot of these counters, not a parallel copy
        self.hits = _metrics.Counter()
        self.misses = _metrics.Counter()
        self.recompiles = _metrics.Counter()
        self.evictions = _metrics.Counter()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def _evict_one(self, key: Hashable) -> None:
        # caller holds self._lock
        del self._entries[key]
        self._bytes -= self._sizes.pop(key, 0)
        self.evictions.inc()
        _metrics.counter("cache.evictions").inc()

    def _enforce_caps(self) -> None:
        # caller holds self._lock; in-flight builds (no recorded size — their
        # future is unresolved) are skipped so waiters never lose their build
        entry_cap = self.maxsize
        if self.max_entries is not None:
            entry_cap = min(entry_cap, self.max_entries)
        while len(self._entries) > entry_cap:
            victim = next(
                (k for k in self._entries if k in self._sizes or
                 self._entries[k].done()),
                None,
            )
            if victim is None:
                break
            self._evict_one(victim)
        if self.max_bytes is not None:
            while self._bytes > self.max_bytes:
                victim = next((k for k in self._entries if k in self._sizes), None)
                if victim is None:
                    break
                self._evict_one(victim)

    def get_or_build(self, key: Hashable, build: Callable[[], Any]) -> Any:
        """Return the cached executable for ``key``, building it (once) on a
        miss.  Build failures are returned (and cached) as the exception
        object rather than raised — the measurement layer classifies them."""
        with self._lock:
            fut = self._entries.get(key)
            if fut is not None:
                self.hits.inc()
                self._entries.move_to_end(key)
                owner = False
            else:
                fut = Future()
                self._entries[key] = fut
                self.misses.inc()
                if key in self._built:
                    self.recompiles.inc()
                self._built.add(key)
                owner = True
                self._enforce_caps()
        if owner:
            t_build = time.perf_counter()
            try:
                run = self._guard(build) if self._guard is not None else build
                result: Any = run()
            except Exception as e:  # cached: deterministic for a fixed context
                result = e
                _metrics.counter("compile.failed").inc()
                if self._cache_failures is not None and not self._cache_failures(e):
                    # possibly transient: answer current waiters with the
                    # error but drop the entry so a revisit rebuilds
                    with self._lock:
                        if self._entries.get(key) is fut:
                            del self._entries[key]
                        self._built.discard(key)
            except BaseException as e:
                # never cache (e.g. KeyboardInterrupt mid-compile would
                # poison the key): drop the entry, unblock waiters, propagate
                with self._lock:
                    if self._entries.get(key) is fut:
                        del self._entries[key]
                    self._built.discard(key)
                fut.set_result(e)
                raise
            if not isinstance(result, BaseException):
                _metrics.histogram("compile.seconds").observe(
                    time.perf_counter() - t_build
                )
                if self.max_bytes is not None:
                    size = self._measure_size(result)
                    with self._lock:
                        if self._entries.get(key) is fut:
                            self._bytes += size - self._sizes.get(key, 0)
                            self._sizes[key] = size
                            self._enforce_caps()
            fut.set_result(result)
        return fut.result()

    def _measure_size(self, result: Any) -> int:
        """Byte size of one completed build for the ``max_bytes`` budget:
        the caller's ``size_of`` when given, else the executable's own code
        size where the artifact exposes one, else a ``sys.getsizeof``
        floor."""
        if self._size_of is not None:
            try:
                return max(0, int(self._size_of(result)))
            except Exception:
                return 0
        try:
            ma = result.memory_analysis()
            for attr in ("generated_code_size_in_bytes", "serialized_size"):
                v = getattr(ma, attr, None)
                if v:
                    return int(v)
        except Exception:
            pass
        import sys

        try:
            return int(sys.getsizeof(result))
        except Exception:
            return 0

    def peek(self, key: Hashable, default: Any = None) -> Any:
        """Non-building, non-blocking lookup: the cached value (executable or
        cached exception object) if the build for ``key`` has *completed*,
        else ``default``.  Does not count toward hit/miss stats and does not
        touch LRU order — this is the serving hot path's "is it ready yet?"
        probe, which must never trigger or wait on a compile."""
        with self._lock:
            fut = self._entries.get(key)
        if fut is not None and fut.done():
            return fut.result()
        return default

    def stats(self) -> dict:
        with self._lock:
            return {
                "size": len(self._entries),
                "bytes": self._bytes,
                "hits": self.hits.value,
                "misses": self.misses.value,
                "recompiles": self.recompiles.value,
                "evictions": self.evictions.value,
            }

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._built.clear()
            self._sizes.clear()
            self._bytes = 0
            for c in (self.hits, self.misses, self.recompiles, self.evictions):
                c.inc(-c.value)

    def partition(self, tag: Hashable) -> "CachePartition":
        """A namespaced view of this cache: every key is transparently
        prefixed with ``tag``.  Fleet workers pinned to different devices
        compile the *same* candidate into device-specific executables —
        partitioned views keep those from colliding under one key while
        still sharing the process-wide LRU budget, stats, and per-key
        build deduplication."""
        return CachePartition(self, tag)


class CachePartition:
    """A key-prefixed view over a shared :class:`ExecutableCache` (see
    :meth:`ExecutableCache.partition`).  Same surface as the base cache;
    ``stats()``/``clear()`` act on the *shared* underlying cache."""

    def __init__(self, base: ExecutableCache, tag: Hashable) -> None:
        self.base = base
        self.tag = tag

    def _key(self, key: Hashable) -> Hashable:
        return ("__partition__", self.tag, key)

    def get_or_build(self, key: Hashable, build: Callable[[], Any]) -> Any:
        return self.base.get_or_build(self._key(key), build)

    def peek(self, key: Hashable, default: Any = None) -> Any:
        return self.base.peek(self._key(key), default)

    def partition(self, tag: Hashable) -> "CachePartition":
        return CachePartition(self.base, (self.tag, tag))

    def stats(self) -> dict:
        return self.base.stats()

    def clear(self) -> None:
        self.base.clear()

    def __len__(self) -> int:
        return len(self.base)


def compile_fanout(
    items: Sequence[Tuple[Hashable, Callable[[], Any]]],
    *,
    cache: Optional[ExecutableCache] = None,
    jobs: int = 1,
    deadline: Optional[float] = None,
    fatal: Optional[Callable[[BaseException], bool]] = None,
) -> List[Any]:
    """Compile ``items`` = [(key, build), ...] concurrently, deduped through
    ``cache``.  Returns one executable-or-exception per item, in order.

    XLA compilation releases the GIL, so a thread pool genuinely overlaps the
    expensive part; Python tracing inside each ``build`` stays GIL-bound.

    ``deadline`` bounds the *whole round* in seconds: builds not finished at
    the deadline are cancelled where possible (never-started futures) or
    abandoned (in-flight builds keep running in the background and still
    populate the cache for a later round), and their items come back as
    :class:`~repro.core.guard.GuardTimeout` failure objects.

    ``fatal`` is a predicate on completed failure results: the first failure
    it marks fatal cancels every outstanding future and is **raised** instead
    of returned — a poisoned round (e.g. a TypeError that would hit every
    candidate identically) fails fast instead of silently draining the
    executor.  Non-fatal failures keep the classic returned-not-raised
    contract.
    """
    from .guard import GuardTimeout

    if cache is None:
        cache = ExecutableCache(maxsize=max(len(items), 1))
    if jobs <= 1 or len(items) <= 1:
        t0 = time.monotonic()
        results: List[Any] = []
        for k, b in items:
            if deadline is not None and (time.monotonic() - t0) >= deadline:
                results.append(GuardTimeout(
                    f"compile round exceeded deadline of {deadline:.3g}s"
                ))
                continue
            # span the build thunk, not the lookup: a cache hit never runs
            # ``b``, so hits cost no span and the trace shows real compiles
            r = cache.get_or_build(k, _tracer().wrap(b, "compile"))
            if fatal is not None and isinstance(r, BaseException) and fatal(r):
                raise r
            results.append(r)
        return results
    pool = ThreadPoolExecutor(max_workers=min(jobs, len(items)))
    # wrap() captures *this* thread's current span, so worker-side compile
    # spans attach to the round that submitted them (pool threads have no
    # ambient span of their own); wrapping the build thunk rather than the
    # lookup means cache hits cost no span and the trace shows real compiles
    tr = _tracer()
    futs = [pool.submit(cache.get_or_build, k, tr.wrap(b, "compile"))
            for k, b in items]
    results = [None] * len(items)
    pending = {f: i for i, f in enumerate(futs)}
    try:
        t0 = time.monotonic()
        while pending:
            budget = None
            if deadline is not None:
                budget = deadline - (time.monotonic() - t0)
                if budget <= 0:
                    break
            done, _ = futures_wait(
                list(pending), timeout=budget, return_when=FIRST_COMPLETED
            )
            if not done:
                break  # deadline expired with builds still in flight
            for f in done:
                i = pending.pop(f)
                r = f.result()
                results[i] = r
                if fatal is not None and isinstance(r, BaseException) and fatal(r):
                    for pf in pending:
                        pf.cancel()
                    raise r
        for f, i in pending.items():
            f.cancel()
            results[i] = GuardTimeout(
                f"compile round exceeded deadline of {deadline:.3g}s"
            )
    finally:
        # never wait: a hung build must not block the round past its deadline
        pool.shutdown(wait=False)
    return results


# --------------------------------------------------------------------- HLO
# Matches e.g.:  %all-reduce.5 = bf16[4096,1024]{1,0} all-reduce(...)
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|tuple\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str, per_op: bool = False):
    """Sum output-shape bytes of every collective op in an HLO module text.

    cost_analysis() does not expose collective traffic, so the roofline's
    collective term is derived here.  We count the op's *result* bytes
    (operand bytes ≈ result bytes for AG/AR/A2A/CP; reduce-scatter result is
    the post-scatter shard — the wire cost of RS equals its *operand* size,
    but HLO text reliably exposes the result shape, and for the ring
    algorithms AG/RS wire bytes = (n-1)/n * full size; we report result bytes
    as the canonical, mesh-independent proxy and fold algorithm factors into
    the roofline model).
    """
    totals: dict = {
        "all-gather": 0,
        "all-reduce": 0,
        "reduce-scatter": 0,
        "all-to-all": 0,
        "collective-permute": 0,
    }
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        # bytes of the result: first shape(s) on the line left of the op name
        head = line[: m.end(1)]
        byte_count = 0
        for dt, dims in _SHAPE_RE.findall(head):
            byte_count += _shape_bytes(dt, dims)
        totals[op] += byte_count
    if per_op:
        return totals
    return sum(totals.values())


def hlo_flops_bytes(compiled) -> tuple:
    """(flops, bytes_accessed) from compiled.cost_analysis(); robust to the
    per-device dict/list shapes different jax versions return."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    return flops, nbytes


@dataclasses.dataclass
class RooflineTerms:
    """The three §Roofline terms, in seconds, plus bookkeeping."""

    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    coll_bytes: float
    chips: int
    hw: HardwareSpec

    @property
    def dominant(self) -> str:
        vals = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(vals, key=vals.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "coll_bytes": self.coll_bytes,
            "chips": self.chips,
        }


def roofline_terms(
    compiled,
    chips: int,
    hw: HardwareSpec = TPU_V5E,
    hlo_text: Optional[str] = None,
    links_per_chip: int = 4,
) -> RooflineTerms:
    """Compute the three roofline terms from a compiled artifact.

    Notes on normalization: XLA's SPMD cost_analysis reports the *per
    partition* program (flops/bytes of one device's share), so terms divide by
    per-chip peaks directly.  The collective bytes from the HLO are likewise
    the per-device program's collective results; each chip drives
    ``links_per_chip`` ICI links (v5e: 4 usable links in a 2D torus).
    """
    flops, nbytes = hlo_flops_bytes(compiled)
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cbytes = float(collective_bytes(text))
    return RooflineTerms(
        compute_s=flops / hw.peak_flops,
        memory_s=nbytes / hw.hbm_bw,
        collective_s=cbytes / (hw.ici_bw * links_per_chip),
        flops=flops,
        bytes_accessed=nbytes,
        coll_bytes=cbytes,
        chips=chips,
        hw=hw,
    )
