"""Cost backends for the tuner.

* :class:`RuntimeCost` — the paper's Runtime mode: wall time of a callable,
  with ``jax.block_until_ready`` so asynchronous dispatch is included.
* :class:`AnalyticCost` — beyond-paper: roofline terms derived from an XLA
  ``lowered``/``compiled`` artifact.  This is what lets the *distributed
  config* search run on a CPU-only container (§Perf hillclimb): the cost of a
  candidate is its dominant roofline term on the target hardware, not a wall
  clock on the host.

Also home to :func:`collective_bytes` — the HLO-text parser used by the
roofline analysis (sums operand bytes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops).
"""
from __future__ import annotations

import dataclasses
import re
import time
from typing import Callable, Optional

__all__ = [
    "HardwareSpec",
    "TPU_V5E",
    "RuntimeCost",
    "roofline_terms",
    "collective_bytes",
    "hlo_flops_bytes",
]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip peak numbers for the roofline (target hardware, not host)."""

    name: str
    peak_flops: float  # FLOP/s (bf16)
    hbm_bw: float  # bytes/s
    ici_bw: float  # bytes/s per link

    def __str__(self) -> str:
        return self.name


# Brief-mandated constants: 197 TFLOP/s bf16; 819 GB/s HBM; ~50 GB/s/link ICI.
TPU_V5E = HardwareSpec("tpu-v5e", 197e12, 819e9, 50e9)


class RuntimeCost:
    """Median wall time of ``fn(*args)`` over ``repeats`` runs (after
    ``warmup`` discarded runs — the `ignore` idea at measurement level)."""

    def __init__(self, warmup: int = 1, repeats: int = 3) -> None:
        self.warmup = warmup
        self.repeats = repeats

    def __call__(self, fn: Callable, *args, **kwargs) -> float:
        try:
            import jax

            block = jax.block_until_ready
        except Exception:  # pragma: no cover - jax always present here
            block = lambda x: x
        for _ in range(self.warmup):
            block(fn(*args, **kwargs))
        times = []
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            block(fn(*args, **kwargs))
            times.append(time.perf_counter() - t0)
        times.sort()
        return times[len(times) // 2]


# --------------------------------------------------------------------- HLO
# Matches e.g.:  %all-reduce.5 = bf16[4096,1024]{1,0} all-reduce(...)
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|tuple\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str, per_op: bool = False):
    """Sum output-shape bytes of every collective op in an HLO module text.

    cost_analysis() does not expose collective traffic, so the roofline's
    collective term is derived here.  We count the op's *result* bytes
    (operand bytes ≈ result bytes for AG/AR/A2A/CP; reduce-scatter result is
    the post-scatter shard — the wire cost of RS equals its *operand* size,
    but HLO text reliably exposes the result shape, and for the ring
    algorithms AG/RS wire bytes = (n-1)/n * full size; we report result bytes
    as the canonical, mesh-independent proxy and fold algorithm factors into
    the roofline model).
    """
    totals: dict = {
        "all-gather": 0,
        "all-reduce": 0,
        "reduce-scatter": 0,
        "all-to-all": 0,
        "collective-permute": 0,
    }
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        # bytes of the result: first shape(s) on the line left of the op name
        head = line[: m.end(1)]
        byte_count = 0
        for dt, dims in _SHAPE_RE.findall(head):
            byte_count += _shape_bytes(dt, dims)
        totals[op] += byte_count
    if per_op:
        return totals
    return sum(totals.values())


def hlo_flops_bytes(compiled) -> tuple:
    """(flops, bytes_accessed) from compiled.cost_analysis(); robust to the
    per-device dict/list shapes different jax versions return."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    return flops, nbytes


@dataclasses.dataclass
class RooflineTerms:
    """The three §Roofline terms, in seconds, plus bookkeeping."""

    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    coll_bytes: float
    chips: int
    hw: HardwareSpec

    @property
    def dominant(self) -> str:
        vals = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(vals, key=vals.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "coll_bytes": self.coll_bytes,
            "chips": self.chips,
        }


def roofline_terms(
    compiled,
    chips: int,
    hw: HardwareSpec = TPU_V5E,
    hlo_text: Optional[str] = None,
    links_per_chip: int = 4,
) -> RooflineTerms:
    """Compute the three roofline terms from a compiled artifact.

    Notes on normalization: XLA's SPMD cost_analysis reports the *per
    partition* program (flops/bytes of one device's share), so terms divide by
    per-chip peaks directly.  The collective bytes from the HLO are likewise
    the per-device program's collective results; each chip drives
    ``links_per_chip`` ICI links (v5e: 4 usable links in a 2D torus).
    """
    flops, nbytes = hlo_flops_bytes(compiled)
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cbytes = float(collective_bytes(text))
    return RooflineTerms(
        compute_s=flops / hw.peak_flops,
        memory_s=nbytes / hw.hbm_bw,
        collective_s=cbytes / (hw.ici_bw * links_per_chip),
        flops=flops,
        bytes_accessed=nbytes,
        coll_bytes=cbytes,
        chips=chips,
        hw=hw,
    )
