"""Adaptive measurement engine — spend repetitions where they buy information.

PATSMA's premise is that every cost evaluation is expensive, yet a fixed
``RuntimeCost(warmup, repeats)`` loop spends identical wall-clock on every
candidate, whether it is a near-tie with the incumbent or 40× slower.  This
module is the measurement layer's answer, three tiers deep — all
deterministic given the observed rep times, all seedable:

* **Noise-floor calibration** (:meth:`MeasureEngine.calibrate`): replaying
  one executable a few times estimates timer/scheduler jitter, from which
  per-candidate confidence intervals are derived.  No candidate is ever
  culled against another inside that noise floor.
* **Successive-halving racing** (:meth:`MeasureEngine.measure_round`): every
  candidate of a deduped tuning round gets one measured repetition;
  candidates whose CI lower bound exceeds the running round-best's CI upper
  bound (by a configurable margin) are culled with their single-rep median —
  a real, finite ``tell`` cost, never ``inf`` — while survivors escalate
  through a repeat ladder (1→3→7 by default) until the top-k are
  statistically separated or the ladder is exhausted.
* **Roofline prefilter**: for AOT-compiled executables the analytic lower
  bound (``roofline_terms(...).bound_s``) is compared against the best cost
  measured so far; a candidate whose *lower bound* already loses is charged
  at the bound without a single repetition, flagged ``pruned="roofline"`` so
  re-searches after a drift reset revisit it.

``MeasurePolicy(mode="fixed")`` reproduces the classic fixed-repeat loop
(:class:`repro.core.costs.RuntimeCost` semantics) for trajectory-pinned
tests and CI; ``mode="adaptive"`` is the racing engine.  The process default
comes from the ``REPRO_TUNE_MEASURE`` env var (see
:func:`resolve_measure_policy`).
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from typing import Callable, List, Optional, Sequence, Tuple

from repro.obs import metrics as _metrics
from repro.obs.trace import tracer as _tracer

__all__ = [
    "ENV_TUNE_MEASURE",
    "OBJECTIVES",
    "MeasurePolicy",
    "MeasureResult",
    "NoiseEstimate",
    "MeasureEngine",
    "resolve_measure_policy",
    "time_rep",
    "summarize",
    "quantile",
    "objective_value",
    "objective_quantile",
]

#: env var: process-default measurement policy for tune_call/pretune
#: ("adaptive" | "fixed"; unset → adaptive)
ENV_TUNE_MEASURE = "REPRO_TUNE_MEASURE"

#: tuning objectives: which statistic of a candidate's rep times the search
#: minimizes.  ``median``/``p50`` are synonyms (the classic behaviour);
#: ``p95``/``p99`` optimize tail latency — production serving cares about
#: the slow requests, and a knob that wins the median can lose the tail.
OBJECTIVES = ("median", "p50", "p95", "p99")

_OBJECTIVE_Q = {"median": 0.5, "p50": 0.5, "p95": 0.95, "p99": 0.99}


def objective_quantile(objective: str) -> float:
    """The quantile (in [0, 1]) a named objective minimizes."""
    try:
        return _OBJECTIVE_Q[str(objective).strip().lower()]
    except KeyError:
        raise ValueError(
            f"objective must be one of {OBJECTIVES}, got {objective!r}"
        ) from None


def quantile(times: Sequence[float], q: float) -> float:
    """Deterministic linear-interpolated quantile of ``times``.

    ``q=0.5`` reproduces :func:`summarize`'s median exactly (even-length
    inputs average the two middle values), so the default objective is
    bit-identical to the pre-objective behaviour."""
    ts = sorted(float(t) for t in times)
    n = len(ts)
    if n == 0:
        return math.inf
    if n == 1:
        return ts[0]
    pos = min(max(q, 0.0), 1.0) * (n - 1)
    i = int(math.floor(pos))
    frac = pos - i
    if frac <= 0.0 or i + 1 >= n:
        return ts[i]
    return ts[i] * (1.0 - frac) + ts[i + 1] * frac


def objective_value(times: Sequence[float], objective: str = "median") -> float:
    """The objective statistic of one candidate's rep times."""
    return quantile(times, objective_quantile(objective))


def time_rep(fn: Callable, *args, **kwargs) -> float:
    """One timed repetition of ``fn(*args)``; blocks on the result so
    asynchronous dispatch is included (the unit the ladder escalates in)."""
    try:
        import jax

        block = jax.block_until_ready
    except Exception:  # pragma: no cover - jax always present here
        block = lambda x: x
    t0 = time.perf_counter()
    block(fn(*args, **kwargs))
    return time.perf_counter() - t0


# -------------------------------------------------------------------- policy
@dataclasses.dataclass(frozen=True)
class MeasurePolicy:
    """How to spend repetitions on a candidate set.

    ``mode="fixed"``: every candidate gets ``warmup`` discarded + ``repeats``
    measured reps, cost is the median — byte-for-byte the classic
    :class:`~repro.core.costs.RuntimeCost` schedule.

    ``mode="adaptive"``: racing over the repeat ``ladder`` (cumulative rep
    targets per stage), culling against the round best with ``margin`` extra
    half-widths of slack, plus the roofline prefilter when analytic bounds
    are available.  ``rel_noise``/``abs_noise`` are the noise-floor *priors*
    used until :meth:`MeasureEngine.calibrate` has run (and as lower bounds
    afterwards — a calibration fluke must not shrink the floor to zero).
    """

    mode: str = "adaptive"
    warmup: int = 1
    repeats: int = 3  # fixed-mode measured reps (and online fixed reps)
    ladder: Tuple[int, ...] = (1, 3, 7)  # cumulative reps per racing stage
    margin: float = 0.5  # cull slack, in units of the best's CI half-width
    top_k: int = 1  # stop escalating once this many are separated
    calibrate_reps: int = 5
    rel_noise: float = 0.02  # noise-floor prior, fraction of the median
    abs_noise: float = 5e-7  # noise-floor prior, seconds
    roofline: bool = True
    prune_margin: float = 1.0  # prune iff bound > incumbent * prune_margin
    # which statistic of a candidate's reps the search minimizes.  Racing
    # CIs and cull decisions stay median-based (the robust statistic noise
    # calibration is built around); the objective is applied when a
    # candidate's cost is finalized, so "median" is bit-identical to the
    # pre-objective engine.
    objective: str = "median"

    def __post_init__(self) -> None:
        if self.mode not in ("fixed", "adaptive"):
            raise ValueError(f"mode must be 'fixed' or 'adaptive', got {self.mode!r}")
        obj = str(self.objective).strip().lower()
        objective_quantile(obj)  # raises on unknown names
        object.__setattr__(self, "objective", obj)
        if self.warmup < 0 or self.repeats < 1:
            raise ValueError("warmup must be >= 0 and repeats >= 1")
        lad = tuple(int(x) for x in self.ladder)
        if not lad or lad[0] < 1 or any(b <= a for a, b in zip(lad, lad[1:])):
            raise ValueError(f"ladder must be strictly increasing from >= 1, got {lad}")
        object.__setattr__(self, "ladder", lad)


def resolve_measure_policy(
    measure=None,
    *,
    warmup: Optional[int] = None,
    repeats: Optional[int] = None,
    objective: Optional[str] = None,
) -> MeasurePolicy:
    """Coerce a user-facing ``measure=`` value into a :class:`MeasurePolicy`.

    ``None`` reads ``REPRO_TUNE_MEASURE`` (default ``"adaptive"``); a string
    names the mode; a mapping supplies :class:`MeasurePolicy` fields (mode
    defaulting from the env var — the declarative route-spec form, e.g.
    ``measure={"objective": "p99"}``); a policy object passes through
    untouched.  ``warmup`` / ``repeats`` / ``objective`` override the
    named-mode defaults (they are the classic ``tune_call(warmup=,
    repeats=)`` knobs plus the tail-latency objective) but never an
    explicit policy or an explicit mapping field."""
    if isinstance(measure, MeasurePolicy):
        return measure
    fields: dict = {}
    if measure is not None and not isinstance(measure, str):
        try:
            fields = dict(measure)
        except (TypeError, ValueError):
            raise TypeError(
                "measure must be None, 'fixed', 'adaptive', a field mapping, "
                f"or MeasurePolicy; got {measure!r}"
            ) from None
        measure = fields.pop("mode", None)
    if measure is None:
        measure = os.environ.get(ENV_TUNE_MEASURE, "") or "adaptive"
    fields["mode"] = str(measure).strip().lower()
    if warmup is not None:
        fields.setdefault("warmup", int(warmup))
    if repeats is not None:
        fields.setdefault("repeats", int(repeats))
    if objective is not None:
        fields.setdefault("objective", objective)
    return MeasurePolicy(**fields)


# -------------------------------------------------------------------- results
@dataclasses.dataclass
class MeasureResult:
    """One candidate's measurement outcome within a round.

    ``cost`` is always finite for measured/pruned candidates and ``inf`` for
    failures; ``pruned`` is ``"roofline"`` when the candidate was never
    measured (cost == its analytic bound), ``culled`` is True when racing
    stopped it before the full ladder (cost == median of the reps it got).
    """

    cost: float
    cost_std: float = 0.0
    repeats_spent: int = 0
    culled: bool = False
    pruned: Optional[str] = None
    times: list = dataclasses.field(default_factory=list)
    # the racing CI at finalization time — what the cull decision actually
    # compared (obs event stream: candidate_culled carries these)
    ci_lo: float = 0.0
    ci_hi: float = 0.0

    def meta(self) -> dict:
        """The bookkeeping the driver stores per measured point."""
        return {
            "cost_std": float(self.cost_std),
            "repeats_spent": int(self.repeats_spent),
            "culled": bool(self.culled),
            "pruned": self.pruned,
        }


@dataclasses.dataclass(frozen=True)
class NoiseEstimate:
    """Timer-jitter floor: no two costs closer than this are distinguishable."""

    abs_floor: float  # seconds
    rel: float  # fraction of the measured median
    n: int = 0  # calibration reps behind the estimate (0 = priors only)

    def floor(self, median: float) -> float:
        """The indifference band around a measurement at ``median``."""
        return max(self.abs_floor, self.rel * abs(median))


def summarize(times: Sequence[float], noise: NoiseEstimate):
    """``(median, std, ci_lo, ci_hi)`` of one candidate's rep times.

    The CI half-width is the larger of the calibrated noise floor and the
    standard error of the observed reps — deterministic given the times, and
    never narrower than what the timer can actually resolve."""
    ts = sorted(float(t) for t in times)
    n = len(ts)
    if n == 0:
        return math.inf, 0.0, math.inf, math.inf
    med = ts[n // 2] if n % 2 == 1 else 0.5 * (ts[n // 2 - 1] + ts[n // 2])
    if n > 1:
        mean = sum(ts) / n
        std = math.sqrt(sum((t - mean) ** 2 for t in ts) / (n - 1))
    else:
        std = 0.0
    hw = max(noise.floor(med), 2.0 * std / math.sqrt(n))
    return med, std, med - hw, med + hw


# --------------------------------------------------------------------- engine
class MeasureEngine:
    """Stateful per-search measurement engine (one instance per tuning run).

    Feed it one deduped optimizer round at a time via
    :meth:`measure_round`; it remembers the best *measured* cost across
    rounds (the roofline prefilter's incumbent) and the calibrated noise
    floor.  ``stats`` accumulates repetitions, culls, and prunes for run
    summaries and the overhead benchmark.
    """

    def __init__(
        self,
        policy: Optional[MeasurePolicy] = None,
        *,
        noise: Optional[NoiseEstimate] = None,
        on_error: Optional[Callable[[int, BaseException], None]] = None,
        guard=None,
    ) -> None:
        self.policy = policy if policy is not None else MeasurePolicy()
        self.noise = noise
        self.on_error = on_error
        # optional FaultPolicy: every repetition runs under its watchdog
        # deadline (a hung candidate is charged inf, the run survives) with
        # transient failures retried in place
        self.guard = guard
        self.best_measured = math.inf  # incumbent for the roofline prefilter
        # every increment mirrors into the process metrics registry as
        # measure.<key> — one bookkeeping site, two views
        self.stats = _metrics.MirroredStats("measure", {
            "mode": self.policy.mode,
            "rounds": 0,
            "candidates": 0,
            "measured": 0,
            "culled": 0,
            "pruned_roofline": 0,
            "failed": 0,
            "reps": 0,
            "warmup_reps": 0,
            "calibration_reps": 0,
            "timeouts": 0,
            "retried": 0,
        })

    # ------------------------------------------------------------- internals
    def _rep(self, idx: int, fn: Callable[[], float], counter: str = "reps"):
        """One repetition; returns the observed time or the exception.
        Control-flow exceptions always propagate — a Ctrl-C mid-measurement
        must never be classified into a candidate's failure cost."""
        from .guard import GuardTimeout, guarded_call

        g = self.guard
        try:
            if g is not None and (g.measure_timeout is not None or g.retries > 0):
                def _on_retry(attempt, exc, delay):
                    self.stats["retried"] += 1

                t = float(guarded_call(
                    fn,
                    timeout=g.measure_timeout,
                    retries=g.retries,
                    backoff=g.backoff,
                    backoff_mult=g.backoff_mult,
                    jitter=g.jitter,
                    label=f"measure[{idx}]",
                    on_retry=_on_retry,
                ))
            else:
                t = float(fn())
        except (KeyboardInterrupt, SystemExit):
            raise
        except GuardTimeout as e:
            self.stats["timeouts"] += 1
            if self.on_error is not None:
                self.on_error(idx, e)
            return e
        except Exception as e:
            if self.on_error is not None:
                self.on_error(idx, e)
            return e
        self.stats[counter] += 1
        _metrics.histogram("measure.rep_seconds").observe(t)
        return t

    def _noise(self) -> NoiseEstimate:
        if self.noise is None:
            p = self.policy
            return NoiseEstimate(p.abs_noise, p.rel_noise, 0)
        return self.noise

    def _objective_cost(self, times: Sequence[float], med: float) -> float:
        """Finalized cost of one candidate: ``med`` for the median objective
        (bit-identical to the classic engine), the objective quantile of the
        reps otherwise."""
        if objective_quantile(self.policy.objective) == 0.5:
            return med
        return objective_value(times, self.policy.objective)

    # ------------------------------------------------------------ calibration
    def calibrate(self, rep_fn: Callable[[], float], idx: int = -1) -> NoiseEstimate:
        """Estimate the timer noise floor by replaying one known-good
        callable (the incumbent, or the round's first compiled candidate —
        ``idx`` is its round index, forwarded to ``on_error`` so a failure
        is attributed to the right candidate).  The policy's priors are kept
        as lower bounds: a lucky streak of identical timings must not
        collapse the floor to zero."""
        p = self.policy
        times: List[float] = []
        for _ in range(max(2, p.calibrate_reps)):
            t = self._rep(idx, rep_fn, counter="calibration_reps")
            if isinstance(t, BaseException):
                break
            times.append(t)
        if len(times) < 2:
            self.noise = NoiseEstimate(p.abs_noise, p.rel_noise, len(times))
            return self.noise
        med, std, _, _ = summarize(times, NoiseEstimate(0.0, 0.0))
        abs_floor = max(p.abs_noise, 2.0 * std)
        rel = max(p.rel_noise, (2.0 * std / med) if med > 0 else 0.0)
        self.noise = NoiseEstimate(abs_floor, rel, len(times))
        return self.noise

    # ------------------------------------------------------------ measurement
    def measure_round(
        self,
        reps: Sequence[Optional[Callable[[], float]]],
        *,
        bounds: Optional[Sequence[Optional[float]]] = None,
    ) -> List[MeasureResult]:
        """Measure one deduped candidate round.

        ``reps[i]`` is a zero-arg callable timing ONE repetition of candidate
        ``i`` (``None`` marks a candidate whose executable failed to build —
        charged ``inf`` with zero reps).  ``bounds[i]`` is an optional
        analytic lower bound in the same units as the rep times; with a
        finite cross-round incumbent, a candidate whose bound already loses
        is pruned unmeasured.  Returns one :class:`MeasureResult` per input,
        in order.
        """
        p = self.policy
        n = len(reps)
        self.stats["rounds"] += 1
        self.stats["candidates"] += n
        with _tracer().span("measure", candidates=n):
            return self._measure_round_inner(reps, bounds)

    def _measure_round_inner(self, reps, bounds) -> List[MeasureResult]:
        p = self.policy
        n = len(reps)
        results: List[Optional[MeasureResult]] = [None] * n
        alive: List[int] = []
        for i, fn in enumerate(reps):
            if fn is None:
                results[i] = MeasureResult(cost=math.inf)
                self.stats["failed"] += 1
            else:
                alive.append(i)

        # ------------------------------------------------ roofline prefilter
        if (
            p.mode == "adaptive"
            and p.roofline
            and bounds is not None
            and math.isfinite(self.best_measured)
        ):
            cutoff = self.best_measured * p.prune_margin
            for i in list(alive):
                b = bounds[i]
                if b is not None and math.isfinite(b) and b > cutoff:
                    results[i] = MeasureResult(cost=float(b), pruned="roofline")
                    self.stats["pruned_roofline"] += 1
                    alive.remove(i)

        if p.mode == "fixed":
            for i in alive:
                results[i] = self._measure_fixed(i, reps[i])
        else:
            calibrated_on = None
            if self.noise is None:
                # first round: the first warm candidate doubles as the
                # calibration target.  Warm it up *before* calibrating —
                # first-call overhead (dispatch caches, page faults) would
                # otherwise inflate the noise floor enough to disable racing.
                for i in list(alive):
                    failed = False
                    for _ in range(p.warmup):
                        t = self._rep(i, reps[i], counter="warmup_reps")
                        if isinstance(t, BaseException):
                            results[i] = MeasureResult(cost=math.inf)
                            self.stats["failed"] += 1
                            alive.remove(i)
                            failed = True
                            break
                    if not failed:
                        self.calibrate(reps[i], idx=i)
                        calibrated_on = i
                        break
            self._race(alive, reps, results, skip_warmup=calibrated_on)

        finite = [
            r.cost
            for r in results
            if r is not None and r.pruned is None and math.isfinite(r.cost)
        ]
        if finite:
            self.best_measured = min(self.best_measured, min(finite))
        return [r if r is not None else MeasureResult(cost=math.inf) for r in results]

    def _measure_fixed(self, idx: int, fn: Callable[[], float]) -> MeasureResult:
        p = self.policy
        for _ in range(p.warmup):
            t = self._rep(idx, fn, counter="warmup_reps")
            if isinstance(t, BaseException):
                self.stats["failed"] += 1
                return MeasureResult(cost=math.inf)
        times: List[float] = []
        for _ in range(p.repeats):
            t = self._rep(idx, fn)
            if isinstance(t, BaseException):
                self.stats["failed"] += 1
                return MeasureResult(cost=math.inf, times=times)
            times.append(t)
        med, std, lo, hi = summarize(times, self._noise())
        self.stats["measured"] += 1
        return MeasureResult(
            cost=self._objective_cost(times, med), cost_std=std,
            repeats_spent=len(times), times=times,
            ci_lo=lo, ci_hi=hi,
        )

    def _race(
        self,
        alive: List[int],
        reps: Sequence[Optional[Callable[[], float]]],
        results: List[Optional[MeasureResult]],
        skip_warmup: Optional[int] = None,
    ) -> None:
        """Successive-halving over the repeat ladder, culling vs round-best."""
        p = self.policy
        noise = self._noise()
        times: dict = {i: [] for i in alive}

        def fail(i: int) -> None:
            results[i] = MeasureResult(
                cost=math.inf, repeats_spent=len(times[i]), times=list(times[i])
            )
            self.stats["failed"] += 1
            alive.remove(i)

        def finalize(i: int, culled: bool) -> None:
            med, std, lo, hi = summarize(times[i], noise)
            results[i] = MeasureResult(
                cost=self._objective_cost(times[i], med),
                cost_std=std,
                repeats_spent=len(times[i]),
                culled=culled,
                times=list(times[i]),
                ci_lo=lo,
                ci_hi=hi,
            )
            self.stats["measured"] += 1
            if culled:
                self.stats["culled"] += 1
            alive.remove(i)

        # per-candidate warmup (the calibration target already ran)
        for i in list(alive):
            if i == skip_warmup:
                continue
            for _ in range(p.warmup):
                t = self._rep(i, reps[i], counter="warmup_reps")
                if isinstance(t, BaseException):
                    fail(i)
                    break

        for target in p.ladder:
            # escalate every surviving candidate to `target` cumulative reps
            for i in list(alive):
                while len(times[i]) < target:
                    t = self._rep(i, reps[i])
                    if isinstance(t, BaseException):
                        fail(i)
                        break
                    times[i].append(t)
            if not alive:
                return
            stats = {i: summarize(times[i], noise) for i in alive}
            order = sorted(alive, key=lambda i: stats[i][0])
            best = order[0]
            med_b, _, lo_b, hi_b = stats[best]
            # the cross-round incumbent races too: a round of uniformly
            # regressive candidates must not escalate the ladder against
            # each other when every one of them already loses to the best
            # measurement of an earlier round
            inc_line = None
            if math.isfinite(self.best_measured):
                f = noise.floor(self.best_measured)
                inc_line = self.best_measured + f * (1.0 + p.margin)
            cull_line = hi_b + p.margin * (hi_b - med_b)
            if inc_line is not None:
                cull_line = min(cull_line, inc_line)
            for i in list(alive):
                if i == best:
                    # only the incumbent may cull the round's own best
                    if inc_line is not None and lo_b > inc_line:
                        finalize(i, culled=True)
                    continue
                if stats[i][2] > cull_line:  # CI low end already loses
                    finalize(i, culled=True)
            if len(alive) <= max(1, p.top_k):
                break
            # separated: the top-k's CI high ends clear everyone else's low end
            order = [i for i in order if results[i] is None]
            k = min(max(1, p.top_k), len(order) - 1)
            top_hi = max(stats[i][3] for i in order[:k])
            rest_lo = min(stats[i][2] for i in order[k:])
            if top_hi < rest_lo:
                break
        for i in list(alive):
            finalize(i, culled=False)
