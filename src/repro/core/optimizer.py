"""NumericalOptimizer interface — faithful port of PATSMA Algorithm 1.

The paper's interface (C++):

    class NumericalOptimizer {
      virtual double* run(double cost) = 0;
      virtual int getNumPoints() const = 0;
      virtual int getDimension() const = 0;
      virtual bool isEnd() const = 0;
      virtual void reset(int level) {};
      virtual void print() const {}
    };

The key contract (paper §2.2): ``run`` is a *staged* state machine.  Each call
delivers the cost of the **previously returned** candidate and receives the
next candidate to test.  The first call's cost argument is ignored.  Once the
optimization has ended, ``run`` keeps returning the final solution (which does
not require further testing) and ``is_end()`` is True.

Batch protocol (beyond-paper): the staged machine is now built on an
``ask()``/``tell(costs)`` pair so a driver can evaluate a whole round of
candidates concurrently (compile fan-out, within-round dedup):

* :meth:`ask` returns the full list of candidates whose costs the optimizer
  needs next — CSA's m coupled probes, NM's initial simplex, a grid's sweep.
  Calling it again before :meth:`tell` returns the same batch; once the
  optimization has ended it returns ``[]``.
* :meth:`tell` delivers the costs, in order, for the batch `ask` returned,
  advancing the optimizer exactly as the equivalent sequence of sequential
  ``run`` calls would — same RNG draws, same accept decisions, same budget
  accounting.  The protocols may be switched at round boundaries; a direct
  ``tell`` mid-way through a drip-fed ``run`` round discards the costs
  ``run`` had buffered (the whole round's costs must come through ``tell``).

``run`` itself is implemented *on top of* ask/tell: it hands out the pending
batch one candidate per call and buffers the incoming costs until the round
completes.  Subclasses implement the primitives :meth:`_next_batch` /
:meth:`_consume_batch` and inherit ``run``/``ask``/``tell``.

Optimizers work in the normalized hypercube ``[-1, 1]^dim``; rescaling to the
user domain (min/max, int/float/log/categorical) is the responsibility of
:class:`repro.core.space.SearchSpace` inside :class:`repro.core.autotuning.Autotuning`.
"""
from __future__ import annotations

import abc
from typing import List, Optional, Sequence

import numpy as np

__all__ = ["NumericalOptimizer"]


class NumericalOptimizer(abc.ABC):
    """Abstract staged optimizer (paper Algorithm 1) with batch ask/tell."""

    #: normalized search bounds
    LO: float = -1.0
    HI: float = 1.0

    # batch-protocol state; instance attributes shadow these class defaults
    _pending_batch: Optional[List[np.ndarray]] = None  # asked, awaiting tell
    _run_batch: Optional[List[np.ndarray]] = None  # being drip-fed via run()
    _run_costs: Optional[List[float]] = None  # costs buffered by run()

    # --------------------------------------------------- batch primitives
    @abc.abstractmethod
    def _next_batch(self) -> Optional[List[np.ndarray]]:
        """Produce the next round of candidates, or None/[] if the search is
        over (implementations set their DONE state before returning None).
        Called exactly once per round — RNG draws happen here."""

    @abc.abstractmethod
    def _consume_batch(self, points: List[np.ndarray], costs: List[float]) -> None:
        """Deliver ``costs[i]`` for ``points[i]`` (the batch `_next_batch`
        produced) and advance the round.  Costs are already sanitized
        (non-finite → inf)."""

    # --------------------------------------------------------- batch API
    def ask(self) -> List[np.ndarray]:
        """Candidates whose costs the optimizer needs next ([] once ended).

        Idempotent: repeated calls return (copies of) the same batch until
        :meth:`tell` delivers its costs."""
        if self.is_end():
            return []
        if self._pending_batch is None:
            batch = self._next_batch()
            if not batch:
                return []
            self._pending_batch = [np.asarray(p, dtype=float).copy() for p in batch]
        return [p.copy() for p in self._pending_batch]

    def tell(self, costs: Sequence[float]) -> None:
        """Deliver the costs for the batch returned by :meth:`ask`."""
        if self.is_end():
            return
        if self._pending_batch is None:
            raise RuntimeError("tell() before ask(): no batch is pending")
        if len(costs) != len(self._pending_batch):
            raise ValueError(
                f"tell() got {len(costs)} costs for a batch of {len(self._pending_batch)}"
            )
        batch = self._pending_batch
        self._pending_batch = None
        clean = [float(c) if np.isfinite(c) else np.inf for c in costs]
        self._consume_batch(batch, clean)
        # a direct tell() supersedes any half-delivered run() round
        self._run_batch = None
        self._run_costs = None

    def _clear_batch_state(self) -> None:
        """Drop pending ask/run bookkeeping (call from reset())."""
        self._pending_batch = None
        self._run_batch = None
        self._run_costs = None

    # ----------------------------------------------------- sequential run
    def run(self, cost: float) -> np.ndarray:
        """Deliver ``cost`` of the last returned candidate; return the next one.

        Returns an array of shape ``(dimension,)`` in ``[-1, 1]``.  After
        :meth:`is_end` becomes True, returns the final solution.  Implemented
        over :meth:`ask`/:meth:`tell`: costs buffer until the pending round is
        complete, then the round advances in one step.
        """
        if self.is_end():
            return self.best_solution
        if self._run_batch is None:
            self._run_batch = self.ask()  # first call: cost is ignored
            self._run_costs = []
            if not self._run_batch:
                return self.best_solution
        else:
            self._run_costs.append(float(cost) if np.isfinite(cost) else np.inf)
            if len(self._run_costs) == len(self._run_batch):
                self.tell(self._run_costs)  # resets _run_batch/_run_costs
                if self.is_end():
                    return self.best_solution
                self._run_batch = self.ask()
                self._run_costs = []
                if not self._run_batch:
                    return self.best_solution
        return self._run_batch[len(self._run_costs)].copy()

    # ------------------------------------------------------------- interface
    @abc.abstractmethod
    def get_num_points(self) -> int:
        """Number of solutions the algorithm maintains (``num_opt`` for CSA)."""

    @abc.abstractmethod
    def get_dimension(self) -> int:
        """Dimensionality of the solutions."""

    @abc.abstractmethod
    def is_end(self) -> bool:
        """Whether the optimization has finished."""

    def reset(self, level: int = 0) -> None:  # optional (paper line 10)
        """Reset the optimization.  ``level`` semantics (paper §2.2):
        0 → light reset retaining found solutions; higher levels discard
        progressively more, up to a complete reset."""

    # --- warm-start hooks (beyond-paper; used by repro.tuning) --------------
    def seed(self, z0: np.ndarray, spread: float = 0.2) -> bool:
        """Bias the initial state toward ``z0`` (normalized coords).

        Called before the first :meth:`run` by the warm-start machinery when a
        persisted tuning record for a *nearby* context exists.  Implementations
        should concentrate their initial population / simplex around ``z0``
        with the given ``spread``.  Returns True if applied; the default is a
        no-op (optimizers without a useful notion of seeding stay faithful)."""
        return False

    def shrink_budget(self, frac: float) -> bool:
        """Scale the remaining evaluation budget by ``frac`` (0 < frac <= 1).

        Warm-started searches begin near a known-good solution, so they are
        granted a reduced budget (the point of persisting tuning results).
        Returns True if applied; default no-op."""
        return False

    def print(self) -> None:  # optional (paper line 11); keep the paper's name
        """Print debug/verbose optimizer state."""

    # --- conveniences shared by all implementations -------------------------
    @property
    def best_solution(self) -> np.ndarray:
        raise NotImplementedError

    @property
    def best_cost(self) -> float:
        raise NotImplementedError

    @staticmethod
    def _wrap(z: np.ndarray) -> np.ndarray:
        """Wrap into [-1, 1] (toroidal, as PATSMA's CSA does with fmod)."""
        return np.mod(z + 1.0, 2.0) - 1.0

    @staticmethod
    def _clip(z: np.ndarray) -> np.ndarray:
        return np.clip(z, -1.0, 1.0)
