"""NumericalOptimizer interface — faithful port of PATSMA Algorithm 1.

The paper's interface (C++):

    class NumericalOptimizer {
      virtual double* run(double cost) = 0;
      virtual int getNumPoints() const = 0;
      virtual int getDimension() const = 0;
      virtual bool isEnd() const = 0;
      virtual void reset(int level) {};
      virtual void print() const {}
    };

The key contract (paper §2.2): ``run`` is a *staged* state machine.  Each call
delivers the cost of the **previously returned** candidate and receives the
next candidate to test.  The first call's cost argument is ignored.  Once the
optimization has ended, ``run`` keeps returning the final solution (which does
not require further testing) and ``is_end()`` is True.

Optimizers work in the normalized hypercube ``[-1, 1]^dim``; rescaling to the
user domain (min/max, int/float/log/categorical) is the responsibility of
:class:`repro.core.space.SearchSpace` inside :class:`repro.core.autotuning.Autotuning`.
"""
from __future__ import annotations

import abc

import numpy as np

__all__ = ["NumericalOptimizer"]


class NumericalOptimizer(abc.ABC):
    """Abstract staged optimizer (paper Algorithm 1)."""

    #: normalized search bounds
    LO: float = -1.0
    HI: float = 1.0

    @abc.abstractmethod
    def run(self, cost: float) -> np.ndarray:
        """Deliver ``cost`` of the last returned candidate; return the next one.

        Returns an array of shape ``(dimension,)`` in ``[-1, 1]``.  After
        :meth:`is_end` becomes True, returns the final solution.
        """

    @abc.abstractmethod
    def get_num_points(self) -> int:
        """Number of solutions the algorithm maintains (``num_opt`` for CSA)."""

    @abc.abstractmethod
    def get_dimension(self) -> int:
        """Dimensionality of the solutions."""

    @abc.abstractmethod
    def is_end(self) -> bool:
        """Whether the optimization has finished."""

    def reset(self, level: int = 0) -> None:  # optional (paper line 10)
        """Reset the optimization.  ``level`` semantics (paper §2.2):
        0 → light reset retaining found solutions; higher levels discard
        progressively more, up to a complete reset."""

    # --- warm-start hooks (beyond-paper; used by repro.tuning) --------------
    def seed(self, z0: np.ndarray, spread: float = 0.2) -> bool:
        """Bias the initial state toward ``z0`` (normalized coords).

        Called before the first :meth:`run` by the warm-start machinery when a
        persisted tuning record for a *nearby* context exists.  Implementations
        should concentrate their initial population / simplex around ``z0``
        with the given ``spread``.  Returns True if applied; the default is a
        no-op (optimizers without a useful notion of seeding stay faithful)."""
        return False

    def shrink_budget(self, frac: float) -> bool:
        """Scale the remaining evaluation budget by ``frac`` (0 < frac <= 1).

        Warm-started searches begin near a known-good solution, so they are
        granted a reduced budget (the point of persisting tuning results).
        Returns True if applied; default no-op."""
        return False

    def print(self) -> None:  # optional (paper line 11); keep the paper's name
        """Print debug/verbose optimizer state."""

    # --- conveniences shared by all implementations -------------------------
    @property
    def best_solution(self) -> np.ndarray:
        raise NotImplementedError

    @property
    def best_cost(self) -> float:
        raise NotImplementedError

    @staticmethod
    def _wrap(z: np.ndarray) -> np.ndarray:
        """Wrap into [-1, 1] (toroidal, as PATSMA's CSA does with fmod)."""
        return np.mod(z + 1.0, 2.0) - 1.0

    @staticmethod
    def _clip(z: np.ndarray) -> np.ndarray:
        return np.clip(z, -1.0, 1.0)
