"""Search-space codec: optimizer hypercube [-1,1]^d  <->  user parameter domain.

PATSMA's C++ API exposes scalar ``min``/``max`` bounds and templated point
types (int / floating).  We reproduce that (``SearchSpace.uniform``) and extend
it with log-scaled and categorical dimensions, which are the natural domains
for the JAX knobs this framework tunes (block sizes are powers of two, remat
policies are categorical, ...).  The extension is additive: a plain
``Autotuning(min, max, ignore, dim, ...)`` behaves exactly like the paper.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import numpy as np

__all__ = ["IntDim", "FloatDim", "LogIntDim", "ChoiceDim", "Constraint", "SearchSpace"]


@dataclasses.dataclass(frozen=True)
class IntDim:
    """Integer in [lo, hi] (inclusive), linear scale."""

    name: str
    lo: int
    hi: int

    def decode(self, z: float) -> int:
        t = (z + 1.0) / 2.0  # [-1,1] -> [0,1]
        v = self.lo + t * (self.hi - self.lo)
        return int(np.clip(round(v), self.lo, self.hi))

    def encode(self, v: Any) -> float:
        if self.hi == self.lo:
            return 0.0
        t = (float(v) - self.lo) / (self.hi - self.lo)
        return float(np.clip(2.0 * t - 1.0, -1.0, 1.0))


@dataclasses.dataclass(frozen=True)
class FloatDim:
    """Float in [lo, hi], linear scale."""

    name: str
    lo: float
    hi: float

    def decode(self, z: float) -> float:
        t = (z + 1.0) / 2.0
        return float(np.clip(self.lo + t * (self.hi - self.lo), self.lo, self.hi))

    def encode(self, v: Any) -> float:
        if self.hi == self.lo:
            return 0.0
        t = (float(v) - self.lo) / (self.hi - self.lo)
        return float(np.clip(2.0 * t - 1.0, -1.0, 1.0))


@dataclasses.dataclass(frozen=True)
class LogIntDim:
    """Integer sampled on a log2 grid: {lo, 2*lo, 4*lo, ..., hi}.

    The canonical domain for tile/block sizes (MXU-aligned powers of two).
    """

    name: str
    lo: int
    hi: int

    def __post_init__(self) -> None:
        if self.lo <= 0 or self.hi < self.lo:
            raise ValueError(f"bad LogIntDim bounds [{self.lo}, {self.hi}]")

    @property
    def _steps(self) -> int:
        return int(math.floor(math.log2(self.hi / self.lo)))

    def decode(self, z: float) -> int:
        t = (z + 1.0) / 2.0
        k = int(np.clip(round(t * self._steps), 0, self._steps))
        return self.lo * (2**k)

    def encode(self, v: Any) -> float:
        k = math.log2(max(float(v), self.lo) / self.lo)
        if self._steps == 0:
            return 0.0
        return float(np.clip(2.0 * (k / self._steps) - 1.0, -1.0, 1.0))


@dataclasses.dataclass(frozen=True)
class ChoiceDim:
    """Categorical over an ordered tuple of python values."""

    name: str
    values: tuple

    def __post_init__(self) -> None:
        if len(self.values) < 1:
            raise ValueError("ChoiceDim needs at least one value")

    def decode(self, z: float) -> Any:
        n = len(self.values)
        t = (z + 1.0) / 2.0
        i = int(np.clip(round(t * (n - 1)), 0, n - 1))
        return self.values[i]

    def encode(self, v: Any) -> float:
        i = self.values.index(v)
        n = len(self.values)
        if n == 1:
            return 0.0
        return float(np.clip(2.0 * (i / (n - 1)) - 1.0, -1.0, 1.0))


@dataclasses.dataclass(frozen=True)
class Constraint:
    """Declarative validity predicate over decoded points.

    ``predicate(point) -> bool`` receives the decoded ``{name: value}`` dict
    and returns True for legal points.  Constraints are evaluated *before*
    compile/measure: the Autotuning driver charges failing candidates ``inf``
    via the ``skip(reason="constraint")`` path at zero compile cost, so an
    intractable product space (mesh factorizations × microbatches × remat ×
    flags) collapses to its feasible region for free — the model-checking
    style pruning of "Auto-Tuning HPC Programs Using Model Checking"
    (PAPERS.md), expressed as plain python predicates.
    """

    name: str
    predicate: Any  # Callable[[dict], bool]
    describe: str = ""  # human-readable clause for docs / `pretune --list`

    def ok(self, point: dict) -> bool:
        try:
            return bool(self.predicate(point))
        except Exception:
            # a predicate that cannot even evaluate the point rejects it
            return False


class SearchSpace:
    """Ordered collection of dimensions with vector encode/decode.

    ``constraints`` (optional) are :class:`Constraint` validity predicates
    over decoded points; see :meth:`check`.  Spaces without constraints are
    byte-identical to the pre-constraint era (fingerprints, codec, keys).
    """

    def __init__(self, dims: Sequence[Any], constraints: Sequence[Constraint] = ()) -> None:
        if not dims:
            raise ValueError("empty search space")
        self.dims = list(dims)
        names = [d.name for d in self.dims]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate dim names: {names}")
        self.constraints = tuple(constraints)
        cnames = [c.name for c in self.constraints]
        if len(set(cnames)) != len(cnames):
            raise ValueError(f"duplicate constraint names: {cnames}")

    @classmethod
    def uniform(cls, lo, hi, dim: int, integer: bool = True) -> "SearchSpace":
        """The paper's (min, max, dim) constructor.  ``lo``/``hi`` may be
        scalars or length-``dim`` sequences."""
        lo = np.broadcast_to(np.asarray(lo, dtype=float), (dim,))
        hi = np.broadcast_to(np.asarray(hi, dtype=float), (dim,))
        mk = IntDim if integer else FloatDim
        cast = int if integer else float
        return cls([mk(f"p{i}", cast(lo[i]), cast(hi[i])) for i in range(dim)])

    def __len__(self) -> int:
        return len(self.dims)

    @property
    def names(self) -> list:
        return [d.name for d in self.dims]

    def decode(self, z: np.ndarray) -> dict:
        z = np.asarray(z, dtype=float).reshape(-1)
        if z.shape[0] != len(self.dims):
            raise ValueError(f"point has dim {z.shape[0]}, space has {len(self.dims)}")
        return {d.name: d.decode(z[i]) for i, d in enumerate(self.dims)}

    def decode_vector(self, z: np.ndarray) -> list:
        return list(self.decode(z).values())

    def encode(self, values) -> np.ndarray:
        if isinstance(values, dict):
            vals = [values[d.name] for d in self.dims]
        else:
            vals = list(values)
        return np.array([d.encode(v) for d, v in zip(self.dims, vals)], dtype=float)

    def key(self, values) -> tuple:
        """Hashable cache key for a decoded point."""
        if isinstance(values, dict):
            return tuple(values[d.name] for d in self.dims)
        return tuple(values)

    def check(self, point) -> "str | None":
        """Name of the first violated constraint for a decoded point, or None.

        Accepts a ``{name: value}`` dict or an ordered value sequence."""
        if not self.constraints:
            return None
        if not isinstance(point, dict):
            point = {d.name: v for d, v in zip(self.dims, point)}
        for c in self.constraints:
            if not c.ok(point):
                return c.name
        return None

    def _dim_values(self, d) -> "list | None":
        """All representable values of one dim, or None if continuous."""
        if isinstance(d, ChoiceDim):
            return list(d.values)
        if isinstance(d, LogIntDim):
            return [d.lo * (2**k) for k in range(d._steps + 1)]
        if isinstance(d, IntDim):
            return list(range(d.lo, d.hi + 1))
        return None  # FloatDim and friends: continuous

    def size(self) -> "int | None":
        """Cardinality of the raw product space (None if any dim is
        continuous)."""
        n = 1
        for d in self.dims:
            vals = self._dim_values(d)
            if vals is None:
                return None
            n *= len(vals)
        return n

    def grid_points(self):
        """Iterate every representable point (dicts).  Raises for continuous
        spaces — guard with :meth:`size`."""
        import itertools

        per_dim = []
        for d in self.dims:
            vals = self._dim_values(d)
            if vals is None:
                raise ValueError(f"dim {d.name!r} is continuous; no finite grid")
            per_dim.append(vals)
        names = self.names
        for combo in itertools.product(*per_dim):
            yield dict(zip(names, combo))

    def constrained_size(self, cap: int = 1_000_000) -> "int | None":
        """Count of points that satisfy every constraint — the feasible-region
        size operators see in ``pretune --list``.  None if the space is
        continuous or its raw size exceeds ``cap`` (enumeration too big)."""
        raw = self.size()
        if raw is None or raw > cap:
            return None
        if not self.constraints:
            return raw
        return sum(1 for p in self.grid_points() if self.check(p) is None)

    def resolution(self) -> float:
        """Coarsest normalized grid step across dims (0.0 if all continuous).

        The distance in ``[-1, 1]`` between adjacent representable values of
        the coarsest discrete dimension — what a warm-start spread must
        exceed for a seeded population to straddle neighboring grid points
        instead of collapsing onto the seed (a ``LogIntDim`` with 6 octaves
        has steps of 1/3; a 4-way ``ChoiceDim`` has steps of 2/3)."""
        step = 0.0
        for d in self.dims:
            if isinstance(d, LogIntDim):
                n = d._steps
            elif isinstance(d, ChoiceDim):
                n = len(d.values) - 1
            elif isinstance(d, IntDim):
                n = d.hi - d.lo
            else:  # FloatDim and friends: continuous
                continue
            if n > 0:
                step = max(step, 2.0 / n)
        return step
