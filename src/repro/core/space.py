"""Search-space codec: optimizer hypercube [-1,1]^d  <->  user parameter domain.

PATSMA's C++ API exposes scalar ``min``/``max`` bounds and templated point
types (int / floating).  We reproduce that (``SearchSpace.uniform``) and extend
it with log-scaled and categorical dimensions, which are the natural domains
for the JAX knobs this framework tunes (block sizes are powers of two, remat
policies are categorical, ...).  The extension is additive: a plain
``Autotuning(min, max, ignore, dim, ...)`` behaves exactly like the paper.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import numpy as np

__all__ = ["IntDim", "FloatDim", "LogIntDim", "ChoiceDim", "SearchSpace"]


@dataclasses.dataclass(frozen=True)
class IntDim:
    """Integer in [lo, hi] (inclusive), linear scale."""

    name: str
    lo: int
    hi: int

    def decode(self, z: float) -> int:
        t = (z + 1.0) / 2.0  # [-1,1] -> [0,1]
        v = self.lo + t * (self.hi - self.lo)
        return int(np.clip(round(v), self.lo, self.hi))

    def encode(self, v: Any) -> float:
        if self.hi == self.lo:
            return 0.0
        t = (float(v) - self.lo) / (self.hi - self.lo)
        return float(np.clip(2.0 * t - 1.0, -1.0, 1.0))


@dataclasses.dataclass(frozen=True)
class FloatDim:
    """Float in [lo, hi], linear scale."""

    name: str
    lo: float
    hi: float

    def decode(self, z: float) -> float:
        t = (z + 1.0) / 2.0
        return float(np.clip(self.lo + t * (self.hi - self.lo), self.lo, self.hi))

    def encode(self, v: Any) -> float:
        if self.hi == self.lo:
            return 0.0
        t = (float(v) - self.lo) / (self.hi - self.lo)
        return float(np.clip(2.0 * t - 1.0, -1.0, 1.0))


@dataclasses.dataclass(frozen=True)
class LogIntDim:
    """Integer sampled on a log2 grid: {lo, 2*lo, 4*lo, ..., hi}.

    The canonical domain for tile/block sizes (MXU-aligned powers of two).
    """

    name: str
    lo: int
    hi: int

    def __post_init__(self) -> None:
        if self.lo <= 0 or self.hi < self.lo:
            raise ValueError(f"bad LogIntDim bounds [{self.lo}, {self.hi}]")

    @property
    def _steps(self) -> int:
        return int(math.floor(math.log2(self.hi / self.lo)))

    def decode(self, z: float) -> int:
        t = (z + 1.0) / 2.0
        k = int(np.clip(round(t * self._steps), 0, self._steps))
        return self.lo * (2**k)

    def encode(self, v: Any) -> float:
        k = math.log2(max(float(v), self.lo) / self.lo)
        if self._steps == 0:
            return 0.0
        return float(np.clip(2.0 * (k / self._steps) - 1.0, -1.0, 1.0))


@dataclasses.dataclass(frozen=True)
class ChoiceDim:
    """Categorical over an ordered tuple of python values."""

    name: str
    values: tuple

    def __post_init__(self) -> None:
        if len(self.values) < 1:
            raise ValueError("ChoiceDim needs at least one value")

    def decode(self, z: float) -> Any:
        n = len(self.values)
        t = (z + 1.0) / 2.0
        i = int(np.clip(round(t * (n - 1)), 0, n - 1))
        return self.values[i]

    def encode(self, v: Any) -> float:
        i = self.values.index(v)
        n = len(self.values)
        if n == 1:
            return 0.0
        return float(np.clip(2.0 * (i / (n - 1)) - 1.0, -1.0, 1.0))


class SearchSpace:
    """Ordered collection of dimensions with vector encode/decode."""

    def __init__(self, dims: Sequence[Any]) -> None:
        if not dims:
            raise ValueError("empty search space")
        self.dims = list(dims)
        names = [d.name for d in self.dims]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate dim names: {names}")

    @classmethod
    def uniform(cls, lo, hi, dim: int, integer: bool = True) -> "SearchSpace":
        """The paper's (min, max, dim) constructor.  ``lo``/``hi`` may be
        scalars or length-``dim`` sequences."""
        lo = np.broadcast_to(np.asarray(lo, dtype=float), (dim,))
        hi = np.broadcast_to(np.asarray(hi, dtype=float), (dim,))
        mk = IntDim if integer else FloatDim
        cast = int if integer else float
        return cls([mk(f"p{i}", cast(lo[i]), cast(hi[i])) for i in range(dim)])

    def __len__(self) -> int:
        return len(self.dims)

    @property
    def names(self) -> list:
        return [d.name for d in self.dims]

    def decode(self, z: np.ndarray) -> dict:
        z = np.asarray(z, dtype=float).reshape(-1)
        if z.shape[0] != len(self.dims):
            raise ValueError(f"point has dim {z.shape[0]}, space has {len(self.dims)}")
        return {d.name: d.decode(z[i]) for i, d in enumerate(self.dims)}

    def decode_vector(self, z: np.ndarray) -> list:
        return list(self.decode(z).values())

    def encode(self, values) -> np.ndarray:
        if isinstance(values, dict):
            vals = [values[d.name] for d in self.dims]
        else:
            vals = list(values)
        return np.array([d.encode(v) for d, v in zip(self.dims, vals)], dtype=float)

    def key(self, values) -> tuple:
        """Hashable cache key for a decoded point."""
        if isinstance(values, dict):
            return tuple(values[d.name] for d in self.dims)
        return tuple(values)

    def resolution(self) -> float:
        """Coarsest normalized grid step across dims (0.0 if all continuous).

        The distance in ``[-1, 1]`` between adjacent representable values of
        the coarsest discrete dimension — what a warm-start spread must
        exceed for a seeded population to straddle neighboring grid points
        instead of collapsing onto the seed (a ``LogIntDim`` with 6 octaves
        has steps of 1/3; a 4-way ``ChoiceDim`` has steps of 2/3)."""
        step = 0.0
        for d in self.dims:
            if isinstance(d, LogIntDim):
                n = d._steps
            elif isinstance(d, ChoiceDim):
                n = len(d.values) - 1
            elif isinstance(d, IntDim):
                n = d.hi - d.lo
            else:  # FloatDim and friends: continuous
                continue
            if n > 0:
                step = max(step, 2.0 / n)
        return step
