"""repro.core — PATSMA (Parameter Auto-Tuning for Shared Memory Algorithms)
ported to JAX: staged numerical optimizers (CSA, Nelder–Mead), the Autotuning
driver with Single-Iteration / Entire-Execution × Runtime / user-cost modes,
search-space codecs, and the cost backends used across the framework.
"""
from .autotuning import Autotuning
from .costs import (
    TPU_V5E,
    CachePartition,
    ExecutableCache,
    HardwareSpec,
    RooflineTerms,
    RuntimeCost,
    aot_compile,
    collective_bytes,
    compile_fanout,
    hlo_flops_bytes,
    roofline_terms,
)
from .csa import CSA
from .grid_random import GridSearch, RandomSearch
from .guard import (
    CircuitBreaker,
    FaultPolicy,
    GuardTimeout,
    Quarantine,
    SandboxCrash,
    deterministic_backoff,
    guarded_call,
    is_transient_failure,
    sandboxed_probe,
)
from .measure import (
    MeasureEngine,
    MeasurePolicy,
    MeasureResult,
    NoiseEstimate,
    resolve_measure_policy,
    time_rep,
)
from .nelder_mead import NelderMead
from .optimizer import NumericalOptimizer
from .space import ChoiceDim, Constraint, FloatDim, IntDim, LogIntDim, SearchSpace
from .strategy import (
    Pipeline,
    Portfolio,
    SearchStrategy,
    cull_laggards,
    make_strategy,
    strategy_label,
)
from .tuned_jit import TunedStep

__all__ = [
    "Autotuning",
    "CSA",
    "NelderMead",
    "GridSearch",
    "RandomSearch",
    "NumericalOptimizer",
    "SearchStrategy",
    "Pipeline",
    "Portfolio",
    "cull_laggards",
    "make_strategy",
    "strategy_label",
    "SearchSpace",
    "Constraint",
    "IntDim",
    "FloatDim",
    "LogIntDim",
    "ChoiceDim",
    "TunedStep",
    "RuntimeCost",
    "MeasurePolicy",
    "MeasureResult",
    "MeasureEngine",
    "NoiseEstimate",
    "resolve_measure_policy",
    "time_rep",
    "ExecutableCache",
    "CachePartition",
    "aot_compile",
    "compile_fanout",
    "FaultPolicy",
    "GuardTimeout",
    "SandboxCrash",
    "CircuitBreaker",
    "Quarantine",
    "guarded_call",
    "sandboxed_probe",
    "is_transient_failure",
    "deterministic_backoff",
    "HardwareSpec",
    "RooflineTerms",
    "TPU_V5E",
    "collective_bytes",
    "hlo_flops_bytes",
    "roofline_terms",
]
