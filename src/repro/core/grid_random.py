"""Beyond-paper baseline optimizers sharing the NumericalOptimizer interface.

The paper's interface (§2.2) is explicitly designed so "other optimization
methods can be incorporated as a new class".  These two are used as controls
in the benchmarks (exhaustive truth for small spaces; random-search baseline
for the CSA-vs-NM comparisons).
"""
from __future__ import annotations

import numpy as np

from .optimizer import NumericalOptimizer

__all__ = ["GridSearch", "RandomSearch"]


class GridSearch(NumericalOptimizer):
    """Exhaustive scan of a regular grid over [-1,1]^dim."""

    def __init__(self, dim: int, points_per_dim: int = 8) -> None:
        self._dim = dim
        self._ppd = int(points_per_dim)
        axes = [np.linspace(-1.0, 1.0, self._ppd) for _ in range(dim)]
        grid = np.meshgrid(*axes, indexing="ij")
        self._pts = np.stack([g.reshape(-1) for g in grid], axis=-1)
        self._i = 0
        self._best_x = self._pts[0].copy()
        self._best_e = np.inf

    def get_num_points(self) -> int:
        return len(self._pts)

    def get_dimension(self) -> int:
        return self._dim

    def is_end(self) -> bool:
        return self._i > len(self._pts)

    @property
    def best_solution(self) -> np.ndarray:
        return self._best_x.copy()

    @property
    def best_cost(self) -> float:
        return float(self._best_e)

    def reset(self, level: int = 0) -> None:
        self._i = 0
        if level >= 2:
            self._best_e = np.inf

    def run(self, cost: float) -> np.ndarray:
        if self._i > 0 and self._i <= len(self._pts) and np.isfinite(cost):
            if cost < self._best_e:
                self._best_e = float(cost)
                self._best_x = self._pts[self._i - 1].copy()
        if self._i < len(self._pts):
            out = self._pts[self._i].copy()
            self._i += 1
            return out
        self._i = len(self._pts) + 1
        return self.best_solution


class RandomSearch(NumericalOptimizer):
    """Uniform random sampling for ``max_iter`` evaluations."""

    def __init__(self, dim: int, max_iter: int = 64, seed: int = 0) -> None:
        self._dim = dim
        self._max = int(max_iter)
        self._seed = seed
        self._rng = np.random.default_rng(seed)
        self._i = 0
        self._last = None
        self._best_x = np.zeros(dim)
        self._best_e = np.inf

    def get_num_points(self) -> int:
        return 1

    def get_dimension(self) -> int:
        return self._dim

    def is_end(self) -> bool:
        return self._i > self._max

    @property
    def best_solution(self) -> np.ndarray:
        return self._best_x.copy()

    @property
    def best_cost(self) -> float:
        return float(self._best_e)

    def reset(self, level: int = 0) -> None:
        self._i = 0
        if level >= 2:
            self._rng = np.random.default_rng(self._seed)
            self._best_e = np.inf

    def run(self, cost: float) -> np.ndarray:
        if self._last is not None and np.isfinite(cost) and cost < self._best_e:
            self._best_e = float(cost)
            self._best_x = self._last.copy()
        if self._i < self._max:
            self._last = self._rng.uniform(-1.0, 1.0, size=self._dim)
            self._i += 1
            return self._last.copy()
        self._i = self._max + 1
        self._last = None
        return self.best_solution
