"""Beyond-paper baseline optimizers sharing the NumericalOptimizer interface.

The paper's interface (§2.2) is explicitly designed so "other optimization
methods can be incorporated as a new class".  These two are used as controls
in the benchmarks (exhaustive truth for small spaces; random-search baseline
for the CSA-vs-NM comparisons).

Both have trivially perfect batch shapes: the whole remaining sweep is one
``ask()`` round (no point depends on another's cost), so a batched driver can
compile every candidate concurrently.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from .optimizer import NumericalOptimizer

__all__ = ["GridSearch", "RandomSearch"]


class GridSearch(NumericalOptimizer):
    """Exhaustive scan of a regular grid over [-1,1]^dim."""

    def __init__(self, dim: int, points_per_dim: int = 8) -> None:
        self._dim = dim
        self._ppd = int(points_per_dim)
        axes = [np.linspace(-1.0, 1.0, self._ppd) for _ in range(dim)]
        grid = np.meshgrid(*axes, indexing="ij")
        self._pts = np.stack([g.reshape(-1) for g in grid], axis=-1)
        self._i = 0  # next unevaluated grid index
        self._done = False
        self._best_x = self._pts[0].copy()
        self._best_e = np.inf

    def get_num_points(self) -> int:
        return len(self._pts)

    def get_dimension(self) -> int:
        return self._dim

    def is_end(self) -> bool:
        return self._done

    @property
    def best_solution(self) -> np.ndarray:
        return self._best_x.copy()

    @property
    def best_cost(self) -> float:
        return float(self._best_e)

    def reset(self, level: int = 0) -> None:
        """Reset contract parity with CSA (the PR 3 hardening): every level
        restarts the sweep with the full cold budget; level 0 retains the
        found solution, level 1 keeps the best *coordinates* but drops the
        stale energy (CSA's drift-reset semantics — the point must re-prove
        itself post-drift; NM instead rebuilds cold at level >= 1), and
        level >= 2 is complete."""
        self._i = 0
        self._done = False
        if level == 1:
            self._best_e = np.inf  # coordinates kept, stale energy dropped
        elif level >= 2:
            self._best_x = self._pts[0].copy()
            self._best_e = np.inf
        self._clear_batch_state()

    def _next_batch(self) -> Optional[List[np.ndarray]]:
        if self._i >= len(self._pts):
            self._done = True
            return None
        return [p.copy() for p in self._pts[self._i:]]

    def _consume_batch(self, points: List[np.ndarray], costs: List[float]) -> None:
        for p, c in zip(points, costs):
            self._i += 1
            if np.isfinite(c) and c < self._best_e:
                self._best_e = float(c)
                self._best_x = p.copy()
        if self._i >= len(self._pts):
            self._done = True


class RandomSearch(NumericalOptimizer):
    """Uniform random sampling for ``max_iter`` evaluations."""

    def __init__(self, dim: int, max_iter: int = 64, seed: int = 0) -> None:
        self._dim = dim
        self._max = int(max_iter)
        self._cold_max = int(max_iter)  # shrink_budget narrows the live value
        self._seed = seed
        self._rng = np.random.default_rng(seed)
        self._i = 0
        self._done = False
        self._best_x = np.zeros(dim)
        self._best_e = np.inf

    def get_num_points(self) -> int:
        return 1

    def get_dimension(self) -> int:
        return self._dim

    def is_end(self) -> bool:
        return self._done

    @property
    def best_solution(self) -> np.ndarray:
        return self._best_x.copy()

    @property
    def best_cost(self) -> float:
        return float(self._best_e)

    def shrink_budget(self, frac: float) -> bool:
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"frac must be in (0, 1], got {frac}")
        self._max = max(1, int(np.ceil(self._max * frac)))
        return True

    def reset(self, level: int = 0) -> None:
        """Reset contract parity with CSA (the PR 3 hardening): every level
        restores the cold sample budget (a warm-start-shrunk budget never
        compounds); level 0 retains the found solution, level 1 keeps the
        best coordinates but drops the stale energy (CSA's drift-reset
        semantics; NM instead rebuilds cold at level >= 1), and level >= 2
        additionally replays the seed's RNG stream from the start."""
        self._i = 0
        self._done = False
        self._max = self._cold_max
        if level == 1:
            self._best_e = np.inf  # coordinates kept, stale energy dropped
        elif level >= 2:
            self._rng = np.random.default_rng(self._seed)
            self._best_x = np.zeros(self._dim)
            self._best_e = np.inf
        self._clear_batch_state()

    def _next_batch(self) -> Optional[List[np.ndarray]]:
        if self._i >= self._max:
            self._done = True
            return None
        # draw the remaining samples in sequence order (same stream as the
        # one-per-call staging)
        return [
            self._rng.uniform(-1.0, 1.0, size=self._dim)
            for _ in range(self._max - self._i)
        ]

    def _consume_batch(self, points: List[np.ndarray], costs: List[float]) -> None:
        for p, c in zip(points, costs):
            self._i += 1
            if np.isfinite(c) and c < self._best_e:
                self._best_e = float(c)
                self._best_x = p.copy()
        if self._i >= self._max:
            self._done = True
