"""TunedStep — PATSMA wired into a jitted step function.

The JAX analogue of bolting PATSMA onto an OpenMP loop: the *target method*
is a jitted step produced by a ``step_factory(knobs) -> step`` (knobs are
static arguments: microbatch count, remat policy, kernel block sizes, ...).

* **Single Iteration mode** (paper Fig. 1a): call the :class:`TunedStep` as
  your training step.  While tuning is live, each real step evaluates one
  candidate; afterwards the best compiled step runs with zero overhead.
* **Entire Execution mode** (paper Fig. 1b): call :meth:`tune` with a replica
  batch before the loop.

Persistent warm-start: pass ``db=`` (repro.tuning.TuningDB) plus either an
explicit ``key=`` or ``name=``/``key_extra=`` to fingerprint the step.  A
prior run's result is then replayed (exact hit → tuning is skipped entirely)
or used to seed the search, and new results are committed back automatically.

``ignore=1`` by default: the first call per candidate bears XLA compilation,
the second is the measured steady-state — exactly the paper's stabilization
semantics.  Compiled executables are memoized per candidate so a revisited
candidate never recompiles (beyond-paper; harmless to faithfulness because
compile time is already excluded via ``ignore``).

**Adaptive runtime mode** (``runtime="adaptive"``): the step stays tuned for
the *lifetime* of the loop.  Calls route through a
:class:`repro.runtime.online.OnlineTuner` — while the search is live an
ε-fraction of steps measures a candidate (``epsilon=1.0`` by default, i.e.
the classic Single-Iteration behaviour); once converged, step times stream
into a :class:`repro.runtime.drift.DriftDetector`, and sustained
degradation triggers ``reset(level)`` plus a half-budget warm re-search
automatically — no external watchdog wiring needed.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

from .autotuning import Autotuning, _block
from .optimizer import NumericalOptimizer
from .space import SearchSpace

__all__ = ["TunedStep"]


class TunedStep:
    def __init__(
        self,
        step_factory: Callable[..., Callable],
        space: SearchSpace,
        *,
        ignore: int = 1,
        num_opt: int = 4,
        max_iter: int = 10,
        search=None,
        optimizer: Optional[NumericalOptimizer] = None,
        strategy: Optional[str] = None,
        cache: bool = True,
        seed: int = 0,
        verbose: bool = False,
        on_candidate: Optional[Callable[[dict], None]] = None,
        db=None,
        key=None,
        name: Optional[str] = None,
        key_extra: Optional[dict] = None,
        warm_start: bool = True,
        runtime: Optional[str] = None,
        epsilon: float = 1.0,
        drift=None,
        warm_frac: float = 0.5,
        measure=None,
    ) -> None:
        if db is not None and key is None and name is not None:
            # fingerprint a step by its name + knob space + caller context
            from repro.tuning import make_key

            key = make_key(name, space=space, extra=key_extra)
        given = [v for v in (search, optimizer, strategy) if v is not None]
        if len(given) > 1:
            raise ValueError(
                "pass a single search method (optimizer= and strategy= are "
                "aliases of search=)"
            )
        self._factory = step_factory
        self.at = Autotuning(
            ignore=ignore,
            space=space,
            num_opt=num_opt,
            max_iter=max_iter,
            search=given[0] if given else None,
            cache=cache,
            seed=seed,
            verbose=verbose,
            db=db,
            key=key,
            warm_start=warm_start,
        )
        self._steps: dict = {}  # knobs key -> compiled step  (executable cache)
        self._on_candidate = on_candidate
        self._online = None
        if runtime is not None:
            if runtime != "adaptive":
                raise ValueError(f"unknown runtime mode {runtime!r} (use 'adaptive')")
            # late import: repro.runtime depends on repro.core
            from repro.runtime.drift import DriftDetector
            from repro.runtime.online import OnlineTuner

            if not isinstance(drift, DriftDetector):
                drift = DriftDetector(**(drift or {}))
            self._online = OnlineTuner(
                self.at,
                epsilon=epsilon,
                drift=drift,
                warm_frac=warm_frac,
                name=name or "tuned_step",
                measure=measure,
            )

    # ------------------------------------------------------------------ api
    @property
    def finished(self) -> bool:
        return self.at.finished

    @property
    def knobs(self) -> dict:
        return self.at.point

    @property
    def best_knobs(self) -> dict:
        return self.at.best_point

    def reset(self, level: int = 0) -> None:
        self.at.reset(level)

    def _step_for(self, knobs: dict) -> Callable:
        key = self.at.space.key(knobs)
        step = self._steps.get(key)
        if step is None:
            step = self._factory(**knobs)
            self._steps[key] = step
        return step

    @property
    def online(self):
        """The adaptive-mode :class:`OnlineTuner` (None in classic mode)."""
        return self._online

    @property
    def drift_events(self) -> list:
        return list(self._online.events) if self._online is not None else []

    def __call__(self, *args, **kwargs):
        """Single Iteration mode: run one (possibly tuning) step."""
        if self._online is not None:
            return self._adaptive_call(args, kwargs)
        knobs = self.at.start()
        if self._on_candidate is not None:
            self._on_candidate(knobs)
        step = self._step_for(knobs)
        out = step(*args, **kwargs)
        self.at.end(out)  # blocks on out; no-op once finished
        return out

    def _adaptive_call(self, args: tuple, kwargs: dict):
        """Adaptive runtime mode: explore/exploit via the online tuner, with
        drift-triggered warm re-searches.  ``ignore`` still absorbs a fresh
        candidate's compile: explore costs flow through ``Autotuning.exec``."""
        decision = self._online.begin()
        knobs = dict(decision.point)
        if self._on_candidate is not None:
            self._on_candidate(knobs)
        step = self._step_for(knobs)
        t0 = time.perf_counter()
        out = step(*args, **kwargs)
        _block(out)
        self._online.observe(decision, time.perf_counter() - t0)
        return out

    def tune(self, *replica_args, **replica_kwargs) -> dict:
        """Entire Execution mode: run the whole tuning loop on replica args."""
        while not self.at.finished:
            knobs = self.at.start()
            if self._on_candidate is not None:
                self._on_candidate(knobs)
            step = self._step_for(knobs)
            out = step(*replica_args, **replica_kwargs)
            self.at.end(out)
        return self.at.point
