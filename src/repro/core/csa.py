"""Coupled Simulated Annealing (CSA) — PATSMA's primary optimizer.

Faithful implementation of CSA with modified (coupled) acceptance and
acceptance-variance control, after

    Xavier-de-Souza, Suykens, Vandewalle, Bollé,
    "Coupled Simulated Annealing", IEEE Trans. SMC-B 40(2), 2010.

``m = num_opt`` SA solvers run in parallel.  Each solver ``i`` holds a current
solution ``x_i`` with energy ``E_i``.  Probes are generated with a Cauchy-like
kernel scaled by the generation temperature ``T_gen`` (schedule
``T_gen_k = T_gen0 / k``).  Acceptance of an *uphill* probe is coupled across
solvers through

    gamma   = sum_j exp((E_j - max_j E_j) / T_ac)
    A_i     = exp((E_i - max_j E_j) / T_ac) / gamma

and the acceptance temperature ``T_ac`` is steered so that the variance of
``A`` approaches ``sigma_D^2 = 0.99 * (m-1)/m^2`` (99% of its maximum), the
rule recommended in the CSA paper: variance below target → multiply ``T_ac``
by ``(1 - alpha)``, above → ``(1 + alpha)``.

Rounds are natural batches — the CSA paper runs its m solvers in parallel by
construction — so the batch protocol maps directly:

    ask()  : the m initial random solutions (INIT round) or the m probes of
             the current iteration, generated in solver order;
    tell() : store the m costs, perform the coupled acceptance step, update
             temperatures, advance the iteration counter.

The sequential ``run(cost)`` staging (paper §2.2) is the base-class adapter
over ask/tell and emits the exact same candidate sequence.  Evaluation count
therefore still matches paper Eq. (1):
``num_eval = max_iter * (ignore + 1) * num_opt`` (the INIT round counts as
iteration 1; ``ignore`` is applied by the Autotuning driver).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from .optimizer import NumericalOptimizer

__all__ = ["CSA"]

_INIT, _PROBE, _DONE = 0, 1, 2


class CSA(NumericalOptimizer):
    def __init__(
        self,
        dim: int,
        num_opt: int = 4,
        max_iter: int = 100,
        *,
        tgen0: float = 1.0,
        tac0: float = 0.9,
        alpha: float = 0.05,
        seed: int = 0,
    ) -> None:
        if dim < 1:
            raise ValueError(f"dim must be >= 1, got {dim}")
        if num_opt < 2:
            raise ValueError(f"CSA needs num_opt >= 2 coupled solvers, got {num_opt}")
        if max_iter < 1:
            raise ValueError(f"max_iter must be >= 1, got {max_iter}")
        self._dim = dim
        self._m = num_opt
        self._max_iter = max_iter
        self._tgen0 = float(tgen0)
        # cold-start configuration; seed()/shrink_budget() narrow the live
        # values, a complete reset must restore these
        self._cold_max_iter = max_iter
        self._cold_tgen0 = float(tgen0)
        self._tac0 = float(tac0)
        self._alpha = float(alpha)
        self._seed = seed
        self._rng = np.random.default_rng(seed)
        self._full_init()

    # ------------------------------------------------------------------ state
    def _full_init(self) -> None:
        self._x = self._rng.uniform(self.LO, self.HI, size=(self._m, self._dim))
        self._e = np.full(self._m, np.inf)
        self._probes = np.zeros_like(self._x)
        self._probe_e = np.full(self._m, np.inf)
        self._tgen = self._tgen0
        self._tac = self._tac0
        self._iter = 1  # INIT round is iteration 1 (keeps Eq.1 exact)
        self._phase = _INIT
        self._best_x = self._x[0].copy()
        self._best_e = np.inf
        # target acceptance-probability variance (99% of max, CSA paper §V)
        self._sigma_d2 = 0.99 * (self._m - 1) / self._m**2
        self._clear_batch_state()

    # ------------------------------------------------------------- interface
    def get_num_points(self) -> int:
        return self._m

    def get_dimension(self) -> int:
        return self._dim

    def is_end(self) -> bool:
        return self._phase == _DONE

    @property
    def best_solution(self) -> np.ndarray:
        return self._best_x.copy()

    @property
    def best_cost(self) -> float:
        return float(self._best_e)

    @property
    def iteration(self) -> int:
        return self._iter

    @property
    def temperatures(self) -> tuple:
        return (self._tgen, self._tac)

    def print(self) -> None:  # noqa: A003 - paper API name
        print(
            f"CSA(m={self._m}, dim={self._dim}) iter={self._iter}/{self._max_iter} "
            f"phase={self._phase} Tgen={self._tgen:.4g} Tac={self._tac:.4g} "
            f"best={self._best_e:.6g} @ {np.array2string(self._best_x, precision=3)}"
        )

    def seed(self, z0, spread: float = 0.2) -> bool:
        """Warm start: place solver 0 exactly at ``z0`` and scatter the other
        coupled solvers around it (Cauchy-free gaussian cloud, wrapped into the
        toroidal domain).  Only valid before the first candidate is emitted."""
        if self._phase != _INIT or self._pending_batch is not None:
            return False
        z0 = np.asarray(z0, dtype=float).reshape(-1)
        if z0.shape[0] != self._dim:
            raise ValueError(f"seed dim {z0.shape[0]} != {self._dim}")
        self._x[0] = self._clip(z0)
        for i in range(1, self._m):
            self._x[i] = self._wrap(z0 + self._rng.normal(0.0, spread, size=self._dim))
        # a tight start wants a cooler generation schedule than a blind one
        self._tgen = self._tgen0 = min(self._tgen0, max(spread, 1e-3))
        return True

    def shrink_budget(self, frac: float) -> bool:
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"frac must be in (0, 1], got {frac}")
        self._max_iter = max(1, int(np.ceil(self._max_iter * frac)))
        return True

    def reset(self, level: int = 0) -> None:
        """level 0: re-anneal keeping all current solutions (and their
        energies — found solutions are retained, paper §2.2);
        level 1: keep only the best solution's *coordinates* as solver 0,
        randomize the rest, and forget all stored energies — the point
        survives but must re-prove itself in the new environment (this is
        the drift-reset level: stale pre-drift costs must not outbid fresh
        measurements);
        level >= 2: complete reset (paper §2.2: 'a complete reset').

        Every level restores the cold generation temperature and iteration
        budget: a reset starts a new annealing episode, so a budget shrunk
        by an earlier warm start does not compound across resets (the caller
        re-applies ``seed()``/``shrink_budget()`` if the new episode should
        be warm too)."""
        if level >= 2:
            self._rng = np.random.default_rng(self._seed)
            self._tgen0 = self._cold_tgen0
            self._max_iter = self._cold_max_iter
            self._full_init()
            return
        self._tgen0 = self._cold_tgen0
        self._max_iter = self._cold_max_iter
        if level == 1:
            keep = self._best_x.copy()
            self._x = self._rng.uniform(self.LO, self.HI, size=(self._m, self._dim))
            self._x[0] = keep
            self._best_e = np.inf  # coordinates kept, stale energy dropped
        # level 0 and 1 share: restart the annealing schedule + re-evaluate
        self._e = np.full(self._m, np.inf)
        self._tgen = self._tgen0
        self._tac = self._tac0
        self._iter = 1
        self._phase = _INIT
        self._clear_batch_state()

    # -------------------------------------------------------- batch protocol
    def _next_batch(self) -> Optional[List[np.ndarray]]:
        if self._phase == _INIT:
            return [self._x[i].copy() for i in range(self._m)]
        # _PROBE: one probe per solver, generated in solver order (the same
        # RNG draw order the sequential staging used)
        return [self._gen_probe(i) for i in range(self._m)]

    def _consume_batch(self, points: List[np.ndarray], costs: List[float]) -> None:
        if self._phase == _INIT:
            for i in range(self._m):
                self._e[i] = costs[i]
                self._note_best(self._x[i], costs[i])
        else:
            for i in range(self._m):
                self._probe_e[i] = costs[i]
                self._note_best(self._probes[i], costs[i])
            self._coupled_acceptance()
        self._iter += 1
        if self._iter > self._max_iter:
            self._phase = _DONE
            return
        self._phase = _PROBE
        self._tgen = self._tgen0 / self._iter  # T_gen_k = T_gen0 / k

    def _note_best(self, x: np.ndarray, e: float) -> None:
        if e < self._best_e:
            self._best_e = e
            self._best_x = x.copy()

    def _gen_probe(self, i: int) -> np.ndarray:
        u = self._rng.uniform(size=self._dim)
        step = self._tgen * np.tan(np.pi * (u - 0.5))  # Cauchy kernel
        y = self._wrap(self._x[i] + step)
        self._probes[i] = y
        return y.copy()

    def _coupled_acceptance(self) -> None:
        """Vectorized coupled-acceptance step (numpy masks, no solver loop).

        RNG-stream compatible with the historical per-solver staging: a
        uniform is drawn only for finite, *uphill* probes (downhill moves are
        accepted unconditionally; crashed configurations are never adopted),
        in solver order — ``uniform(size=k)`` yields the same doubles as k
        sequential draws, so trajectories for a given seed are unchanged.
        """
        e = self._e
        emax = float(np.max(e[np.isfinite(e)])) if np.any(np.isfinite(e)) else 0.0
        ex = np.exp((np.where(np.isfinite(e), e, emax) - emax) / max(self._tac, 1e-300))
        gamma = float(np.sum(ex))
        probs = ex / gamma  # A_i, sum to 1
        finite = np.isfinite(self._probe_e)  # never move onto a crashed config
        downhill = self._probe_e < self._e
        need_u = finite & ~downhill  # uphill probes gamble on coupled A_i
        u = np.full(self._m, np.inf)
        u[need_u] = self._rng.uniform(size=int(np.count_nonzero(need_u)))
        accept = finite & (downhill | (u < probs))
        self._x[accept] = self._probes[accept]
        self._e[accept] = self._probe_e[accept]
        # variance steering of T_ac toward sigma_D^2 = 0.99*(m-1)/m^2
        sigma2 = float(np.mean(probs**2) - (1.0 / self._m) ** 2)
        if sigma2 < self._sigma_d2:
            self._tac *= 1.0 - self._alpha
        else:
            self._tac *= 1.0 + self._alpha
