"""Composable search strategies — the paper's CSA→NM hybrid as a first-class
multi-stage pipeline.

PATSMA's central design point (§1, §2.2) is the *coupling* of CSA's global
exploration with Nelder–Mead's local refinement, yet a single
:class:`~repro.core.optimizer.NumericalOptimizer` can only express one method.
This module turns the hybrid into a first-class object: a **search strategy**
is anything speaking the optimizer's batch ``ask()``/``tell(costs)`` surface
(:data:`SearchStrategy` is that protocol — every ``NumericalOptimizer``
already satisfies it), and two combinators compose existing optimizers into
richer strategies while *remaining* optimizers themselves, so the
``Autotuning`` driver, PR 2's batched evaluation, and PR 4's adaptive
measurement engine all work on them unchanged:

* :class:`Pipeline` — staged search with an explicit budget split.  Stage
  ``i+1`` is warm-seeded from the pipeline's incumbent best (for the
  canonical ``CSA → NM`` hybrid: NM's initial simplex is built in a
  simplex-radius neighborhood of CSA's best).  ``reset`` is stage-aware:
  level 0 restarts the *current stage* only, level ≥ 1 restarts the whole
  pipeline warm at the incumbent's coordinates, and
  :meth:`Pipeline.enter_refinement` re-enters through the final
  (refinement) stage alone — the online tuner's answer to environment
  drift, where the optimum moved a little but the basin did not.
* :class:`Portfolio` — interleaved rounds of several optimizers racing on
  the same cost, with successive-halving budget reallocation toward the
  leader.  A member is culled only when its best is *statistically
  separated* from the leader's, reusing the measurement engine's
  noise-floor machinery (:class:`~repro.core.measure.NoiseEstimate`);
  a culled member's remaining budget flows to the survivors.

Budgets are counted in **tells** (cost evaluations delivered), the unit of
paper Eq. (1)/(2), so ``Pipeline([CSA, NM], budget=B)`` and a pure
``CSA(max_iter=B/num_opt)`` consume exactly the same number of measurements.

:func:`make_strategy` parses the user-facing string specs (``"csa+nm"``,
``"csa:0.7+nm:0.3"``, ``"csa|nm"``) into strategy objects, and
:func:`strategy_label` derives the canonical spec back from any optimizer
tree — the provenance string stamped on persisted ``TuningRecord``s.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence

import numpy as np

from repro.obs import metrics as _metrics

from .csa import CSA
from .grid_random import GridSearch, RandomSearch
from .measure import NoiseEstimate
from .nelder_mead import NelderMead
from .optimizer import NumericalOptimizer

__all__ = [
    "SearchStrategy",
    "Pipeline",
    "Portfolio",
    "cull_laggards",
    "make_strategy",
    "strategy_label",
]

#: The strategy protocol *is* the optimizer's batch surface: anything with
#: ask()/tell()/is_end()/reset(level)/seed()/shrink_budget().  Combinators
#: subclass NumericalOptimizer so they satisfy it by construction and drop
#: into every existing driver (Autotuning, OnlineTuner, ContextRouter).
SearchStrategy = NumericalOptimizer

def cull_laggards(
    active: Sequence[int],
    member_bests: Sequence[float],
    noise: NoiseEstimate,
    margin: float = 0.5,
) -> List[int]:
    """The successive-halving cull decision, as a pure function.

    Given the indices of the members still racing and every member's best
    cost so far, return the indices to cull *now*: members whose best is
    statistically separated from the leader's — beyond the noise floor
    widened by ``margin`` — worst first, at most half the field per check,
    never the leader.  Shared verbatim by :class:`Portfolio` (serial
    round-robin driver) and :class:`repro.tuning.fleet.ShardedPortfolio`
    (one worker per member), so the two drivers make identical cull
    decisions from identical scoreboards.
    """
    if len(active) < 2:
        return []
    order = sorted(active, key=lambda i: member_bests[i])
    leader_best = member_bests[order[0]]
    if not np.isfinite(leader_best):
        return []
    line = leader_best + noise.floor(leader_best) * (1.0 + margin)
    may_cull = len(active) // 2  # successive halving: keep ⌈n/2⌉
    culled: List[int] = []
    for i in reversed(order[1:]):  # worst first; never the leader
        if len(culled) >= may_cull:
            break
        if member_bests[i] > line:
            culled.append(i)
    return culled


#: default seeding radius when a stage hands off to the next (normalized
#: coords) — the "simplex-radius neighborhood" of the incumbent.  Wider than
#: the DB warm-start spread (0.2) on purpose: the global stage's best may sit
#: one basin off on a multimodal landscape, and the refinement simplex must
#: straddle the neighboring basin to correct it (empirically the difference
#: between losing and beating pure CSA on rastrigin at small budgets).
DEFAULT_HANDOFF_SPREAD = 0.5


class Pipeline(NumericalOptimizer):
    """Staged search: run ``stages[0]``, seed ``stages[1]`` at its best, ...

    Parameters
    ----------
    stages:
        The stage optimizers, in order (same dimension).  The canonical
        instance is ``[CSA(...), NelderMead(...)]`` — the paper's hybrid.
    budget_fracs:
        Per-stage share of ``budget`` (normalized; default: equal split).
        A stage that converges early donates its unspent share downstream.
    budget:
        Total tell budget across all stages.  ``None`` lets every stage run
        to its own intrinsic end (``budget_fracs`` must then be None too).
    seed_spread:
        Normalized radius of the warm seed handed to the next stage.

    Budget enforcement is exact: the final batch of a stage (and of the
    pipeline) is truncated to the remaining allowance.  A truncated round's
    costs still update the pipeline-level incumbent but are *not* fed to the
    stage optimizer — its round contract (m probes in, m costs back) stays
    intact, the stage is simply abandoned at the boundary.
    """

    def __init__(
        self,
        stages: Sequence[NumericalOptimizer],
        budget_fracs: Optional[Sequence[float]] = None,
        *,
        budget: Optional[int] = None,
        seed_spread: float = DEFAULT_HANDOFF_SPREAD,
    ) -> None:
        stages = list(stages)
        if not stages:
            raise ValueError("Pipeline needs at least one stage")
        dims = {s.get_dimension() for s in stages}
        if len(dims) != 1:
            raise ValueError(f"stage dimensions differ: {sorted(dims)}")
        if budget is None:
            if budget_fracs is not None:
                raise ValueError("budget_fracs requires an explicit budget")
        else:
            if budget < 1:
                raise ValueError(f"budget must be >= 1, got {budget}")
        if budget_fracs is None:
            fracs = [1.0 / len(stages)] * len(stages)
        else:
            fracs = [float(f) for f in budget_fracs]
            if len(fracs) != len(stages):
                raise ValueError(
                    f"{len(fracs)} budget_fracs for {len(stages)} stages"
                )
            if any(f < 0 for f in fracs) or sum(fracs) <= 0:
                raise ValueError(f"budget_fracs must be >= 0 and sum > 0: {fracs}")
            total = sum(fracs)
            fracs = [f / total for f in fracs]
        self._stages = stages
        self._fracs = fracs
        self._budget0 = int(budget) if budget is not None else None
        self._budget = self._budget0  # live episode budget (shrink_budget)
        self._dim = stages[0].get_dimension()
        self._seed_spread = float(seed_spread)
        self._si = 0
        self._spent = 0  # tells delivered this episode
        self._entry_spent = 0  # tells at entry into the current stage
        self._refining = False  # episode = final stage only (enter_refinement)
        self._truncated = False  # pending round not forwarded to the stage
        self._done = False
        self._best_x = np.zeros(self._dim)
        self._best_e = np.inf

    # ------------------------------------------------------------- interface
    def get_num_points(self) -> int:
        return max(s.get_num_points() for s in self._stages)

    def get_dimension(self) -> int:
        return self._dim

    def is_end(self) -> bool:
        return self._done

    @property
    def stages(self) -> list:
        return list(self._stages)

    @property
    def stage_index(self) -> int:
        """Index of the stage currently being driven."""
        return self._si

    @property
    def refining(self) -> bool:
        """Whether this episode runs the final (refinement) stage alone."""
        return self._refining

    @property
    def spent(self) -> int:
        """Tells delivered this episode (== the measurement budget consumed)."""
        return self._spent

    @property
    def best_solution(self) -> np.ndarray:
        if np.isfinite(self._best_e):
            return self._best_x.copy()
        return self._stages[self._si].best_solution

    @property
    def best_cost(self) -> float:
        return float(self._best_e)

    def print(self) -> None:  # noqa: A003 - paper API name
        print(
            f"Pipeline(stage {self._si + 1}/{len(self._stages)}"
            f"{', refining' if self._refining else ''}) spent={self._spent}"
            f"/{self._budget if self._budget is not None else '∞'} "
            f"best={self._best_e:.6g}"
        )
        self._stages[self._si].print()

    # --------------------------------------------------------------- budget
    def _boundary(self, si: int) -> Optional[float]:
        """Cumulative tell allowance through stage ``si`` this episode.
        Unspent earlier allocation rolls forward automatically (the boundary
        is cumulative, not per-stage)."""
        if self._budget is None:
            return None
        if self._refining or si >= len(self._stages) - 1:
            return self._budget
        cum = sum(self._fracs[: si + 1])
        return int(round(cum * self._budget))

    def seed(self, z0, spread: float = 0.2) -> bool:
        """Warm start the *current* stage (stage 0 at cold construction — a
        DB warm start seeds only the first stage; after
        :meth:`enter_refinement`, the refinement stage)."""
        return self._stages[self._si].seed(z0, spread=spread)

    def shrink_budget(self, frac: float) -> bool:
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"frac must be in (0, 1], got {frac}")
        if self._budget is not None:
            self._budget = max(1, int(math.ceil(self._budget * frac)))
            return True
        applied = False
        for s in self._stages:
            applied = s.shrink_budget(frac) or applied
        return applied

    # ---------------------------------------------------------------- resets
    def reset(self, level: int = 0) -> None:
        """Stage-aware reset (paper §2.2, lifted to the pipeline):

        * level 0 — restart the **current stage** only: its tell allowance is
          restored and it re-anneals keeping its found solutions; earlier
          stages' work (and the pipeline incumbent) is retained.
        * level 1 — restart the **whole pipeline warm at the incumbent**:
          every stage resets, stage 0 is re-seeded at the best coordinates
          found so far, and the stale energy is dropped (the point must
          re-prove itself — the drift-reset contract shared with CSA/NM).
        * level ≥ 2 — complete cold reset of every stage.

        Every level restores the cold episode budget (a shrunk warm-start
        budget never compounds across resets); level >= 1 also leaves
        refinement mode (level 0 inside a refinement episode restarts that
        episode at its own cold allowance, not the full pipeline's).
        """
        self._truncated = False
        self._done = False
        if level == 0:
            if self._refining and self._budget0 is not None:
                self._budget = max(1, int(round(self._fracs[-1] * self._budget0)))
            else:
                self._budget = self._budget0
            self._stages[self._si].reset(0)
            self._spent = self._entry_spent
            self._clear_batch_state()
            return
        self._budget = self._budget0
        keep = self._best_x.copy() if np.isfinite(self._best_e) else None
        for s in self._stages:
            s.reset(level)
        self._si = 0
        self._spent = 0
        self._entry_spent = 0
        self._refining = False
        if level == 1 and keep is not None:
            self._stages[0].seed(keep, spread=self._seed_spread)
            self._best_x = keep  # coordinates survive, energy must re-prove
        self._best_e = np.inf
        if level >= 2:
            self._best_x = np.zeros(self._dim)
        self._clear_batch_state()

    def enter_refinement(self) -> bool:
        """Re-enter the search through the final stage alone — the response
        to *environment drift* (the optimum's basin is unchanged, its floor
        moved): a full global re-exploration would waste the budget the
        refinement stage can spend walking downhill from the deployed point.

        The final stage is cold-reset and the episode budget becomes that
        stage's nominal share of the cold total; the caller then seeds it at
        the incumbent (``seed`` targets the current — now final — stage) and
        may shrink the episode further.  Returns True (the strategy supports
        level-aware refinement; drivers fall back to ``reset`` when absent).
        """
        last = len(self._stages) - 1
        self._stages[last].reset(1)
        self._si = last
        self._refining = True
        self._spent = 0
        self._entry_spent = 0
        if self._budget0 is not None:
            self._budget = max(1, int(round(self._fracs[last] * self._budget0)))
        else:
            self._budget = None
        self._best_e = np.inf  # incumbent coordinates kept, energy re-proves
        self._truncated = False
        self._done = False
        self._clear_batch_state()
        return True

    # -------------------------------------------------------- batch protocol
    def _advance(self) -> None:
        """Move to the next stage, warm-seeding it at the incumbent."""
        self._si += 1
        _metrics.counter("strategy.stage_transitions").inc()
        if self._si >= len(self._stages):
            return
        self._entry_spent = self._spent
        if np.isfinite(self._best_e):
            self._stages[self._si].seed(self._best_x, spread=self._seed_spread)

    def _next_batch(self) -> Optional[List[np.ndarray]]:
        while True:
            if self._budget is not None and self._spent >= self._budget:
                self._done = True
                return None
            if self._si >= len(self._stages):
                self._done = True
                return None
            st = self._stages[self._si]
            bound = self._boundary(self._si)
            if st.is_end() or (bound is not None and self._spent >= bound):
                if self._si == len(self._stages) - 1:
                    self._done = True
                    return None
                self._advance()
                continue
            batch = st.ask()
            if not batch:
                if self._si == len(self._stages) - 1:
                    self._done = True
                    return None
                self._advance()
                continue
            allowed = None if bound is None else bound - self._spent
            if self._budget is not None:
                rem = self._budget - self._spent
                allowed = rem if allowed is None else min(allowed, rem)
            if allowed is not None and len(batch) > allowed:
                batch = batch[:allowed]
                self._truncated = True
            else:
                self._truncated = False
            return batch

    def _consume_batch(self, points: List[np.ndarray], costs: List[float]) -> None:
        for p, c in zip(points, costs):
            if np.isfinite(c) and c < self._best_e:
                self._best_e = float(c)
                self._best_x = np.array(p, dtype=float, copy=True)
        self._spent += len(costs)
        if not self._truncated:
            # a full round: the stage's own accept/anneal step runs
            self._stages[self._si].tell(costs)
        self._truncated = False


class Portfolio(NumericalOptimizer):
    """Interleaved optimizer rounds with successive-halving reallocation.

    Members take turns receiving **rung-sized chunks** of the shared tell
    budget (a member whose natural round is larger than one rung — a grid's
    whole sweep, CSA's m probes — has its round drip-fed across turns: the
    chunk costs buffer until the full round is delivered, exactly like the
    sequential ``run`` adapter, so no member can monopolize the budget in a
    single ask).  Once every active member has consumed a rung since the
    last check, members whose best cost is **statistically separated** from
    the leader's — beyond the measurement noise floor, the same
    :class:`~repro.core.measure.NoiseEstimate` machinery the adaptive
    measurement engine races candidates with — are culled, at most half of
    the field per check (successive halving).  A culled member stops
    consuming turns, so with a shared ``budget`` its remaining allowance
    flows toward the leader.

    ``noise`` defaults to the measurement engine's priors; a driver that has
    calibrated a real noise floor can tighten the separation test via
    :meth:`set_noise` (``tune_call`` wires the engine's calibration in).
    """

    def __init__(
        self,
        optimizers: Sequence[NumericalOptimizer],
        *,
        budget: Optional[int] = None,
        noise: Optional[NoiseEstimate] = None,
        margin: float = 0.5,
        rung: Optional[int] = None,
    ) -> None:
        opts = list(optimizers)
        if len(opts) < 2:
            raise ValueError("Portfolio needs at least two optimizers")
        dims = {o.get_dimension() for o in opts}
        if len(dims) != 1:
            raise ValueError(f"member dimensions differ: {sorted(dims)}")
        if budget is not None and budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        self._opts = opts
        self._dim = opts[0].get_dimension()
        self._budget0 = int(budget) if budget is not None else None
        self._budget = self._budget0
        self._noise = noise if noise is not None else NoiseEstimate(0.0, 0.02)
        self._margin = float(margin)
        if rung is not None and int(rung) < 1:
            raise ValueError(f"rung must be >= 1, got {rung}")
        if rung is not None:
            self._rung = int(rung)
        else:
            # one rung = one natural round of the widest member — but capped
            # at a fair share of the budget: a sweep-style member whose
            # "round" is its whole grid (get_num_points == sweep size) must
            # not swallow the entire budget in its first chunk
            self._rung = max(o.get_num_points() for o in opts)
            if budget is not None:
                self._rung = max(1, min(self._rung, int(budget) // (2 * len(opts))))
        self._active: List[int] = list(range(len(opts)))
        self._turn = 0  # position in the active list
        self._spent = 0
        self._member_best = [np.inf] * len(opts)
        self._since_check = [0] * len(opts)  # tells since the last cull check
        self._round: List[Optional[list]] = [None] * len(opts)  # pending round
        self._fed: List[list] = [[] for _ in opts]  # costs buffered for it
        self._cur: Optional[int] = None  # member owning the pending chunk
        self._done = False
        self._best_x = np.zeros(self._dim)
        self._best_e = np.inf

    # ------------------------------------------------------------- interface
    def get_num_points(self) -> int:
        return max(o.get_num_points() for o in self._opts)

    def get_dimension(self) -> int:
        return self._dim

    def is_end(self) -> bool:
        return self._done

    @property
    def members(self) -> list:
        return list(self._opts)

    @property
    def active(self) -> list:
        """Indices of members still racing (culled members are dropped)."""
        return list(self._active)

    @property
    def member_bests(self) -> list:
        """Best finite cost seen per member (inf if none yet)."""
        return [float(b) for b in self._member_best]

    @property
    def spent(self) -> int:
        return self._spent

    @property
    def best_solution(self) -> np.ndarray:
        if np.isfinite(self._best_e):
            return self._best_x.copy()
        return self._opts[self._active[0] if self._active else 0].best_solution

    @property
    def best_cost(self) -> float:
        return float(self._best_e)

    def set_noise(self, noise: NoiseEstimate) -> None:
        """Adopt a calibrated noise floor for the separation test (the
        measurement engine's calibration supersedes the priors)."""
        self._noise = noise

    def print(self) -> None:  # noqa: A003 - paper API name
        bests = ", ".join(
            f"#{i}={self._member_best[i]:.4g}{'' if i in self._active else '†'}"
            for i in range(len(self._opts))
        )
        print(
            f"Portfolio({len(self._active)}/{len(self._opts)} active) "
            f"spent={self._spent}/{self._budget if self._budget is not None else '∞'} "
            f"[{bests}]"
        )

    def seed(self, z0, spread: float = 0.2) -> bool:
        applied = False
        for o in self._opts:
            applied = o.seed(z0, spread=spread) or applied
        return applied

    def shrink_budget(self, frac: float) -> bool:
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"frac must be in (0, 1], got {frac}")
        if self._budget is not None:
            self._budget = max(1, int(math.ceil(self._budget * frac)))
            return True
        applied = False
        for o in self._opts:
            applied = o.shrink_budget(frac) or applied
        return applied

    def reset(self, level: int = 0) -> None:
        """Portfolio resets re-activate every member (a culled method may be
        the right one for the drifted environment).  Level semantics follow
        the shared contract: 0 keeps found solutions, 1 keeps the incumbent's
        coordinates but drops stale energies, ≥ 2 is a complete reset.  Every
        level restores the cold budget."""
        self._budget = self._budget0
        keep = self._best_x.copy() if np.isfinite(self._best_e) else None
        for o in self._opts:
            o.reset(level)
        self._active = list(range(len(self._opts)))
        self._turn = 0
        self._spent = 0
        self._member_best = [np.inf] * len(self._opts)
        self._since_check = [0] * len(self._opts)
        self._round = [None] * len(self._opts)
        self._fed = [[] for _ in self._opts]
        self._cur = None
        self._done = False
        if level >= 1:
            self._best_e = np.inf
            if level >= 2 or keep is None:
                self._best_x = np.zeros(self._dim)
            else:
                self._best_x = keep
                for o in self._opts:  # every member restarts at the incumbent
                    o.seed(keep, spread=DEFAULT_HANDOFF_SPREAD)
        self._clear_batch_state()

    # -------------------------------------------------------- batch protocol
    def _member_live(self, i: int) -> bool:
        """A member is live if it has a round in flight or can still ask."""
        return self._round[i] is not None or not self._opts[i].is_end()

    def _next_batch(self) -> Optional[List[np.ndarray]]:
        for _ in range(len(self._opts) + 1):
            if self._budget is not None and self._spent >= self._budget:
                self._done = True
                return None
            if not any(self._member_live(i) for i in self._active):
                self._done = True
                return None
            if self._turn >= len(self._active):
                self._turn = 0
            i = self._active[self._turn]
            if self._round[i] is None:
                if self._opts[i].is_end():
                    self._turn += 1
                    continue
                r = self._opts[i].ask()
                if not r:
                    self._turn += 1
                    continue
                self._round[i] = r
                self._fed[i] = []
            # the next rung-sized chunk of the member's pending round
            allowed = self._rung
            if self._budget is not None:
                allowed = min(allowed, self._budget - self._spent)
            done_n = len(self._fed[i])
            chunk = self._round[i][done_n:done_n + max(1, allowed)]
            self._cur = i
            return [np.asarray(p, dtype=float).copy() for p in chunk]
        self._done = True
        return None

    def _consume_batch(self, points: List[np.ndarray], costs: List[float]) -> None:
        i = self._cur
        for p, c in zip(points, costs):
            if np.isfinite(c):
                if c < self._member_best[i]:
                    self._member_best[i] = float(c)
                if c < self._best_e:
                    self._best_e = float(c)
                    self._best_x = np.array(p, dtype=float, copy=True)
        self._spent += len(costs)
        self._since_check[i] += len(costs)
        self._fed[i].extend(costs)
        if len(self._fed[i]) >= len(self._round[i]):
            # the member's full round is in: its accept/anneal step runs
            self._opts[i].tell(self._fed[i])
            self._round[i] = None
            self._fed[i] = []
        self._cur = None
        self._turn += 1
        self._maybe_halve()

    def _maybe_halve(self) -> None:
        """Cull statistically separated laggards once every active member has
        consumed its check quota since the last check (at most half the
        field).  The quota is the member's own natural round size, capped by
        the rung — a small-round member (CSA's m probes) must not wait for a
        sweep-style member's full rung before the race is scored."""
        if len(self._active) < 2:
            return

        def quota(i: int) -> int:
            return min(self._rung, max(1, self._opts[i].get_num_points()))

        if not all(
            self._since_check[i] >= quota(i) or not self._member_live(i)
            for i in self._active
        ):
            return
        for i in self._active:
            self._since_check[i] = 0
        for i in cull_laggards(
            self._active, self._member_best, self._noise, self._margin
        ):
            self._active.remove(i)
        if self._turn >= len(self._active):
            self._turn = 0


# ------------------------------------------------------------------- parsing
_STAGE_NAMES = ("csa", "nm", "random", "grid")


def strategy_label(opt: NumericalOptimizer) -> str:
    """Canonical spec string of an optimizer tree (provenance for
    ``TuningRecord.strategy``).  Inverse of :func:`make_strategy` up to
    budget fractions, which are printed only when non-uniform."""
    if isinstance(opt, Pipeline):
        stages = opt.stages
        fracs = opt._fracs
        # elide fractions only when they are exactly the parser's default
        # split — any other split (including a uniform one built directly)
        # must round-trip through make_strategy to the same budget shares
        default = all(
            abs(f - d) < 1e-9 for f, d in zip(fracs, _default_fracs(len(fracs)))
        )
        parts = []
        for s, f in zip(stages, fracs):
            lbl = strategy_label(s)
            parts.append(lbl if default else f"{lbl}:{f:g}")
        return "+".join(parts)
    if isinstance(opt, Portfolio):
        return "|".join(strategy_label(o) for o in opt.members)
    if isinstance(opt, CSA):
        return "csa"
    if isinstance(opt, NelderMead):
        return "nm"
    if isinstance(opt, RandomSearch):
        return "random"
    if isinstance(opt, GridSearch):
        return "grid"
    return type(opt).__name__.lower()


def _parse_stage(token: str):
    """``name[:frac]`` -> (name, frac-or-None)."""
    token = token.strip().lower()
    frac = None
    if ":" in token:
        token, _, f = token.partition(":")
        token = token.strip()
        try:
            frac = float(f)
        except ValueError:
            raise ValueError(f"bad budget fraction in stage spec {token!r}:{f!r}")
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"stage fraction must be in (0, 1], got {frac}")
    if token not in _STAGE_NAMES:
        raise ValueError(
            f"unknown stage {token!r}; known stages: {', '.join(_STAGE_NAMES)}"
        )
    return token, frac


def _build_stage(
    name: str, dim: int, budget: int, *, num_opt: int, seed: int
) -> NumericalOptimizer:
    """One stage optimizer sized to ``budget`` tells."""
    if name == "csa":
        m = max(2, min(num_opt, budget))
        return CSA(dim, num_opt=m, max_iter=max(1, int(round(budget / m))), seed=seed)
    if name == "nm":
        return NelderMead(dim, error=0.0, max_iter=max(dim + 2, budget), seed=seed)
    if name == "random":
        return RandomSearch(dim, max_iter=max(1, budget), seed=seed)
    if name == "grid":
        ppd = max(2, int(round(budget ** (1.0 / dim))))
        return GridSearch(dim, points_per_dim=ppd)
    raise ValueError(f"unknown stage {name!r}")


#: default pipeline split: each stage takes this share of the *remaining*
#: budget, the final stage takes the rest — exploration-heavy (a 2-stage
#: "csa+nm" gets 0.7/0.3: the global stage does the paper's heavy lifting,
#: local refinement converges in far fewer tells).  Chosen empirically on
#: the strategy_shootout cost models: an even split lets the global stage
#: hand off from the wrong basin on multimodal landscapes (rastrigin).
EXPLORE_FRAC = 0.7


def _default_fracs(n: int) -> List[float]:
    out, rem = [], 1.0
    for _ in range(n - 1):
        out.append(rem * EXPLORE_FRAC)
        rem *= 1.0 - EXPLORE_FRAC
    out.append(rem)
    return out


def _resolve_fracs(fracs: List[Optional[float]]) -> List[float]:
    """Fill unspecified fractions; all-unspecified uses the exploration-heavy
    default split, a partial spec splits the remainder equally."""
    if all(f is None for f in fracs):
        return _default_fracs(len(fracs))
    fixed = sum(f for f in fracs if f is not None)
    free = [i for i, f in enumerate(fracs) if f is None]
    if fixed > 1.0 + 1e-9:
        raise ValueError(f"stage fractions sum to {fixed:g} > 1")
    if free:
        share = max(0.0, 1.0 - fixed) / len(free)
        if share <= 0.0:
            raise ValueError(
                "explicit stage fractions leave no budget for the unspecified stages"
            )
        out = [share if f is None else f for f in fracs]
    else:
        out = [float(f) for f in fracs]
    total = sum(out)
    return [f / total for f in out]


def make_strategy(
    spec: str,
    dim: int,
    *,
    num_opt: int = 4,
    max_iter: int = 20,
    seed: int = 0,
    budget: Optional[int] = None,
    seed_spread: float = DEFAULT_HANDOFF_SPREAD,
    noise: Optional[NoiseEstimate] = None,
) -> NumericalOptimizer:
    """Parse a strategy spec into an optimizer.

    Grammar: ``pipeline ('|' pipeline)*`` builds a :class:`Portfolio`;
    ``stage ('+' stage)*`` builds a :class:`Pipeline`; a ``stage`` is
    ``name[:frac]`` with names ``csa | nm | random | grid``.  Examples::

        "csa"            # plain CSA — identical to the default optimizer
        "csa+nm"         # the paper's hybrid, exploration-heavy 0.7/0.3 split
        "csa:0.5+nm:0.5" # explicit budget fractions
        "csa|nm"         # portfolio: CSA and NM race, loser is halved away

    The total tell budget is ``budget`` (default ``num_opt * max_iter`` —
    exactly what the default CSA consumes per paper Eq. (1), so swapping
    ``strategy=`` for ``optimizer=`` never changes the measurement count).
    A single bare stage name returns the raw optimizer, not a one-stage
    wrapper, so ``strategy="csa"`` is bit-for-bit the default search.

    The built object carries the normalized spec on ``.spec`` for
    provenance (``TuningRecord.strategy``).
    """
    if not isinstance(spec, str) or not spec.strip():
        raise ValueError(f"strategy spec must be a non-empty string, got {spec!r}")
    total = int(budget) if budget is not None else max(1, int(num_opt) * int(max_iter))
    arms = [a.strip() for a in spec.split("|")]
    if any(not a for a in arms):
        raise ValueError(f"empty portfolio arm in strategy spec {spec!r}")

    def parse_arm(arm: str):
        tokens = [t for t in arm.split("+")]
        if any(not t.strip() for t in tokens):
            raise ValueError(f"empty stage in strategy spec {arm!r}")
        return [_parse_stage(t) for t in tokens]

    def build_arm(parsed, arm_budget: int) -> NumericalOptimizer:
        if len(parsed) == 1 and parsed[0][1] is None:
            return _build_stage(
                parsed[0][0], dim, arm_budget, num_opt=num_opt, seed=seed
            )
        fracs = _resolve_fracs([f for _, f in parsed])
        # every stage is sized to the FULL arm budget: the pipeline's
        # cumulative boundaries enforce the per-stage shares, and a stage
        # that converges early rolls its unspent share downstream — which an
        # intrinsic per-share stage budget could never absorb
        stages = [
            _build_stage(name, dim, arm_budget, num_opt=num_opt, seed=seed)
            for name, _ in parsed
        ]
        return Pipeline(
            stages, fracs, budget=arm_budget, seed_spread=seed_spread
        )

    parsed_arms = [parse_arm(a) for a in arms]
    if len(parsed_arms) == 1:
        out = build_arm(parsed_arms[0], total)
    else:
        # members are sized to the FULL budget: successive halving means the
        # surviving arm inherits the culled arms' allowance, so each must be
        # able to spend it; the portfolio's own cap bounds the total.
        members = [build_arm(p, total) for p in parsed_arms]
        out = Portfolio(members, budget=total, noise=noise)
    # the normalized spec (whitespace/case folded away) is the provenance
    # string — identical strategies must stamp identical specs on records
    out.spec = "|".join(
        "+".join(n if f is None else f"{n}:{f:g}" for n, f in p)
        for p in parsed_arms
    )
    return out
