"""The ``Autotuning`` driver — PATSMA's user-facing class (paper §2.3/§2.4).

Execution modes (paper Fig. 1):

  * **Single Iteration** (Fig. 1a): one auto-tuning iteration per natural
    iteration of the target loop — ``single_exec_runtime`` /
    ``single_exec``, or the raw ``start()``/``end()`` brackets.
  * **Entire Execution** (Fig. 1b): the full tuning loop is run up-front on a
    replica of the target — ``entire_exec_runtime`` / ``entire_exec``.

Each mode has a **Runtime** flavour (PATSMA measures the wall time of the
bracketed section itself — adapted here with ``jax.block_until_ready`` so
asynchronous dispatch does not hide the cost) and a user-cost flavour
(``exec(cost)`` — the application supplies any cost it likes).

``ignore`` (paper §2.3): per candidate solution, the first ``ignore`` target
iterations are measured and discarded so execution stabilizes.  In the JAX
port this is what absorbs XLA compile time: the first call of a jitted step
with new static knobs compiles, the ``ignore+1``-th call measures steady
state.  Evaluation counts follow paper Eq. (1)/(2).

Beyond-paper (flagged, default off → faithful): ``cache=True`` memoizes cost
by decoded point so the optimizer never re-measures a revisited candidate.

Persistent warm-start (beyond-paper, default off → faithful): pass ``db=``
(a :class:`repro.tuning.TuningDB`) and ``key=`` (a context fingerprint from
``repro.tuning.make_key``).  An exact key hit adopts the stored best with
**zero** measurements; a near-miss (same computation/hardware, different
shapes) seeds the optimizer around the stored point and halves the budget.
When tuning finishes the result is committed back to the DB automatically.
"""
from __future__ import annotations

import time
import warnings
from typing import Any, Callable, Optional

import numpy as np

from repro.obs import events as _events
from repro.obs.log import get_logger
from repro.obs.trace import tracer as _tracer

log = get_logger(__name__)

from .csa import CSA
from .nelder_mead import NelderMead
from .optimizer import NumericalOptimizer
from .space import SearchSpace

__all__ = ["Autotuning"]


def _block(x):
    """Block on JAX results so wall time includes the actual computation."""
    try:
        import jax

        return jax.block_until_ready(x)
    except Exception:
        return x


def _known_std(record) -> Optional[float]:
    """A record's meaningful measurement std (see TuningRecord.known_std)."""
    return record.known_std()


class Autotuning:
    """Paper API::

        Autotuning(min, max, ignore, dim, num_opt, max_iter)      # default CSA
        Autotuning(min, max, ignore, search=<spec | optimizer | strategy>)

    plus the extended form ``Autotuning(space=SearchSpace(...), ...)``.

    ``search=`` is the single knob picking the search method.  It accepts

    * a **spec string** (``"csa+nm"``, ``"csa:0.7+nm:0.3"``, ``"csa|nm"``)
      parsed by :func:`repro.core.strategy.make_strategy` — the paper's
      CSA→NM hybrid as a staged pipeline, portfolios, ... — over the same
      ``num_opt * max_iter`` tell budget the default CSA consumes;
    * a raw :class:`~repro.core.optimizer.NumericalOptimizer` instance;
    * any :data:`~repro.core.strategy.SearchStrategy` object (pipelines and
      portfolios are themselves optimizers).

    The legacy ``optimizer=`` / ``strategy=`` kwargs remain as deprecated
    aliases of ``search=`` (they emit a ``DeprecationWarning`` and stay
    trajectory-identical); passing more than one of the three is an error.
    The resolved spec is exposed as :attr:`strategy` and stamped on
    committed tuning records.  Decoded points are dicts
    ``{dim_name: value}``; the paper-style vector form is available via
    ``point_vector``.
    """

    def __init__(
        self,
        min: Any = -1.0,  # noqa: A002 - paper parameter names
        max: Any = 1.0,  # noqa: A002
        ignore: int = 0,
        dim: int = 1,
        num_opt: int = 4,
        max_iter: int = 20,
        *,
        search: Any = None,
        optimizer: Optional[NumericalOptimizer] = None,
        strategy: Any = None,
        space: Optional[SearchSpace] = None,
        integer: bool = True,
        seed: int = 0,
        cache: bool = False,
        verbose: bool = False,
        db=None,
        key=None,
        warm_start: bool = True,
        db_source: str = "online",
        objective: Optional[str] = None,
    ) -> None:
        if ignore < 0:
            raise ValueError("ignore must be >= 0")
        self.space = space if space is not None else SearchSpace.uniform(
            min, max, dim, integer=integer
        )
        d = len(self.space)
        given = [n for n, v in (
            ("search", search), ("optimizer", optimizer), ("strategy", strategy)
        ) if v is not None]
        if len(given) > 1:
            raise ValueError(
                f"pass a single search method, got {' and '.join(given)} "
                "(optimizer= and strategy= are deprecated aliases of search=)"
            )
        if optimizer is not None or strategy is not None:
            alias = "optimizer" if optimizer is not None else "strategy"
            warnings.warn(
                f"Autotuning({alias}=...) is deprecated; pass the same value "
                "as search= (spec string, optimizer, or SearchStrategy)",
                DeprecationWarning,
                stacklevel=2,
            )
            search = optimizer if optimizer is not None else strategy
        if isinstance(search, str):
            from .strategy import make_strategy

            search = make_strategy(
                search, d, num_opt=num_opt, max_iter=max_iter, seed=seed
            )
        self.optimizer = search if search is not None else CSA(
            d, num_opt=num_opt, max_iter=max_iter, seed=seed
        )
        # provenance spec stamped on committed TuningRecords (records.strategy)
        from .strategy import strategy_label

        self.strategy = getattr(self.optimizer, "spec", None) or strategy_label(
            self.optimizer
        )
        # the statistic the fed costs minimize ("median" | "p95" | "p99" |
        # None = unknown/user cost) — pure provenance here, stamped on
        # committed TuningRecords; the measurement layer computes it
        self.objective = str(objective).strip().lower() if objective else None
        if self.optimizer.get_dimension() != d:
            raise ValueError(
                f"optimizer dim {self.optimizer.get_dimension()} != space dim {d}"
            )
        self.ignore = int(ignore)
        self.verbose = verbose
        self._use_cache = bool(cache)
        self._cost_cache: dict = {}
        self._t0: Optional[float] = None
        self._ignore_left = self.ignore
        self._evals = 0  # completed cost evaluations fed to the optimizer
        self._measurements = 0  # target iterations spent on tuning (incl. ignored)
        self._history: list = []  # (point_dict, cost)
        self.skip_reasons: dict = {}  # reason -> count of tagged skip() calls
        # declarative validity predicates (space.constraints): candidates a
        # predicate rejects are charged inf via skip(reason="constraint") at
        # zero compile/measure cost — before measure_batch ever sees them
        self.constraint_violations: dict = {}  # constraint name -> prune count
        self._constraint_keys: set = set()  # space.key of pruned points
        self._in_constraint_skip = False  # re-entrancy guard for _skip_invalid
        self._round_no = 0  # batch round counter (obs candidate_asked events)
        self._measure_meta: dict = {}  # space.key -> measurement bookkeeping
        self._measured_costs: dict = {}  # space.key -> last *real* measured cost
        # persistent tuning store (repro.tuning): exact hit / warm seed
        self.db = db
        self.key = key
        self._db_source = str(db_source)  # provenance stamped on auto-commit
        self._db_hit = None  # record adopted wholesale (exact fingerprint hit)
        self._db_seeded = False  # near-miss: optimizer seeded + budget shrunk
        self._committed = False
        if db is not None and key is not None and warm_start:
            rec, exact = db.lookup(key)
            if exact and rec is not None:
                self._db_hit = rec
                self._point = dict(rec.point)
                if self.verbose:
                    log.info("db hit %s (cost %.6g); skipping tuning",
                             rec.point, rec.cost)
                _events.emit("warm_start", name=self.ctx_name(),
                             kind="exact", point=dict(rec.point))
                return  # finished before the first measurement
            if rec is not None:
                from repro.tuning.warm_start import apply_warm_start

                self._db_seeded = apply_warm_start(self.space, self.optimizer, rec)
                if self._db_seeded:
                    if self.verbose:
                        log.info("warm start from neighbor %s", rec.point)
                    _events.emit("warm_start", name=self.ctx_name(),
                                 kind="neighbor", point=dict(rec.point))
        # prime: first run() call's cost is ignored by contract
        self._z = self.optimizer.run(np.nan)
        self._point = self.space.decode(self._z)
        self._advance_through_cache()

    def ctx_name(self) -> str:
        """Stable label for this search in spans and the obs event stream
        (the DB key's name + shapes when tuning a fingerprinted context)."""
        if self.key is not None:
            try:
                return f"{self.key.name}{self.key.shapes()}"
            except Exception:
                return str(getattr(self.key, "name", self.key))
        return f"search@{id(self):x}"

    # ----------------------------------------------------------- properties
    @property
    def finished(self) -> bool:
        return self._db_hit is not None or self.optimizer.is_end()

    @property
    def warm_started(self) -> bool:
        """True if a stored record shaped this run (exact hit or neighbor seed)."""
        return self._db_hit is not None or self._db_seeded

    @property
    def point(self) -> dict:
        """Current candidate (or final solution once finished), decoded."""
        return dict(self._point)

    @property
    def point_vector(self) -> list:
        return list(self._point.values())

    def _history_best(self):
        """(point, cost) of the best delivered measurement, (None, inf) if
        none.  The optimizer's own best can lag behind this by up to one
        batch round: the ``run`` adapter buffers costs until a full
        ask/tell round is delivered, so a driver that stops mid-round
        (e.g. a short serving stream) would otherwise under-report."""
        best_p, best_c = None, np.inf
        for p, c in self._history:
            if c < best_c:
                best_p, best_c = p, c
        return best_p, best_c

    @property
    def best_point(self) -> dict:
        if self._db_hit is not None:
            return dict(self._db_hit.point)
        hist_p, hist_c = self._history_best()
        opt_c = self.optimizer.best_cost
        if np.isfinite(opt_c) and opt_c <= hist_c:
            return self.space.decode(self.optimizer.best_solution)
        if hist_p is not None:
            return dict(hist_p)
        return dict(self._point)

    @property
    def best_cost(self) -> float:
        if self._db_hit is not None:
            return float(self._db_hit.cost)
        _, hist_c = self._history_best()
        return float(min(self.optimizer.best_cost, hist_c))

    @property
    def num_evals(self) -> int:
        return self._evals

    @property
    def num_measurements(self) -> int:
        return self._measurements

    @property
    def num_crashed(self) -> int:
        """Distinct visited candidates whose (final) cost was non-finite —
        i.e. configurations that crashed or were rejected by the measurement
        layer.  Constraint-pruned candidates are excluded: a validity
        predicate rejecting a point is the *space* working as declared, not a
        crash.  Surfaced on committed tuning records."""
        seen: dict = {}
        for p, c in self._history:
            seen[self.space.key(p)] = c
        return sum(
            1 for k, c in seen.items()
            if not np.isfinite(c) and k not in self._constraint_keys
        )

    @property
    def history(self) -> list:
        return list(self._history)

    def measurement_meta(self, point: Optional[dict] = None) -> Optional[dict]:
        """Measurement bookkeeping for ``point`` (default: the best point):
        ``{"cost_std", "repeats_spent", "culled", "pruned"}`` when the
        adaptive measurement engine (or a rich ``measure_batch``) delivered a
        :class:`~repro.core.measure.MeasureResult` for it; ``None`` for
        plain-float costs, DB hits, and points this run never measured.  A
        ``pruned="roofline"`` entry marks a candidate that was charged its
        analytic bound without a single repetition — cleared (so the point is
        re-measured) by ``reset(level >= 1)``."""
        if point is None:
            point = self.best_point
        try:
            k = self.space.key({n: point[n] for n in self.space.names})
        except Exception:
            return None
        meta = self._measure_meta.get(k)
        return dict(meta) if meta is not None else None

    def reset(
        self,
        level: int = 0,
        *,
        warm_point: Optional[dict] = None,
        budget_frac: Optional[float] = None,
        spread: float = 0.2,
        refine: bool = False,
    ) -> None:
        """Re-enter tuning (e.g. when the watchdog detects environment drift).

        Forwards to the optimizer's reset (paper §2.2) and clears the cost
        cache: a drift reset means the old measurements no longer describe
        the environment, and a kept cache would answer every revisited
        candidate instantly — finishing the "re-tune" with zero fresh
        measurements and committing pre-drift data to the DB.  At
        ``level >= 1`` the measurement history is dropped for the same
        reason (level 0 retains found solutions per the paper, so their
        record stays).

        ``warm_point`` turns the reset into a *warm re-search*: the
        optimizer is re-seeded around the given decoded point (normally the
        pre-drift best, which is already deployed) and, with ``budget_frac``,
        its budget is shrunk — the online-tuning analogue of the DB
        near-miss warm start.

        ``refine=True`` asks a staged strategy to re-enter through its final
        *refinement* stage alone (``Pipeline.enter_refinement``) instead of
        resetting at ``level`` — the environment-drift response: the optimum's
        basin is assumed unchanged, so a local NM walk from ``warm_point``
        beats re-running the global stage.  Optimizers without a refinement
        stage fall back to the plain ``reset(level)``."""
        refiner = getattr(self.optimizer, "enter_refinement", None) if refine else None
        if refiner is not None and refiner():
            pass  # the strategy re-entered via its refinement stage
        else:
            self.optimizer.reset(level)
        self._cost_cache.clear()
        if level >= 1:
            self._constraint_keys.clear()  # derived from the cleared history
            self._history.clear()
            # measurement bookkeeping is pre-drift data too: in particular a
            # roofline-pruned candidate (charged its analytic bound, never
            # run) must be eligible for a real measurement in the re-search
            self._measure_meta.clear()
            self._measured_costs.clear()
        # a reset means the environment drifted: re-enter real tuning even if
        # this run was answered from the DB, and allow a fresh commit
        self._db_hit = None
        self._committed = False
        self._t0 = None
        self._ignore_left = self.ignore
        if warm_point is not None:
            from repro.tuning.warm_start import effective_spread

            try:
                z0 = self.space.encode(warm_point)
            except Exception:
                z0 = None  # incompatible point (renamed dims): cold restart
            if z0 is not None and self.optimizer.seed(
                z0, spread=effective_spread(self.space, spread)
            ):
                if budget_frac is not None and budget_frac < 1.0:
                    self.optimizer.shrink_budget(budget_frac)
        self._z = self.optimizer.run(np.nan)
        self._point = self.space.decode(self._z)
        self._advance_through_cache()

    def print(self) -> None:  # noqa: A003 - paper API name
        self.optimizer.print()

    # ------------------------------------------------- start/end (Runtime)
    def start(self) -> dict:
        """Begin the measured section; returns the candidate to use."""
        self._skip_invalid()
        if not self.finished:
            self._t0 = time.perf_counter()
        return self.point

    def end(self, result: Any = None) -> None:
        """End the measured section (blocks on ``result`` if given)."""
        if self.finished or self._t0 is None:
            return
        _block(result)
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self._feed(dt)

    # ------------------------------------------------------ exec (user cost)
    def exec(self, cost: float) -> dict:  # noqa: A003 - paper API name
        """Deliver a user-computed cost for the current candidate; returns the
        next candidate (paper §2.4: cost is always associated with the last
        returned solution)."""
        if not self.finished:
            self._feed(float(cost))
        self._skip_invalid()
        return self.point

    def skip(
        self,
        cost: float = np.inf,
        *,
        reason: Optional[str] = None,
        detail: Optional[str] = None,
    ) -> dict:
        """Reject the current candidate outright and advance to the next one.

        Unlike :meth:`exec`, the cost is delivered immediately — ``ignore``
        stabilization is bypassed, because no target iteration actually ran.
        Used by the online tuner when a candidate's executable fails to
        build: the candidate is charged ``inf`` without spending a serving
        request on it.  The charge is *not* written to the cost cache — a
        failure may be transient (compile resource pressure), so a revisited
        candidate must be offered for a fresh build attempt rather than have
        the cached ``inf`` replayed for the rest of the search.

        ``reason`` tags the rejection for run summaries (``skip_reasons``):
        the resilience layer distinguishes ``"build-failed"``, ``"timeout"``,
        and ``"quarantined"`` skips when reporting why a search starved;
        the constraint layer charges predicate-pruned candidates through
        ``reason="constraint"`` with the violated predicate as ``detail``."""
        if not self.finished:
            if reason is not None:
                self.skip_reasons[reason] = self.skip_reasons.get(reason, 0) + 1
                if self.verbose:
                    log.info("skip %s (%s)", self._point, reason)
                if reason == "quarantined":
                    _events.emit("candidate_quarantined",
                                 name=self.ctx_name(), point=dict(self._point))
                elif detail is not None:
                    _events.emit("candidate_skipped", name=self.ctx_name(),
                                 point=dict(self._point), reason=str(reason),
                                 detail=str(detail))
                else:
                    _events.emit("candidate_skipped", name=self.ctx_name(),
                                 point=dict(self._point), reason=str(reason))
            self._deliver(float(cost), cacheable=False)
        self._skip_invalid()
        return self.point

    def note(self, point: dict, cost: float) -> None:
        """Record an out-of-band measurement into this run's history.

        The optimizer is *not* fed — this is for costs observed outside the
        search itself, e.g. the live serving cost of the currently deployed
        point right after a drift reset.  It gives :attr:`best_point` /
        :meth:`commit` an honest, current-environment view of points the
        (re-)search has not visited yet: a warm re-search that fails to beat
        the incumbent still commits the incumbent at its *fresh* cost."""
        missing = [n for n in self.space.names if n not in point]
        if missing:
            raise ValueError(f"note(): point is missing dims {missing}")
        self._history.append(
            ({n: point[n] for n in self.space.names}, float(cost))
        )

    # --------------------------------------------------------- cost plumbing
    def _feed(self, cost: float) -> None:
        self._measurements += 1
        if self._ignore_left > 0:  # stabilization iterations (paper `ignore`)
            self._ignore_left -= 1
            return
        self._deliver(cost, cacheable=True)

    def _deliver(self, cost: float, cacheable: bool) -> None:
        key = self.space.key(self._point)
        if cacheable and self._use_cache:
            self._cost_cache[key] = cost
        self._evals += 1
        self._history.append((dict(self._point), float(cost)))
        if self.verbose:
            log.info("eval#%d %s -> %.6g", self._evals, self._point, cost)
        self._z = self.optimizer.run(cost)
        self._point = self.space.decode(self._z)
        self._ignore_left = self.ignore
        if self.optimizer.is_end():
            self.commit()
        self._advance_through_cache()

    def _visited(self, point: dict) -> bool:
        """Whether ``point`` was measured (or noted) during this run."""
        try:
            k = self.space.key({n: point[n] for n in self.space.names})
        except Exception:
            return False
        return any(self.space.key(p) == k for p, _ in self._history)

    def commit(self, *, source: Optional[str] = None, force: bool = False) -> bool:
        """Persist the current best into the attached tuning DB (idempotent;
        called automatically when the optimizer finishes).  ``source``
        defaults to the constructor's ``db_source`` provenance label.

        Clobber guard: if the DB already holds a *better* record for this
        key whose point this run never re-measured (so nothing says it
        stopped being good — e.g. a drifted, worse re-search that wandered
        elsewhere), the stored record is kept.  A run that did re-measure
        the stored point always wins — its best already accounts for that
        point under current conditions, so committing it is a refresh, not a
        clobber.  When both records carry measurement confidence
        (``cost_std``), a *near-tie* — the new best beats the stored cost by
        less than the larger of the two standard deviations — also keeps a
        lower-variance stored record: inside the noise band the
        better-trusted measurement wins, not the luckier one.  ``force=True``
        bypasses the guard.  Returns True iff a record was written."""
        if self.db is None or self.key is None or self._committed:
            return False
        if self._db_hit is not None:
            return False  # answered from the DB; nothing new to write back
        from repro.tuning.warm_start import record_from

        rec = record_from(self, self.key, source=source or self._db_source)
        if rec is None:
            return False
        if not force:
            existing = self.db.get(self.key)
            if (
                existing is not None
                and existing.objective is not None
                and rec.objective is not None
                and existing.objective != rec.objective
            ):
                # tuned for a different statistic: a p99 cost and a median
                # cost are not comparable, so the clobber guard cannot
                # arbitrate — the caller changed what they optimize and the
                # fresh record wins
                existing = None
            if (
                existing is not None
                and np.isfinite(existing.cost)
                and not self._visited(existing.point)
            ):
                keep = existing.cost < rec.cost  # ties: fresher data wins
                if not keep:
                    # near-tie tiebreak: inside the noise band the better-
                    # measured record stands, symmetric in both directions.
                    # A single-rep record has *unknown* variance, not zero —
                    # its std must neither read as perfect confidence nor
                    # widen the band (see _known_std).
                    e_std = _known_std(existing)
                    r_std = _known_std(rec)
                    stds = [s for s in (e_std, r_std) if s is not None]
                    if stds and (existing.cost - rec.cost) <= max(stds):
                        if e_std is not None and (r_std is None or e_std < r_std):
                            keep = True  # the lower-variance record stands
                if keep:
                    self._committed = True  # nothing better to say for this run
                    return False
        with _tracer().span("commit"):
            self.db.put(rec)
        _events.emit("db_commit", name=self.ctx_name(),
                     point=dict(rec.point), cost=rec.cost)
        self._committed = True
        return True

    def _advance_through_cache(self) -> None:
        """If caching is on, answer revisited candidates from the cache."""
        if not self._use_cache:
            return
        guard = 0
        while not self.finished:
            key = self.space.key(self._point)
            if key not in self._cost_cache:
                return
            self._deliver(self._cost_cache[key], cacheable=False)
            guard += 1
            if guard > 100_000:  # safety: pathological optimizer loop
                return

    def _note_pruned(self, point: dict, violated: str) -> None:
        """Bookkeeping shared by both prune paths: the violated-predicate
        tally and the key set that keeps pruned points out of
        :attr:`num_crashed`."""
        self.constraint_violations[violated] = (
            self.constraint_violations.get(violated, 0) + 1
        )
        self._constraint_keys.add(self.space.key(point))

    def _skip_invalid(self) -> None:
        """Auto-skip constraint-invalid candidates before presenting one.

        Runs at the sequential presentation points (``start``/``exec``/
        ``skip``/``single_exec``) — *not* inside ``__init__``/``reset`` —
        so the batch ask/tell protocol never sees a half-delivered round:
        batch mode prunes inside :meth:`_batch_round` instead.  Each invalid
        candidate is charged ``inf`` through :meth:`skip`
        (``reason="constraint"``) with zero compile/measure cost, and is
        *not* cached, so ``reset(level >= 1)`` makes it revisitable."""
        if not self.space.constraints or self._in_constraint_skip:
            return
        self._in_constraint_skip = True
        try:
            guard = 0
            while not self.finished:
                violated = self.space.check(self._point)
                if violated is None:
                    return
                self._note_pruned(self._point, violated)
                self.skip(reason="constraint", detail=violated)
                guard += 1
                if guard > 100_000:  # safety: fully-infeasible space
                    return
        finally:
            self._in_constraint_skip = False

    # ------------------------------------------------- pre-programmed modes
    # Paper Algorithm 3.  `point_arg` semantics: the function receives the
    # decoded point dict's values in declaration order, prepended to *args
    # (paper: "the initial variable must serve as both input and output").
    def single_exec_runtime(self, func: Callable, *args, **kwargs):
        """One tuning iteration per call; PATSMA measures the runtime
        (paper ``singleExecRuntime``, Fig. 1a).  Returns func's result."""
        point = self.start()
        result = func(*self._point_args(point), *args, **kwargs)
        self.end(result)
        return result

    def single_exec(self, func: Callable, *args, **kwargs):
        """One tuning iteration per call; ``func`` returns the cost
        (paper ``singleExec``)."""
        self._skip_invalid()
        if self.finished:
            return func(*self._point_args(self.point), *args, **kwargs)
        cost = func(*self._point_args(self.point), *args, **kwargs)
        self.exec(float(cost))
        return cost

    def entire_exec_runtime(self, func: Callable, *args, **kwargs) -> dict:
        """Run the complete tuning loop now, measuring runtimes of replica
        executions (paper ``entireExecRuntime``, Fig. 1b).  Returns the final
        point."""
        while not self.finished:
            self.single_exec_runtime(func, *args, **kwargs)
        return self.point

    def entire_exec(self, func: Callable, *args, **kwargs) -> dict:
        """Run the complete tuning loop now with func-supplied costs
        (paper ``entireExec``)."""
        while not self.finished:
            self.single_exec(func, *args, **kwargs)
        return self.point

    # ----------------------------------------------------------- batch mode
    def entire_exec_batch(self, measure_batch: Callable) -> dict:
        """Entire Execution over the optimizer's batch protocol.

        Per round, :meth:`NumericalOptimizer.ask` yields the full set of
        candidates the optimizer needs next (CSA's m probes, NM's simplex).
        The round is **deduplicated by decoded point** — duplicates within the
        round, and (with ``cache=True``) candidates revisited from earlier
        rounds, are never re-measured — and the surviving unique points are
        handed to ``measure_batch(points) -> costs`` in one call, so the
        measurement layer can compile them concurrently.  ``ignore``
        stabilization calls are issued per round on the same unique points and
        discarded, matching the sequential modes' per-candidate accounting.

        ``measure_batch`` may return plain floats or
        :class:`~repro.core.measure.MeasureResult` objects (the adaptive
        measurement engine's output): rich results contribute their ``cost``
        to the optimizer exactly like a float, while their bookkeeping
        (``cost_std``, ``repeats_spent``, racing/roofline flags) is kept per
        point — see :meth:`measurement_meta` — and ``num_measurements``
        counts the repetitions actually spent rather than one per point.

        The candidate trajectory, history, and final point are identical to
        :meth:`entire_exec` with a deterministic cost function (same seed ⇒
        same visited points); only the measurement schedule changes.  With a
        *speculative* optimizer (``NelderMead(speculative=True)``) the
        optimizer's internal ``evaluations`` budget stays bit-identical to
        the sequential run, but the driver-side ``num_evals``/``history``
        (and hence a committed record's ``evals``/``crashed``) honestly count
        every point that was actually measured, including speculative probes
        the optimizer discarded.
        """
        ctx = self.ctx_name()
        _events.emit("search_start", name=ctx)
        with _tracer().span("search", ctx=ctx):
            rounds = self._batch_loop(measure_batch)
        # expose the final solution as the current point (as the sequential
        # staging does once the optimizer ends) and persist it
        if self._db_hit is None and self.optimizer.is_end():
            self._z = self.optimizer.best_solution
            self._point = self.space.decode(self._z)
        self.commit()
        _events.emit(
            "search_end", name=ctx,
            best_point=dict(self.best_point) if self.best_point else None,
            best_cost=self.best_cost, evals=self._evals, rounds=rounds,
        )
        return self.point

    def _batch_loop(self, measure_batch: Callable) -> int:
        """The ask → dedup → measure → tell rounds of
        :meth:`entire_exec_batch`; returns how many rounds ran.  Each round
        runs under a ``round`` span so worker-side compile/measure spans
        nest where they belong."""
        round_no = 0
        while not self.finished:
            zs = self.optimizer.ask()
            if not zs:
                break
            round_no += 1
            self._round_no = round_no
            with _tracer().span("round", round=round_no):
                self._batch_round(zs, measure_batch)
        return round_no

    def _batch_round(self, zs, measure_batch: Callable) -> None:
        points = [self.space.decode(z) for z in zs]
        keys = [self.space.key(p) for p in points]
        self._z = zs[0]
        self._point = dict(points[0])
        # unique decoded points, in first-seen order
        unique: dict = {}
        for k, p in zip(keys, points):
            unique.setdefault(k, p)
        to_measure = [
            k for k in unique
            if not (self._use_cache and k in self._cost_cache)
        ]
        # constraint predicates run *before* compile/measure: invalid points
        # are charged inf here at zero cost — measure_batch never sees them.
        # The driver emits the asked/skipped event pair itself (the
        # measurement layer's emitter only sees the points it receives), so
        # the completeness identity asked == terminals keeps holding.
        pruned: dict = {}
        if self.space.constraints:
            for k in to_measure:
                violated = self.space.check(unique[k])
                if violated is None:
                    continue
                pruned[k] = float(np.inf)
                self.skip_reasons["constraint"] = (
                    self.skip_reasons.get("constraint", 0) + 1
                )
                self._note_pruned(unique[k], violated)
                if self.verbose:
                    log.info("prune %s (constraint %s)", unique[k], violated)
                if _events.sink() is not None:
                    ctx = self.ctx_name()
                    _events.emit("candidate_asked", name=ctx,
                                 point=dict(unique[k]), round=self._round_no)
                    _events.emit("candidate_skipped", name=ctx,
                                 point=dict(unique[k]), reason="constraint",
                                 detail=violated)
            to_measure = [k for k in to_measure if k not in pruned]
        measured: dict = {}
        if to_measure:
            pts = [dict(unique[k]) for k in to_measure]
            for _ in range(self.ignore):  # stabilization (paper `ignore`)
                measure_batch([dict(p) for p in pts])
                self._measurements += len(pts)
            costs = list(measure_batch([dict(p) for p in pts]))
            if len(costs) != len(pts):
                raise ValueError(
                    f"measure_batch returned {len(costs)} costs for {len(pts)} points"
                )
            from .measure import MeasureResult

            measured = {}
            for k, c in zip(to_measure, costs):
                if isinstance(c, MeasureResult):
                    prev = self._measure_meta.get(k)
                    if (
                        c.pruned is not None
                        and prev is not None
                        and prev.get("pruned") is None
                        and k in self._measured_costs
                    ):
                        # the point was *really* measured in an earlier
                        # round — typically by a previous pipeline stage —
                        # and a later revisit came back analytically
                        # pruned (the engine's incumbent moved on).  The
                        # optimistic lower bound must not clobber the
                        # real measurement: keep the stored meta and
                        # deliver the measured cost, or the next stage
                        # would sit on a bound it can never realize.
                        measured[k] = self._measured_costs[k]
                    else:
                        measured[k] = float(c.cost)
                        self._measure_meta[k] = c.meta()
                        if c.pruned is None and np.isfinite(c.cost):
                            self._measured_costs[k] = float(c.cost)
                    # pruned/failed candidates honestly spent zero reps
                    self._measurements += int(c.repeats_spent)
                else:
                    measured[k] = float(c)
                    if np.isfinite(c):
                        self._measured_costs[k] = float(c)
                    self._measurements += 1
        full = []
        for k, p in zip(keys, points):
            # measured this round, constraint-pruned this round, or answered
            # by the cross-round cache
            if k in measured:
                c = measured[k]
            elif k in pruned:
                c = pruned[k]
            else:
                c = self._cost_cache[k]
            if self._use_cache:
                self._cost_cache[k] = c
            self._evals += 1
            self._history.append((dict(p), float(c)))
            if self.verbose:
                log.info("eval#%d %s -> %.6g", self._evals, p, c)
            full.append(c)
        self.optimizer.tell(full)

    @staticmethod
    def _point_args(point: dict) -> tuple:
        return tuple(point.values())
