"""Tiled matmul kernel (Pallas TPU) — the canonical block-size auto-tuning
demo (paper §2.3: "block size (or loop granularity)" as the tunable).

Grid (M/bm, N/bn, K/bk) with the K dimension sequential and an fp32
accumulator tile in VMEM.  (bm, bn, bk) are the PATSMA-tunables; MXU wants
multiples of 128 on the minor dims — the tuner discovers this itself, which
is exactly the paper's pitch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["matmul_tiled"]


def _kernel(a_ref, b_ref, o_ref, acc_scr, *, n_k):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ik == n_k - 1)
    def _emit():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def matmul_tiled(a, b, *, bm: int = 256, bn: int = 256, bk: int = 256, interpret: bool = False):
    """a: (M,K) @ b: (K,N) -> (M,N) with fp32 accumulation."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    if M % bm or N % bn or K % bk:
        raise ValueError(f"dims {(M, N, K)} not divisible by tiles {(bm, bn, bk)}")
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=K // bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
