"""Flash-attention forward kernel (Pallas TPU): online-softmax over KV tiles.

TPU adaptation of the FlashAttention insight (arXiv:2205.14135): stream KV
through VMEM in ``block_kv`` tiles while a ``block_q`` query tile and the
(m, l, acc) online-softmax carry stay VMEM-resident; MXU does the two
matmuls per tile.  Grid = (B, H, nQ, nKV) with the KV dimension sequential
("arbitrary") so the carry persists in scratch across KV tiles.

``block_q`` / ``block_kv`` are the PATSMA-tunable parameters (the paper's
OpenMP-chunk analogue).  Causal masking skips fully-masked KV tiles.
GQA: query head h reads KV head h // (H // Kh).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["flash_attention_fwd"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, causal, scale, block_q, block_kv, n_kv):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    kv_start = ikv * block_kv

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bkv, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bkv)
        if causal:
            qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
            kj = kv_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(qi >= kj, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)  # (bkv, hd)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # causal tile skip: run only tiles not entirely in the future
        pl.when(kv_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ikv == n_kv - 1)
    def _emit():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(
    q, k, v, *, causal: bool = True, block_q: int = 128, block_kv: int = 128,
    interpret: bool = False,
):
    """q: (B,H,Sq,hd); k/v: (B,Kh,Skv,hd) -> o: (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    Kh, Skv = k.shape[1], k.shape[2]
    g = H // Kh
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    if Sq % block_q or Skv % block_kv:
        raise ValueError(f"seq ({Sq},{Skv}) not divisible by blocks ({block_q},{block_kv})")
    n_q, n_kv = Sq // block_q, Skv // block_kv
    grid = (B, H, n_q, n_kv)
    kern = functools.partial(
        _kernel,
        causal=causal,
        scale=1.0 / np.sqrt(hd),
        block_q=block_q,
        block_kv=block_kv,
        n_kv=n_kv,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ikv: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, iq, ikv, g=g: (b, h // g, ikv, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, iq, ikv, g=g: (b, h // g, ikv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ikv: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
