"""Flash-decode kernel (Pallas TPU): one query token vs a long KV cache.

Streams the KV cache through VMEM in ``block_kv`` tiles with the online
softmax carry in scratch — the decode-shaped sibling of flash attention
(FlashDecoding, arXiv:2311.01282, adapted to TPU tiles).  Validity of each
cache slot comes from an explicit ``valid`` mask vector (int32 0/1), which
uniformly supports ring buffers (windowed layers) and partially-filled
caches.  ``block_kv`` is PATSMA-tunable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["decode_attention_fwd"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, n_kv):
    ikv = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (g, hd) — the GQA group
    k = k_ref[0, 0].astype(jnp.float32)  # (bkv, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    s = jnp.where((valid_ref[0] > 0)[None, :], s, NEG_INF)  # (g, bkv)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ikv == n_kv - 1)
    def _emit():
        l = jnp.where(l_scr[...] == 0.0, 1.0, l_scr[...])
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_fwd(q, k, v, valid, *, block_kv: int = 512, interpret: bool = False):
    """q: (B,H,hd); k/v: (B,Kh,S,hd); valid: (B,S) int32 -> o: (B,H,hd).

    Layout: queries regrouped to (B, Kh, g, hd) so one grid cell handles one
    KV head's whole GQA group (g query heads share the streamed KV tiles)."""
    B, H, hd = q.shape
    Kh, S = k.shape[1], k.shape[2]
    g = H // Kh
    block_kv = min(block_kv, S)
    if S % block_kv:
        raise ValueError(f"cache length {S} not divisible by block_kv {block_kv}")
    n_kv = S // block_kv
    qg = q.reshape(B, Kh, g, hd)
    grid = (B, Kh, n_kv)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / np.sqrt(hd), n_kv=n_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b, h, ikv: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, ikv: (b, h, ikv, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, ikv: (b, h, ikv, 0)),
            pl.BlockSpec((1, block_kv), lambda b, h, ikv: (b, ikv)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b, h, ikv: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Kh, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qg, k, v, valid)
    return out.reshape(B, H, hd)
