"""Pallas TPU kernels (pl.pallas_call + BlockSpec) with jnp oracles in ref.py.

Tunable block shapes are first-class PATSMA targets; validated on CPU with
interpret=True against ref.py in tests/test_kernels.py.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
