"""Pallas TPU kernels (pl.pallas_call + BlockSpec) with jnp oracles in ref.py.

Tunable block shapes are first-class PATSMA targets; validated on CPU with
interpret=True against ref.py in tests/test_kernels.py.  ``autotuned`` is the
tuning-DB-backed dispatch layer (stored best block shapes per call context).
"""
from . import ops, ref
from .autotuned import autotuned, tune_call

__all__ = ["ops", "ref", "autotuned", "tune_call"]
