"""Pallas TPU kernels (pl.pallas_call + BlockSpec) with jnp oracles in ref.py.

Tunable block shapes are first-class PATSMA targets; validated on CPU with
interpret=True against ref.py in tests/test_kernels.py.  ``autotuned`` is the
tuning-DB-backed dispatch layer (stored best block shapes per call context);
``routed`` is its adaptive sibling — calls flow through the process-wide
``ContextRouter`` so knobs keep improving online and drifted contexts
re-tune themselves in the background.
"""
from . import ops, ref
from .autotuned import autotuned, kernel_router, routed, tune_call

__all__ = ["ops", "ref", "autotuned", "routed", "kernel_router", "tune_call"]
