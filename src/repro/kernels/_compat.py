"""Version compatibility for Pallas TPU symbols.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in newer
jax releases; resolve whichever this interpreter provides so the kernels run
on both sides of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)

__all__ = ["CompilerParams"]
