"""Public jit'd wrappers for the Pallas kernels.

Gradients: forward passes run the kernels; backward passes recompute through
the jnp references via ``jax.custom_vjp`` (exact — the references are the
oracles the kernels are validated against).  Writing fused backward kernels
is listed as future work in DESIGN.md; the custom-vjp split keeps training
correct on day one while the forward hot path uses the tuned kernels.

All wrappers accept ``interpret=True`` so the kernel *bodies* execute on CPU
for validation (this container has no TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention_fwd
from .flash_attention import flash_attention_fwd
from .linear_scan import lru_scan_chunked, rwkv_scan_chunked
from .matmul import matmul_tiled

__all__ = ["flash_attention", "decode_attention", "rwkv_scan", "lru_scan", "matmul"]


# ---------------------------------------------------------- flash attention
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, block_q, block_kv, interpret):
    # q: (B,Sq,H,hd) layout; kernel wants (B,H,Sq,hd)
    qt = q.transpose(0, 2, 1, 3)
    o = flash_attention_fwd(
        qt, k, v, causal=causal, block_q=block_q, block_kv=block_kv, interpret=interpret
    )
    return o.transpose(0, 2, 1, 3)


def _flash_fwd(q, k, v, causal, block_q, block_kv, interpret):
    return _flash(q, k, v, causal, block_q, block_kv, interpret), (q, k, v)


def _flash_bwd(causal, block_q, block_kv, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.flash_attention_ref(q_, k_, v_, causal=causal), q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal=True, block_q=128, block_kv=128, interpret=False):
    """q: (B,Sq,H,hd); k/v: (B,Kh,Skv,hd) -> (B,Sq,H,hd)."""
    return _flash(q, k, v, causal, block_q, block_kv, interpret)


# ---------------------------------------------------------- decode attention
def decode_attention(q, k, v, valid, *, block_kv=512, interpret=False):
    """Inference-only (no vjp needed). q: (B,H,hd); k/v: (B,Kh,S,hd);
    valid: (B,S) int32."""
    return decode_attention_fwd(q, k, v, valid, block_kv=block_kv, interpret=interpret)


# ----------------------------------------------------------------- rwkv scan
@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _rwkv(r, k, v, lw, u, s0, chunk, interpret):
    return rwkv_scan_chunked(r, k, v, lw, u, s0, chunk=chunk, interpret=interpret)


def _rwkv_fwd(r, k, v, lw, u, s0, chunk, interpret):
    return _rwkv(r, k, v, lw, u, s0, chunk, interpret), (r, k, v, lw, u, s0)


def _rwkv_bwd(chunk, interpret, res, g):
    r, k, v, lw, u, s0 = res
    _, vjp = jax.vjp(lambda *a: ref.rwkv_scan_ref(*a), r, k, v, lw, u, s0)
    return vjp(g)


_rwkv.defvjp(_rwkv_fwd, _rwkv_bwd)


def rwkv_scan(r, k, v, lw, u, s0, *, chunk=64, interpret=False):
    """Chunked WKV: r,k,v,lw (B,T,H,hd); u (H,hd); s0 (B,H,hd,hd)."""
    return _rwkv(r, k, v, lw, u, s0, chunk, interpret)


# ------------------------------------------------------------------ lru scan
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _lru(a, b, h0, chunk, interpret):
    return lru_scan_chunked(a, b, h0, chunk=chunk, interpret=interpret)


def _lru_fwd(a, b, h0, chunk, interpret):
    return _lru(a, b, h0, chunk, interpret), (a, b, h0)


def _lru_bwd(chunk, interpret, res, g):
    a, b, h0 = res
    _, vjp = jax.vjp(lambda *x: ref.lru_scan_ref(*x), a, b, h0)
    return vjp(g)


_lru.defvjp(_lru_fwd, _lru_bwd)


def lru_scan(a, b, h0, *, chunk=128, interpret=False):
    """First-order scan h_t = a_t h_{t-1} + b_t.  a,b: (B,T,D); h0: (B,D)."""
    return _lru(a, b, h0, chunk, interpret)


# -------------------------------------------------------------------- matmul
def matmul(a, b, *, bm=256, bn=256, bk=256, interpret=False):
    return matmul_tiled(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)
