"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the ground truth the kernels are validated against in
``tests/test_kernels.py`` (interpret=True on CPU, shape/dtype sweeps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "flash_attention_ref",
    "decode_attention_ref",
    "rwkv_scan_ref",
    "lru_scan_ref",
    "matmul_ref",
]


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B,Sq,H,hd), k/v: (B,Kh,Skv,hd) -> (B,Sq,H,hd).  GQA by grouping."""
    B, Sq, H, hd = q.shape
    Kh, Skv = k.shape[1], k.shape[2]
    g = H // Kh
    qh = q.reshape(B, Sq, Kh, g, hd)
    s = jnp.einsum("bqkgh,bksh->bkgqs", qh, k, preferred_element_type=jnp.float32)
    s = s * (1.0 / np.sqrt(hd))
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bksh->bqkgh", p, v)
    return o.reshape(B, Sq, H, hd)


def decode_attention_ref(q, k, v, length):
    """Single-step decode: q: (B,H,hd); k/v: (B,Kh,S,hd); length: () int —
    number of valid cache entries.  -> (B,H,hd)."""
    B, H, hd = q.shape
    Kh, S = k.shape[1], k.shape[2]
    g = H // Kh
    qh = q.reshape(B, Kh, g, hd)
    s = jnp.einsum("bkgh,bksh->bkgs", qh, k, preferred_element_type=jnp.float32)
    s = s * (1.0 / np.sqrt(hd))
    valid = jnp.arange(S) < length
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgs,bksh->bkgh", p, v)
    return o.reshape(B, H, hd)


def rwkv_scan_ref(r, k, v, lw, u, s0):
    """Exact RWKV-6 WKV recurrence (see models.rwkv6.wkv_scan_ref)."""
    from repro.models.rwkv6 import wkv_scan_ref as _impl

    return _impl(r, k, v, lw, u, s0)


def lru_scan_ref(a, b, h0):
    """h_t = a_t h_{t-1} + b_t (see models.rglru.lru_scan_ref)."""
    from repro.models.rglru import lru_scan_ref as _impl

    return _impl(a, b, h0)


def matmul_ref(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)
