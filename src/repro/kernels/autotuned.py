"""DB-backed entry point for the Pallas kernels.

``autotuned(name, *args)`` is the one call sites use when they want tuned
block shapes without owning a tuning loop: it fingerprints the call context
(kernel name, input shapes/dtypes, search space, backend/device), consults the
:class:`repro.tuning.TuningDB`, and dispatches the kernel with

* the stored best on an **exact** fingerprint hit (zero overhead),
* a stored **neighbor**'s point clamped into this shape's space (near miss),
* the kernel's registered defaults on a cold miss — or, with ``tune=True``,
  a measured PATSMA search (warm-seeded from the neighbor when one exists)
  whose result is committed back to the DB.

The ``pretune`` CLI sweeps the registered grid below offline so production
processes and CI land on the first branch.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core import CSA, Autotuning, LogIntDim, RuntimeCost, SearchSpace
from repro.tuning import TuningDB, default_db, make_key

from . import ops

__all__ = ["autotuned", "tune_call", "register", "get_spec", "registered", "KernelSpec"]


# ------------------------------------------------------------------ registry
@dataclasses.dataclass(frozen=True)
class KernelSpec:
    name: str
    fn: Callable  # fn(*args, **kwargs, **knobs, interpret=...)
    space: Callable  # space(*args, **kwargs) -> SearchSpace over the knobs
    defaults: Callable  # defaults(*args, **kwargs) -> dict of knob values


_REGISTRY: dict = {}


def register(spec: KernelSpec) -> KernelSpec:
    _REGISTRY[spec.name] = spec
    return spec


def get_spec(name: str) -> KernelSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown kernel {name!r}; registered: {sorted(_REGISTRY)}") from None


def registered() -> list:
    return sorted(_REGISTRY)


def _pow2_floor(n: int) -> int:
    """Largest power of two that divides n (n>0)."""
    return n & (-n)


def _log_dim(name: str, n: int, lo: int, cap: int) -> LogIntDim:
    """Power-of-two tile dim that always divides ``n``: bounds clamp to the
    largest power-of-two divisor of n, so every decodable value is legal."""
    g = _pow2_floor(int(n))
    lo = max(1, min(lo, g))
    hi = max(lo, min(cap, g, int(n)))
    return LogIntDim(name, lo, hi)


# --------------------------------------------------------- registered kernels
register(
    KernelSpec(
        name="matmul",
        fn=ops.matmul,
        space=lambda a, b: SearchSpace(
            [
                _log_dim("bm", a.shape[0], 32, 256),
                _log_dim("bn", b.shape[1], 32, 256),
                _log_dim("bk", a.shape[1], 32, 256),
            ]
        ),
        defaults=lambda a, b: {
            "bm": min(256, _pow2_floor(a.shape[0])),
            "bn": min(256, _pow2_floor(b.shape[1])),
            "bk": min(256, _pow2_floor(a.shape[1])),
        },
    )
)

register(
    KernelSpec(
        name="flash_attention",
        fn=ops.flash_attention,
        # q: (B,Sq,H,hd); k/v: (B,Kh,Skv,hd)
        space=lambda q, k, v, **kw: SearchSpace(
            [
                _log_dim("block_q", q.shape[1], 16, 512),
                _log_dim("block_kv", k.shape[2], 16, 512),
            ]
        ),
        defaults=lambda q, k, v, **kw: {
            "block_q": min(128, _pow2_floor(q.shape[1])),
            "block_kv": min(128, _pow2_floor(k.shape[2])),
        },
    )
)

register(
    KernelSpec(
        name="decode_attention",
        fn=ops.decode_attention,
        # q: (B,H,hd); k/v: (B,Kh,S,hd); valid: (B,S)
        space=lambda q, k, v, valid: SearchSpace(
            [_log_dim("block_kv", k.shape[2], 64, 1024)]
        ),
        defaults=lambda q, k, v, valid: {"block_kv": min(512, _pow2_floor(k.shape[2]))},
    )
)

register(
    KernelSpec(
        name="lru_scan",
        fn=ops.lru_scan,
        # a,b: (B,T,D); h0: (B,D)
        space=lambda a, b, h0: SearchSpace([_log_dim("chunk", a.shape[1], 16, 256)]),
        defaults=lambda a, b, h0: {"chunk": min(128, _pow2_floor(a.shape[1]))},
    )
)

register(
    KernelSpec(
        name="rwkv_scan",
        fn=ops.rwkv_scan,
        # r,k,v,lw: (B,T,H,hd); u: (H,hd); s0: (B,H,hd,hd)
        space=lambda r, k, v, lw, u, s0: SearchSpace(
            [_log_dim("chunk", r.shape[1], 16, 128)]
        ),
        defaults=lambda r, k, v, lw, u, s0: {"chunk": min(64, _pow2_floor(r.shape[1]))},
    )
)


# ------------------------------------------------------------------- tuning
def tune_call(
    name: str,
    *args,
    db: Optional[TuningDB] = None,
    interpret: bool = False,
    num_opt: int = 3,
    max_iter: int = 4,
    seed: int = 0,
    warmup: int = 1,
    repeats: int = 2,
    verbose: bool = False,
    source: str = "online",
    **kwargs,
):
    """Run a measured PATSMA search for this call context and commit the
    result to ``db``.  Warm-seeds from the nearest stored neighbor when one
    exists (half budget).  Returns the TuningRecord for the context."""
    import jax

    spec = get_spec(name)
    space = spec.space(*args, **kwargs)
    key = make_key(name, args=args, kwargs=kwargs, space=space,
                   extra={"interpret": bool(interpret)})
    db = db if db is not None else default_db()
    cost = RuntimeCost(warmup=warmup, repeats=repeats)

    def measure(*knob_values):
        knobs = dict(zip(space.names, knob_values))
        try:
            fn = jax.jit(
                lambda *xs: spec.fn(*xs, **kwargs, **knobs, interpret=interpret)
            )
            return cost(fn, *args)
        except Exception:
            return np.inf  # illegal tile for this shape → crashed candidate

    at = Autotuning(
        space=space,
        ignore=0,  # RuntimeCost already discards warmup runs
        optimizer=CSA(len(space), num_opt=num_opt, max_iter=max_iter, seed=seed),
        cache=True,
        verbose=verbose,
        db=db,
        key=key,
        db_source=source,
    )
    at.entire_exec(measure)
    at.commit()  # no-op if auto-committed / exact hit
    return db.get(key)


def autotuned(
    name: str,
    *args,
    db: Optional[TuningDB] = None,
    tune: bool = False,
    interpret: bool = False,
    **kwargs,
):
    """Dispatch kernel ``name`` with the best knobs known for this context."""
    spec = get_spec(name)
    space = spec.space(*args, **kwargs)
    key = make_key(name, args=args, kwargs=kwargs, space=space,
                   extra={"interpret": bool(interpret)})
    db = db if db is not None else default_db()
    rec, exact = db.lookup(key)
    if not exact and tune:
        tuned_rec = tune_call(name, *args, db=db, interpret=interpret, **kwargs)
        if tuned_rec is not None:  # all-crashed run: keep the neighbor fallback
            rec, exact = tuned_rec, True
    if exact:
        knobs = {n: rec.point[n] for n in space.names}
    elif rec is not None and all(n in rec.point for n in space.names):
        # neighbor: reuse its point, clamped into this shape's (smaller) space
        knobs = space.decode(space.encode(rec.point))
    else:
        knobs = spec.defaults(*args, **kwargs)
    return spec.fn(*args, **kwargs, **knobs, interpret=interpret)
