"""DB-backed entry point for the Pallas kernels.

``autotuned(name, *args)`` is the one call sites use when they want tuned
block shapes without owning a tuning loop: it fingerprints the call context
(kernel name, input shapes/dtypes, search space, backend/device), consults the
:class:`repro.tuning.TuningDB`, and dispatches the kernel with

* the stored best on an **exact** fingerprint hit (zero overhead),
* a stored **neighbor**'s point clamped into this shape's space (near miss),
* the kernel's registered defaults on a cold miss — or, with ``tune=True``,
  a measured PATSMA search (warm-seeded from the neighbor when one exists)
  whose result is committed back to the DB.

The ``pretune`` CLI sweeps the registered grid below offline so production
processes and CI land on the first branch.

:func:`routed` is the *adaptive* dispatch on top: calls go through the
process-wide ``repro.runtime.ContextRouter`` (:func:`kernel_router`), which
keeps an ε-fraction of live traffic exploring candidates compiled off-thread
and re-tunes a context in the background when its costs drift.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Callable, Optional

import numpy as np

from repro.core import (
    Autotuning,
    ExecutableCache,
    FaultPolicy,
    GuardTimeout,
    LogIntDim,
    MeasureEngine,
    MeasurePolicy,
    MeasureResult,
    Quarantine,
    RuntimeCost,
    SearchSpace,
    compile_fanout,
    guarded_call,
    is_transient_failure,
    resolve_measure_policy,
    sandboxed_probe,
    time_rep,
)
from repro.core.guard import TRANSIENT_MARKERS as _TRANSIENT_MARKERS
from repro.core.measure import ENV_TUNE_MEASURE  # noqa: F401 - public re-export
from repro.obs import events as _events
from repro.obs.log import get_logger
from repro.obs.trace import tracer as _tracer
from repro.tuning import TuningDB, default_db, make_key

from . import ops

log = get_logger(__name__)

__all__ = [
    "autotuned",
    "routed",
    "kernel_router",
    "tune_call",
    "register",
    "get_spec",
    "registered",
    "KernelSpec",
    "exec_cache",
    "classify_failure",
]

#: env var: default compile fan-out width for tune_call (0/unset → cpu count)
ENV_TUNE_JOBS = "REPRO_TUNE_JOBS"

#: env var: default for tune_call's ``drain`` (finish all compiles before the
#: first measurement of a round instead of overlapping them)
ENV_TUNE_DRAIN = "REPRO_TUNE_DRAIN"


# ------------------------------------------------------------------ registry
@dataclasses.dataclass(frozen=True)
class KernelSpec:
    name: str
    fn: Callable  # fn(*args, **kwargs, **knobs, interpret=...)
    space: Callable  # space(*args, **kwargs) -> SearchSpace over the knobs
    defaults: Callable  # defaults(*args, **kwargs) -> dict of knob values


_REGISTRY: dict = {}


def register(spec: KernelSpec) -> KernelSpec:
    _REGISTRY[spec.name] = spec
    return spec


def get_spec(name: str) -> KernelSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown kernel {name!r}; registered: {sorted(_REGISTRY)}") from None


def registered() -> list:
    return sorted(_REGISTRY)


def _pow2_floor(n: int) -> int:
    """Largest power of two that divides n (n>0)."""
    return n & (-n)


def _log_dim(name: str, n: int, lo: int, cap: int) -> LogIntDim:
    """Power-of-two tile dim that always divides ``n``: bounds clamp to the
    largest power-of-two divisor of n, so every decodable value is legal."""
    g = _pow2_floor(int(n))
    lo = max(1, min(lo, g))
    hi = max(lo, min(cap, g, int(n)))
    return LogIntDim(name, lo, hi)


# --------------------------------------------------------- registered kernels
register(
    KernelSpec(
        name="matmul",
        fn=ops.matmul,
        space=lambda a, b: SearchSpace(
            [
                _log_dim("bm", a.shape[0], 32, 256),
                _log_dim("bn", b.shape[1], 32, 256),
                _log_dim("bk", a.shape[1], 32, 256),
            ]
        ),
        defaults=lambda a, b: {
            "bm": min(256, _pow2_floor(a.shape[0])),
            "bn": min(256, _pow2_floor(b.shape[1])),
            "bk": min(256, _pow2_floor(a.shape[1])),
        },
    )
)

register(
    KernelSpec(
        name="flash_attention",
        fn=ops.flash_attention,
        # q: (B,Sq,H,hd); k/v: (B,Kh,Skv,hd)
        space=lambda q, k, v, **kw: SearchSpace(
            [
                _log_dim("block_q", q.shape[1], 16, 512),
                _log_dim("block_kv", k.shape[2], 16, 512),
            ]
        ),
        defaults=lambda q, k, v, **kw: {
            "block_q": min(128, _pow2_floor(q.shape[1])),
            "block_kv": min(128, _pow2_floor(k.shape[2])),
        },
    )
)

register(
    KernelSpec(
        name="decode_attention",
        fn=ops.decode_attention,
        # q: (B,H,hd); k/v: (B,Kh,S,hd); valid: (B,S)
        space=lambda q, k, v, valid: SearchSpace(
            [_log_dim("block_kv", k.shape[2], 64, 1024)]
        ),
        defaults=lambda q, k, v, valid: {"block_kv": min(512, _pow2_floor(k.shape[2]))},
    )
)

register(
    KernelSpec(
        name="lru_scan",
        fn=ops.lru_scan,
        # a,b: (B,T,D); h0: (B,D)
        space=lambda a, b, h0: SearchSpace([_log_dim("chunk", a.shape[1], 16, 256)]),
        defaults=lambda a, b, h0: {"chunk": min(128, _pow2_floor(a.shape[1]))},
    )
)

register(
    KernelSpec(
        name="rwkv_scan",
        fn=ops.rwkv_scan,
        # r,k,v,lw: (B,T,H,hd); u: (H,hd); s0: (B,H,hd,hd)
        space=lambda r, k, v, lw, u, s0: SearchSpace(
            [_log_dim("chunk", r.shape[1], 16, 128)]
        ),
        defaults=lambda r, k, v, lw, u, s0: {"chunk": min(64, _pow2_floor(r.shape[1]))},
    )
)


# ------------------------------------------------------------------- tuning
#: substrings that mark an *expected* failure: a candidate whose tile/block
#: configuration is illegal for this shape or doesn't fit the target memory.
#: Anything else is an unexpected error — a real bug the search must not eat.
_ILLEGAL_MARKERS = (
    "block",
    "tile",
    "grid",
    "divisible",
    "divides",
    "not a multiple",
    "memory space",
    "vmem",
    "smem",
    "out of memory",
    "resource_exhausted",
    "resource exhausted",
    "mosaic",
)

#: exception types that are programmer errors no matter what the message says:
#: the knob names themselves ("block_q", "tile"...) show up in e.g. a TypeError
#: about an unknown kwarg, which must never pass for an illegal-tile failure
_BUG_EXC_TYPES = (TypeError, AttributeError, NameError, ImportError, SyntaxError)

# the transient-failure markers (RESOURCE_EXHAUSTED and friends) are shared
# with the guard layer — imported above as _TRANSIENT_MARKERS so both layers
# agree on what "worth retrying" means


def exec_cache() -> ExecutableCache:
    """The process-wide executable cache used by :func:`tune_call`."""
    return _EXEC_CACHE


def classify_failure(exc: BaseException) -> str:
    """``"illegal"`` (expected: bad tile for this shape/memory) or
    ``"unexpected"`` (a real bug that deserves a log line)."""
    if isinstance(exc, GuardTimeout):
        # a watchdog-expired candidate is an expected hazard of tuning on
        # live hardware, not a framework bug: charge inf quietly
        return "illegal"
    if isinstance(exc, _BUG_EXC_TYPES):
        return "unexpected"
    msg = f"{type(exc).__name__}: {exc}".lower()
    return "illegal" if any(m in msg for m in _ILLEGAL_MARKERS) else "unexpected"


def _failure_is_deterministic(exc: BaseException) -> bool:
    """Whether a build failure may be cached for the process lifetime.

    Only clearly deterministic illegal-tile failures qualify; unexpected
    errors, watchdog timeouts, and resource exhaustion (which can all be
    artifacts of concurrent compile load rather than the candidate itself)
    are retried on revisit."""
    return classify_failure(exc) == "illegal" and not is_transient_failure(exc)


#: process-level cache of AOT-compiled kernel executables, keyed by
#: (context fingerprint, decoded knobs) — revisited candidates across rounds,
#: optimizer resets, and pretune grid cells never recompile.  Only
#: deterministic illegal-tile failures are cached; transient/unexpected
#: build failures are retried on revisit.
_EXEC_CACHE = ExecutableCache(
    maxsize=int(os.environ.get("REPRO_EXEC_CACHE_SIZE", "1024")),
    cache_failures=_failure_is_deterministic,
)


def _resolve_jobs(jobs: Optional[int]) -> int:
    if jobs is None:
        jobs = int(os.environ.get(ENV_TUNE_JOBS, "0") or 0)
    if jobs <= 0:
        # leave one core for the serial measurement thread; on 1-2 core hosts
        # concurrent XLA compiles contend more than they overlap, so fall back
        # to the serial compile path there.  Capped at 8 by default — tuning
        # rounds rarely have more unique candidates, and wider fan-out mostly
        # adds compile memory pressure; pass jobs=/REPRO_TUNE_JOBS to exceed it
        jobs = min(8, max(1, (os.cpu_count() or 2) - 1))
    return max(1, jobs)


def _roofline_bound(ex) -> Optional[float]:
    """Analytic lower bound (seconds) of a compiled executable, or ``None``
    when cost analysis is unavailable.  Conservative by construction: the
    bound assumes peak accelerator hardware (the default
    :data:`~repro.core.costs.TPU_V5E` spec), so it can only *under*-estimate
    the real wall time — a candidate is pruned only when even its ideal
    execution loses to the incumbent's measured cost.  On hosts far slower
    than the spec (CPU interpret mode) the bound sits orders of magnitude
    below any measurement and the prefilter simply never fires; pass an
    explicit ``bound_fn`` to :func:`tune_call` for host-calibrated bounds."""
    from repro.core import roofline_terms

    try:
        b = float(roofline_terms(ex, chips=1).bound_s)
    except Exception:
        return None
    return b if b > 0.0 else None


def tune_call(
    name: str,
    *args,
    db: Optional[TuningDB] = None,
    interpret: bool = False,
    num_opt: int = 3,
    max_iter: int = 4,
    seed: int = 0,
    warmup: int = 1,
    repeats: int = 2,
    verbose: bool = False,
    source: str = "online",
    jobs: Optional[int] = None,
    drain: Optional[bool] = None,
    cost_fn: Optional[Callable] = None,
    measure=None,
    bound_fn: Optional[Callable] = None,
    measure_stats: Optional[dict] = None,
    strategy: Optional[str] = None,
    objective: Optional[str] = None,
    warm_start: bool = True,
    fault_policy: Optional[FaultPolicy] = None,
    fault_plan=None,
    **kwargs,
):
    """Run a measured PATSMA search for this call context and commit the
    result to ``db``.  Warm-seeds from the nearest stored neighbor when one
    exists (half budget).  Returns the TuningRecord for the context.

    Candidates are evaluated in batches: each optimizer round is deduplicated,
    its unique points AOT-compiled concurrently (``jobs`` threads, default
    ``REPRO_TUNE_JOBS`` or min(8, CPU count − 1) — XLA compilation releases
    the GIL) through the process-level executable cache, and then measured
    strictly serially (one candidate at a time) so wall-clock timings stay
    honest.

    ``measure`` picks the measurement policy (a
    :class:`~repro.core.measure.MeasurePolicy`, ``"adaptive"``, ``"fixed"``,
    or ``None`` → the ``REPRO_TUNE_MEASURE`` env var, default adaptive):

    * **adaptive** — the racing engine: every candidate of a round gets one
      measured repetition, dominated candidates are culled at their single-rep
      cost, survivors escalate through the repeat ladder until separated;
      candidates whose roofline lower bound already exceeds the incumbent's
      measured cost skip measurement entirely.  The whole round's compiles
      are drained before the first rep (racing compares candidates within a
      round, so timings must not run against background compile load).
    * **fixed** — the classic :class:`RuntimeCost` ``warmup``/``repeats``
      median per candidate, trajectory-identical to earlier releases; early
      candidates' measurements overlap the remaining compiles unless
      ``drain=True`` (or ``REPRO_TUNE_DRAIN=1``).

    Failures are classified either way: expected illegal-tile candidates
    quietly cost ``inf``, each distinct unexpected error is logged once per
    search, and the committed record carries a ``crashed`` count plus the
    best point's ``cost_std``/``repeats_spent`` measurement confidence.

    ``cost_fn(executable, *args) -> float`` overrides wall-clock timing
    (used by tests/benchmarks for deterministic costs); under the adaptive
    policy each call supplies one repetition, and the roofline prefilter is
    disabled unless an explicit ``bound_fn(point, executable)`` provides
    bounds in the cost function's own units.  ``measure_stats``, if given a
    dict, receives the measurement engine's counters (reps spent, culls,
    roofline prunes) when the search finishes.

    ``strategy`` picks the search strategy (``"csa+nm"``, ``"csa|nm"``, ...
    — the :func:`repro.core.strategy.make_strategy` grammar) over the same
    ``num_opt * max_iter`` tell budget the default CSA consumes; ``None``
    keeps the classic CSA search, trajectory-identical to earlier releases.
    A :class:`~repro.core.strategy.Portfolio` strategy reuses the adaptive
    engine's calibrated noise floor for its statistically-separated-lead
    culls.  The spec is stamped on the committed record (``strategy``).

    ``objective`` picks the statistic a candidate's repetitions reduce to
    (``"median"`` default, ``"p95"``, ``"p99"`` — see
    :data:`repro.core.measure.OBJECTIVES`).  Tail objectives tune for
    worst-case latency: the search minimizes the chosen quantile of each
    candidate's measured repetitions, and the committed record is stamped
    with the objective so a p99 cost is never compared against a median one.

    ``warm_start=False`` disables the DB neighbor seeding, making each
    context's search independent of what else the DB holds — the fleet's
    shard-equivalence contract (a sharded sweep must reproduce the
    unsharded sweep's points) needs searches whose trajectories do not
    depend on the sweep's visiting order.

    ``fault_policy`` (a :class:`~repro.core.guard.FaultPolicy`, default
    ``None`` = unguarded, trajectory-identical to earlier releases) arms the
    resilience layer: per-stage watchdog timeouts charge hung candidates
    ``inf`` instead of wedging the run, transient failures
    (RESOURCE_EXHAUSTED class) are retried in place with deterministic
    backoff, a candidate failing ``max_failures`` times is quarantined
    (skipped without a build, charged ``inf``), and with
    ``sandbox_first_touch`` each never-seen candidate is crash-probed in a
    forked child first so a hard crash is contained.  ``fault_plan`` injects
    a deterministic :class:`~repro.testing.faults.FaultPlan` at the
    ``"tune"``/``"build"``/``"cost"`` seams (``None`` reads the
    ``REPRO_FAULT_PLAN`` env var — the chaos CI lane's hook).
    """
    import jax

    spec = get_spec(name)
    space = spec.space(*args, **kwargs)
    key = make_key(name, args=args, kwargs=kwargs, space=space,
                   extra={"interpret": bool(interpret)})
    db = db if db is not None else default_db()
    policy = resolve_measure_policy(
        measure, warmup=warmup, repeats=repeats, objective=objective
    )
    cost = cost_fn if cost_fn is not None else RuntimeCost(
        warmup=warmup, repeats=repeats, objective=policy.objective
    )
    jobs = _resolve_jobs(jobs)
    if drain is None:
        drain = bool(int(os.environ.get(ENV_TUNE_DRAIN, "0") or 0))
    ctx = key.encode()
    logged: set = set()  # distinct unexpected errors already reported

    # --- resilience layer (all opt-in; None → identical trajectories)
    if fault_plan is None:
        from repro.testing.faults import active_plan

        fault_plan = active_plan()
    plan = fault_plan
    fpol = fault_policy
    quarantine = Quarantine(fpol.max_failures) if fpol is not None else None

    def qkey(p: dict):
        return tuple(sorted(p.items()))

    if plan is not None:
        plan.fire("tune", key=name)

    fatal = None
    if fpol is not None and fpol.fail_fast:
        def fatal(e: BaseException) -> bool:
            # a poisoned round: an error that is neither an expected illegal
            # tile nor a load transient would hit every candidate identically
            return classify_failure(e) == "unexpected" and not is_transient_failure(e)
    compile_deadline = fpol.compile_deadline if fpol is not None else None

    def build_for(knobs: dict):
        def build():
            if plan is not None:
                plan.fire("build", key=knobs)
            fn = jax.jit(
                lambda *xs: spec.fn(*xs, **kwargs, **knobs, interpret=interpret)
            )
            return fn.lower(*args).compile()

        if fpol is None:
            return build

        def probed():
            if fpol.sandbox_first_touch:
                # crash canary: a hard crash dies in a forked child and
                # surfaces as SandboxCrash, charged inf by the layers above
                sandboxed_probe(
                    build, timeout=fpol.sandbox_timeout, label=f"{name}:{knobs}"
                )
            return build()

        return fpol.wrap(probed, stage="compile", label=f"{name}:build")

    def note_failure(knobs: dict, exc: BaseException, stage: str) -> None:
        if isinstance(exc, (KeyboardInterrupt, SystemExit)):
            raise exc  # user interrupt, not a candidate failure
        if quarantine is not None and quarantine.note_failure(qkey(knobs)):
            log.info(
                "%s: candidate %s quarantined after %d failures",
                name, knobs, quarantine.max_failures,
            )
        kind = classify_failure(exc)
        if kind == "unexpected":
            sig = (type(exc).__name__, str(exc).splitlines()[0] if str(exc) else "")
            if sig not in logged:
                logged.add(sig)
                log.warning(
                    "%s: unexpected %s error for %s: %s: %s",
                    name, stage, knobs, type(exc).__name__, exc,
                )
        elif verbose:
            log.info("%s: illegal candidate %s: %s", name, knobs, exc)

    # fixed-path counters (the adaptive engine keeps its own): measure_stats
    # must report repetitions spent in either mode
    fixed_counts = {"rounds": 0, "candidates": 0, "measured": 0, "failed": 0,
                    "reps": 0, "warmup_reps": 0, "timeouts": 0, "retried": 0}

    # obs forensics: every candidate a round asks for gets exactly one
    # terminal event — this is the completeness invariant the acceptance
    # gate checks (committed + culled + pruned + skipped + quarantined =
    # asked).  Emission lives here, after measurement, because only this
    # frame sees both the quarantine decision and the final MeasureResult.
    ev_round = [0]

    def emit_round_events(points, live, results) -> None:
        if _events.sink() is None:
            return
        ev_round[0] += 1
        sname = at.ctx_name()
        rnd = ev_round[0]
        live_set = set(live)
        for i, p in enumerate(points):
            _events.emit("candidate_asked", name=sname, point=dict(p), round=rnd)
            if i not in live_set:
                _events.emit("candidate_quarantined", name=sname, point=dict(p))
                continue
            r = results[i]
            if isinstance(r, MeasureResult):
                if r.pruned is not None:
                    _events.emit("candidate_pruned", name=sname, point=dict(p),
                                 bound=float(r.cost))
                elif r.culled:
                    _events.emit("candidate_culled", name=sname, point=dict(p),
                                 cost=float(r.cost), ci_lo=float(r.ci_lo),
                                 ci_hi=float(r.ci_hi))
                elif math.isfinite(r.cost):
                    _events.emit("candidate_committed", name=sname,
                                 point=dict(p), cost=float(r.cost))
                else:
                    _events.emit("candidate_skipped", name=sname,
                                 point=dict(p), reason="failed")
            else:
                c = float(r)
                if math.isfinite(c):
                    _events.emit("candidate_committed", name=sname,
                                 point=dict(p), cost=c)
                else:
                    _events.emit("candidate_skipped", name=sname,
                                 point=dict(p), reason="failed")

    def measure_one(p, ex):
        if isinstance(ex, BaseException):
            note_failure(p, ex, "compile")
            fixed_counts["failed"] += 1
            return np.inf

        def run():
            if plan is not None:
                plan.fire("cost", key=p)
            return float(cost(ex, *args))

        try:
            if fpol is not None and (
                fpol.measure_timeout is not None or fpol.retries > 0
            ):
                c = guarded_call(
                    run,
                    timeout=fpol.measure_timeout,
                    retries=fpol.retries,
                    backoff=fpol.backoff,
                    backoff_mult=fpol.backoff_mult,
                    jitter=fpol.jitter,
                    label=f"{name}:measure",
                    on_retry=lambda *_: fixed_counts.__setitem__(
                        "retried", fixed_counts["retried"] + 1
                    ),
                )
            else:
                c = run()
        except Exception as e:
            if isinstance(e, GuardTimeout):
                fixed_counts["timeouts"] += 1
            note_failure(p, e, "measure")
            fixed_counts["failed"] += 1
            return np.inf
        if quarantine is not None:
            quarantine.note_success(qkey(p))
        fixed_counts["measured"] += 1
        if isinstance(cost, RuntimeCost):
            fixed_counts["reps"] += len(cost.last_times)
            fixed_counts["warmup_reps"] += cost.warmup
            # surface the fixed schedule's measurement confidence too
            return MeasureResult(
                cost=c,
                cost_std=cost.last_std,
                repeats_spent=len(cost.last_times),
                times=list(cost.last_times),
            )
        fixed_counts["reps"] += 1  # one cost_fn call per candidate
        return c

    def measure_batch_fixed(points):
        # Concurrent AOT compile of the round's unique candidates, deduped
        # against every executable this process ever built; wall-clock
        # measurement stays strictly serial (one candidate at a time, in
        # order) but overlaps the *remaining* compiles — candidate i is
        # measured as soon as its executable is ready while i+1.. still
        # compile on the pool (``drain`` trades that overlap for unbiased
        # timings).
        fixed_counts["rounds"] += 1
        fixed_counts["candidates"] += len(points)
        results: list = [None] * len(points)
        live: list = []  # indices not quarantined
        for i, p in enumerate(points):
            if quarantine is not None and qkey(p) in quarantine:
                results[i] = np.inf  # skipped outright: no build, no measure
            else:
                live.append(i)
        items = [((ctx, qkey(points[i])), build_for(points[i])) for i in live]
        if jobs <= 1 or len(items) <= 1 or compile_deadline is not None or fatal:
            # the serial path — and, when a round deadline or fail-fast is
            # armed, the managed fan-out (compile/measure overlap is traded
            # for cancellable builds)
            compiled = compile_fanout(
                items,
                cache=_EXEC_CACHE,
                jobs=1 if jobs <= 1 else min(jobs, max(1, len(items))),
                deadline=compile_deadline,
                fatal=fatal,
            )
            for i, ex in zip(live, compiled):
                results[i] = measure_one(points[i], ex)
            emit_round_events(points, live, results)
            return results
        from concurrent.futures import ThreadPoolExecutor, wait

        with ThreadPoolExecutor(max_workers=min(jobs, len(items))) as pool:
            # wrap the build thunk, not the lookup: cache hits cost no span
            tr = _tracer()
            futs = [pool.submit(_EXEC_CACHE.get_or_build, k,
                                tr.wrap(b, "compile"))
                    for k, b in items]
            if drain:  # no compile runs in the background of any measurement
                wait(futs)
            for i, f in zip(live, futs):
                results[i] = measure_one(points[i], f.result())
        emit_round_events(points, live, results)
        return results

    # --- adaptive policy: racing engine over each compiled round
    analytic = bound_fn if bound_fn is not None else (
        _roofline_bound_for if cost_fn is None else None
    )

    def make_rep(p, ex):
        def rep():
            if plan is not None:
                plan.fire("cost", key=p)
            t = float(cost_fn(ex, *args)) if cost_fn is not None else time_rep(ex, *args)
            if quarantine is not None:
                quarantine.note_success(qkey(p))
            return t

        return rep

    engine_policy = policy
    if cost_fn is not None and policy.mode == "adaptive" and not isinstance(
        measure, MeasurePolicy
    ):
        # a user cost function owns its own stabilization (per-rep warmup
        # would burn extra cost_fn calls) and returns costs in *its own
        # units* — the seconds-denominated abs_noise prior would swamp
        # small-magnitude costs and disable racing, so only the relative
        # floor applies.  An explicitly passed MeasurePolicy is authoritative.
        import dataclasses as _dc

        engine_policy = _dc.replace(policy, warmup=0, abs_noise=0.0)
    engine = MeasureEngine(engine_policy, guard=fpol)

    def measure_batch_adaptive(points):
        # racing compares candidates *within* the round, so the round's
        # compiles are always drained before the first repetition — overlap
        # would bias early candidates against late ones
        if engine.noise is not None and hasattr(at.optimizer, "set_noise"):
            # a Portfolio strategy separates leads with the same noise floor
            # the engine calibrated for candidate racing
            at.optimizer.set_noise(engine.noise)
        live = [
            i for i, p in enumerate(points)
            if quarantine is None or qkey(p) not in quarantine
        ]
        items = [((ctx, qkey(points[i])), build_for(points[i])) for i in live]
        compiled_live = compile_fanout(
            items,
            cache=_EXEC_CACHE,
            jobs=min(jobs, max(1, len(items))),
            deadline=compile_deadline,
            fatal=fatal,
        )
        by_index = dict(zip(live, compiled_live))
        # bounds are only worth computing once a finite incumbent exists —
        # the prefilter is disabled before the first measured round anyway,
        # so round 1 never pays HLO cost analysis per candidate
        want_bounds = analytic is not None and math.isfinite(engine.best_measured)
        reps, bounds = [], []
        for i, p in enumerate(points):
            ex = by_index.get(i)  # quarantined candidates never compiled
            if ex is None or isinstance(ex, BaseException):
                if ex is not None:
                    note_failure(p, ex, "compile")
                reps.append(None)
                bounds.append(None)
            else:
                reps.append(make_rep(p, ex))
                bounds.append(analytic(p, ex) if want_bounds else None)
        engine.on_error = lambda i, e: note_failure(points[i], e, "measure")
        results = engine.measure_round(reps, bounds=bounds)
        emit_round_events(points, live, results)
        return results

    measure_batch = (
        measure_batch_adaptive if policy.mode == "adaptive" else measure_batch_fixed
    )
    at = Autotuning(
        space=space,
        ignore=0,  # RuntimeCost already discards warmup runs
        search=strategy,  # None -> the classic default CSA search
        num_opt=num_opt,
        max_iter=max_iter,
        seed=seed,
        cache=True,
        verbose=verbose,
        db=db,
        key=key,
        warm_start=warm_start,
        db_source=source,
        objective=policy.objective,
    )
    at.entire_exec_batch(measure_batch)
    at.commit()  # no-op if auto-committed / exact hit
    if measure_stats is not None:
        if policy.mode == "fixed":
            stats = dict(engine.stats)  # zeroed template (right key set)
            stats.update(fixed_counts)
        else:
            stats = dict(engine.stats)
            if engine.noise is not None:
                stats["noise_abs_floor"] = engine.noise.abs_floor
                stats["noise_rel"] = engine.noise.rel
        stats["mode"] = policy.mode
        if quarantine is not None:
            stats["quarantined"] = quarantine.stats()["quarantined"]
        if plan is not None:
            stats["faults_fired"] = plan.count()
        measure_stats.update(stats)
    return db.get(key)


def _roofline_bound_for(point: dict, ex) -> Optional[float]:
    """Default ``bound_fn``: roofline lower bound of the compiled candidate
    (the point itself is already baked into the executable)."""
    return _roofline_bound(ex)


# --------------------------------------------------- router-backed dispatch
_ROUTERS: dict = {}  # interpret flag -> process-wide ContextRouter
_ROUTER_EPSILON = 0.1


def _router_build(spec: KernelSpec, interpret: bool) -> Callable:
    """AOT-compile one candidate; runs on the router's background pool."""

    def build(point: dict, *args, **kwargs):
        import jax

        fn = jax.jit(lambda *xs: spec.fn(*xs, **kwargs, **point, interpret=interpret))
        return fn.lower(*args).compile()

    return build


def kernel_router(
    *,
    interpret: bool = False,
    db: Optional[TuningDB] = None,
    epsilon: float = _ROUTER_EPSILON,
    jobs: Optional[int] = None,
    fresh: bool = False,
):
    """The process-wide :class:`repro.runtime.ContextRouter` over every
    registered kernel (one router per ``interpret`` flavour).

    Contexts are (kernel × pow2 shape-bucket); each starts from the tuning
    DB (exact pretuned fingerprints replay instantly, neighbors warm-start a
    half-budget search) and keeps adapting online: an ``epsilon`` fraction
    of live calls measures a candidate whose executable was AOT-compiled on
    the background pool through the shared process executable cache, and
    drift in the exploit costs triggers a warm re-search.  ``fresh=True``
    builds an independently configured router (tests, custom db/epsilon)
    instead of the cached singleton; asking the existing singleton for a
    different configuration is an error, not a silent no-op.
    """
    from repro.runtime.context import ContextRouter

    flag = bool(interpret)
    if not fresh and flag in _ROUTERS:
        if db is not None or epsilon != _ROUTER_EPSILON or jobs is not None:
            raise ValueError(
                f"kernel_router(interpret={flag}) is already configured; "
                "pass fresh=True for a differently-configured router"
            )
        return _ROUTERS[flag]
    router = ContextRouter(
        db=db if db is not None else default_db(),
        cache=_EXEC_CACHE,
        jobs=_resolve_jobs(jobs),
    )
    for name in registered():
        spec = get_spec(name)
        router.register(
            name,
            space=spec.space,
            defaults=spec.defaults,
            build=_router_build(spec, flag),
            epsilon=epsilon,
            extra={"interpret": flag},
        )
    if not fresh:
        _ROUTERS[flag] = router
    return router


def routed(
    name: str,
    *args,
    router=None,
    interpret: bool = False,
    **kwargs,
):
    """Adaptive kernel dispatch: like :func:`autotuned`, but every call flows
    through the kernel router — knobs keep improving while the process
    serves, and a drifted context re-tunes itself in the background.

    The serving call never compiles a *candidate* in-band: exploration only
    happens once the candidate's executable is ready in the process cache.
    The fallback path (no executable yet for the exploit knobs — e.g. the
    very first call of a cold context) dispatches the kernel directly.
    """
    import time as _time

    import jax

    r = router if router is not None else kernel_router(interpret=interpret)
    decision = r.begin(name, *args, **kwargs)
    t0 = _time.perf_counter()
    if decision.executable is not None:
        out = decision.executable(*args)
    else:
        # fallback dispatch: the router already clamped the knobs from the
        # shape-bucket's space into this exact shape's space
        spec = get_spec(name)
        out = spec.fn(*args, **kwargs, **decision.point, interpret=interpret)
    try:
        out = jax.block_until_ready(out)
    except Exception:
        pass
    r.observe(decision, _time.perf_counter() - t0)
    return out


def autotuned(
    name: str,
    *args,
    db: Optional[TuningDB] = None,
    tune: bool = False,
    interpret: bool = False,
    **kwargs,
):
    """Dispatch kernel ``name`` with the best knobs known for this context."""
    spec = get_spec(name)
    space = spec.space(*args, **kwargs)
    key = make_key(name, args=args, kwargs=kwargs, space=space,
                   extra={"interpret": bool(interpret)})
    db = db if db is not None else default_db()
    rec, exact = db.lookup(key)
    if not exact and tune:
        tuned_rec = tune_call(name, *args, db=db, interpret=interpret, **kwargs)
        if tuned_rec is not None:  # all-crashed run: keep the neighbor fallback
            rec, exact = tuned_rec, True
    if exact:
        knobs = {n: rec.point[n] for n in space.names}
    elif rec is not None and all(n in rec.point for n in space.names):
        # neighbor: reuse its point, clamped into this shape's (smaller) space
        knobs = space.decode(space.encode(rec.point))
    else:
        knobs = spec.defaults(*args, **kwargs)
    return spec.fn(*args, **kwargs, **knobs, interpret=interpret)
