"""Chunked linear-recurrence kernels (Pallas TPU): RWKV-6 WKV and RG-LRU.

Both kernels keep the recurrent state VMEM-resident across a sequential
chunk grid — the TPU analogue of the GPU "chunked scan" kernels (fla /
flash-linear-attention): HBM traffic is one pass over the sequence while the
O(state) carry never leaves VMEM.  ``chunk`` (the tile length) is the
PATSMA-tunable parameter.

rwkv_scan: per (batch·head, chunk) tile, the intra-chunk term uses exact
log-space cumulative-decay differences (all exponents <= 0 — numerically
stable, no decay clamping), the inter-chunk term is one MXU matmul against
the carried state.

lru_scan: first-order elementwise recurrence h_t = a_t h_{t-1} + b_t; the
in-chunk step loop is elementwise on (d_block,) lanes; grid parallelism over
(batch, d-blocks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["rwkv_scan_chunked", "lru_scan_chunked"]


# ------------------------------------------------------------------ RWKV-6
def _rwkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, sT_ref, s_scr, *, L, n_chunks):
    nc = pl.program_id(1)

    @pl.when(nc == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)  # (L, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)  # log decay <= 0
    u = u_ref[0].astype(jnp.float32)  # (1, hd) bonus
    S = s_scr[...]  # (hd, hd)

    c = jnp.cumsum(lw, axis=0)  # (L, hd), decreasing
    # inter-chunk: y += (r_t e^{c_{t-1}}) @ S
    q_dec = r * jnp.exp(c - lw)
    y_inter = jax.lax.dot_general(
        q_dec, S, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # intra-chunk: scores_ts = sum_i r_t k_s e^{c_{t-1}-c_s} (s<t), + u diag
    expo = (c - lw)[:, None, :] - c[None, :, :]  # (L, L, hd), <= 0 on s<t
    ti = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    tri = ti > si
    ew = jnp.where(tri[:, :, None], jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
    scores = jnp.sum(ew * r[:, None, :] * k[None, :, :], axis=-1)  # (L, L)
    diag = jnp.sum(r * u * k, axis=-1)  # (L,)
    scores = jnp.where(ti == si, diag[:, None], scores)
    y = y_inter + jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state update: S' = e^{c_L} ⊙ S + (k e^{c_L - c}).T @ v
    k_end = k * jnp.exp(c[-1:, :] - c)
    s_scr[...] = jnp.exp(c[-1, :])[:, None] * S + jax.lax.dot_general(
        k_end, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(nc == n_chunks - 1)
    def _emit():
        sT_ref[0] = s_scr[...]


def rwkv_scan_chunked(r, k, v, lw, u, s0, *, chunk: int = 64, interpret: bool = False):
    """r,k,v,lw: (B,T,H,hd); u: (H,hd); s0: (B,H,hd,hd) fp32.
    Returns y: (B,T,H,hd), sT: (B,H,hd,hd)."""
    B, T, H, hd = r.shape
    L = min(chunk, T)
    if T % L:
        raise ValueError(f"T={T} not divisible by chunk={L}")
    n_chunks = T // L
    BH = B * H

    def flat(x):  # (B,T,H,hd) -> (BH, n_chunks, L, hd) row-major per head
        return x.transpose(0, 2, 1, 3).reshape(BH, n_chunks, L, hd)

    rf, kf, vf, lwf = flat(r), flat(k), flat(v), flat(lw)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(BH, 1, hd)
    s0f = s0.reshape(BH, hd, hd)
    grid = (BH, n_chunks)
    y, sT = pl.pallas_call(
        functools.partial(_rwkv_kernel, L=L, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, L, hd), lambda bh, nc: (bh, nc, 0, 0)),
            pl.BlockSpec((1, 1, L, hd), lambda bh, nc: (bh, nc, 0, 0)),
            pl.BlockSpec((1, 1, L, hd), lambda bh, nc: (bh, nc, 0, 0)),
            pl.BlockSpec((1, 1, L, hd), lambda bh, nc: (bh, nc, 0, 0)),
            pl.BlockSpec((1, 1, hd), lambda bh, nc: (bh, 0, 0)),
            pl.BlockSpec((1, hd, hd), lambda bh, nc: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, hd), lambda bh, nc: (bh, nc, 0, 0)),
            pl.BlockSpec((1, hd, hd), lambda bh, nc: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, n_chunks, L, hd), r.dtype),
            jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(rf, kf, vf, lwf, uf, s0f)
    y = y.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
    return y, sT.reshape(B, H, hd, hd)


# ------------------------------------------------------------------ RG-LRU
def _lru_kernel(a_ref, b_ref, h0_ref, y_ref, hT_ref, h_scr, *, L, n_chunks):
    nc = pl.program_id(2)

    @pl.when(nc == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)  # (L, bd)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        y_ref[0, pl.ds(t, 1), :] = h[None].astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, L, step, h_scr[0])
    h_scr[...] = h[None]

    @pl.when(nc == n_chunks - 1)
    def _emit():
        hT_ref[0] = h[None].astype(hT_ref.dtype)


def lru_scan_chunked(a, b, h0, *, chunk: int = 128, block_d: int = 512, interpret: bool = False):
    """a,b: (B,T,D); h0: (B,D) -> (hs: (B,T,D), hT: (B,D))."""
    B, T, D = a.shape
    L = min(chunk, T)
    if T % L:
        raise ValueError(f"T={T} not divisible by chunk={L}")
    bd = min(block_d, D)
    if D % bd:
        raise ValueError(f"D={D} not divisible by block_d={bd}")
    n_chunks = T // L
    grid = (B, D // bd, n_chunks)
    hs, hT = pl.pallas_call(
        functools.partial(_lru_kernel, L=L, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, bd), lambda ib, id_, nc: (ib, nc, id_)),
            pl.BlockSpec((1, L, bd), lambda ib, id_, nc: (ib, nc, id_)),
            pl.BlockSpec((1, 1, bd), lambda ib, id_, nc: (ib, 0, id_)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, bd), lambda ib, id_, nc: (ib, nc, id_)),
            pl.BlockSpec((1, 1, bd), lambda ib, id_, nc: (ib, 0, id_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, D), a.dtype),
            jax.ShapeDtypeStruct((B, 1, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b, h0.reshape(B, 1, D))
    return hs, hT.reshape(B, D)
