"""PATSMA (Parameter Auto-tuning for Shared Memory Algorithms) on JAX/Pallas.

Subpackages: ``core`` (optimizers + Autotuning), ``tuning`` (persistent
tuning DB), ``kernels`` (Pallas kernels + DB-backed dispatch), ``models``,
``parallel``, ``train``, ``runtime``, ``launch``, ``checkpoint``, ``data``,
``configs``, ``optim``.
"""

__version__ = "0.1.0"
