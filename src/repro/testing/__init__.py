"""repro.testing — deterministic fault injection for the resilience layer."""
from .faults import (
    ENV_FAULT_PLAN,
    FaultPlan,
    FaultSpec,
    InjectedCrash,
    active_plan,
    parse_plan,
    tear_file,
)

__all__ = [
    "ENV_FAULT_PLAN",
    "FaultPlan",
    "FaultSpec",
    "InjectedCrash",
    "active_plan",
    "parse_plan",
    "tear_file",
]
