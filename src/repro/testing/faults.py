"""Deterministic fault injection — every resilience behavior testable
without real hangs, real OOMs, or real power loss.

A :class:`FaultPlan` is a list of :class:`FaultSpec` entries, each saying
*where* (an injection ``site`` and optional candidate/call match), *what*
(hang, transient error, hard crash, straggler slowdown, process kill), and
*how often* (``times`` firings).  Execution paths that opted in call
``plan.fire(site, key)`` at their injection seams — ``tune_call`` fires
``"tune"`` on entry, ``"build"`` per candidate compile, and ``"cost"`` per
cost evaluation — and the plan deterministically raises/sleeps per its specs.

Everything is counted, never random: the n-th call at a site always behaves
the same, so a faulted run is exactly reproducible and tests can assert the
recovery, not chase the injection.

Activation: pass a plan to ``tune_call(fault_plan=...)`` directly, or set
``REPRO_FAULT_PLAN`` to the plan's JSON — the CI chaos lane runs the whole
guard suite with a straggler plan injected this way.  :func:`active_plan`
caches one plan instance per distinct env value, so firing counters persist
across ``tune_call`` invocations within a process (a "kill at tune-call #2"
spec means the second *overall*, not the second per call).

:func:`tear_file` simulates a torn write (power loss mid-append) by
truncating a file mid-record — the journal/DB loaders must tolerate it.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Tuple

__all__ = [
    "ENV_FAULT_PLAN",
    "InjectedCrash",
    "FaultSpec",
    "FaultPlan",
    "parse_plan",
    "active_plan",
    "tear_file",
]

#: env var: JSON fault plan injected into every tune_call of the process
ENV_FAULT_PLAN = "REPRO_FAULT_PLAN"

_KINDS = ("hang", "slow", "transient", "crash", "kill")


class InjectedCrash(RuntimeError):
    """A deterministic stand-in for a hard candidate crash.  Classified
    "unexpected" by the kernel layer's failure classifier — a permanent,
    non-transient failure the guard must charge ``inf``, never retry."""


@dataclasses.dataclass
class FaultSpec:
    """One injected fault.

    ``site`` names the injection seam (``"tune"`` / ``"build"`` /
    ``"cost"`` / ``"save"`` — or any label a harness fires).  ``match``
    restricts firing to keys it matches: a dict matches candidate points by
    subset (``{"bm": 32}`` fires on every candidate with that knob), a
    string matches ``str(key)`` by substring.  ``calls`` restricts firing to
    the given 1-based call indices *at that site* (counted across the whole
    plan lifetime).  ``times`` caps total firings of this spec.

    Kinds: ``hang`` sleeps ``seconds`` (pair with a watchdog deadline
    shorter than that — the sleep bounds test runtime where a real hang
    would not); ``slow`` sleeps ``seconds`` then lets the call proceed (a
    straggler); ``transient`` raises a RESOURCE_EXHAUSTED-classed error;
    ``crash`` raises :class:`InjectedCrash`; ``kill`` raises ``SystemExit``
    — which intentionally propagates through every guard layer, simulating
    process death in-process for resume tests."""

    kind: str
    site: str = "cost"
    match: Optional[object] = None
    calls: Optional[Tuple[int, ...]] = None
    times: int = 1
    seconds: float = 0.05
    message: str = "RESOURCE_EXHAUSTED: injected transient failure"

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; known: {_KINDS}")
        if self.calls is not None:
            self.calls = tuple(int(c) for c in self.calls)
        self.times = int(self.times)

    def matches(self, key) -> bool:
        if self.match is None:
            return True
        if isinstance(self.match, dict):
            if not isinstance(key, dict):
                return False
            return all(key.get(k) == v for k, v in self.match.items())
        return str(self.match) in str(key)


class FaultPlan:
    """An ordered set of fault specs with per-site call counters."""

    def __init__(self, specs) -> None:
        self.specs: List[FaultSpec] = [
            s if isinstance(s, FaultSpec) else FaultSpec(**s) for s in specs
        ]
        self._site_calls: Dict[str, int] = {}
        self._fired_counts: Dict[int, int] = {}
        self.fired: list = []  # (site, call#, spec index, key) log for tests

    def fire(self, site: str, key=None) -> None:
        """One pass through an injection seam; applies every matching spec's
        effect in declaration order."""
        n = self._site_calls.get(site, 0) + 1
        self._site_calls[site] = n
        for i, spec in enumerate(self.specs):
            if spec.site != site:
                continue
            if self._fired_counts.get(i, 0) >= spec.times:
                continue
            if spec.calls is not None and n not in spec.calls:
                continue
            if not spec.matches(key):
                continue
            self._fired_counts[i] = self._fired_counts.get(i, 0) + 1
            self.fired.append((site, n, i, key))
            self._apply(spec, site, key)

    def _apply(self, spec: FaultSpec, site: str, key) -> None:
        if spec.kind in ("hang", "slow"):
            time.sleep(spec.seconds)
            return  # hang relies on the caller's watchdog firing first
        if spec.kind == "transient":
            raise RuntimeError(spec.message)
        if spec.kind == "crash":
            raise InjectedCrash(
                f"injected hard crash at {site} (key={key!r})"
            )
        if spec.kind == "kill":
            raise SystemExit(f"injected kill at {site} (key={key!r})")

    def count(self, site: Optional[str] = None) -> int:
        """Fired effects so far (optionally restricted to one site)."""
        if site is None:
            return len(self.fired)
        return sum(1 for s, *_ in self.fired if s == site)

    def stats(self) -> dict:
        return {
            "site_calls": dict(self._site_calls),
            "fired": len(self.fired),
        }


def parse_plan(text: str) -> FaultPlan:
    """Build a plan from JSON: a list of spec dicts, or ``{"specs": [...]}``."""
    blob = json.loads(text)
    if isinstance(blob, dict):
        blob = blob.get("specs", [])
    if not isinstance(blob, list):
        raise ValueError("fault plan JSON must be a list of specs")
    return FaultPlan(blob)


_active: Dict[str, FaultPlan] = {}  # env value -> live plan (counters persist)


def active_plan() -> Optional[FaultPlan]:
    """The process's env-configured plan, or ``None``.  One plan instance
    per distinct ``REPRO_FAULT_PLAN`` value — its counters span every
    ``tune_call`` of the process, so call-indexed specs count globally."""
    text = os.environ.get(ENV_FAULT_PLAN, "").strip()
    if not text:
        return None
    plan = _active.get(text)
    if plan is None:
        plan = parse_plan(text)
        _active[text] = plan
    return plan


def tear_file(path: str, keep_bytes: Optional[int] = None) -> int:
    """Simulate a torn write: truncate ``path`` mid-record.  Keeps
    ``keep_bytes`` (default: half, landing inside the final line) and
    returns the new size — loaders must treat the dangling tail as absent,
    not as corruption of the whole file."""
    size = os.path.getsize(path)
    keep = size // 2 if keep_bytes is None else int(keep_bytes)
    keep = max(0, min(keep, size))
    with open(path, "r+b") as f:
        f.truncate(keep)
    return keep
