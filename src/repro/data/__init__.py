"""Deterministic synthetic data pipeline (sharded, resumable).

Real deployments swap in a tokenized corpus reader behind the same iterator
contract: ``(step) -> batch dict`` with per-host sharding and exact resume
(the pipeline is a pure function of (seed, step), so checkpoint/restart
replays identically — required by the fault-tolerance tests).
"""
from .pipeline import SyntheticLM, make_batch_for

__all__ = ["SyntheticLM", "make_batch_for"]
