"""Synthetic token stream: structured (learnable) sequences, pure function of
(seed, step) — deterministic resume for free."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLM", "make_batch_for"]


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Markov-ish synthetic LM data: next token = (a*tok + b) % vocab with
    per-sequence (a, b) — learnable structure so training loss moves."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 32) ^ step)
        B, S = self.global_batch, self.seq_len
        a = rng.integers(1, 8, (B, 1), dtype=np.int64)
        b = rng.integers(0, self.vocab_size, (B, 1), dtype=np.int64)
        t0 = rng.integers(0, self.vocab_size, (B, 1), dtype=np.int64)
        idx = np.arange(S + 1, dtype=np.int64)[None, :]
        toks = (t0 + a * idx + b * (idx // 7)) % self.vocab_size
        return {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_batch_for(cfg, B: int, S: int, step: int = 0, seed: int = 0) -> dict:
    """Batch with the modality-stub extras each family needs."""
    base = SyntheticLM(cfg.vocab_size, S, B, seed).batch(step)
    rng = jax.random.PRNGKey((seed << 8) ^ step)
    if cfg.is_encdec:
        base["frames"] = 0.1 * jax.random.normal(
            rng, (B, cfg.ctx_tokens, cfg.d_model), jnp.float32
        )
    if cfg.family == "vlm":
        base["ctx_embeds"] = 0.1 * jax.random.normal(
            rng, (B, cfg.ctx_tokens, cfg.d_model), jnp.float32
        )
    return base
