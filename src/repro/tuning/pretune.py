"""Offline pre-tuning sweep — fill the tuning DB before anyone pays online.

    PYTHONPATH=src python -m repro.tuning.pretune --db tuned/cpu.json --smoke
    PYTHONPATH=src python -m repro.tuning.pretune --db tuned/cpu.json \
        --kernel matmul --kernel flash_attention
    PYTHONPATH=src python -m repro.tuning.pretune --db tuned/cpu.json --list
    PYTHONPATH=src python -m repro.tuning.pretune --db tuned/serve.json \
        --only 'matmul/128*'
    PYTHONPATH=src python -m repro.tune pretune --db tuned/shard0.json \
        --smoke --shard 0/4          # fleet worker 0 of 4

(``python -m repro.tune pretune`` is the same command behind the umbrella
CLI, which also provides ``db merge`` / ``db list`` / ``db diff``.)

**Fleet sharding**: ``--shard i/n`` keeps only the contexts whose stable
fingerprint hash lands in shard ``i`` — n workers running the same command
with shards 0..n-1 partition the grid exactly, with zero coordination and
no shared filesystem; each writes its own ``--db`` and
``python -m repro.tune db merge`` folds them.  ``--cost analytic`` swaps
wall-clock measurement for the candidate's deterministic roofline bound and
``--no-warm-start`` removes sweep-order dependence, together making a
sharded sweep bit-reproduce the unsharded one (the CI equivalence lane).

**Crash resume**: every sweep writes a write-ahead journal next to the DB
(``<db>.journal`` — one fsynced JSONL event per case: ``start`` before
measurement, ``commit``/``failed`` after).  A worker killed mid-sweep
restarts with ``--resume`` and re-measures nothing already completed; the
journal's committed records also reconstruct the DB if the kill tore the
final save, and ``python -m repro.tune db merge`` accepts partial journals
as sources directly.

Sweeps the registered (kernel, shape) grid, runs the PATSMA search per
context, and commits every record atomically.  Each context's candidate
rounds are AOT-compiled concurrently (``--jobs`` threads; measurement stays
serial) through the process-wide executable cache, so revisited candidates
never recompile.  ``--measure adaptive`` (the default) races each round's
candidates — dominated ones are culled after a single repetition and
roofline-hopeless ones skip measurement — while ``--measure fixed`` keeps
the classic ``RuntimeCost`` fixed-repeat loop for trajectory-pinned
reproduction; the run summary reports repetitions spent, culls, and prunes.  The committed ``tuned/cpu.json`` snapshot is what the test
suite and CI replay: the suite's kernel dispatches become exact fingerprint
hits, so they skip straight to the stored best with zero re-measurement.  On
a TPU host the same command (without ``--smoke``) produces the production
snapshot for that device kind.

``--list`` prints the registered grid with each case's DB status (exact hit
/ warm neighbor / cold, plus the stored record's search strategy) without
tuning anything, and ``--only <glob>`` restricts a sweep to matching cases —
together they are how a serving deployment seeds exactly the router contexts
its traffic will touch, without sweeping the whole grid.  ``--strategy
csa+nm`` swaps the per-context search for the paper's CSA→NM hybrid pipeline
(or any :func:`repro.core.strategy.make_strategy` spec) at the same total
measurement budget; the spec is stamped on every committed record.
"""
from __future__ import annotations

import argparse
import fnmatch
import sys
import time


def _cases(smoke: bool, abstract: bool = False):
    """(kernel name, case label, thunk building the call args) grid.  Thunks
    defer array construction so filtering never materializes unused inputs.
    ``abstract=True`` yields ``jax.ShapeDtypeStruct`` stand-ins — enough for
    fingerprints and search spaces (both read only shape/dtype), so
    ``--list`` stays metadata-only instead of allocating the whole grid."""
    import jax
    import jax.numpy as jnp

    if abstract:
        def rnd(seed, shape, dtype=jnp.float32):
            return jax.ShapeDtypeStruct(shape, dtype)

        def filled(value, shape, dtype=jnp.float32):
            return jax.ShapeDtypeStruct(shape, dtype)
    else:
        def rnd(seed, shape, dtype=jnp.float32):
            return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)

        def filled(value, shape, dtype=jnp.float32):
            return jnp.full(shape, value, dtype)

    if smoke:
        mm_shapes = [(64, 64, 64), (128, 128, 128)]
        fa_shapes = [(1, 2, 2, 64, 16)]
        da_shapes = [(2, 4, 2, 128, 16)]
        ls_shapes = [(2, 64, 32)]
    else:
        mm_shapes = [(128,) * 3, (256,) * 3, (512, 512, 256)]
        fa_shapes = [(1, 2, 2, 64, 16), (1, 4, 2, 128, 32), (2, 4, 4, 256, 32)]
        da_shapes = [(2, 4, 2, 128, 16), (4, 8, 2, 512, 32)]
        ls_shapes = [(2, 64, 32), (2, 256, 64)]

    cases = []
    for m, n, k in mm_shapes:
        cases.append(
            ("matmul", f"{m}x{n}x{k}",
             lambda m=m, n=n, k=k: (rnd(0, (m, k)), rnd(1, (k, n))))
        )
    for b, h, kh, s, hd in fa_shapes:
        cases.append(
            (
                "flash_attention",
                f"b{b}h{h}kh{kh}s{s}d{hd}",
                lambda b=b, h=h, kh=kh, s=s, hd=hd: (
                    rnd(0, (b, s, h, hd)),
                    rnd(1, (b, kh, s, hd)),
                    rnd(2, (b, kh, s, hd)),
                ),
            )
        )
    for b, h, kh, s, hd in da_shapes:
        cases.append(
            (
                "decode_attention",
                f"b{b}h{h}kh{kh}s{s}d{hd}",
                lambda b=b, h=h, kh=kh, s=s, hd=hd: (
                    rnd(0, (b, h, hd)),
                    rnd(1, (b, kh, s, hd)),
                    rnd(2, (b, kh, s, hd)),
                    filled(1, (b, s), jnp.int32),
                ),
            )
        )
    for b, t, d in ls_shapes:
        cases.append(
            (
                "lru_scan",
                f"b{b}t{t}d{d}",
                lambda b=b, t=t, d=d: (
                    filled(0.9, (b, t, d)),
                    rnd(1, (b, t, d)),
                    rnd(2, (b, d)),
                ),
            )
        )
    return cases


def _case_key(name: str, abstract_args, interpret: bool):
    """The context fingerprint :func:`tune_call` would compute for this case
    — built from ``ShapeDtypeStruct`` stand-ins (signatures and search
    spaces read only shape/dtype), so shard assignment never materializes
    the grid."""
    from repro.kernels.autotuned import get_spec
    from repro.tuning import make_key

    spec = get_spec(name)
    space = spec.space(*abstract_args)
    return make_key(name, args=abstract_args, space=space,
                    extra={"interpret": bool(interpret)})


def _shard_filter(cases, smoke, wanted, only, shard, interpret: bool):
    """Keep the cases whose fingerprint lands in ``shard`` = (index, num).
    Assignment hashes the full context key (:meth:`TuningKey.shard`), so
    every fleet worker computes the same partition with no coordination."""
    from repro.tuning.fleet import in_shard

    index, num = shard
    abstract = {
        (n, label): build
        for n, label, build in _selected(_cases(smoke, abstract=True), wanted, only)
    }
    kept = []
    for name, label, build in cases:
        key = _case_key(name, abstract[(name, label)](), interpret=interpret)
        if in_shard(key, index, num):
            kept.append((name, label, build))
    return kept


def _analytic_cost_fn():
    """Deterministic stand-in for wall-clock measurement: the candidate's
    roofline lower bound (:func:`repro.core.costs.roofline_terms` over its
    compiled HLO).  Identical inputs give identical costs on every host and
    every run, which is what the fleet's shard-equivalence contract needs —
    a sharded sweep and an unsharded sweep must land on the same best
    points.  Candidates whose HLO defeats cost analysis fall back to a
    constant (still deterministic); relative quality between such ties is
    then decided by the search trajectory, which is equally deterministic."""
    from repro.core import roofline_terms

    def cost(ex, *args):
        try:
            b = float(roofline_terms(ex, chips=1).bound_s)
        except Exception:
            b = 0.0
        return b if b > 0.0 else 1.0

    return cost


def _selected(cases, wanted, only):
    """Filter the grid by --kernel names and --only globs (case ids match as
    ``kernel`` or ``kernel/label``)."""
    out = []
    for name, label, build in cases:
        if wanted is not None and name not in wanted:
            continue
        case_id = f"{name}/{label}"
        if only and not any(
            fnmatch.fnmatch(case_id, pat) or fnmatch.fnmatch(name, pat)
            for pat in only
        ):
            continue
        out.append((name, label, build))
    return out


def _space_size_str(space) -> str:
    """`size=raw/feasible` — the resolved product-space size and what is left
    after the validity predicates prune (equal when unconstrained), so
    operators can see whether a sweep is tractable before launching it."""
    raw = space.size()
    if raw is None:
        return "size=∞"
    feas = space.constrained_size()
    return f"size={raw}/{feas if feas is not None else '?'}"


def _list_grid(cases, db, interpret: bool) -> int:
    """Print each case with its resolved space size (raw/constrained) and DB
    status: exact hit, warm neighbor, or cold."""
    from repro.kernels.autotuned import get_spec
    from repro.tuning import make_key

    for name, label, build in cases:
        call_args = build()
        spec = get_spec(name)
        space = spec.space(*call_args)
        key = make_key(name, args=call_args, space=space,
                       extra={"interpret": bool(interpret)})
        rec, exact = db.lookup(key)
        case_id = f"{name}/{label}"
        case_id = f"{case_id:<28} {_space_size_str(space):<14}"
        if exact:
            # same convention as the run summary: the default CSA search is
            # not news, only a non-default strategy earns the column
            strat = (f" strategy={rec.strategy}"
                     if rec.strategy and rec.strategy != "csa" else "")
            print(f"  {case_id:<42} HIT   best={rec.point} "
                  f"cost={rec.cost * 1e3:.2f}ms source={rec.source}{strat}")
        elif rec is not None and key.distance(rec.key) != float("inf"):
            print(f"  {case_id:<42} warm  neighbor={rec.point} "
                  f"(shapes {rec.key.shapes()})")
        else:
            print(f"  {case_id:<42} cold")
    return 0


def _launch_main(args, db, *, max_iter: int) -> int:
    """The ``--launch`` family: sweep launch-level (arch, shape) contexts
    through :func:`repro.launch.spaces.tune_launch` with the same journal /
    shard / list / resume machinery as the kernel grid."""
    import os

    from repro import configs, obs
    from repro.launch.spaces import (
        launch_cases,
        launch_key,
        launch_space,
        tune_launch,
    )
    from repro.tuning import RunJournal

    n_devices = args.devices or int(os.environ.get("REPRO_DRYRUN_DEVICES") or 8)
    mode = "model" if args.cost == "analytic" else "dryrun"
    cases = launch_cases(smoke=args.smoke)
    if args.only:
        cases = [
            (a, s) for a, s in cases
            if any(fnmatch.fnmatch(f"launch/{a}/{s}", pat)
                   or fnmatch.fnmatch(a, pat) for pat in args.only)
        ]
    if not cases:
        print("pretune: no launch cases match the given filters", file=sys.stderr)
        return 2

    def case_key(arch, shape_name):
        cfg = configs.get(arch)
        shape = configs.SHAPES[shape_name]
        space = launch_space(cfg, shape, n_devices)
        return launch_key(arch, shape, n_devices, space, mode=mode), space

    if args.shard is not None:
        from repro.tuning.fleet import in_shard, parse_shard

        index, num = parse_shard(args.shard)
        total = len(cases)
        cases = [
            (a, s) for a, s in cases if in_shard(case_key(a, s)[0], index, num)
        ]
        print(f"pretune: shard {index}/{num}: {len(cases)}/{total} launch cases")
        if not cases:
            db.save()
            return 0

    if args.list_grid:
        for arch, shape_name in cases:
            key, space = case_key(arch, shape_name)
            rec, exact = db.lookup(key)
            case_id = f"launch/{arch}/{shape_name}"
            case_id = f"{case_id:<40} {_space_size_str(space):<16}"
            if exact:
                print(f"  {case_id} HIT   best={rec.point} cost={rec.cost:.4g}s "
                      f"source={rec.source}")
            else:
                print(f"  {case_id} cold  devices={n_devices} mode={mode}")
        return 0

    jpath = RunJournal.path_for(args.db)
    done_keys: set = set()
    if args.resume:
        journal = RunJournal(jpath)
        s = journal.summary()
        done_keys = set(s["committed"]) | set(s["failed"])
        if s["committed"]:
            db.merge(journal.to_db())
        journal.resume()
        print(f"pretune: resume from {jpath}: skipping {len(done_keys)} "
              f"completed launch cases")
    else:
        if os.path.exists(jpath):
            os.remove(jpath)
        journal = RunJournal(jpath)

    n_done = 0
    t_all = time.perf_counter()
    totals = {"measured": 0, "pruned": 0}
    sweep_span = obs.span("pretune", cases=len(cases), family="launch")
    sweep_span.__enter__()
    try:
        for arch, shape_name in cases:
            key, space = case_key(arch, shape_name)
            if key.encode() in done_keys:
                continue
            t0 = time.perf_counter()
            stats: dict = {}
            journal.start(key)
            rec = tune_launch(
                arch,
                shape_name,
                n_devices,
                db=db,
                mode=mode,
                num_opt=args.num_opt,
                max_iter=max_iter,
                seed=args.seed,
                search=args.strategy,
                warm_start=not args.no_warm_start,
                source="pretune",
                stats=stats,
            )
            dt = time.perf_counter() - t0
            totals["measured"] += int(stats.get("measured", 0))
            totals["pruned"] += int(stats.get("pruned", 0))
            if rec is None:
                journal.failed(key, "every candidate failed")
                print(f"  launch/{arch}/{shape_name}: every candidate failed; "
                      f"nothing stored ({dt:.1f}s)", file=sys.stderr)
                continue
            journal.commit(key, rec)
            sz = _space_size_str(space)
            replay = " (replayed)" if stats.get("replayed") else ""
            print(
                f"  launch/{arch}/{shape_name}: best={rec.point} "
                f"cost={rec.cost:.4g}s {sz} measured={stats.get('measured', 0)} "
                f"pruned={stats.get('pruned', 0)}{replay} ({dt:.1f}s)"
            )
            n_done += 1
        db.save()
        print(
            f"pretune: {n_done} launch contexts tuned, {len(db)} records in "
            f"{args.db} ({time.perf_counter() - t_all:.1f}s); "
            f"{totals['measured']} candidates scored ({mode}), "
            f"{totals['pruned']} constraint-pruned at zero cost"
        )
        return 0
    finally:
        sweep_span.__exit__(None, None, None)
        obs.shutdown()


def main(argv=None, prog: str = "repro.tuning.pretune") -> int:
    ap = argparse.ArgumentParser(
        prog=prog, description="offline tuning sweep -> JSON DB"
    )
    ap.add_argument("--db", type=str, default="tuned/cpu.json", help="DB file to fill")
    ap.add_argument("--smoke", action="store_true", help="tiny grid + budget (CI lane)")
    ap.add_argument(
        "--kernel", action="append", default=None, help="restrict to kernel(s); repeatable"
    )
    ap.add_argument(
        "--only", action="append", default=None, metavar="GLOB",
        help="restrict to matching cases, e.g. 'matmul/128*'; repeatable",
    )
    ap.add_argument(
        "--list", action="store_true", dest="list_grid",
        help="print the registered grid with DB hit status; tune nothing",
    )
    ap.add_argument("--num-opt", type=int, default=3, help="CSA coupled solvers")
    ap.add_argument("--max-iter", type=int, default=None, help="CSA iterations (default 2 smoke / 4)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-interpret", action="store_true", help="run kernels compiled (TPU host)")
    ap.add_argument(
        "--jobs", type=int, default=None,
        help="concurrent AOT compiles per tuning round (default: REPRO_TUNE_JOBS or cpu count)",
    )
    ap.add_argument(
        "--measure", choices=("adaptive", "fixed"), default=None,
        help="measurement policy: adaptive racing + roofline prefilter, or the "
             "classic fixed-repeat loop (default: REPRO_TUNE_MEASURE or adaptive)",
    )
    ap.add_argument(
        "--strategy", type=str, default=None, metavar="SPEC",
        help="search strategy spec per context, e.g. 'csa+nm' (the paper's "
             "CSA→NM hybrid pipeline), 'csa:0.7+nm:0.3', or 'csa|nm' "
             "(portfolio); default: plain CSA — same total tell budget either way",
    )
    ap.add_argument(
        "--objective", choices=("median", "p95", "p99"), default=None,
        help="statistic a candidate's measured repetitions reduce to "
             "(default median — classic PATSMA; p95/p99 tune for tail "
             "latency and stamp the objective on the committed records)",
    )
    ap.add_argument(
        "--shard", type=str, default=None, metavar="I/N",
        help="tune only this worker's deterministic slice of the grid "
             "(stable context-fingerprint hash mod N — N workers with "
             "--shard 0/N .. (N-1)/N cover the grid exactly once with zero "
             "coordination; merge the per-shard DBs with "
             "'python -m repro.tune db merge')",
    )
    ap.add_argument(
        "--cost", choices=("runtime", "analytic"), default="runtime",
        help="candidate cost: measured wall-clock (default) or the "
             "deterministic roofline lower bound of the compiled candidate — "
             "host-independent and noise-free, so sharded and unsharded "
             "sweeps land on identical best points (the CI equivalence lane)",
    )
    ap.add_argument(
        "--no-warm-start", action="store_true",
        help="disable DB neighbor seeding: each context's search is "
             "independent of sweep order and of what the DB already holds "
             "(required for exact shard-equivalence)",
    )
    ap.add_argument(
        "--obs-dir", type=str, default=None, metavar="DIR",
        help="write observability artifacts (events.jsonl, trace.json, "
             "metrics.json) into DIR; 'python -m repro.tune report DIR' "
             "renders them (default: the REPRO_OBS env var, else off)",
    )
    ap.add_argument(
        "--resume", action="store_true",
        help="resume a killed sweep from its run journal (<db>.journal): "
             "cases already committed or failed are skipped, only "
             "interrupted and never-started cases are (re-)measured",
    )
    ap.add_argument(
        "--launch", action="store_true",
        help="tune the launch-level grid (launch.spaces: mesh dp×tp "
             "factorization, microbatches, remat, collective chunking, XLA "
             "preset) instead of kernel tiles.  '--cost analytic' (the CI "
             "mode) scores candidates with the deterministic launch cost "
             "model; '--cost runtime' compiles each candidate on the "
             "host-platform mesh via launch.dryrun and charges its roofline "
             "bound",
    )
    ap.add_argument(
        "--devices", type=int, default=None,
        help="device count the launch grid factorizes (with --launch; "
             "default: REPRO_DRYRUN_DEVICES, else 8)",
    )
    args = ap.parse_args(argv)

    from repro import obs
    from repro.kernels.autotuned import exec_cache, registered, tune_call
    from repro.tuning import TuningDB, default_device

    if args.obs_dir:
        obs.configure(args.obs_dir)
    else:
        obs.configure_from_env()

    max_iter = args.max_iter if args.max_iter is not None else (2 if args.smoke else 4)
    db = TuningDB(args.db)
    backend, device_kind = default_device()
    print(f"pretune: db={args.db} ({len(db)} records) device={backend}/{device_kind}")

    if args.launch:
        return _launch_main(args, db, max_iter=max_iter)

    wanted = set(args.kernel) if args.kernel else None
    unknown = (wanted or set()) - set(registered())
    if unknown:
        print(f"pretune: unknown kernel(s) {sorted(unknown)}", file=sys.stderr)
        return 2

    cases = _selected(
        _cases(args.smoke, abstract=args.list_grid), wanted, args.only
    )
    if not cases:
        print("pretune: no cases match the given filters", file=sys.stderr)
        return 2
    if args.shard is not None:
        from repro.tuning.fleet import parse_shard

        index, num = parse_shard(args.shard)
        total = len(cases)
        cases = _shard_filter(cases, args.smoke, wanted, args.only,
                              (index, num), interpret=not args.no_interpret)
        print(f"pretune: shard {index}/{num}: {len(cases)}/{total} cases")
        if not cases:
            # an empty shard is a fleet worker with nothing to do, not an error
            db.save()
            return 0
    if args.list_grid:
        return _list_grid(cases, db, interpret=not args.no_interpret)

    cost_fn = _analytic_cost_fn() if args.cost == "analytic" else None

    # write-ahead run journal: 'start' before each case's measurement,
    # 'commit'/'failed' after — a killed shard restarts with --resume
    # re-measuring nothing already completed
    import os

    from repro.tuning import RunJournal

    jpath = RunJournal.path_for(args.db)
    done_keys: set = set()
    if args.resume:
        journal = RunJournal(jpath)
        s = journal.summary()
        done_keys = set(s["committed"]) | set(s["failed"])
        if s["committed"]:
            # belt-and-braces: the journal carries full committed records, so
            # even a DB save torn by the kill is reconstructed here
            db.merge(journal.to_db())
        journal.resume()
        print(
            f"pretune: resume from {jpath}: {len(s['committed'])} committed, "
            f"{len(s['failed'])} failed, {len(s['interrupted'])} interrupted; "
            f"skipping {len(done_keys)} completed cases"
        )
    else:
        if os.path.exists(jpath):
            os.remove(jpath)  # a fresh sweep owns a fresh journal
        journal = RunJournal(jpath)

    n_done = 0
    n_skipped = 0
    t_all = time.perf_counter()
    # aggregate measurement-engine counters across the sweep (run summary)
    totals = {"reps": 0, "warmup_reps": 0, "calibration_reps": 0,
              "culled": 0, "pruned_roofline": 0, "measured": 0, "failed": 0}
    # root span: every search/round/compile span of the sweep nests here,
    # and shutdown() flushes trace.json + metrics.json even on a crash
    sweep_span = obs.span("pretune", cases=len(cases))
    sweep_span.__enter__()
    try:
        for name, label, build in cases:
            call_args = build()
            key = _case_key(name, call_args, interpret=not args.no_interpret)
            if key.encode() in done_keys:
                n_skipped += 1
                continue
            t0 = time.perf_counter()
            mstats: dict = {}
            journal.start(key)
            rec = tune_call(
                name,
                *call_args,
                db=db,
                interpret=not args.no_interpret,
                num_opt=args.num_opt,
                max_iter=max_iter,
                seed=args.seed,
                jobs=args.jobs,
                source="pretune",
                measure=args.measure,
                measure_stats=mstats,
                strategy=args.strategy,
                objective=args.objective,
                cost_fn=cost_fn,
                warm_start=not args.no_warm_start,
            )
            dt = time.perf_counter() - t0
            for k in totals:
                totals[k] += int(mstats.get(k, 0))
            if rec is None:
                journal.failed(key, "every candidate failed")
                print(f"  {name}/{label}: every candidate failed; nothing stored ({dt:.1f}s)",
                      file=sys.stderr)
                continue
            journal.commit(key, rec)
            crashed = f" crashed={rec.crashed}" if rec.crashed else ""
            strat = f" strategy={rec.strategy}" if rec.strategy and rec.strategy != "csa" else ""
            obj = f" objective={rec.objective}" if rec.objective and rec.objective != "median" else ""
            raced = ""
            if mstats.get("mode") == "adaptive" and mstats.get("measured"):
                raced = (f" reps={mstats['reps']}"
                         f" culled={mstats['culled']}"
                         f" pruned={mstats['pruned_roofline']}")
            print(
                f"  {name}/{label}: best={rec.point} cost={rec.cost * 1e3:.2f}ms "
                f"evals={rec.evals}{crashed}{strat}{obj}{raced} ({dt:.1f}s)"
            )
            n_done += 1
        db.save()
        cs = exec_cache().stats()
        skipped = f", {n_skipped} resumed-as-done" if n_skipped else ""
        print(
            f"pretune: {n_done} contexts tuned{skipped}, {len(db)} records in {args.db} "
            f"({time.perf_counter() - t_all:.1f}s); exec cache: {cs['misses']} compiles, "
            f"{cs['hits']} hits, {cs['recompiles']} recompiles"
        )
        if totals["measured"] or totals["reps"]:
            print(
                f"pretune: measurement: {totals['reps']} reps "
                f"(+{totals['warmup_reps']} warmup, {totals['calibration_reps']} "
                f"calibration) over {totals['measured']} candidates; "
                f"{totals['culled']} culled by racing, "
                f"{totals['pruned_roofline']} roofline-pruned, "
                f"{totals['failed']} failed"
            )
        return 0
    finally:
        sweep_span.__exit__(None, None, None)
        obs.shutdown()


if __name__ == "__main__":
    # thin shim: ``python -m repro.tuning.pretune`` is the historical entry
    # point; it now routes through the umbrella CLI (``python -m repro.tune
    # pretune``) so both spellings share one dispatch path
    import sys as _sys

    from repro.tune import main as _tune_main

    raise SystemExit(_tune_main(["pretune", *_sys.argv[1:]]))
