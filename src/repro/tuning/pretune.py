"""Offline pre-tuning sweep — fill the tuning DB before anyone pays online.

    PYTHONPATH=src python -m repro.tuning.pretune --db tuned/cpu.json --smoke
    PYTHONPATH=src python -m repro.tuning.pretune --db tuned/cpu.json \
        --kernel matmul --kernel flash_attention

Sweeps the registered (kernel, shape) grid, runs the PATSMA search per
context, and commits every record atomically.  Each context's candidate
rounds are AOT-compiled concurrently (``--jobs`` threads; measurement stays
serial) through the process-wide executable cache, so revisited candidates
never recompile.  The committed ``tuned/cpu.json`` snapshot is what the test
suite and CI replay: the suite's kernel dispatches become exact fingerprint
hits, so they skip straight to the stored best with zero re-measurement.  On
a TPU host the same command (without ``--smoke``) produces the production
snapshot for that device kind.
"""
from __future__ import annotations

import argparse
import sys
import time


def _cases(smoke: bool):
    """(kernel name, thunk building the call args) grid.  Thunks defer array
    construction so ``--kernel`` filtering never materializes unused inputs."""
    import jax
    import jax.numpy as jnp

    def rnd(seed, shape, dtype=jnp.float32):
        return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)

    if smoke:
        mm_shapes = [(64, 64, 64), (128, 128, 128)]
        fa_shapes = [(1, 2, 2, 64, 16)]
        da_shapes = [(2, 4, 2, 128, 16)]
        ls_shapes = [(2, 64, 32)]
    else:
        mm_shapes = [(128,) * 3, (256,) * 3, (512, 512, 256)]
        fa_shapes = [(1, 2, 2, 64, 16), (1, 4, 2, 128, 32), (2, 4, 4, 256, 32)]
        da_shapes = [(2, 4, 2, 128, 16), (4, 8, 2, 512, 32)]
        ls_shapes = [(2, 64, 32), (2, 256, 64)]

    cases = []
    for m, n, k in mm_shapes:
        cases.append(("matmul", lambda m=m, n=n, k=k: (rnd(0, (m, k)), rnd(1, (k, n)))))
    for b, h, kh, s, hd in fa_shapes:
        cases.append(
            (
                "flash_attention",
                lambda b=b, h=h, kh=kh, s=s, hd=hd: (
                    rnd(0, (b, s, h, hd)),
                    rnd(1, (b, kh, s, hd)),
                    rnd(2, (b, kh, s, hd)),
                ),
            )
        )
    for b, h, kh, s, hd in da_shapes:
        cases.append(
            (
                "decode_attention",
                lambda b=b, h=h, kh=kh, s=s, hd=hd: (
                    rnd(0, (b, h, hd)),
                    rnd(1, (b, kh, s, hd)),
                    rnd(2, (b, kh, s, hd)),
                    jnp.ones((b, s), jnp.int32),
                ),
            )
        )
    for b, t, d in ls_shapes:
        cases.append(
            (
                "lru_scan",
                lambda b=b, t=t, d=d: (
                    0.9 * jnp.ones((b, t, d)),
                    rnd(1, (b, t, d)),
                    rnd(2, (b, d)),
                ),
            )
        )
    return cases


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.tuning.pretune", description="offline tuning sweep -> JSON DB"
    )
    ap.add_argument("--db", type=str, default="tuned/cpu.json", help="DB file to fill")
    ap.add_argument("--smoke", action="store_true", help="tiny grid + budget (CI lane)")
    ap.add_argument(
        "--kernel", action="append", default=None, help="restrict to kernel(s); repeatable"
    )
    ap.add_argument("--num-opt", type=int, default=3, help="CSA coupled solvers")
    ap.add_argument("--max-iter", type=int, default=None, help="CSA iterations (default 2 smoke / 4)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-interpret", action="store_true", help="run kernels compiled (TPU host)")
    ap.add_argument(
        "--jobs", type=int, default=None,
        help="concurrent AOT compiles per tuning round (default: REPRO_TUNE_JOBS or cpu count)",
    )
    args = ap.parse_args(argv)

    from repro.kernels.autotuned import exec_cache, registered, tune_call
    from repro.tuning import TuningDB, default_device

    max_iter = args.max_iter if args.max_iter is not None else (2 if args.smoke else 4)
    db = TuningDB(args.db)
    backend, device_kind = default_device()
    print(f"pretune: db={args.db} ({len(db)} records) device={backend}/{device_kind}")

    wanted = set(args.kernel) if args.kernel else None
    unknown = (wanted or set()) - set(registered())
    if unknown:
        print(f"pretune: unknown kernel(s) {sorted(unknown)}", file=sys.stderr)
        return 2

    n_done = 0
    t_all = time.perf_counter()
    for name, build in _cases(args.smoke):
        if wanted is not None and name not in wanted:
            continue
        call_args = build()
        t0 = time.perf_counter()
        rec = tune_call(
            name,
            *call_args,
            db=db,
            interpret=not args.no_interpret,
            num_opt=args.num_opt,
            max_iter=max_iter,
            seed=args.seed,
            jobs=args.jobs,
            source="pretune",
        )
        dt = time.perf_counter() - t0
        shapes = [tuple(a.shape) for a in call_args]
        if rec is None:
            print(f"  {name} {shapes}: every candidate failed; nothing stored ({dt:.1f}s)",
                  file=sys.stderr)
            continue
        crashed = f" crashed={rec.crashed}" if rec.crashed else ""
        print(
            f"  {name} {shapes}: best={rec.point} cost={rec.cost * 1e3:.2f}ms "
            f"evals={rec.evals}{crashed} ({dt:.1f}s)"
        )
        n_done += 1
    db.save()
    cs = exec_cache().stats()
    print(
        f"pretune: {n_done} contexts tuned, {len(db)} records in {args.db} "
        f"({time.perf_counter() - t_all:.1f}s); exec cache: {cs['misses']} compiles, "
        f"{cs['hits']} hits, {cs['recompiles']} recompiles"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
