"""Distributed tuning fleet — sharded measurement behind one tuning API.

The paper tunes one process on one device; a fleet amortizes the same search
across many.  Two independent mechanisms compose, one per axis of scale:

* **Across hosts** — *shard the context grid*.  Every tuning context carries
  a stable fingerprint (:class:`~repro.tuning.records.TuningKey`), so a
  stable hash partitions the pretune grid with **zero coordination**:
  ``pretune --shard i/n`` on n hosts covers the grid exactly once, each host
  writing its own DB, and :func:`merge_dbs` folds the shard DBs into one.
  The merge resolver is a *total order* over records (min by
  :func:`record_rank`), so merging is associative and order-independent —
  any fold tree over any arrival order yields the same DB.

* **Across devices** — :class:`ShardedPortfolio` runs a Portfolio race with
  **one worker per member** instead of round-robin turns: each member's
  rung-sized ask-batches are measured concurrently on its own device slot
  (see :func:`repro.parallel.devices.local_device_pool`), costs are gathered
  at a rung barrier, and the cull decision is the *same pure function*
  (:func:`repro.core.strategy.cull_laggards`) the serial Portfolio applies —
  so with deterministic costs the surviving members and their bests match
  the serial race, while wall-clock drops to the slowest surviving member's
  own measurement time.

Merge semantics mirror ``Autotuning.commit()``'s keep-better guard: lower
cost wins, and inside the noise band the better-*measured* record wins, not
the luckier one.  The pairwise guard alone is not transitive (three records
can cycle under "near-tie keeps lower variance"), which would make a fold
order-dependent; :func:`record_rank` linearizes it by scoring every record
with its *noise-penalized* cost — ``cost + known_std`` when the record
carries real measurement confidence, ``cost + 0.02·|cost|`` (the measurement
engine's relative-noise prior) when it does not — then breaking exact ties
deterministically.  A lower penalized cost is exactly "would survive the
guard against anything it beats", and a total order makes ``min`` over any
subset associative by construction.
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from concurrent.futures import ThreadPoolExecutor

from repro.obs.trace import tracer as _tracer
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .records import TuningKey, TuningRecord

__all__ = [
    "REL_NOISE_PRIOR",
    "parse_shard",
    "shard_of",
    "in_shard",
    "record_rank",
    "better_record",
    "merge_records",
    "MergeStats",
    "merge_dbs",
    "journal_to_db",
    "FleetResult",
    "ShardedPortfolio",
    "device_bound_measure",
]

#: relative noise prior applied to records with *unknown* measurement
#: variance when ranking merge candidates — the same 2% relative floor the
#: measurement engine (:class:`repro.core.measure.NoiseEstimate`) assumes
#: before calibration, so an unconfident record is penalized exactly as wide
#: as the noise band the racing engine would grant it.
REL_NOISE_PRIOR = 0.02


# ------------------------------------------------------------------ sharding
def parse_shard(spec: str) -> Tuple[int, int]:
    """``"i/n"`` → ``(i, n)`` with ``0 <= i < n`` — the CLI form of a fleet
    worker's identity (shard 2 of 8 is ``"2/8"``)."""
    s = str(spec).strip()
    try:
        i_s, _, n_s = s.partition("/")
        index, num = int(i_s), int(n_s)
    except ValueError:
        raise ValueError(f"bad shard spec {spec!r}: expected 'i/n', e.g. '0/4'")
    if num < 1:
        raise ValueError(f"bad shard spec {spec!r}: need at least one shard")
    if not 0 <= index < num:
        raise ValueError(
            f"bad shard spec {spec!r}: index must be in [0, {num})"
        )
    return index, num


def shard_of(key: TuningKey, num_shards: int) -> int:
    """The shard owning ``key`` — delegates to :meth:`TuningKey.shard`."""
    return key.shard(num_shards)


def in_shard(key: TuningKey, index: int, num_shards: int) -> bool:
    """Whether ``key`` belongs to shard ``index`` of ``num_shards``."""
    return key.shard(num_shards) == index


# ----------------------------------------------------------- merge resolver
def _penalized_cost(rec: TuningRecord) -> float:
    """The record's cost widened by its measurement uncertainty: the real
    std when known, else the engine's relative prior.  This is the scalar
    the total order primarily sorts by — a well-measured record beats a
    lucky single-rep near-tie, mirroring ``commit()``'s guard."""
    cost = float(rec.cost)
    if not math.isfinite(cost):
        return math.inf
    std = rec.known_std()
    if std is None:
        std = REL_NOISE_PRIOR * abs(cost)
    return cost + std


def record_rank(rec: TuningRecord) -> tuple:
    """Total-order score of a record — **lower is better**.

    Sort keys, in order: finite cost first; lower noise-penalized cost
    (:data:`REL_NOISE_PRIOR` stands in for unknown variance); lower raw
    cost; known variance beats unknown; more repetitions behind the
    measurement; earlier ``created`` (the incumbent stands on an exact tie);
    finally the canonical JSON of the point, so the order is total even for
    byte-identical measurements of different points.  Every component is a
    pure function of the record, so ``min`` by this key over any subset of
    records — in any order, any fold tree — picks the same winner: the
    property :func:`merge_dbs` needs for shard merges to be associative.
    """
    cost = float(rec.cost)
    finite = math.isfinite(cost)
    std = rec.known_std()
    return (
        0 if finite else 1,
        _penalized_cost(rec),
        cost if finite else math.inf,
        0 if std is not None else 1,
        -(rec.repeats_spent or 0),
        float(rec.created),
        json.dumps(rec.point, sort_keys=True, default=repr),
    )


def better_record(a: TuningRecord, b: TuningRecord) -> TuningRecord:
    """The winner of two records for the same key under :func:`record_rank`
    (returns ``a`` on an exact rank tie, but ranks tie only for
    indistinguishable records)."""
    return a if record_rank(a) <= record_rank(b) else b


def merge_records(records: Sequence[TuningRecord]) -> TuningRecord:
    """The winner among any number of records for the same key."""
    recs = list(records)
    if not recs:
        raise ValueError("merge_records needs at least one record")
    return min(recs, key=record_rank)


@dataclasses.dataclass
class MergeStats:
    """What a :func:`merge_dbs` fold did: ``seen`` source records, of which
    ``new`` filled empty keys, ``replaced`` beat the destination's record,
    and ``kept`` lost to it."""

    sources: int = 0
    seen: int = 0
    new: int = 0
    replaced: int = 0
    kept: int = 0

    @property
    def adopted(self) -> int:
        return self.new + self.replaced

    def __str__(self) -> str:
        return (
            f"{self.seen} records from {self.sources} sources: "
            f"{self.new} new, {self.replaced} replaced, {self.kept} kept"
        )


def merge_dbs(dest, sources) -> MergeStats:
    """Fold shard DBs into ``dest``, resolving per-key conflicts with the
    total-order winner (:func:`better_record`).  ``sources`` are
    :class:`~repro.tuning.db.TuningDB` instances; ``dest`` may be empty or
    already hold records (they compete like any shard's).  Saves once at the
    end when ``dest`` is file-backed with autosave.  Associative and
    order-independent: merging shards pairwise, in any order, or all at once
    yields the identical destination."""
    stats = MergeStats()
    for src in sources:
        stats.sources += 1
        for rec in src.records():
            stats.seen += 1
            mine = dest.get(rec.key)
            if mine is None:
                dest.put(rec, save=False)
                stats.new += 1
            elif better_record(mine, rec) is rec:
                dest.put(rec, save=False)
                stats.replaced += 1
            else:
                stats.kept += 1
    if dest.autosave and dest.path is not None:
        dest.save()
    return stats


def journal_to_db(path: str):
    """The committed records of a run journal (``<db>.journal``) as an
    in-memory :class:`~repro.tuning.db.TuningDB` — the shape
    :func:`merge_dbs` folds.  This is how a fleet merge adopts the completed
    work of a shard that died mid-sweep: committed cases count, the
    interrupted case it was measuring is simply absent (and re-measured by
    that shard's ``pretune --resume``)."""
    from .db import RunJournal

    return RunJournal(path).to_db()


# ------------------------------------------------------- sharded portfolio
@dataclasses.dataclass
class FleetResult:
    """Outcome of a :meth:`ShardedPortfolio.run` race."""

    best_x: np.ndarray  # normalized coordinates of the overall best
    best_cost: float
    member_bests: List[float]  # best finite cost per member (inf if none)
    member_best_x: List[Optional[np.ndarray]]
    survivors: List[int]  # members still active when the race ended
    spent: int  # total tells delivered
    member_spent: List[int]
    wall_s: float


class ShardedPortfolio:
    """A Portfolio race with one concurrent worker per member.

    The serial :class:`~repro.core.strategy.Portfolio` interleaves its
    members' rung-sized chunks on a single measurement thread, so the race's
    wall-clock is the *sum* of every member's measurements.  This driver
    runs the same race as lockstep **passes**: every active member takes one
    rung-sized turn of its own ask→measure→tell loop *concurrently* — each
    turn touches only its own optimizer and its own state slots, so workers
    never contend — then a **rung barrier** gathers the scoreboard.  The
    cull check fires under the serial driver's exact gating rule (every
    active member has consumed its ``min(rung, natural round)`` check quota
    since the last check) and applies the identical pure decision
    (:func:`~repro.core.strategy.cull_laggards`): statistically separated
    laggards are dropped, at most half the field per check, never the
    leader.  A culled member's worker goes idle, so with a shared budget
    its remaining allowance flows to the survivors — and the wall-clock of
    the whole race collapses to that of its slowest surviving member.

    With deterministic costs each member's search trajectory is identical
    to the serial race by construction (a member's tells depend only on its
    own costs), and the cull decisions match exactly whenever quota
    crossings land on pass boundaries — every member crosses its quota
    within one turn, which holds when member round sizes are either one
    natural round ≤ rung (CSA's m probes, a random stream) or drip-fed
    sweeps ≥ rung (a grid).  A member that needs *several* turns to
    accumulate its quota (a simplex asking fewer points per round than its
    ``get_num_points``) may see checks land one turn later than the serial
    mid-pass firing — both are valid successive-halving schedules over the
    same trajectories.

    ``measure(member_index, points) -> costs`` is the caller's measurement
    hook; it runs on the member's worker thread.  Wrap it with
    :func:`device_bound_measure` to pin each member's evaluations to its
    own device from :func:`repro.parallel.devices.local_device_pool`
    (per-slot executable caches keep concurrent compiles from colliding).
    """

    def __init__(
        self,
        optimizers: Sequence,
        *,
        budget: Optional[int] = None,
        noise=None,
        margin: float = 0.5,
        rung: Optional[int] = None,
    ) -> None:
        from repro.core.measure import NoiseEstimate

        opts = list(optimizers)
        if len(opts) < 2:
            raise ValueError("ShardedPortfolio needs at least two optimizers")
        dims = {o.get_dimension() for o in opts}
        if len(dims) != 1:
            raise ValueError(f"member dimensions differ: {sorted(dims)}")
        if budget is not None and budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        self._opts = opts
        self._dim = opts[0].get_dimension()
        self._budget = int(budget) if budget is not None else None
        self._noise = noise if noise is not None else NoiseEstimate(0.0, 0.02)
        self._margin = float(margin)
        if rung is not None and int(rung) < 1:
            raise ValueError(f"rung must be >= 1, got {rung}")
        if rung is not None:
            self._rung = int(rung)
        else:
            # same sizing rule as the serial Portfolio: one natural round of
            # the widest member, capped at a fair share of the budget
            self._rung = max(o.get_num_points() for o in opts)
            if budget is not None:
                self._rung = max(1, min(self._rung, int(budget) // (2 * len(opts))))
        n = len(opts)
        self._active: List[int] = list(range(n))
        self._spent = 0
        self._member_spent = [0] * n
        self._member_best = [np.inf] * n
        self._member_best_x: List[Optional[np.ndarray]] = [None] * n
        self._since_check = [0] * n  # tells since the last cull check
        # per-member round buffering, mirroring the serial driver: a round
        # larger than one turn's allowance is drip-fed, its costs buffered
        # until the member's full round is in and its accept/anneal runs
        self._round: List[Optional[list]] = [None] * n
        self._fed: List[list] = [[] for _ in opts]

    # ------------------------------------------------------------- interface
    @property
    def members(self) -> list:
        return list(self._opts)

    @property
    def active(self) -> list:
        return list(self._active)

    @property
    def member_bests(self) -> list:
        return [float(b) for b in self._member_best]

    @property
    def spent(self) -> int:
        return self._spent

    def set_noise(self, noise) -> None:
        """Adopt a calibrated noise floor for the separation test."""
        self._noise = noise

    def _quota(self, i: int) -> int:
        """Per-cycle allowance: the member's own check quota (its natural
        round size, capped by the rung) — the serial driver's scoring unit."""
        return min(self._rung, max(1, self._opts[i].get_num_points()))

    def _member_live(self, i: int) -> bool:
        return self._round[i] is not None or not self._opts[i].is_end()

    def _turn(self, i: int, allowance: int, measure: Callable) -> int:
        """Member ``i``'s turn: measure **one** chunk of up to ``allowance``
        tells from its pending round (asking a fresh round when none is in
        flight), exactly like one serial-driver turn.  Touches only
        index-``i`` state slots, so concurrent workers need no locks."""
        if self._round[i] is None:
            if self._opts[i].is_end():
                return 0
            r = self._opts[i].ask()
            if not r:
                return 0
            self._round[i] = [np.asarray(p, dtype=float).copy() for p in r]
            self._fed[i] = []
        done_n = len(self._fed[i])
        chunk = self._round[i][done_n : done_n + max(1, allowance)]
        costs = [float(c) for c in measure(i, [p.copy() for p in chunk])]
        if len(costs) != len(chunk):
            raise ValueError(
                f"measure returned {len(costs)} costs for {len(chunk)} points"
            )
        for p, c in zip(chunk, costs):
            if np.isfinite(c) and c < self._member_best[i]:
                self._member_best[i] = float(c)
                self._member_best_x[i] = np.array(p, dtype=float, copy=True)
        self._fed[i].extend(costs)
        if len(self._fed[i]) >= len(self._round[i]):
            # the member's full round is in: its accept/anneal step runs
            self._opts[i].tell(self._fed[i])
            self._round[i] = None
            self._fed[i] = []
        return len(costs)

    def run(
        self,
        measure: Callable[[int, List[np.ndarray]], Sequence[float]],
        *,
        max_workers: Optional[int] = None,
    ) -> FleetResult:
        """Race the members to completion (every member finished or culled,
        or the shared budget exhausted) and return the scoreboard."""
        from repro.core.strategy import cull_laggards

        t0 = time.perf_counter()
        workers = min(len(self._opts), max_workers or len(self._opts))
        with ThreadPoolExecutor(max_workers=max(1, workers)) as pool:
            while True:
                if self._budget is not None and self._spent >= self._budget:
                    break
                live = [i for i in self._active if self._member_live(i)]
                if not live:
                    break
                # one lockstep pass: every live member takes one rung-sized
                # turn, all turns concurrent (each touches only its own
                # member's state); the shared budget is reserved in member
                # order, as the serial round-robin would spend it
                allow = {}
                rem = (
                    None if self._budget is None else self._budget - self._spent
                )
                for i in live:
                    a = self._rung
                    if rem is not None:
                        a = min(a, rem)
                        rem -= a
                    if a > 0:
                        allow[i] = a
                if not allow:
                    break
                # worker turns open member_turn spans attached to *this*
                # thread's current span, so a fleet run nests under the
                # caller's search/pretune span in the trace
                futs = {
                    pool.submit(
                        _tracer().wrap(self._turn, "member_turn", member=i),
                        i, a, measure,
                    ): i
                    for i, a in allow.items()
                }
                for f, i in futs.items():
                    n_tells = f.result()
                    self._spent += n_tells
                    self._member_spent[i] += n_tells
                    self._since_check[i] += n_tells
                # rung barrier: the cull check fires only once every active
                # member has consumed its check quota since the last check —
                # the serial driver's gating rule, applied at pass boundaries
                if len(self._active) >= 2 and all(
                    self._since_check[i] >= self._quota(i)
                    or not self._member_live(i)
                    for i in self._active
                ):
                    for i in self._active:
                        self._since_check[i] = 0
                    for i in cull_laggards(
                        self._active, self._member_best, self._noise, self._margin
                    ):
                        self._active.remove(i)
        best_i = min(
            range(len(self._opts)), key=lambda i: self._member_best[i]
        )
        best_cost = float(self._member_best[best_i])
        best_x = (
            self._member_best_x[best_i]
            if self._member_best_x[best_i] is not None
            else np.zeros(self._dim)
        )
        return FleetResult(
            best_x=np.array(best_x, dtype=float, copy=True),
            best_cost=best_cost,
            member_bests=self.member_bests,
            member_best_x=[
                None if x is None else np.array(x, dtype=float, copy=True)
                for x in self._member_best_x
            ],
            survivors=list(self._active),
            spent=self._spent,
            member_spent=list(self._member_spent),
            wall_s=time.perf_counter() - t0,
        )


def device_bound_measure(measure: Callable, slots: Sequence) -> Callable:
    """Pin each member's evaluations to its device slot: member ``i`` runs
    ``measure`` under ``jax.default_device(slots[i % len(slots)].device)``,
    so a multi-device host measures the whole field concurrently — one
    member per chip — instead of queueing on device 0.  Slots with no device
    (CPU-only hosts) pass through unchanged."""
    slots = list(slots)
    if not slots:
        return measure

    def wrapped(i: int, points):
        slot = slots[i % len(slots)]
        device = getattr(slot, "device", None)
        if device is None:
            return measure(i, points)
        import jax

        with jax.default_device(device):
            return measure(i, points)

    return wrapped
