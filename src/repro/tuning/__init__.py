"""Persistent tuning store: context-keyed records of PATSMA search results.

The paper's "Entire Execution" mode re-pays the full evaluation budget every
launch; this package amortizes it across processes.  Results are keyed by a
context fingerprint — (name, input shapes+dtypes, search-space hash, jax
backend, device kind) — and stored in a versioned JSON DB with atomic writes.

* :mod:`repro.tuning.records`    — fingerprints + record schema
* :mod:`repro.tuning.db`         — the on-disk database
* :mod:`repro.tuning.warm_start` — exact-hit replay / neighbor seeding policy
* :mod:`repro.tuning.pretune`    — offline sweep CLI (``python -m repro.tuning.pretune``)
"""
from .db import ENV_DB_PATH, TuningDB, default_db
from .records import (
    SCHEMA_VERSION,
    TuningKey,
    TuningRecord,
    default_device,
    make_key,
    signature_of,
    space_fingerprint,
)
from .warm_start import apply_warm_start, record_from

__all__ = [
    "SCHEMA_VERSION",
    "ENV_DB_PATH",
    "TuningDB",
    "TuningKey",
    "TuningRecord",
    "default_db",
    "default_device",
    "make_key",
    "signature_of",
    "space_fingerprint",
    "apply_warm_start",
    "record_from",
]
