"""The unified tuning surface: persistence, search, and the distributed fleet.

The paper's "Entire Execution" mode re-pays the full evaluation budget every
launch; this package amortizes it across processes — and, with the fleet
layer, across devices and hosts.  Results are keyed by a context fingerprint
— (name, input shapes+dtypes, search-space hash, jax backend, device kind) —
and stored in a versioned JSON DB with atomic writes.

* :mod:`repro.tuning.records`    — fingerprints + record schema
* :mod:`repro.tuning.db`         — the on-disk database
* :mod:`repro.tuning.warm_start` — exact-hit replay / neighbor seeding policy
* :mod:`repro.tuning.fleet`      — sharded pretuning, order-independent DB
  merging, and the :class:`~repro.tuning.fleet.ShardedPortfolio` race
* :mod:`repro.tuning.pretune`    — offline sweep CLI (``python -m repro.tune
  pretune``; ``python -m repro.tuning.pretune`` is a compatibility shim)

This module is also the package's *facade*: the handful of names a tuning
user needs — :class:`Autotuning`, :func:`tune_call`, :func:`make_strategy`,
:class:`MeasurePolicy`, and the fleet entry points — are importable from
``repro.tuning`` directly, whichever layer defines them.  Cross-layer names
resolve lazily (PEP 562): ``repro.kernels`` itself imports ``repro.tuning``,
so eager re-exports would cycle.
"""
from .db import ENV_DB_PATH, RunJournal, TuningDB, default_db
from .fleet import (
    FleetResult,
    MergeStats,
    ShardedPortfolio,
    better_record,
    device_bound_measure,
    merge_dbs,
    merge_records,
    parse_shard,
    record_rank,
)
from .records import (
    SCHEMA_VERSION,
    TuningKey,
    TuningRecord,
    default_device,
    make_key,
    signature_of,
    space_fingerprint,
)
from .warm_start import apply_warm_start, record_from

__all__ = [
    "SCHEMA_VERSION",
    "ENV_DB_PATH",
    "RunJournal",
    "TuningDB",
    "TuningKey",
    "TuningRecord",
    "default_db",
    "default_device",
    "make_key",
    "signature_of",
    "space_fingerprint",
    "apply_warm_start",
    "record_from",
    # fleet layer
    "FleetResult",
    "MergeStats",
    "ShardedPortfolio",
    "better_record",
    "device_bound_measure",
    "merge_dbs",
    "merge_records",
    "parse_shard",
    "record_rank",
    # facade re-exports (lazy: see __getattr__)
    "Autotuning",
    "tune_call",
    "autotuned",
    "make_strategy",
    "MeasurePolicy",
    "local_device_pool",
]

#: facade name -> defining module (resolved on first attribute access —
#: ``repro.kernels.autotuned`` imports this package at its own top level,
#: so these must not be imported eagerly here)
_FACADE = {
    "Autotuning": "repro.core",
    "make_strategy": "repro.core",
    "MeasurePolicy": "repro.core",
    "tune_call": "repro.kernels.autotuned",
    "autotuned": "repro.kernels.autotuned",
    "local_device_pool": "repro.parallel.devices",
}


def __getattr__(name: str):
    mod = _FACADE.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    value = getattr(importlib.import_module(mod), name)
    globals()[name] = value  # cache: next access skips the indirection
    return value


def __dir__():
    return sorted(set(__all__) | set(globals()))
