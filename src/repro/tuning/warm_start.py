"""Warm-start policy: turn a stored tuning record into optimizer state.

Two tiers (wired into :class:`repro.core.autotuning.Autotuning`):

* **Exact hit** — same fingerprint: adopt the stored best outright, zero
  re-measurements (handled by Autotuning; nothing to do here).
* **Near miss** — a neighbor record (same computation + hardware, different
  shapes): seed the optimizer's initial state around the stored point
  (CSA population / NM simplex) and shrink the evaluation budget — starting
  next to a known-good solution is what makes a half-budget search converge.
"""
from __future__ import annotations

from typing import Optional

from .records import TuningRecord

__all__ = ["apply_warm_start", "DEFAULT_BUDGET_FRAC", "DEFAULT_SPREAD"]

#: warm-started searches get half the cold budget (acceptance: ≤ 50% evals)
DEFAULT_BUDGET_FRAC = 0.5
#: normalized-coords radius of the seeded population around the stored point
DEFAULT_SPREAD = 0.2
#: cap on the space-resolution-widened spread (never seed near-globally)
MAX_SPREAD = 0.6


def effective_spread(space, spread: float = DEFAULT_SPREAD) -> float:
    """Widen ``spread`` to at least ~one grid step of the coarsest discrete
    dimension: on a 6-octave ``LogIntDim`` a 0.2 radius is *sub-step* — the
    seeded population would collapse onto the stored point and a half-budget
    re-search could never reach an optimum two octaves away."""
    try:
        step = space.resolution()
    except Exception:
        return spread
    return max(spread, min(MAX_SPREAD, 1.1 * step))


def apply_warm_start(
    space,
    optimizer,
    record: TuningRecord,
    *,
    budget_frac: float = DEFAULT_BUDGET_FRAC,
    spread: float = DEFAULT_SPREAD,
) -> bool:
    """Seed ``optimizer`` around ``record.point`` and shrink its budget.

    Must run before the optimizer's first ``run`` call.  The stored point may
    come from a neighboring context whose space had different bounds —
    ``space.encode`` clips it into the current domain.  Returns True iff the
    optimizer accepted the seed (budget is only shrunk then; a blind search
    keeps its full budget).
    """
    try:
        missing = [n for n in space.names if n not in record.point]
        if missing:
            return False
        z0 = space.encode(record.point)
    except Exception:
        return False  # incompatible point (e.g. renamed dims) → cold start
    if not optimizer.seed(z0, spread=effective_spread(space, spread)):
        return False
    if budget_frac < 1.0:
        optimizer.shrink_budget(budget_frac)
    return True


def record_from(autotuner, key, *, source: str = "online") -> Optional[TuningRecord]:
    """Snapshot an Autotuning run's result as a record (None if nothing found)."""
    import numpy as np

    cost = autotuner.best_cost
    if not np.isfinite(cost):
        # every candidate crashed / was never measured: storing this would
        # replay a broken point as an exact hit forever
        return None
    # measurement confidence of the best point, when the measurement engine
    # delivered it (None for plain-float costs and pre-engine drivers)
    cost_std = repeats_spent = None
    meta_of = getattr(autotuner, "measurement_meta", None)
    if callable(meta_of):
        meta = meta_of()
        if meta is not None:
            cost_std = meta.get("cost_std")
            repeats_spent = meta.get("repeats_spent")
    return TuningRecord(
        key=key,
        point=dict(autotuner.best_point),
        cost=float(cost),
        evals=int(autotuner.num_evals),
        source=source,
        crashed=int(getattr(autotuner, "num_crashed", 0)),
        cost_std=cost_std,
        repeats_spent=repeats_spent,
        strategy=getattr(autotuner, "strategy", None),
        objective=getattr(autotuner, "objective", None),
    )
