"""JSON-on-disk tuning database with atomic writes and corrupt-file recovery.

Layout (versioned)::

    {
      "schema": 1,
      "records": { "<key.encode()>": {record json}, ... }
    }

* **Atomic writes** — saves go through a same-directory temp file + fsync +
  ``os.replace`` so a crash mid-save never corrupts an existing DB, and
  concurrent writers leave one winner, not a splice.
* **Corrupt recovery** — an unreadable/garbage file is moved aside to
  ``<path>.corrupt`` and the DB starts empty instead of crashing the host
  program (tuning is an accelerant, never a point of failure).
* **Schema gating** — a future-schema file is left untouched on disk and
  ignored in memory.

:class:`RunJournal` is the write-ahead companion for long sweeps
(``pretune``): an append-only JSONL file next to the DB recording, per case,
a ``start`` event before measurement and a ``commit``/``failed`` event after
— each append fsynced, torn trailing lines tolerated on load.  A killed run
restarts with ``--resume`` re-measuring nothing already committed, and
``repro.tune db merge`` folds a partial journal like any shard DB.

``default_db()`` gives library call sites (the kernels' ``autotuned`` entry
point) a process-wide DB without plumbing: file-backed when the
``REPRO_TUNING_DB`` env var names a path, otherwise in-memory.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from typing import Optional, Tuple

from repro.obs.log import get_logger

from .records import SCHEMA_VERSION, TuningKey, TuningRecord

log = get_logger(__name__)

__all__ = ["TuningDB", "RunJournal", "default_db"]


def _fsync_dir(path: str) -> None:
    """fsync a directory so a just-renamed/just-created entry is durable —
    without it, a power loss after ``os.replace`` can resurrect the old file
    (the rename lived only in the directory's page cache).  Best-effort:
    platforms that cannot open directories (Windows) skip silently."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - non-POSIX
        return
    try:
        os.fsync(fd)
    except OSError:  # pragma: no cover - fs without dir fsync
        pass
    finally:
        os.close(fd)

#: env var naming the process-default DB file
ENV_DB_PATH = "REPRO_TUNING_DB"


class TuningDB:
    """Context-keyed store of :class:`TuningRecord`.  ``path=None`` → in-memory."""

    def __init__(self, path: Optional[str] = None, *, autosave: bool = True) -> None:
        self.path = os.fspath(path) if path is not None else None
        self.autosave = autosave
        self._lock = threading.Lock()
        self._records: dict = {}  # encoded key -> TuningRecord
        if self.path is not None:
            self.load()

    # ----------------------------------------------------------------- io
    def load(self) -> int:
        """(Re)load from disk; returns the number of records loaded."""
        if self.path is None or not os.path.exists(self.path):
            return 0
        try:
            with open(self.path, "r", encoding="utf-8") as f:
                blob = json.load(f)
            if not isinstance(blob, dict) or "records" not in blob:
                raise ValueError("not a tuning DB")
            if int(blob.get("schema", -1)) > SCHEMA_VERSION:
                log.warning(
                    "%s: schema %s is newer than supported (%s); ignoring file",
                    self.path, blob.get("schema"), SCHEMA_VERSION,
                )
                return 0
            records = {}
            for k, rj in blob["records"].items():
                records[k] = TuningRecord.from_json(rj)
            with self._lock:
                self._records = records
            return len(records)
        except Exception as e:  # corrupted → quarantine and start fresh
            backup = self.path + ".corrupt"
            try:
                os.replace(self.path, backup)
                note = f"moved to {backup}"
            except OSError:
                note = "could not quarantine"
            log.warning(
                "%s: unreadable (%r); %s; starting empty", self.path, e, note
            )
            with self._lock:
                self._records = {}
            return 0

    def save(self) -> None:
        """Atomic write (temp file in the same directory + os.replace)."""
        if self.path is None:
            return
        with self._lock:
            blob = {
                "schema": SCHEMA_VERSION,
                "records": {k: r.to_json() for k, r in sorted(self._records.items())},
            }
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(prefix=".tuningdb-", dir=d)
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(blob, f, indent=1, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
            # durability needs the *rename* on disk too, not just the bytes:
            # fsync the containing directory or a crash can resurrect the
            # old file contents
            _fsync_dir(d)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # ------------------------------------------------------------- queries
    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    def keys(self) -> list:
        with self._lock:
            return list(self._records)

    def records(self) -> list:
        with self._lock:
            return list(self._records.values())

    def get(self, key: TuningKey) -> Optional[TuningRecord]:
        with self._lock:
            return self._records.get(key.encode())

    def nearest(self, key: TuningKey) -> Optional[TuningRecord]:
        """Closest warm-start neighbor: same computation + hardware, nearest
        array shapes by log distance (see :meth:`TuningKey.distance`)."""
        best, best_d = None, float("inf")
        for rec in self.records():  # snapshot: concurrent put() must not race
            d = key.distance(rec.key)
            if d < best_d:
                best, best_d = rec, d
        return best

    def lookup(self, key: TuningKey) -> Tuple[Optional[TuningRecord], bool]:
        """(record, exact).  Exact hit → replay with zero re-measurement;
        neighbor hit → seed the search around the stored point."""
        rec = self.get(key)
        if rec is not None:
            return rec, True
        return self.nearest(key), False

    # ------------------------------------------------------------- updates
    def put(self, record: TuningRecord, *, save: Optional[bool] = None) -> None:
        """Insert/overwrite; persists immediately when file-backed (autosave)."""
        with self._lock:
            self._records[record.key.encode()] = record
        if save if save is not None else (self.autosave and self.path is not None):
            self.save()

    def merge(self, other: "TuningDB", *, prefer_lower_cost: bool = True) -> int:
        """Fold another DB in; returns the number of records adopted.

        Conflicts resolve through the fleet merge resolver
        (:func:`repro.tuning.fleet.better_record`): the keep-better rule of
        ``Autotuning.commit()`` linearized into a total order — lower cost
        wins, and inside the noise band the better-measured (lower-variance)
        record stands, so folding shard DBs is associative and
        order-independent.  ``prefer_lower_cost=False`` adopts every
        incoming record unconditionally (a forced overwrite, not a merge)."""
        from .fleet import better_record

        n = 0
        for rec in other.records():
            mine = self.get(rec.key)
            if (
                mine is None
                or not prefer_lower_cost
                or better_record(mine, rec) is rec
            ):
                self.put(rec, save=False)
                n += 1
        if self.autosave and self.path is not None:
            self.save()
        return n


# ----------------------------------------------------------- run journal
class RunJournal:
    """Append-only write-ahead journal for a tuning sweep.

    One JSONL event per line, each append flushed *and fsynced* before the
    sweep proceeds — the journal is the authority on which cases completed,
    so it must hit the disk before the work it describes is assumed done:

    * ``{"event": "start",  "key": <encoded>}`` — measurement is about to
      begin for this case; a start with no matching commit/failed marks a
      run that died mid-measurement (*interrupted*).
    * ``{"event": "commit", "key": <encoded>, "record": {...}}`` — the
      case's committed :class:`TuningRecord` (full JSON, so a journal alone
      can reconstruct a DB — ``repro.tune db merge`` accepts journals as
      sources).
    * ``{"event": "failed", "key": <encoded>, "error": "..."}`` — the case
      completed with no record (e.g. every candidate crashed).  Resumes skip
      it rather than re-dying.
    * ``{"event": "resume"}`` — a ``--resume`` run re-attached.

    Loading tolerates a torn trailing line (power loss mid-append): the
    dangling tail is treated as absent, never as corruption of the whole
    journal.  The conventional location is :meth:`path_for` (``<db>.journal``).
    """

    def __init__(self, path: str) -> None:
        self.path = os.fspath(path)
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)

    @staticmethod
    def path_for(db_path: str) -> str:
        """The conventional journal location for a DB file."""
        return os.fspath(db_path) + ".journal"

    # ------------------------------------------------------------- writing
    def append(self, event: dict) -> None:
        """Durably append one event (fsync before returning; on a fresh
        journal the containing directory is fsynced too so the file itself
        survives a crash)."""
        event = dict(event)
        event.setdefault("ts", time.time())  # shard liveness (obs report)
        line = json.dumps(event, sort_keys=True, default=repr)
        fresh = not os.path.exists(self.path)
        with open(self.path, "a", encoding="utf-8") as f:
            f.write(line + "\n")
            f.flush()
            os.fsync(f.fileno())
        if fresh:
            _fsync_dir(os.path.dirname(os.path.abspath(self.path)))

    def start(self, key) -> None:
        self.append({"event": "start", "key": self._enc(key)})

    def commit(self, key, record: TuningRecord) -> None:
        self.append(
            {"event": "commit", "key": self._enc(key), "record": record.to_json()}
        )

    def failed(self, key, error: BaseException | str) -> None:
        self.append({"event": "failed", "key": self._enc(key), "error": str(error)})

    def resume(self) -> None:
        self.append({"event": "resume"})

    @staticmethod
    def _enc(key) -> str:
        return key.encode() if isinstance(key, TuningKey) else str(key)

    # ------------------------------------------------------------- reading
    def events(self) -> list:
        """Parsed events, in order.  A line that fails to parse ends the
        journal (append-only: anything after a torn line is unreachable
        anyway); the cut is reported once on stderr."""
        if not os.path.exists(self.path):
            return []
        out: list = []
        with open(self.path, "r", encoding="utf-8") as f:
            for i, line in enumerate(f):
                line = line.strip()
                if not line:
                    continue
                try:
                    ev = json.loads(line)
                except ValueError:
                    log.warning(
                        "%s: torn/garbled journal line %d; keeping the %d "
                        "events before it", self.path, i + 1, len(out)
                    )
                    break
                if isinstance(ev, dict) and "event" in ev:
                    out.append(ev)
        return out

    def summary(self) -> dict:
        """Digest of the journal's state::

            {"committed": {key: record_json}, "failed": {key, ...},
             "interrupted": {key, ...}, "resumes": int}

        ``interrupted`` = started but neither committed nor failed — the
        cases a killed run was measuring; a resume re-runs exactly these
        (plus never-started ones) and re-measures nothing committed."""
        committed: dict = {}
        failed: set = set()
        started: set = set()
        resumes = 0
        for ev in self.events():
            kind = ev.get("event")
            key = ev.get("key")
            if kind == "start" and key is not None:
                started.add(key)
            elif kind == "commit" and key is not None:
                committed[key] = ev.get("record")
                failed.discard(key)
            elif kind == "failed" and key is not None:
                if key not in committed:
                    failed.add(key)
            elif kind == "resume":
                resumes += 1
        return {
            "committed": committed,
            "failed": failed,
            "interrupted": started - set(committed) - failed,
            "resumes": resumes,
        }

    @staticmethod
    def is_journal(path: str) -> bool:
        """Sniff: does ``path`` look like a run journal (first non-empty
        line a JSON object with an ``"event"`` key)?  Lets CLI commands
        accept DB files and journals interchangeably."""
        try:
            with open(path, "r", encoding="utf-8") as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    ev = json.loads(line)
                    return isinstance(ev, dict) and "event" in ev
        except (OSError, ValueError):
            return False
        return False

    def to_db(self) -> TuningDB:
        """An in-memory :class:`TuningDB` of the journal's committed
        records — the shape ``merge_dbs`` folds."""
        db = TuningDB(path=None)
        for rec_json in self.summary()["committed"].values():
            if rec_json is None:
                continue
            try:
                db.put(TuningRecord.from_json(rec_json), save=False)
            except Exception as e:
                log.warning(
                    "%s: unreadable committed record (%r); skipping",
                    self.path, e,
                )
        return db


_default: Optional[TuningDB] = None
_default_lock = threading.Lock()


def default_db() -> TuningDB:
    """Process-wide DB: file-backed iff ``REPRO_TUNING_DB`` is set."""
    global _default
    with _default_lock:
        if _default is None:
            _default = TuningDB(os.environ.get(ENV_DB_PATH) or None)
        return _default
