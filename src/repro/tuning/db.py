"""JSON-on-disk tuning database with atomic writes and corrupt-file recovery.

Layout (versioned)::

    {
      "schema": 1,
      "records": { "<key.encode()>": {record json}, ... }
    }

* **Atomic writes** — saves go through a same-directory temp file + fsync +
  ``os.replace`` so a crash mid-save never corrupts an existing DB, and
  concurrent writers leave one winner, not a splice.
* **Corrupt recovery** — an unreadable/garbage file is moved aside to
  ``<path>.corrupt`` and the DB starts empty instead of crashing the host
  program (tuning is an accelerant, never a point of failure).
* **Schema gating** — a future-schema file is left untouched on disk and
  ignored in memory.

``default_db()`` gives library call sites (the kernels' ``autotuned`` entry
point) a process-wide DB without plumbing: file-backed when the
``REPRO_TUNING_DB`` env var names a path, otherwise in-memory.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import threading
from typing import Optional, Tuple

from .records import SCHEMA_VERSION, TuningKey, TuningRecord

__all__ = ["TuningDB", "default_db"]

#: env var naming the process-default DB file
ENV_DB_PATH = "REPRO_TUNING_DB"


class TuningDB:
    """Context-keyed store of :class:`TuningRecord`.  ``path=None`` → in-memory."""

    def __init__(self, path: Optional[str] = None, *, autosave: bool = True) -> None:
        self.path = os.fspath(path) if path is not None else None
        self.autosave = autosave
        self._lock = threading.Lock()
        self._records: dict = {}  # encoded key -> TuningRecord
        if self.path is not None:
            self.load()

    # ----------------------------------------------------------------- io
    def load(self) -> int:
        """(Re)load from disk; returns the number of records loaded."""
        if self.path is None or not os.path.exists(self.path):
            return 0
        try:
            with open(self.path, "r", encoding="utf-8") as f:
                blob = json.load(f)
            if not isinstance(blob, dict) or "records" not in blob:
                raise ValueError("not a tuning DB")
            if int(blob.get("schema", -1)) > SCHEMA_VERSION:
                print(
                    f"[tuning] {self.path}: schema {blob.get('schema')} is newer than "
                    f"supported ({SCHEMA_VERSION}); ignoring file",
                    file=sys.stderr,
                )
                return 0
            records = {}
            for k, rj in blob["records"].items():
                records[k] = TuningRecord.from_json(rj)
            with self._lock:
                self._records = records
            return len(records)
        except Exception as e:  # corrupted → quarantine and start fresh
            backup = self.path + ".corrupt"
            try:
                os.replace(self.path, backup)
                note = f"moved to {backup}"
            except OSError:
                note = "could not quarantine"
            print(
                f"[tuning] {self.path}: unreadable ({e!r}); {note}; starting empty",
                file=sys.stderr,
            )
            with self._lock:
                self._records = {}
            return 0

    def save(self) -> None:
        """Atomic write (temp file in the same directory + os.replace)."""
        if self.path is None:
            return
        with self._lock:
            blob = {
                "schema": SCHEMA_VERSION,
                "records": {k: r.to_json() for k, r in sorted(self._records.items())},
            }
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(prefix=".tuningdb-", dir=d)
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(blob, f, indent=1, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # ------------------------------------------------------------- queries
    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    def keys(self) -> list:
        with self._lock:
            return list(self._records)

    def records(self) -> list:
        with self._lock:
            return list(self._records.values())

    def get(self, key: TuningKey) -> Optional[TuningRecord]:
        with self._lock:
            return self._records.get(key.encode())

    def nearest(self, key: TuningKey) -> Optional[TuningRecord]:
        """Closest warm-start neighbor: same computation + hardware, nearest
        array shapes by log distance (see :meth:`TuningKey.distance`)."""
        best, best_d = None, float("inf")
        for rec in self.records():  # snapshot: concurrent put() must not race
            d = key.distance(rec.key)
            if d < best_d:
                best, best_d = rec, d
        return best

    def lookup(self, key: TuningKey) -> Tuple[Optional[TuningRecord], bool]:
        """(record, exact).  Exact hit → replay with zero re-measurement;
        neighbor hit → seed the search around the stored point."""
        rec = self.get(key)
        if rec is not None:
            return rec, True
        return self.nearest(key), False

    # ------------------------------------------------------------- updates
    def put(self, record: TuningRecord, *, save: Optional[bool] = None) -> None:
        """Insert/overwrite; persists immediately when file-backed (autosave)."""
        with self._lock:
            self._records[record.key.encode()] = record
        if save if save is not None else (self.autosave and self.path is not None):
            self.save()

    def merge(self, other: "TuningDB", *, prefer_lower_cost: bool = True) -> int:
        """Fold another DB in; returns the number of records adopted.

        Conflicts resolve through the fleet merge resolver
        (:func:`repro.tuning.fleet.better_record`): the keep-better rule of
        ``Autotuning.commit()`` linearized into a total order — lower cost
        wins, and inside the noise band the better-measured (lower-variance)
        record stands, so folding shard DBs is associative and
        order-independent.  ``prefer_lower_cost=False`` adopts every
        incoming record unconditionally (a forced overwrite, not a merge)."""
        from .fleet import better_record

        n = 0
        for rec in other.records():
            mine = self.get(rec.key)
            if (
                mine is None
                or not prefer_lower_cost
                or better_record(mine, rec) is rec
            ):
                self.put(rec, save=False)
                n += 1
        if self.autosave and self.path is not None:
            self.save()
        return n


_default: Optional[TuningDB] = None
_default_lock = threading.Lock()


def default_db() -> TuningDB:
    """Process-wide DB: file-backed iff ``REPRO_TUNING_DB`` is set."""
    global _default
    with _default_lock:
        if _default is None:
            _default = TuningDB(os.environ.get(ENV_DB_PATH) or None)
        return _default
