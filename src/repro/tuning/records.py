"""Context fingerprints and tuning records.

A tuning result is only reusable inside the context it was measured in; the
fingerprint captures that context:

    (name, input signature, search-space hash, jax backend, device kind[, extra])

* ``name``       — the kernel / step being tuned ("matmul", "train_step/qwen2_7b").
* ``signature``  — canonical shapes+dtypes of the call's array arguments (plus
  any static scalars); different shapes are different keys.
* ``space_hash`` — hash of the search-space *structure* (dim kinds, names,
  bounds).  A changed space invalidates stored points.
* ``backend`` / ``device_kind`` — a block size tuned on a TPU v5e says nothing
  about CPU interpret mode.
* ``extra``      — free-form context a caller wants keyed (global batch, ...).

Keys must be stable **across processes** (they are the on-disk dict keys), so
everything is canonical JSON + sha256 — never Python ``hash()``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Any, Mapping, Optional, Sequence

__all__ = [
    "SCHEMA_VERSION",
    "TuningKey",
    "TuningRecord",
    "make_key",
    "signature_of",
    "space_fingerprint",
    "default_device",
]

#: bump when the on-disk layout of records/keys changes incompatibly
SCHEMA_VERSION = 1


# ------------------------------------------------------------- fingerprints
def space_fingerprint(space) -> str:
    """Stable hash of a SearchSpace's structure (kind, name, bounds per dim)."""
    spec = []
    for d in space.dims:
        fields = {f.name: getattr(d, f.name) for f in dataclasses.fields(d)}
        spec.append({"kind": type(d).__name__, **fields})
    # validity predicates shrink the feasible region, so they are part of the
    # context; constraint-free spaces hash exactly as before (stored kernel
    # keys stay valid)
    if getattr(space, "constraints", ()):
        spec.append({"kind": "constraints", "names": [c.name for c in space.constraints]})
    blob = json.dumps(spec, sort_keys=True, default=repr, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _sig_entry(v: Any):
    if hasattr(v, "shape") and hasattr(v, "dtype"):  # jax / numpy arrays
        return ["array", str(v.dtype), [int(s) for s in v.shape]]
    if isinstance(v, (bool, int, float, str)) or v is None:
        return ["py", repr(v)]
    return ["py", f"<{type(v).__name__}>"]


def signature_of(args: Sequence[Any] = (), kwargs: Optional[Mapping[str, Any]] = None):
    """Canonical, JSON-able signature of a call's inputs."""
    sig = [_sig_entry(v) for v in args]
    for k in sorted(kwargs or {}):
        sig.append([k, _sig_entry(kwargs[k])])
    return sig


def default_device() -> tuple:
    """(backend, device_kind) of the current process's default jax device."""
    try:
        import jax

        return str(jax.default_backend()), str(jax.devices()[0].device_kind)
    except Exception:
        return "none", "unknown"


def _canon(x: Any) -> str:
    return json.dumps(x, sort_keys=True, default=repr, separators=(",", ":"))


# --------------------------------------------------------------------- keys
@dataclasses.dataclass(frozen=True)
class TuningKey:
    """Context fingerprint.  ``encode()`` is the canonical string form used as
    the on-disk dict key."""

    name: str
    signature: str  # canonical JSON (string so the dataclass stays hashable)
    space_hash: str
    backend: str
    device_kind: str
    extra: str = "{}"  # canonical JSON of caller-supplied context

    def encode(self) -> str:
        return "|".join(
            [
                f"v{SCHEMA_VERSION}",
                self.name,
                self.signature,
                self.space_hash,
                self.backend,
                self.device_kind,
                self.extra,
            ]
        )

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Mapping[str, Any]) -> "TuningKey":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)})

    def shard(self, num_shards: int) -> int:
        """Deterministic shard assignment for fleet pretuning.

        A stable hash (sha256 of the canonical encoding — never Python
        ``hash()``, which is salted per process) of the full fingerprint,
        reduced mod ``num_shards``: every worker of a fleet computes the
        same shard for the same context with **zero coordination**, so
        ``pretune --shard i/n`` partitions the grid without a scheduler."""
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        h = hashlib.sha256(self.encode().encode()).digest()
        return int.from_bytes(h[:8], "big") % num_shards

    # --------------------------------------------------- neighbor matching
    def shapes(self) -> Optional[list]:
        """Array shapes in the signature, or None if it has none.  Memoized:
        ``nearest()`` calls this once per stored record per lookup."""
        try:
            return self._shapes_memo
        except AttributeError:
            pass
        try:
            sig = json.loads(self.signature)
            out = [e[2] for e in sig if isinstance(e, list) and e and e[0] == "array"]
            out = out or None
        except Exception:
            out = None
        # frozen dataclass: bypass the immutability guard for the cache slot
        object.__setattr__(self, "_shapes_memo", out)
        return out

    def distance(self, other: "TuningKey") -> float:
        """Log-scale shape distance to a candidate warm-start neighbor.

        Finite only for keys that describe *the same computation on the same
        hardware in the same execution context* (name, backend, device kind,
        extra — so e.g. interpreter-mode timings never warm-start compiled
        dispatch) with structurally matching signatures; then it is the summed
        |log2| ratio of array dims — the natural metric for block-size spaces,
        where good tiles move with the problem size by powers of two.
        ``space_hash`` may differ: neighbor shapes clamp the space bounds, and
        the warm-start path re-encodes the point into the current domain."""
        import math

        if (self.name, self.backend, self.device_kind, self.extra) != (
            other.name,
            other.backend,
            other.device_kind,
            other.extra,
        ):
            return math.inf
        a, b = self.shapes(), other.shapes()
        if a is None or b is None or len(a) != len(b):
            return math.inf
        d = 0.0
        for sa, sb in zip(a, b):
            if len(sa) != len(sb):
                return math.inf
            for xa, xb in zip(sa, sb):
                if xa <= 0 or xb <= 0:
                    return math.inf
                d += abs(math.log2(xa / xb))
        return d


def make_key(
    name: str,
    *,
    args: Sequence[Any] = (),
    kwargs: Optional[Mapping[str, Any]] = None,
    space=None,
    extra: Optional[Mapping[str, Any]] = None,
    backend: Optional[str] = None,
    device_kind: Optional[str] = None,
) -> TuningKey:
    """Build the context fingerprint for one tuning site."""
    if backend is None or device_kind is None:
        b, dk = default_device()
        backend = backend if backend is not None else b
        device_kind = device_kind if device_kind is not None else dk
    return TuningKey(
        name=name,
        signature=_canon(signature_of(args, kwargs)),
        space_hash=space_fingerprint(space) if space is not None else "-",
        backend=backend,
        device_kind=device_kind,
        extra=_canon(dict(extra or {})),
    )


# ------------------------------------------------------------------ records
@dataclasses.dataclass
class TuningRecord:
    """One persisted tuning result: the best point found for a context key.

    ``cost_std`` / ``repeats_spent`` carry the measurement confidence of the
    stored cost (standard deviation over the repetitions the measurement
    engine actually spent on the best point).  ``strategy`` is the search
    strategy spec that produced the record (``"csa"``, ``"csa+nm"``,
    ``"csa|nm"``, ... — see :func:`repro.core.strategy.make_strategy`);
    ``objective`` is the statistic the stored cost minimizes (``"median"``,
    ``"p95"``, ``"p99"`` — see :data:`repro.core.measure.OBJECTIVES`), so a
    p99-tuned record is never mistaken for a median cost.  All of these are
    optional: records written before the fields existed — and costs
    delivered by user cost functions — load as ``None``, which every
    consumer must treat as "unknown"."""

    key: TuningKey
    point: dict
    cost: float
    evals: int = 0
    source: str = "online"  # "online" | "pretune"
    created: float = dataclasses.field(default_factory=time.time)
    crashed: int = 0  # distinct candidates that failed during the search
    cost_std: Optional[float] = None  # std over the best point's measured reps
    repeats_spent: Optional[int] = None  # reps behind the stored cost
    strategy: Optional[str] = None  # search strategy spec behind the record
    objective: Optional[str] = None  # statistic the stored cost minimizes

    def known_std(self) -> Optional[float]:
        """The record's measured standard deviation, or ``None`` when it
        carries no *meaningful* confidence — absent fields (pre-engine
        records) and single-rep measurements (whose std of 0.0 is unknown,
        not perfect).  The shared definition behind ``commit()``'s near-tie
        guard and the fleet merge resolver."""
        if self.cost_std is None or (self.repeats_spent or 0) <= 1:
            return None
        return float(self.cost_std)

    def to_json(self) -> dict:
        return {
            "key": self.key.to_json(),
            "point": self.point,
            "cost": self.cost,
            "evals": self.evals,
            "source": self.source,
            "created": self.created,
            "crashed": self.crashed,
            "cost_std": self.cost_std,
            "repeats_spent": self.repeats_spent,
            "strategy": self.strategy,
            "objective": self.objective,
        }

    @classmethod
    def from_json(cls, d: Mapping[str, Any]) -> "TuningRecord":
        cost_std = d.get("cost_std")
        repeats_spent = d.get("repeats_spent")
        strategy = d.get("strategy")
        objective = d.get("objective")
        return cls(
            key=TuningKey.from_json(d["key"]),
            point=dict(d["point"]),
            cost=float(d["cost"]),
            evals=int(d.get("evals", 0)),
            source=str(d.get("source", "online")),
            created=float(d.get("created", 0.0)),
            crashed=int(d.get("crashed", 0)),
            cost_std=float(cost_std) if cost_std is not None else None,
            repeats_spent=int(repeats_spent) if repeats_spent is not None else None,
            strategy=str(strategy) if strategy is not None else None,
            objective=str(objective) if objective is not None else None,
        )
