"""repro.runtime — keep a *running* program tuned.

PR 1 made tuning results persistent (the DB), PR 2 made a single search
fast (batched ask/tell + AOT fan-out); this package makes tuning *live*,
the paper's runtime-mode claim at serving scale:

* :mod:`repro.runtime.context` — :class:`ContextRouter`: buckets live calls
  into tuning contexts (name × pow2 shape-bucket × caller extra, reusing
  ``TuningKey`` fingerprints) and dispatches each at its current best.
* :mod:`repro.runtime.online` — :class:`OnlineTuner`: streams an ε-rationed
  fraction of real request timings into the ask/tell search, compiling
  candidates off-thread so serving never blocks on XLA.
* :mod:`repro.runtime.drift` — :class:`DriftDetector`: sliding-window cost
  statistics over the exploit stream; degradation triggers
  ``Autotuning.reset(level)`` + a half-budget warm re-search, recommitted
  to the DB with ``source="online"``.
* :mod:`repro.runtime.driver` — the fault-tolerant training driver
  (:class:`TrainJob`, :class:`Watchdog`), now with a ``runtime="adaptive"``
  mode that delegates drift handling to the online tuner.

``TrainJob``/``Watchdog`` import the full model stack, so they load lazily;
the online-tuning classes above are light (numpy + repro.core only).
"""
from .context import ContextRouter, RouteSpec, bucket_args, pow2_bucket
from .drift import DriftDetector
from .online import EXPLOIT, EXPLORE, Decision, OnlineTuner

__all__ = [
    "ContextRouter",
    "RouteSpec",
    "pow2_bucket",
    "bucket_args",
    "DriftDetector",
    "OnlineTuner",
    "Decision",
    "EXPLORE",
    "EXPLOIT",
    "TrainJob",
    "Watchdog",
]

_DRIVER_NAMES = ("TrainJob", "Watchdog")


def __getattr__(name):  # lazy: driver pulls in models/optim/train
    if name in _DRIVER_NAMES:
        from . import driver

        return getattr(driver, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
