"""Runtime: fault-tolerant training driver, watchdog, elastic restore."""
from .driver import TrainJob, Watchdog

__all__ = ["TrainJob", "Watchdog"]
