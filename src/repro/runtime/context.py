"""ContextRouter — bucket live calls into tuning contexts and dispatch each
at that context's current-best knobs.

A *context* is what a tuning result is valid for: (route name ×
shape-bucket × caller extra such as batch size), fingerprinted with the
same :class:`repro.tuning.TuningKey` machinery the persistent DB uses — so
pretuned records exact-hit router contexts, near-miss records warm-start
them, and whatever the router learns online commits straight back.

Shapes are bucketed to the next power of two (:func:`pow2_bucket`) before
fingerprinting: a decode call at sequence length 1000 and one at 1024 share
knobs (good tiles move with the problem size by powers of two — the same
assumption behind ``TuningKey.distance``), while 64 → 128 opens a fresh
context.  Exact shapes still key the *executables* (an XLA artifact is
shape-exact); only the knob search is shared across a bucket.

Each context owns an :class:`~repro.runtime.online.OnlineTuner` (created
lazily on first sight, DB-warm-started) with its own
:class:`~repro.runtime.drift.DriftDetector`; the router is the front door::

    router = ContextRouter(db=TuningDB("tuned/serve.json"))
    router.register("decode", space=lambda *a: SearchSpace([...]),
                    build=compile_decode_step, epsilon=0.1)
    ...
    d = router.begin("decode", token_batch)      # knobs for THIS request
    out = d.executable(token_batch) if d.executable else fallback(d.point)
    router.observe(d, measured_seconds)          # feeds search / drift

``begin``/``observe`` are **thread-safe** and lock-light on the hot path:
the exact-signature fast path reads one immutable dispatch snapshot (a dict
swapped atomically whenever a context is created — no lock, no contention at
any thread count), and the slow path (first sight of a signature, context
creation) runs under the router lock while per-context state transitions are
striped onto each tuner's own lock.  Compiles happen off-thread inside the
tuners (see :mod:`repro.runtime.online`); ``begin(..., tenant=)`` threads
per-tenant ε-credit accounting through to the context's tuner.
"""
from __future__ import annotations

import dataclasses
import json
import threading
from typing import Any, Callable, Mapping, Optional

from repro.core import Autotuning, ExecutableCache
from repro.core.measure import objective_quantile, resolve_measure_policy
from repro.core.optimizer import NumericalOptimizer
from repro.obs import metrics as _metrics

from .drift import DriftDetector
from .online import Decision, OnlineTuner

__all__ = ["ContextRouter", "RouteSpec", "pow2_bucket", "bucket_args"]


# ----------------------------------------------------------- shape bucketing
def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n (n >= 1); the canonical shape bucket."""
    n = int(n)
    if n <= 1:
        return 1
    return 1 << (n - 1).bit_length()


class _BucketedArray:
    """Shape/dtype proxy standing in for an array when fingerprinting a
    bucketed context (``signature_of`` only reads these two attributes)."""

    __slots__ = ("shape", "dtype")

    def __init__(self, shape: tuple, dtype: Any) -> None:
        self.shape = tuple(shape)
        self.dtype = dtype


def bucket_args(
    args=(), kwargs: Optional[Mapping[str, Any]] = None,
    bucket: Callable[[int], int] = pow2_bucket,
):
    """Replace every array in a call's arguments by a proxy whose dims are
    bucketed; non-array values pass through.  Returns ``(args, kwargs)``."""

    def one(v):
        if hasattr(v, "shape") and hasattr(v, "dtype"):
            return _BucketedArray([bucket(int(d)) for d in v.shape], v.dtype)
        return v

    return tuple(one(v) for v in args), {k: one(v) for k, v in (kwargs or {}).items()}


# ----------------------------------------------------------------- registry
@dataclasses.dataclass
class RouteSpec:
    """How to tune one route (a kernel, a decode step, ...).

    ``space``/``defaults``/``build`` receive the live call's arguments, so
    knob domains follow the request shapes exactly as the kernel registry's
    specs do.  ``drift=None`` disables drift detection for the route;
    otherwise the dict is passed to :class:`DriftDetector`.  ``measure``
    (a :class:`~repro.core.measure.MeasurePolicy` or ``"adaptive"`` /
    ``"fixed"``) turns on multi-repetition explore racing in the route's
    tuners; ``None`` keeps one request per candidate.  ``strategy`` is a
    search-strategy spec string (``"csa+nm"``, ``"csa|nm"``, ... — see
    :func:`repro.core.strategy.make_strategy`) used to build each context's
    search; with a staged strategy, environment drift (level 1) re-tunes
    through the refinement stage alone.  ``optimizer`` (a ``space -> opt``
    factory) overrides it.  ``breaker`` (kwargs dict for a
    :class:`~repro.core.guard.CircuitBreaker`, e.g. ``{"threshold": 3,
    "cooldown": 8}``) arms per-context explore gating: each context gets its
    own breaker, so one failing shape-bucket stops burning ε-credits without
    suspending its healthy siblings; ``None`` disables gating.
    """

    name: str
    space: Callable  # (*args, **kwargs) -> SearchSpace
    build: Optional[Callable] = None  # (point, *args, **kwargs) -> executable
    defaults: Optional[Callable] = None  # (*args, **kwargs) -> dict
    epsilon: float = 0.1
    ignore: int = 0
    num_opt: int = 3
    max_iter: int = 4
    seed: int = 0
    optimizer: Optional[Callable[..., NumericalOptimizer]] = None  # (space) -> opt
    strategy: Optional[str] = None  # strategy spec (make_strategy grammar)
    drift: Optional[dict] = dataclasses.field(default_factory=dict)
    extra: dict = dataclasses.field(default_factory=dict)
    measure: Any = None  # explore repetition policy (None = classic)
    breaker: Optional[dict] = None  # CircuitBreaker kwargs (None = no gating)


class ContextRouter:
    """Maps live calls onto per-context :class:`OnlineTuner` instances.

    One router per process (or per serving component); contexts are created
    lazily as traffic reveals them and warm-start from ``db`` — an exact
    fingerprint hit serves the stored best from the first request with zero
    exploration, a neighbor record seeds a half-budget search.
    """

    def __init__(
        self,
        *,
        db=None,
        cache: Optional[ExecutableCache] = None,
        jobs: int = 1,
        bucket: Callable[[int], int] = pow2_bucket,
        db_source: str = "online",
        warm_start: bool = True,
    ) -> None:
        self.db = db
        # like OnlineTuner's default: never memoize build failures — a
        # transient compile error must not poison a candidate for the
        # process lifetime (callers with a failure classifier, e.g. the
        # kernel layer's _EXEC_CACHE, pass their own cache)
        self.cache = cache if cache is not None else ExecutableCache(
            cache_failures=lambda e: False
        )
        self._jobs = max(1, int(jobs))
        self._bucket = bucket
        self._db_source = str(db_source)
        self._warm_start = bool(warm_start)
        self._specs: dict = {}
        # router lock guards the slow path only (registration, context /
        # fast-path-snapshot creation); the hot path never takes it
        self._lock = threading.RLock()
        self._tuners: dict = {}  # encoded TuningKey -> OnlineTuner
        # exact call signature -> OnlineTuner: an IMMUTABLE snapshot.  Reads
        # are lock-free (reference load); updates copy-on-write under the
        # router lock and swap the reference atomically.
        self._fast: dict = {}
        self._fast_max = 4096  # bound: naturally varied exact shapes on a
        # long-lived server must not grow the memo forever (rebuild is one
        # make_key, so wholesale clearing is cheap)

    # ---------------------------------------------------------- registration
    def register(self, name: str, **fields) -> RouteSpec:
        """Register a route; ``fields`` are :class:`RouteSpec` fields."""
        spec = RouteSpec(name=name, **fields)
        with self._lock:
            self._specs[name] = spec
        return spec

    def spec(self, name: str) -> RouteSpec:
        try:
            return self._specs[name]
        except KeyError:
            raise KeyError(
                f"unknown route {name!r}; registered: {sorted(self._specs)}"
            ) from None

    # ------------------------------------------------------------- contexts
    def context_key(self, name: str, args=(), kwargs=None, extra=None, space=None):
        """The bucketed :class:`TuningKey` fingerprint of one call context.

        Both the signature *and* the search space come from the bucketed
        shapes: every exact shape in a bucket must map to the identical
        fingerprint (and knob domain), or contexts would fragment by
        whichever exact shape arrived first and pretuned pow2 records could
        never exact-hit non-pow2 traffic."""
        from repro.tuning import make_key

        spec = self.spec(name)
        kwargs = kwargs or {}
        b_args, b_kwargs = bucket_args(args, kwargs, self._bucket)
        if space is None:
            space = spec.space(*b_args, **b_kwargs)
        return make_key(
            name, args=b_args, kwargs=b_kwargs, space=space,
            extra={**spec.extra, **(extra or {})},
        )

    def _call_sig(self, name, args, kwargs, extra):
        try:
            if extra:
                try:  # common case: flat dict of hashable scalars — no
                    e = tuple(sorted(extra.items()))  # json round-trip
                    hash(e)
                except TypeError:
                    e = json.dumps(dict(extra), sort_keys=True, default=repr)
            else:
                e = ()
            parts = [name, e]
            for src in (args, sorted((kwargs or {}).items())):
                for v in src:
                    if hasattr(v, "shape") and hasattr(v, "dtype"):
                        parts.append(("a", tuple(int(d) for d in v.shape), str(v.dtype)))
                    else:
                        parts.append(("p", repr(v)))
            return tuple(parts)
        except Exception:
            return None

    def tuner(self, name: str, *args, extra=None, **kwargs) -> OnlineTuner:
        """The (lazily created) tuner owning this call's context.

        Hot path: one signature build + one lock-free dict read against the
        immutable dispatch snapshot.  Slow path (snapshot miss): context
        lookup/creation under the router lock, then a copy-on-write snapshot
        swap so subsequent calls for this signature go lock-free."""
        sig = self._call_sig(name, args, kwargs, extra)
        if sig is not None:
            t = self._fast.get(sig)  # immutable snapshot: no lock
            if t is not None:
                return t
        with self._lock:
            return self._tuner_slow(name, sig, args, kwargs, extra)

    def _tuner_slow(self, name, sig, args, kwargs, extra) -> OnlineTuner:
        if sig is not None:
            t = self._fast.get(sig)  # re-check: another thread raced us here
            if t is not None:
                return t
        spec = self.spec(name)
        b_args, b_kwargs = bucket_args(args, kwargs, self._bucket)
        # knob domain from the bucketed shapes (shared across the bucket);
        # candidates that turn out illegal for an off-bucket *exact* shape
        # fail their build and are absorbed as inf by the tuner
        space = spec.space(*b_args, **b_kwargs)
        key = self.context_key(name, args, kwargs, extra=extra, space=space)
        enc = key.encode()
        t = self._tuners.get(enc)
        if t is None:
            opt = spec.optimizer(space) if spec.optimizer is not None else None
            policy = (
                resolve_measure_policy(spec.measure)
                if spec.measure is not None else None
            )
            at = Autotuning(
                space=space,
                ignore=spec.ignore,
                # factory-built optimizer override, else the route's strategy
                # spec, else the default CSA
                search=opt if opt is not None else spec.strategy,
                num_opt=spec.num_opt,
                max_iter=spec.max_iter,
                seed=spec.seed,
                cache=True,
                db=self.db,
                key=key,
                warm_start=self._warm_start,
                db_source=self._db_source,
                objective=policy.objective if policy is not None else None,
            )
            # the drift detector watches the same statistic the route tunes:
            # a p99-objective route gets a 0.99-quantile detector unless the
            # caller pinned one explicitly
            drift_kw = dict(spec.drift) if spec.drift is not None else None
            if (
                drift_kw is not None
                and policy is not None
                and "quantile" not in drift_kw
            ):
                q = objective_quantile(policy.objective)
                if q != 0.5:
                    drift_kw["quantile"] = q
            drift = DriftDetector(**drift_kw) if drift_kw is not None else None
            # defaults from the EXACT shapes: the caller's fallback dispatch
            # runs the kernel with these knobs on the real arguments, so they
            # must be legal for the shapes actually served, not the bucket
            default_point = (
                spec.defaults(*args, **kwargs) if spec.defaults is not None else None
            )
            t = OnlineTuner(
                at,
                build=spec.build,
                cache=self.cache if spec.build is not None else None,
                jobs=self._jobs,
                epsilon=spec.epsilon,
                drift=drift,
                default_point=default_point,
                name=enc,  # executables are keyed per-context + exact shapes
                measure=policy if policy is not None else spec.measure,
                # a fresh breaker per context: failure storms are gated where
                # they happen, not across the whole route
                breaker=dict(spec.breaker) if spec.breaker is not None else None,
            )
            self._tuners[enc] = t
            _metrics.gauge("router.contexts").set(len(self._tuners))
        if sig is not None:
            # copy-on-write: readers keep their lock-free reference while we
            # publish a new snapshot (wholesale restart when the memo is full)
            fast = {} if len(self._fast) >= self._fast_max else dict(self._fast)
            fast[sig] = t
            self._fast = fast
        return t

    # ------------------------------------------------------------- serving
    def begin(
        self, name: str, *args, extra=None, tenant=None, **kwargs
    ) -> Decision:
        """Route one call: returns the decision of its context's tuner.

        A decision that carries an ``executable`` is always safe to run —
        the artifact was compiled for this exact call.  A decision *without*
        one (cold context, compile in flight) is served by the caller's
        fallback dispatch, so its knobs are clamped from the bucket's space
        into the exact shapes' space first: a bucket-legal block size is not
        necessarily legal for an off-bucket exact shape.  ``tenant`` names
        the request stream for per-tenant ε-credit budgeting."""
        d = self.tuner(name, *args, extra=extra, **kwargs).begin(
            *args, tenant=tenant, **kwargs
        )
        if d.executable is None and (args or kwargs):
            try:
                exact_space = self.spec(name).space(*args, **kwargs)
                d.point = exact_space.decode(exact_space.encode(d.point))
            except Exception:
                pass  # incompatible knobs: leave as-is, caller's fallback guards
        return d

    def observe(self, decision: Decision, cost: float) -> int:
        """Feed a served decision's measured cost back to its tuner."""
        if decision.tuner is None:
            raise ValueError("decision is not attached to a tuner")
        return decision.tuner.observe(decision, cost)

    def prewarm(self, name: str, points, *args, extra=None, wait=True, **kwargs):
        """Compile a route's candidate executables before serving starts."""
        self.tuner(name, *args, extra=extra, **kwargs).prewarm(
            points, *args, wait=wait, **kwargs
        )

    def wait_pending(self) -> None:
        with self._lock:
            tuners = list(self._tuners.values())
        for t in tuners:
            t.wait_pending()

    # ------------------------------------------------------------ inspection
    def contexts(self) -> list:
        """One summary dict per live context (for logs / debugging)."""
        with self._lock:
            items = list(self._tuners.items())
        out = []
        for enc, t in items:
            out.append(
                {
                    "key": enc,
                    "finished": t.finished,
                    "best_point": t.best_point,
                    "warm_started": t.at.warm_started,
                    "stats": t.stats(),
                }
            )
        return out

    def stats(self) -> dict:
        """Aggregate serving counters across every context.  Each context's
        counters are read under its own tuner lock, so per-tuner accounting
        identities survive into the aggregate even mid-traffic."""
        keys = (
            "calls", "explores", "exploits", "explore_candidates",
            "culled_explores", "deferred_explores", "inband_builds",
            "candidate_failures", "breaker_denied", "drift_resets",
            "searches_completed", "explore_reps_decided", "stale_explore_reps",
        )
        with self._lock:
            tuners = list(self._tuners.values())
        total = {"contexts": len(tuners)}
        total.update({k: 0 for k in keys})
        for t in tuners:
            with t._lock:
                for k in keys:
                    total[k] += t.stats_[k]
        total["cache"] = self.cache.stats()
        return total

    def snapshot(self) -> dict:
        """Cheap per-context health: each tuner's :meth:`OnlineTuner.snapshot`
        keyed by the encoded context (no cache walk, no drift stats)."""
        with self._lock:
            items = list(self._tuners.items())
        return {enc: t.snapshot() for enc, t in items}
