"""Fault-tolerant training driver.

Wires together the substrates: data pipeline, train step, checkpointing
(async, atomic, keep-k), PATSMA auto-tuning of step knobs (Single-Iteration
mode riding the training loop — paper Fig. 1a), and the step-time watchdog
that calls ``Autotuning.reset(level)`` when the environment drifts
(straggler mitigation: the paper's reset semantics at datacenter scale).
With ``runtime="adaptive"`` the drift handling moves inside the
:class:`~repro.core.TunedStep` (an ``OnlineTuner`` + ``DriftDetector``
doing a warm half-budget re-search) and the watchdog stays observer-only.

Crash/preemption recovery: the driver resumes from the newest complete
checkpoint; the data pipeline is a pure function of (seed, step) so the
replayed trajectory is bit-identical (asserted in tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.core import LogIntDim, SearchSpace, TunedStep
from repro.core.space import ChoiceDim, IntDim
from repro.data import make_batch_for
from repro.models import ExecConfig, Model
from repro.optim import AdamW, cosine_schedule
from repro.train import make_train_step

__all__ = ["TrainJob", "Watchdog"]


class Watchdog:
    """EWMA step-time monitor.  ``check`` returns an escalation level when the
    current step time drifts beyond ``factor``× the smoothed time (0 = fine)."""

    def __init__(self, factor: float = 1.8, alpha: float = 0.2, warmup: int = 3):
        self.factor = factor
        self.alpha = alpha
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.n = 0
        self.events: list = []

    def check(self, dt: float, step: int) -> int:
        self.n += 1
        if self.ewma is None:
            self.ewma = dt
            return 0
        level = 0
        if self.n > self.warmup and dt > self.factor * self.ewma:
            level = 1 if dt < 2 * self.factor * self.ewma else 2
            self.events.append({"step": step, "dt": dt, "ewma": self.ewma, "level": level})
        # don't fold outliers into the smoothed estimate
        if level == 0:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return level


@dataclasses.dataclass
class TrainJob:
    arch: str = "qwen2_7b"
    tiny: bool = True
    steps: int = 50
    global_batch: int = 8
    seq_len: int = 64
    lr: float = 1e-3
    warmup: int = 10
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 20
    ckpt_keep: int = 2
    ckpt_async: bool = True
    # PATSMA integration (Single Iteration mode over step knobs)
    tune: bool = False
    tune_microbatches: tuple = (1, 2, 4)
    tune_max_iter: int = 4
    tune_num_opt: int = 3
    tune_db: Optional[str] = None  # tuning DB path: warm-start knobs across runs
    ignore: int = 1
    watchdog_factor: float = 1.8
    # runtime="adaptive": the TunedStep owns drift handling (OnlineTuner +
    # DriftDetector with a warm half-budget re-search) instead of the
    # external watchdog->reset wiring below; epsilon rations how many steps
    # measure a candidate while a search is live (1.0 = classic behaviour)
    runtime: Optional[str] = None
    tune_epsilon: float = 1.0
    drift: Optional[dict] = None  # DriftDetector kwargs for adaptive mode
    exec_cfg: ExecConfig = dataclasses.field(default_factory=lambda: ExecConfig(rec_chunk=8))
    # test hooks
    delay_hook: Optional[Callable[[int], None]] = None

    def build(self):
        cfg = configs.get_tiny(self.arch) if self.tiny else configs.get(self.arch)
        model = Model(cfg, self.exec_cfg)
        opt = AdamW(lr=cosine_schedule(self.lr, self.warmup, self.steps))
        params = model.init(jax.random.PRNGKey(self.seed))
        opt_state = opt.init(params)
        return cfg, model, opt, params, opt_state

    def run(self, on_step: Optional[Callable] = None) -> dict:
        cfg, model, opt, params, opt_state = self.build()
        start_step = 0
        ckpt = CheckpointManager(self.ckpt_dir, keep=self.ckpt_keep) if self.ckpt_dir else None
        if ckpt is not None and ckpt.latest_step() is not None:
            (params, opt_state), step_loaded, _ = ckpt.restore((params, opt_state))
            start_step = step_loaded + 1

        def factory(microbatches=1):
            return jax.jit(
                make_train_step(model, opt, microbatches=microbatches),
                donate_argnums=(0, 1),
            )

        tuned: Optional[TunedStep] = None
        if self.tune:
            valid_mbs = tuple(
                m for m in self.tune_microbatches if self.global_batch % m == 0
            ) or (1,)
            space = SearchSpace([ChoiceDim("microbatches", valid_mbs)])
            db = None
            if self.tune_db is not None:
                from repro.tuning import TuningDB

                db = TuningDB(self.tune_db)
            tuned = TunedStep(
                factory,
                space,
                ignore=self.ignore,
                num_opt=self.tune_num_opt,
                max_iter=self.tune_max_iter,
                cache=True,
                seed=self.seed,
                db=db,
                name=f"train_step/{self.arch}",
                key_extra={
                    "tiny": self.tiny,
                    "global_batch": self.global_batch,
                    "seq_len": self.seq_len,
                },
                runtime=self.runtime,
                epsilon=self.tune_epsilon,
                drift=self.drift,
            )
        else:
            step_fn = factory()

        watchdog = Watchdog(factor=self.watchdog_factor)
        history = {"loss": [], "step_time": [], "resets": [], "steps": []}
        for step in range(start_step, self.steps):
            batch = make_batch_for(cfg, self.global_batch, self.seq_len, step, self.seed)
            t0 = time.perf_counter()
            if self.delay_hook is not None:
                self.delay_hook(step)  # inside the timed window (straggler sim)
            if tuned is not None:
                params, opt_state, metrics = tuned(params, opt_state, batch)
            else:
                params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            level = watchdog.check(dt, step)
            if (
                level
                and tuned is not None
                and tuned.finished
                and tuned.online is None  # adaptive mode resets itself
            ):
                # environment drift: re-enter tuning (paper reset semantics)
                tuned.reset(level - 1)
                history["resets"].append({"step": step, "level": level - 1})
            history["loss"].append(float(metrics["loss"]))
            history["step_time"].append(dt)
            history["steps"].append(step)
            if on_step is not None:
                on_step(step, metrics)
            if ckpt is not None and (step + 1) % self.ckpt_every == 0:
                payload = (params, opt_state)
                if self.ckpt_async:
                    ckpt.save_async(step, payload, extra={"loss": float(metrics["loss"])})
                else:
                    ckpt.save(step, payload, extra={"loss": float(metrics["loss"])})
        if ckpt is not None:
            ckpt.wait()
            ckpt.save(self.steps - 1, (params, opt_state))
        history["final_knobs"] = tuned.best_knobs if tuned is not None else {}
        history["watchdog_events"] = watchdog.events
        if tuned is not None and tuned.online is not None:
            # drift resets happened inside the TunedStep; surface them in the
            # same shape the watchdog path uses (seq counts calls from resume)
            for ev in tuned.drift_events:
                history["resets"].append(
                    {"step": start_step + ev["seq"] - 1, "level": ev["level"]}
                )
            history["online_stats"] = tuned.online.stats()
        return history
