"""Drift detection for online-tuned contexts.

A tuned configuration is only optimal for the environment it was measured
in; live systems drift away from that environment (input-distribution shift,
thermal throttling, co-tenant contention).  :class:`DriftDetector` watches
the stream of *exploit* costs — the cost of serving requests at the
current-best knobs — with sliding-window statistics and reports an
escalation level when the recent costs degrade beyond a threshold relative
to the post-tuning baseline.  The consumer (``repro.runtime.online
.OnlineTuner``) answers a non-zero level with ``Autotuning.reset(level)``
plus a half-budget warm re-search.

Everything here is sample-count based — no wall clock, no RNG — so drift
behaviour is exactly reproducible from a cost sequence (the deterministic
test seam required by the fast CI lane).

Protocol::

    dd = DriftDetector(window=16, min_samples=6, factor=1.5)
    dd.rebaseline()              # after (re)tuning converges
    level = dd.observe(cost)     # per served request at the tuned knobs
    # level 0: fine; 1: degraded (> factor x baseline median);
    # 2: severe  (> severe_factor x baseline median)
"""
from __future__ import annotations

import math
from collections import deque

from repro.obs import metrics as _metrics
from typing import Optional

__all__ = ["DriftDetector"]


def _median(values) -> Optional[float]:
    vals = sorted(values)
    if not vals:
        return None
    n = len(vals)
    mid = n // 2
    if n % 2:
        return float(vals[mid])
    return 0.5 * (vals[mid - 1] + vals[mid])


def _stat(values, q: float) -> Optional[float]:
    """Window statistic at quantile ``q`` (0.5 delegates to :func:`_median`
    so the default detector is bit-identical to the pre-quantile one)."""
    if q == 0.5:
        return _median(values)
    vals = list(values)
    if not vals:
        return None
    from repro.core.measure import quantile

    return quantile(vals, q)


class DriftDetector:
    """Sliding-window cost monitor with a frozen baseline.

    The first ``window`` finite observations after :meth:`rebaseline` form
    the **baseline** (the healthy, just-tuned cost distribution).  Later
    observations roll through a **recent** window of the same length; once
    at least ``min_samples`` recent costs exist, their median is compared to
    the baseline median:

    * ``recent > severe_factor * baseline`` → level 2 (severe drift),
    * ``recent > factor        * baseline`` → level 1 (degraded),
    * otherwise level 0.

    Medians (not means) so a single straggler request cannot trigger a
    re-tune.  A trigger clears the recent window, so a consumer that ignores
    the signal is not re-triggered on every subsequent sample.  Non-finite
    costs (crashed requests) are excluded from the statistics.
    """

    def __init__(
        self,
        *,
        window: int = 16,
        min_samples: int = 6,
        factor: float = 1.5,
        severe_factor: Optional[float] = None,
        atol: float = 0.0,
        quantile: float = 0.5,
    ) -> None:
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if min_samples < 1 or min_samples > window:
            raise ValueError(f"min_samples must be in [1, window], got {min_samples}")
        if factor <= 1.0:
            raise ValueError(f"factor must be > 1, got {factor}")
        self.window = int(window)
        self.min_samples = int(min_samples)
        self.factor = float(factor)
        self.severe_factor = float(severe_factor) if severe_factor is not None else 2.0 * factor
        if self.severe_factor < self.factor:
            raise ValueError("severe_factor must be >= factor")
        self.atol = float(atol)
        if not (0.0 < quantile < 1.0):
            raise ValueError(f"quantile must be in (0, 1), got {quantile}")
        # which window statistic detection compares — 0.5 is the classic
        # median detector; a p99-objective context watches 0.99 so drift is
        # judged on the same statistic the tuner minimizes
        self.quantile = float(quantile)
        self._baseline: deque = deque(maxlen=self.window)
        self._recent: deque = deque(maxlen=self.window)
        self.observed = 0  # finite samples since the last rebaseline
        self.events: list = []

    # -------------------------------------------------------------- state
    @property
    def ready(self) -> bool:
        """Whether the baseline is established (detection can fire)."""
        return len(self._baseline) >= self.window

    def baseline_median(self) -> Optional[float]:
        """Baseline window statistic (at :attr:`quantile`; the name predates
        non-median detectors)."""
        return _stat(self._baseline, self.quantile)

    def recent_median(self) -> Optional[float]:
        """Statistic of the freshest costs — the detector's current estimate
        of what the deployed configuration costs *now* (falls back to the
        baseline while the recent window is still empty)."""
        if self._recent:
            return _stat(self._recent, self.quantile)
        return _stat(self._baseline, self.quantile)

    def rebaseline(self) -> None:
        """Forget everything measured so far: the next ``window`` samples
        define the new healthy baseline.  Call after a (re)tune converges."""
        self._baseline.clear()
        self._recent.clear()
        self.observed = 0

    # ----------------------------------------------------------- observe
    def observe(self, cost: float) -> int:
        """Feed one exploit-cost sample; returns the escalation level."""
        cost = float(cost)
        if not math.isfinite(cost):
            return 0
        self.observed += 1
        if not self.ready:
            self._baseline.append(cost)
            return 0
        self._recent.append(cost)
        if len(self._recent) < self.min_samples:
            return 0
        base = _stat(self._baseline, self.quantile)
        recent = _stat(self._recent, self.quantile)
        level = 0
        if recent > self.severe_factor * base + self.atol:
            level = 2
        elif recent > self.factor * base + self.atol:
            level = 1
        if level:
            _metrics.counter("drift.events").inc()
            # report the freshest min_samples' median: the rolling window that
            # *detects* drift still contains pre-drift samples, but consumers
            # (the warm re-search noting the incumbent's live cost) want the
            # best estimate of what the deployed point costs now
            fresh = _stat(list(self._recent)[-self.min_samples:], self.quantile)
            self.events.append(
                {"sample": self.observed, "level": level,
                 "baseline": base, "recent": fresh, "window_median": recent}
            )
            self._recent.clear()  # one signal per degradation episode
        return level

    def stats(self) -> dict:
        return {
            "observed": self.observed,
            "ready": self.ready,
            "baseline_median": self.baseline_median(),
            "recent_median": _stat(self._recent, self.quantile) if self._recent else None,
            "events": len(self.events),
        }

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"DriftDetector(window={self.window}, factor={self.factor}, "
            f"observed={self.observed}, events={len(self.events)})"
        )
