"""OnlineTuner — PATSMA tuning in-band with live traffic.

The offline modes (PR 1/PR 2) stop the world: ``entire_exec*`` runs the
whole search on a replica before serving starts.  :class:`OnlineTuner`
instead rides a live request stream:

* an **ε-fraction** of calls *explore* — they serve the request at the
  search's current candidate and feed the measured cost into the
  ``Autotuning`` driver (the paper's Single-Iteration mode, rationed);
* the remaining calls *exploit* the best-known knobs, and once the search
  has converged their costs stream into a :class:`~repro.runtime.drift
  .DriftDetector`;
* when drift fires, the tuner calls ``Autotuning.reset(level)`` with a
  **warm re-search**: the optimizer is re-seeded around the deployed point
  at half budget, the deployed point's fresh (post-drift) cost is recorded
  via ``Autotuning.note``, and the refreshed result is committed back to
  the tuning DB with ``source="online"`` when the re-search converges.

Exploration never blocks the serving thread on XLA: candidate executables
are built through an :class:`~repro.core.costs.ExecutableCache` on a
background thread pool, and a candidate is only *offered* for exploration
once its executable is ready (``ExecutableCache.peek`` — a non-building
probe).  A scheduled exploration whose compile is still in flight silently
degrades to exploitation and retries on a later call.  Candidates whose
build *failed* are charged ``inf`` via ``Autotuning.skip`` without spending
a request on them.  Builds are admission-controlled per exact call
signature: a shape seen only once is served by the caller's fallback
dispatch rather than paying an AOT compile that may never be reused.

The ε-scheduler is a deterministic credit counter, not a coin flip: call
``i`` of a search episode explores iff ``explored + 1 <= ε * i`` (so the
explored fraction tracks ε exactly and tests can assert the schedule).
Episode counters restart when a search converges or a drift reset begins.

With a ``measure`` policy (:class:`~repro.core.measure.MeasurePolicy`) the
tuner additionally races candidates *across requests*: each explore request
contributes one repetition to the current candidate, and the candidate's
cost is only fed to the search once it is decided — immediately (one rep)
when its observed cost is dominated by the incumbent beyond the noise
floor, after climbing the repeat ladder otherwise.  Explore credits are
charged per repetition actually spent, so a culled candidate consumes a
fraction of the ε-budget a full ladder evaluation would, and exploration
converges in fewer live requests than a fixed multi-rep schedule.
``measure=None`` (default) keeps the classic one-request-per-candidate
behaviour; ``MeasurePolicy(mode="fixed", repeats=k)`` spends exactly ``k``
requests per candidate and feeds the median.

``begin``/``observe`` are **thread-safe**: every state transition runs under
one per-tuner lock (striped locking — different contexts never contend), so
many concurrent request streams can route through the same context.  Under a
``measure`` policy the racing protocol extends *across streams*: concurrent
requests exploring the same candidate each contribute one repetition to its
current rung, and a rep that arrives after its candidate was already decided
by a sibling stream is discarded as stale (``stats_["stale_explore_reps"]``)
rather than polluting the next candidate's rung.  Per-``tenant`` ε-credit
budgets (``begin(..., tenant=)``) additionally ration exploration per
request stream: each tenant may explore at most an ε-fraction of *its own*
calls, so one chatty tenant cannot burn the whole episode's explore budget.
The lock is never held across a compile or a measured request — builds stay
on the background pool and the serving work happens between ``begin`` and
``observe``.
"""
from __future__ import annotations

import dataclasses
import json
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import wait as _wait_futures
from typing import Any, Callable, Optional

import numpy as np

from repro.core import Autotuning, CircuitBreaker, ExecutableCache
from repro.core.measure import (
    NoiseEstimate,
    objective_value,
    resolve_measure_policy,
    summarize,
)
from repro.obs import events as _events
from repro.obs import metrics as _metrics
from repro.obs.trace import tracer as _tracer

from .drift import DriftDetector

__all__ = ["Decision", "OnlineTuner", "EXPLORE", "EXPLOIT"]

EXPLORE = "explore"
EXPLOIT = "exploit"

_ABSENT = object()  # peek() sentinel: "no completed build for this key"


@dataclasses.dataclass
class Decision:
    """One routed call: which knobs to serve this request with.

    ``executable`` is the ready AOT-compiled artifact for ``point`` when the
    tuner has one (never compiled on the calling thread), else ``None`` and
    the caller uses its own fallback dispatch.  Hand the decision back to
    :meth:`OnlineTuner.observe` (or ``ContextRouter.observe``) with the
    measured cost.
    """

    kind: str  # EXPLORE | EXPLOIT
    point: dict
    executable: Any = None
    seq: int = 0
    tuner: Optional["OnlineTuner"] = dataclasses.field(default=None, repr=False)
    tenant: Optional[str] = None  # request stream this decision was billed to


class OnlineTuner:
    """Explore/exploit wrapper around one :class:`Autotuning` context.

    Parameters
    ----------
    at:
        The search driver (may be DB-warm-started, may already be finished
        on an exact DB hit — then every call exploits the stored best).
    build:
        Optional ``build(point, *args, **kwargs) -> executable``.  When
        given, explore candidates are compiled off-thread through ``cache``
        and exploration waits (without blocking) for readiness.  When
        ``None`` (analytic costs, or compile time absorbed by ``ignore``),
        every candidate is immediately explorable.
    epsilon:
        Target explored fraction of calls while a search is active.
        ``1.0`` reproduces the paper's Single-Iteration mode (every call
        measures); ``0.0`` never explores (replay-only).
    drift:
        Optional :class:`DriftDetector` fed with exploit costs once the
        search has converged; a non-zero level triggers the warm re-search.
    warm_frac / warm_spread:
        Budget fraction and seeding spread of the drift-triggered re-search.
    default_point:
        Knobs to exploit before any measurement exists (a registered
        kernel's defaults); otherwise the driver's current best is used.
    measure:
        Optional per-candidate repetition policy
        (:class:`~repro.core.measure.MeasurePolicy`, ``"adaptive"``, or
        ``"fixed"``).  ``None`` keeps the classic behaviour: every explore
        request is one full candidate evaluation.
    breaker:
        Optional :class:`~repro.core.guard.CircuitBreaker` (or a kwargs dict
        for one).  A context whose explores keep failing — builds erroring,
        measured costs coming back non-finite — trips the breaker: explores
        and failed-candidate absorption are suspended (the incumbent/default
        keeps serving, no ε-credits burn) until the count-based cooldown
        lapses, then half-open probes decide whether exploration resumes.
        Denied calls do not advance the ε-episode, so recovery does not
        start with a burst of catch-up explores.
    """

    def __init__(
        self,
        at: Autotuning,
        *,
        build: Optional[Callable] = None,
        cache: Optional[ExecutableCache] = None,
        jobs: int = 1,
        epsilon: float = 0.1,
        drift: Optional[DriftDetector] = None,
        warm_frac: float = 0.5,
        warm_spread: float = 0.2,
        default_point: Optional[dict] = None,
        name: str = "online",
        measure=None,
        breaker=None,
    ) -> None:
        if not 0.0 <= epsilon <= 1.0:
            raise ValueError(f"epsilon must be in [0, 1], got {epsilon}")
        self.at = at
        self.epsilon = float(epsilon)
        self.drift = drift
        self.warm_frac = float(warm_frac)
        self.warm_spread = float(warm_spread)
        self.name = str(name)
        self._build = build
        # default cache never memoizes failures: without domain knowledge of
        # which build errors are deterministic (the kernel layer's cache has
        # that via its cache_failures predicate), a transient compile failure
        # (e.g. RESOURCE_EXHAUSTED under load) must not poison the candidate
        # for the process lifetime — a revisit retries the build instead
        self._cache = cache if cache is not None else (
            ExecutableCache(cache_failures=lambda e: False)
            if build is not None else None
        )
        self._jobs = max(1, int(jobs))
        self._pool: Optional[ThreadPoolExecutor] = None
        self._pending: dict = {}  # exec key -> Future (builds this tuner asked for)
        self._sig_seen: dict = {}  # exact call signature -> sightings (bounded)
        self._default = dict(default_point) if default_point else None
        self._seq = 0
        # one lock per tuner (the router's stripe): every begin/observe state
        # transition runs under it, so concurrent streams through the same
        # context stay consistent while different contexts never contend.
        # RLock: internal transitions (drift reset → commit) re-enter.
        self._lock = threading.RLock()
        # per-search-episode ε accounting (reset on converge / drift reset)
        self._episode_calls = 0
        self._episode_explores = 0
        # per-tenant ε accounting within the episode: tenant -> counters
        self._tenant_calls: dict = {}
        self._tenant_explores: dict = {}
        # multi-rep explore measurement (None → one request per candidate)
        self.measure = None if measure is None else resolve_measure_policy(measure)
        if isinstance(breaker, dict):
            breaker = CircuitBreaker(**breaker)
        self.breaker: Optional[CircuitBreaker] = breaker
        self._rep_times: list = []  # current explore candidate's observed reps
        self._rep_key = None  # space.key of the candidate being repped
        # explore decisions issued but not yet observed (begin..observe gap):
        # the term that closes the rep-accounting identity mid-request
        self._explore_inflight = 0
        self.events: list = []  # drift resets, with context
        # mirrored: every numeric increment lands in the process metrics
        # registry as online.<key> (ε-credit spend = online.explores)
        self.stats_ = _metrics.MirroredStats("online", {
            "calls": 0,
            "explores": 0,  # explore *requests* (= repetitions spent)
            "exploits": 0,
            "explore_candidates": 0,  # candidates decided (fed to the search)
            "culled_explores": 0,  # candidates raced out before the full ladder
            "deferred_explores": 0,  # scheduled explore, compile still in flight
            "inband_builds": 0,  # builds that ran on the serving thread (must stay 0)
            "compiles_submitted": 0,
            "candidate_failures": 0,  # candidates charged inf for a failed build
            "breaker_denied": 0,  # calls whose exploration the breaker blocked
            "drift_resets": 0,
            "searches_completed": 0,
            # cross-stream racing accounting: every explore request resolves
            # to exactly one of {decided-candidate rep, buffered rep, stale
            # rep, still-in-flight rep}, so explores == explore_reps_decided
            # + stale_explore_reps + len(current rep buffer) +
            # _explore_inflight at any consistent read point
            "explore_reps_decided": 0,  # reps consumed by decided candidates
            "stale_explore_reps": 0,  # reps for candidates already decided
        })

    # ------------------------------------------------------------ properties
    @property
    def finished(self) -> bool:
        return self.at.finished

    @property
    def best_point(self) -> dict:
        return self.at.best_point

    def exploit_point(self) -> dict:
        """Knobs a non-exploring call should serve with *right now*."""
        with self._lock:
            return self._exploit_point_locked()

    def _exploit_point_locked(self) -> dict:
        at = self.at
        if at.finished or np.isfinite(at.best_cost):
            return at.best_point
        return dict(self._default) if self._default is not None else at.best_point

    def snapshot(self) -> dict:
        """Cheap point-in-time view (no cache walk, no drift window math):
        the serving counters plus the breaker's gate state — what a
        dashboard or ``repro.tune report`` polls between summary dumps.
        Taken under the tuner lock, so the accounting identities (calls ==
        explores + exploits; explores == decided + stale + buffered +
        in-flight reps) hold even while other threads are mid-``begin``."""
        with self._lock:
            out = {
                "name": self.name,
                "calls": self.stats_["calls"],
                "explores": self.stats_["explores"],
                "exploits": self.stats_["exploits"],
                "breaker_denied": self.stats_["breaker_denied"],
                "drift_resets": self.stats_["drift_resets"],
                "explore_reps_decided": self.stats_["explore_reps_decided"],
                "stale_explore_reps": self.stats_["stale_explore_reps"],
                "explore_reps_buffered": len(self._rep_times),
                "explore_inflight": self._explore_inflight,
                "finished": self.at.finished,
            }
        if self.breaker is not None:
            out["breaker"] = self.breaker.snapshot()
        return out

    def stats(self) -> dict:
        with self._lock:
            out = dict(self.stats_)
            out["finished"] = self.at.finished
            out["num_evals"] = self.at.num_evals
            out["explore_reps_buffered"] = len(self._rep_times)
            out["explore_inflight"] = self._explore_inflight
            if self._tenant_calls:
                out["tenants"] = {
                    t: {
                        "calls": self._tenant_calls.get(t, 0),
                        "explores": self._tenant_explores.get(t, 0),
                    }
                    for t in self._tenant_calls
                }
            if self.drift is not None:
                out["drift"] = self.drift.stats()
        if self._cache is not None:
            out["cache"] = self._cache.stats()
        if self.breaker is not None:
            out["breaker"] = self.breaker.stats()
        return out

    # -------------------------------------------------------- build plumbing
    def _ensure_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self._jobs, thread_name_prefix="patsma-online"
            )
        return self._pool

    @staticmethod
    def _call_sig(args: tuple, kwargs: dict) -> str:
        from repro.tuning.records import signature_of

        return json.dumps(signature_of(args, kwargs), default=repr, sort_keys=True)

    def _exec_key(self, point: dict, args: tuple, kwargs: dict):
        return (self.name, self.at.space.key(point), self._call_sig(args, kwargs))

    def _note_signature(self, args: tuple, kwargs: dict) -> bool:
        """Admission control for background builds: record this call's exact
        signature and admit compiles only once it has been seen more than
        once.  Long-tail one-off shapes (each request a new sequence length)
        are served by the caller's fallback dispatch instead of paying one
        AOT compile per request — compiling for a shape that never returns
        is pure waste and churns the shared executable cache.  Signature-free
        calls (serve's fixed decode context, TunedStep) are always admitted."""
        if not args and not kwargs:
            return True
        sig = self._call_sig(args, kwargs)
        if len(self._sig_seen) >= 4096:
            self._sig_seen.clear()
        n = self._sig_seen.get(sig, 0) + 1
        self._sig_seen[sig] = n
        return n >= 2

    def _submit(self, point: dict, args: tuple, kwargs: dict) -> Optional[Future]:
        """Queue a background build for ``point`` (idempotent); returns the
        future tracking it.  Never builds on the calling thread."""
        if self._build is None:
            return None
        key = self._exec_key(point, args, kwargs)
        fut = self._pending.get(key)
        if fut is not None:
            return fut
        done = self._cache.peek(key, default=_ABSENT)
        if done is not _ABSENT:  # someone else (prewarm, sibling) built it
            fut = Future()
            fut.set_result(done)
            self._pending[key] = fut
            return fut
        point = dict(point)
        args = tuple(args)
        kwargs = dict(kwargs)
        serving_thread = threading.get_ident()

        def job():
            def build():
                if threading.get_ident() == serving_thread:
                    # only possible if a caller runs the future inline —
                    # surfaced in stats so benchmarks can assert it never does
                    with self._lock:
                        self.stats_["inband_builds"] += 1
                return self._build(point, *args, **kwargs)

            return self._cache.get_or_build(key, build)

        fut = self._ensure_pool().submit(job)
        self._pending[key] = fut
        self.stats_["compiles_submitted"] += 1
        if len(self._pending) > 4 * self._cache.maxsize:
            self._pending = {k: f for k, f in self._pending.items() if not f.done()}
        return fut

    def _ready(self, point: dict, args: tuple, kwargs: dict, admit: bool = True):
        """(ready, executable-or-exception-or-None) for ``point``, submitting
        a background build on first sight (if ``admit``).  Never blocks."""
        if self._build is None:
            return True, None
        if not admit:
            key = self._exec_key(point, args, kwargs)
            if (
                self._pending.get(key) is None
                and self._cache.peek(key, default=_ABSENT) is _ABSENT
            ):
                return False, None  # no build exists and none is admitted
        fut = self._submit(point, args, kwargs)
        if fut is None or not fut.done():
            return False, None
        result = fut.result()
        if isinstance(result, (KeyboardInterrupt, SystemExit)):
            # a user interrupt captured by a background build is control
            # flow, never a candidate failure to absorb as inf
            raise result
        if isinstance(result, BaseException):
            key = self._exec_key(point, args, kwargs)
            if self._cache.peek(key, default=_ABSENT) is _ABSENT:
                # the cache declined to keep this failure (transient): drop
                # our memo of the failed future too, so a revisit — e.g. the
                # same candidate in a drift re-search — rebuilds
                self._pending.pop(key, None)
        return True, result

    def _absorb_failed_candidates(self, args: tuple, kwargs: dict, admit: bool = True) -> None:
        """Charge candidates whose executable failed to build ``inf`` without
        spending a serving request on them."""
        if self._build is None:
            return
        for _ in range(100_000):  # safety: pathological optimizer loop
            if self.at.finished:
                return
            ready, ex = self._ready(self.at.point, args, kwargs, admit=admit)
            if not ready or not isinstance(ex, BaseException):
                return
            self.stats_["candidate_failures"] += 1
            if self.breaker is not None:
                self.breaker.record_failure()
            self.at.skip(np.inf, reason="build-failed")
            if self.at.finished:
                self._on_search_complete()
                return
            if self.breaker is not None and self.breaker.state != CircuitBreaker.CLOSED:
                # the breaker tripped mid-absorb: stop charging candidates —
                # a failure storm should suspend the search, not drain it
                return

    def executable_for(self, point: dict, *args, **kwargs):
        """Ready executable for ``point`` if one exists, else ``None``.
        Non-blocking: a miss submits a background build so a later call can
        hit; it never compiles on the calling thread."""
        with self._lock:
            ready, ex = self._ready(dict(point), args, kwargs)
        if ready and not isinstance(ex, BaseException):
            return ex
        return None

    def wait_pending(self, timeout: Optional[float] = None) -> None:
        """Block until every background build submitted so far has finished.
        For tests, shutdown, and pre-stream prewarming — never call from the
        serving hot path.  The tuner lock is *not* held while waiting (a
        build must never deadlock against a serving thread)."""
        with self._lock:
            futs = list(self._pending.values())
        _wait_futures(futs, timeout=timeout)

    def prewarm(self, points, *args, wait: bool = True, **kwargs) -> None:
        """Submit builds for ``points`` (e.g. every candidate of a small
        space) before serving starts; with ``wait`` blocks until done so the
        stream begins with a fully warm cache."""
        with self._lock:
            for p in points:
                self._submit(dict(p), args, kwargs)
        if wait:
            self.wait_pending()

    # ------------------------------------------------------------- decisions
    def _want_explore(self, tenant: Optional[str] = None) -> bool:
        if self.epsilon <= 0.0:
            return False
        if not (
            (self._episode_explores + 1)
            <= self.epsilon * self._episode_calls + 1e-12
        ):
            return False
        if tenant is None:
            return True
        # per-tenant budget: the same deterministic credit rule applied to
        # the tenant's own calls — a single tenant reproduces the global
        # schedule exactly, and no tenant can spend more than ε of its own
        # traffic on exploration regardless of how chatty it is
        return (
            (self._tenant_explores.get(tenant, 0) + 1)
            <= self.epsilon * self._tenant_calls.get(tenant, 0) + 1e-12
        )

    def begin(
        self, *args, tenant: Optional[str] = None, _force_explore: bool = False, **kwargs
    ) -> Decision:
        """Decide how to serve the next request (thread-safe).

        ``args``/``kwargs`` are the request's call arguments — they key the
        executable cache (shape-exact) and are what background builds
        compile against.  ``tenant`` names the request stream for per-tenant
        ε-credit accounting (``None`` = unattributed, global budget only)."""
        with _tracer().span("request", cat="serve", sampled=True, ctx=self.name):
            with self._lock:
                return self._begin_locked(args, kwargs, tenant, _force_explore)

    def _begin_locked(
        self, args: tuple, kwargs: dict, tenant: Optional[str], _force_explore: bool
    ) -> Decision:
        self._seq += 1
        self.stats_["calls"] += 1
        at = self.at
        admit = self._note_signature(args, kwargs) if self._build is not None else True
        gate = True
        if self.breaker is not None and not at.finished:
            # one gate decision per serving call: a denied call neither
            # explores nor absorbs failures nor advances the ε-episode —
            # the context serves its incumbent and the cooldown ticks
            gate = self.breaker.allow()
            if not gate:
                self.stats_["breaker_denied"] += 1
        if not at.finished and gate:
            self._episode_calls += 1
            if tenant is not None:
                if len(self._tenant_calls) >= 4096:  # bounded, like _sig_seen
                    self._tenant_calls.clear()
                    self._tenant_explores.clear()
                self._tenant_calls[tenant] = self._tenant_calls.get(tenant, 0) + 1
            self._absorb_failed_candidates(args, kwargs, admit=admit)
        if not at.finished and gate and (_force_explore or self._want_explore(tenant)):
            ready, ex = self._ready(at.point, args, kwargs, admit=admit or _force_explore)
            if ready and not isinstance(ex, BaseException):
                self._episode_explores += 1
                if tenant is not None:
                    self._tenant_explores[tenant] = (
                        self._tenant_explores.get(tenant, 0) + 1
                    )
                self.stats_["explores"] += 1
                self._explore_inflight += 1
                return Decision(EXPLORE, at.point, ex, self._seq, self, tenant)
            if not ready:
                self.stats_["deferred_explores"] += 1
            # failed build: absorbed on the next call; exploit this one
        self.stats_["exploits"] += 1
        point = self._exploit_point_locked()
        executable = None
        if self._build is not None:
            ready, ex = self._ready(point, args, kwargs, admit=admit)
            if ready and not isinstance(ex, BaseException):
                executable = ex
        return Decision(EXPLOIT, point, executable, self._seq, self, tenant)

    def observe(self, decision: Decision, cost: float) -> int:
        """Deliver the measured cost of a served decision (thread-safe).

        Explore costs feed the search (committing to the DB on
        convergence); exploit costs feed drift detection once the search has
        converged.  With a ``measure`` policy an explore cost is one
        *repetition* — the candidate advances only once racing decides it,
        and concurrent streams' reps accumulate on the same rung.  A rep
        whose candidate was already decided by a sibling stream (or swept
        away by a drift reset) between this request's ``begin`` and its
        ``observe`` is discarded as stale — feeding it would attribute the
        old candidate's cost to the new one.  Returns the drift level acted
        on this call (0 = none)."""
        cost = float(cost)
        with self._lock:
            at = self.at
            if decision.kind == EXPLORE:
                if self._explore_inflight > 0:  # lands from the begin() gap
                    self._explore_inflight -= 1
                if self.breaker is not None:
                    if np.isfinite(cost):
                        self.breaker.record_success()
                    else:
                        self.breaker.record_failure()
                if at.finished:
                    # decided after this decision was issued (sibling stream
                    # finished the search / absorbed the candidate)
                    self.stats_["stale_explore_reps"] += 1
                    return 0
                _events.emit("explore_rep", name=self.name,
                             point=dict(decision.point), cost=cost)
                if self.measure is None:
                    if at.space.key(decision.point) != at.space.key(at.point):
                        self.stats_["stale_explore_reps"] += 1
                        return 0
                    self.stats_["explore_reps_decided"] += 1
                    self.stats_["explore_candidates"] += 1
                    at.exec(cost)
                else:
                    self._feed_rep(cost, decision)
                if at.finished:
                    self._on_search_complete()
                return 0
            if self.drift is not None and at.finished:
                level = self.drift.observe(cost)
                if level > 0:
                    self._drift_reset(level)
                    return level
        return 0

    # ------------------------------------------------- multi-rep exploration
    def _feed_rep(self, cost: float, decision: Decision) -> None:
        """One observed repetition of the current explore candidate; feeds
        the search only once the racing policy reaches a verdict.  Keyed by
        the *decision's* point: under cross-stream racing the candidate may
        have advanced between this request's begin and observe, in which
        case the rep is stale and dropped with accounting."""
        at = self.at
        key = at.space.key(at.point)
        if at.space.key(decision.point) != key:
            # the candidate this rep was served at is no longer the one
            # being raced — a sibling stream's rep decided it already
            self.stats_["stale_explore_reps"] += 1
            return
        if self._rep_key != key:  # candidate changed under us (reset, skip)
            self._rep_times = []
            self._rep_key = key
        self._rep_times.append(float(cost))
        verdict = self._race_verdict()
        if verdict is None:
            return  # escalate: the next explore request reps this candidate
        final_cost, culled = verdict
        self.stats_["explore_reps_decided"] += len(self._rep_times)
        self._rep_times = []
        self._rep_key = None
        self.stats_["explore_candidates"] += 1
        if culled:
            self.stats_["culled_explores"] += 1
        at.exec(final_cost)

    def _race_verdict(self):
        """``None`` (needs another rep) or ``(cost, culled)`` for the
        buffered candidate.  Deterministic given the observed costs: decisions
        happen at ladder rungs only, culling when the candidate's CI low end
        is beyond the incumbent's noise band (plus margin), stopping early
        when it clearly wins, finalizing at the ladder top regardless.  The
        racing/cull arithmetic is always median-based; the *finalized* cost
        fed to the search is the policy's objective statistic over the reps
        (identical for ``objective="median"``)."""
        p = self.measure
        n = len(self._rep_times)
        noise = NoiseEstimate(p.abs_noise, p.rel_noise)
        med, _, lo, hi = summarize(self._rep_times, noise)
        if p.objective in ("median", "p50"):
            final = med
        else:
            final = objective_value(self._rep_times, p.objective)
        if p.mode == "fixed":
            return (final, False) if n >= p.repeats else None
        if n >= p.ladder[-1]:
            return (final, False)
        if n not in p.ladder:
            return None  # between rungs
        inc = float(self.at.best_cost)
        if not np.isfinite(inc):
            # establishing the incumbent: a mid-ladder median is denoised
            # enough to race everything that follows against
            rung = p.ladder[min(1, len(p.ladder) - 1)]
            return (final, False) if n >= rung else None
        inc_floor = noise.floor(inc)
        if lo > inc + inc_floor * (1.0 + p.margin):
            return (final, True)  # dominated beyond the noise floor: cull
        if hi < inc - inc_floor:
            return (final, False)  # clear improvement: no more reps needed
        return None  # within noise of the incumbent: climb the ladder

    # --------------------------------------------------------- state changes
    def _on_search_complete(self) -> None:
        self.stats_["searches_completed"] += 1
        self._episode_calls = 0
        self._episode_explores = 0
        self._tenant_calls.clear()
        self._tenant_explores.clear()
        if self._rep_times:  # an undecided rung at convergence is stale
            self.stats_["stale_explore_reps"] += len(self._rep_times)
        self._rep_times = []
        self._rep_key = None
        if self.drift is not None:
            self.drift.rebaseline()

    def _drift_reset(self, level: int) -> None:
        """The tuned config degraded: re-enter tuning with a warm re-search
        seeded at the deployed point, at ``warm_frac`` of the cold budget.

        Level-aware when the search runs a staged strategy (``strategy=
        "csa+nm"`` → a :class:`~repro.core.strategy.Pipeline`): level 1
        (environment drift — the deployed point's cost floor moved, its
        basin did not) re-tunes through the final **NM refinement stage
        alone**, warm-seeded at the deployed point; level 2 (workload shift
        — the landscape itself changed) restarts the full pipeline.  Plain
        single-optimizer searches keep the classic warm ``reset(level)``."""
        at = self.at
        incumbent = at.best_point
        # the trigger event holds the post-drift median (the detector clears
        # its recent window when it fires, so recent_median() is stale here)
        fresh = None
        if self.drift is not None and self.drift.events:
            fresh = self.drift.events[-1].get("recent")
        at.reset(
            1 if level < 2 else 2,
            warm_point=incumbent,
            budget_frac=self.warm_frac,
            spread=self.warm_spread,
            refine=level < 2,
        )
        if fresh is not None and np.isfinite(fresh):
            # the incumbent's live post-drift cost: keeps best_point/commit
            # honest even if the re-search never revisits it
            at.note(incumbent, float(fresh))
        if self.drift is not None:
            self.drift.rebaseline()
        self._episode_calls = 0
        self._episode_explores = 0
        self._tenant_calls.clear()
        self._tenant_explores.clear()
        if self._rep_times:
            self.stats_["stale_explore_reps"] += len(self._rep_times)
        self._rep_times = []  # pre-reset reps describe the old environment
        self._rep_key = None
        self.stats_["drift_resets"] += 1
        _events.emit("drift_reset", name=self.name, level=int(level),
                     point=dict(incumbent), recent_cost=fresh)
        self.events.append(
            {"seq": self._seq, "level": int(level), "point": dict(incumbent),
             "recent_cost": fresh,
             "refined": bool(getattr(at.optimizer, "refining", False))}
        )

    # ------------------------------------------------------------- offline
    def drive(self, cost_fn: Callable[[dict], float], *args, **kwargs) -> dict:
        """Entire-Execution glue: run the search to completion now, with
        ``cost_fn(point)`` supplying each candidate's cost (the launcher /
        hillclimb loop).  Exploration is forced — ε only rations *live*
        traffic, and here every call is a replica evaluation.  Offline there
        is no serving thread to protect, so a pending candidate build is
        simply waited for."""
        stalls = 0
        while not self.at.finished:
            d = self.begin(*args, _force_explore=True, **kwargs)
            if d.kind != EXPLORE:  # compile in flight or just failed
                self.wait_pending()
                stalls += 1
                if stalls > 10_000:  # safety: candidate never materializes
                    break
                continue
            stalls = 0
            self.observe(d, float(cost_fn(dict(d.point))))
        return self.at.best_point
