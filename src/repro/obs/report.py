"""``repro.tune report`` — render a search's forensics from obs artifacts.

Input is the directory ``--obs-dir`` produced (``events.jsonl``,
``trace.json``, ``metrics.json``), plus optionally a tuning DB and run
journals for fleet shard health.  Everything prints as plain text — this is
the human summary; the artifacts themselves stay machine-readable.

Sections:

* **timeline** — one bar per search (event stream), offset + duration
  against the run's span of wall time;
* **phase breakdown** — wall-clock per span phase (compile / measure /
  commit / other) computed as *interval unions* over the trace, so
  concurrent fan-out compiles are not double-counted; the total equals the
  root span's duration;
* **candidate accounting** — per search: asked vs committed + culled +
  pruned + skipped + quarantined (the completeness invariant);
* **metrics** — the registry snapshot's counters and histogram summaries;
* **shard health** — per run journal: committed / failed / interrupted
  cases and the age of its last event (liveness from the fsynced streams).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .events import completeness, read_events, validate_events

__all__ = ["render_report", "load_trace_spans", "phase_breakdown"]

PHASES = ("compile", "measure", "commit")


def load_trace_spans(trace_path: str) -> List[dict]:
    """The ``ph: "X"`` complete events of a Chrome trace file (``ts``/``dur``
    in microseconds)."""
    with open(trace_path, "r", encoding="utf-8") as f:
        blob = json.load(f)
    evs = blob.get("traceEvents", blob if isinstance(blob, list) else [])
    return [e for e in evs if e.get("ph") == "X"]


def _union_us(intervals: List[Tuple[float, float]]) -> float:
    """Total length of the union of [start, end) intervals."""
    if not intervals:
        return 0.0
    intervals = sorted(intervals)
    total = 0.0
    cur_s, cur_e = intervals[0]
    for s, e in intervals[1:]:
        if s > cur_e:
            total += cur_e - cur_s
            cur_s, cur_e = s, e
        else:
            cur_e = max(cur_e, e)
    return total + (cur_e - cur_s)


def phase_breakdown(spans: Sequence[dict]) -> dict:
    """Wall-clock per phase from trace spans, as interval unions.

    Returns ``{"total_s", "phases": {phase: seconds}, "other_s"}`` where
    ``total_s`` is the union of root spans (spans with no ``parent_id`` —
    the run/search roots) and ``other_s = total_s - union(all phases)``, so
    the rows always sum to the total."""
    by_name: Dict[str, List[Tuple[float, float]]] = {}
    roots: List[Tuple[float, float]] = []
    allp: List[Tuple[float, float]] = []
    for e in spans:
        iv = (float(e["ts"]), float(e["ts"]) + float(e.get("dur", 0.0)))
        name = e.get("name", "?")
        if e.get("args", {}).get("parent_id") is None:
            roots.append(iv)
        if name in PHASES:
            by_name.setdefault(name, []).append(iv)
            allp.append(iv)
    total_us = _union_us(roots) if roots else _union_us(allp)
    covered_us = _union_us(allp)
    return {
        "total_s": total_us / 1e6,
        "phases": {p: _union_us(by_name.get(p, [])) / 1e6 for p in PHASES},
        "other_s": max(0.0, total_us - covered_us) / 1e6,
    }


def _fmt_s(s: float) -> str:
    return f"{s * 1e3:.1f}ms" if s < 1.0 else f"{s:.2f}s"


def _bar(frac: float, width: int = 28) -> str:
    n = max(0, min(width, int(round(frac * width))))
    return "#" * n + "." * (width - n)


def _timeline_lines(events: List[dict]) -> List[str]:
    starts: Dict[str, float] = {}
    rows: List[Tuple[str, float, float]] = []  # (name, t0, dur)
    for ev in events:
        if ev.get("type") == "search_start":
            starts[ev["name"]] = float(ev["ts"])
        elif ev.get("type") == "search_end" and ev.get("name") in starts:
            t0 = starts.pop(ev["name"])
            rows.append((ev["name"], t0, float(ev["ts"]) - t0))
    now = time.time()
    for name, t0 in starts.items():  # crashed / still-running searches
        rows.append((name + " (unfinished)", t0, max(0.0, now - t0)))
    if not rows:
        return ["  (no search_start/search_end events)"]
    t_min = min(t0 for _, t0, _ in rows)
    t_max = max(t0 + d for _, t0, d in rows)
    span_s = max(t_max - t_min, 1e-9)
    width = 40
    out = []
    for name, t0, d in sorted(rows, key=lambda r: r[1]):
        lo = int((t0 - t_min) / span_s * width)
        hi = max(lo + 1, int((t0 + d - t_min) / span_s * width))
        lane = " " * lo + "=" * (hi - lo) + " " * (width - hi)
        out.append(f"  [{lane}] {name}  +{_fmt_s(t0 - t_min)} for {_fmt_s(d)}")
    out.append(f"  span of run: {_fmt_s(span_s)} across {len(rows)} searches")
    return out


def _metrics_lines(metrics: dict) -> List[str]:
    out = []
    for name, v in sorted(metrics.items()):
        if isinstance(v, dict) and "count" in v:
            if v["count"]:
                out.append(
                    f"  {name:<34} n={v['count']:<7} mean={_fmt_s(v.get('mean', 0.0))}"
                    f" min={_fmt_s(v.get('min', 0.0))} max={_fmt_s(v.get('max', 0.0))}"
                )
        else:
            out.append(f"  {name:<34} {v}")
    return out or ["  (no metrics recorded)"]


def _journal_lines(journal_paths: Sequence[str], stale_s: float) -> List[str]:
    from repro.tuning.db import RunJournal

    out = []
    for p in journal_paths:
        if not os.path.exists(p):
            out.append(f"  {p}: MISSING")
            continue
        j = RunJournal(p)
        s = j.summary()
        evs = j.events()
        # journals written before events carried timestamps: file mtime is
        # still a truthful "last fsynced append" signal
        last_ts = max((float(e.get("ts", 0.0)) for e in evs), default=0.0)
        if not last_ts:
            last_ts = os.path.getmtime(p)
        age = time.time() - last_ts
        interrupted = len(s.get("interrupted", ()))
        if interrupted == 0:
            health = "done"
        elif age <= stale_s:
            health = "live"
        else:
            health = f"STALLED ({_fmt_s(age)} since last event)"
        out.append(
            f"  {os.path.basename(p):<28} committed={len(s['committed'])} "
            f"failed={len(s['failed'])} interrupted={interrupted}  {health}"
        )
    return out


def render_report(
    obs_dir: str,
    *,
    db_path: Optional[str] = None,
    journals: Sequence[str] = (),
    stale_s: float = 300.0,
) -> Tuple[str, int]:
    """Build the full text report.  Returns ``(text, exit_code)`` — nonzero
    when the event stream fails schema validation or the candidate
    accounting does not balance."""
    lines: List[str] = []
    code = 0
    events_path = os.path.join(obs_dir, "events.jsonl")
    trace_path = os.path.join(obs_dir, "trace.json")
    metrics_path = os.path.join(obs_dir, "metrics.json")

    events = read_events(events_path)
    lines.append(f"obs report: {obs_dir}")
    lines.append(f"  events={len(events)} ({events_path})")

    problems = validate_events(events)
    if problems:
        code = 1
        lines.append(f"  SCHEMA: {len(problems)} problem(s):")
        lines.extend(f"    {p}" for p in problems[:20])
    else:
        lines.append("  schema: ok")

    lines.append("")
    lines.append("search timeline:")
    lines.extend(_timeline_lines(events))

    if os.path.exists(trace_path):
        spans = load_trace_spans(trace_path)
        br = phase_breakdown(spans)
        lines.append("")
        lines.append(f"phase breakdown ({len(spans)} spans, "
                     f"total {_fmt_s(br['total_s'])}):")
        total = max(br["total_s"], 1e-12)
        for p in PHASES:
            s = br["phases"][p]
            lines.append(f"  {p:<10} {_bar(s / total)} {_fmt_s(s)}"
                         f"  ({100.0 * s / total:5.1f}%)")
        lines.append(f"  {'other':<10} {_bar(br['other_s'] / total)} "
                     f"{_fmt_s(br['other_s'])}  ({100.0 * br['other_s'] / total:5.1f}%)")
    else:
        lines.append("")
        lines.append(f"phase breakdown: no trace at {trace_path} "
                     "(run still in flight? shutdown() writes it)")

    lines.append("")
    lines.append("candidate accounting (asked = committed+culled+pruned+skipped+quarantined):")
    acc = completeness(events)
    if not acc:
        lines.append("  (no candidate events)")
    for name, a in sorted(acc.items()):
        ok = "ok" if a["balanced"] else "IMBALANCED"
        if not a["balanced"]:
            code = 1
        lines.append(
            f"  {name:<34} asked={a['asked']:<4} committed={a['committed']:<4}"
            f" culled={a['culled']:<3} pruned={a['pruned']:<3}"
            f" skipped={a['skipped']:<3} quarantined={a['quarantined']:<3} {ok}"
        )

    if os.path.exists(metrics_path):
        with open(metrics_path, "r", encoding="utf-8") as f:
            metrics = json.load(f)
        lines.append("")
        lines.append("metrics:")
        lines.extend(_metrics_lines(metrics))

    if journals:
        lines.append("")
        lines.append("fleet shard health:")
        lines.extend(_journal_lines(journals, stale_s))
    elif db_path is not None:
        from repro.tuning.db import RunJournal

        jp = RunJournal.path_for(db_path)
        if os.path.exists(jp):
            lines.append("")
            lines.append("fleet shard health:")
            lines.extend(_journal_lines([jp], stale_s))

    return "\n".join(lines) + "\n", code
