"""Nestable spans over the tuning stack, exported as Chrome trace JSON.

The span tree mirrors the search structure::

    search                      one tune_call / pretune case
    └─ round                    one optimizer ask/tell round
       ├─ compile               one candidate's AOT build (per fan-out worker)
       ├─ measure               the round's repetition racing
       └─ commit                DB keep-better commit

Design constraints, in order:

* **default-off and cheap**: every instrumentation site goes through
  :func:`span`, which returns a shared no-op context manager while the
  tracer is disabled — no allocation, no clock read, no lock.
* **thread-safe and pool-aware**: each thread keeps its own span stack, so
  concurrent workers can't cross-nest.  ``ThreadPoolExecutor`` does *not*
  carry the submitting thread's context into workers, so cross-thread
  parenting is explicit: capture :func:`current_span` before ``submit`` and
  open the worker's span with ``parent=``, or wrap the callable with
  :meth:`Tracer.wrap`.  This is how ``compile_fanout`` builds and
  ``ShardedPortfolio`` member turns attach to the search that spawned them.
* **fork-aware**: a forked child (``sandbox_first_touch`` probes) must not
  re-export the parent's buffered spans; ``os.register_at_fork`` clears the
  child's buffer and stacks.
* **monotonic clocks**: timestamps are ``time.perf_counter_ns`` offsets from
  a per-process epoch — immune to wall-clock steps; the wall-clock anchor is
  kept once per export for correlating with the event stream.
* **samplable request spans**: per-request serving spans (opened with
  ``sampled=True``) can be decimated with :meth:`Tracer.set_sample_rate` —
  a deterministic 1-in-N counter stride, not an RNG, so a replayed workload
  keeps the same spans.  Sampled-out spans cost one counter tick and return
  the shared no-op; the ``sampled_out`` counter keeps the bookkeeping
  honest.  Structural spans (search/round/compile) are never sampled out.

Export is the Chrome trace ("complete" ``ph: "X"`` events) consumed by
``chrome://tracing`` and https://ui.perfetto.dev.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = [
    "Span",
    "Tracer",
    "tracer",
    "span",
    "current_span",
    "export_chrome",
    "set_sample_rate",
]


class Span:
    """One timed region.  Created by :meth:`Tracer.span`; finished spans are
    buffered on the tracer until export."""

    __slots__ = (
        "name", "cat", "span_id", "parent_id", "pid", "tid",
        "t0_ns", "dur_ns", "args",
    )

    def __init__(
        self,
        name: str,
        cat: str,
        span_id: int,
        parent_id: Optional[int],
        pid: int,
        tid: int,
        t0_ns: int,
        args: Optional[Dict[str, Any]],
    ) -> None:
        self.name = name
        self.cat = cat
        self.span_id = span_id
        self.parent_id = parent_id
        self.pid = pid
        self.tid = tid
        self.t0_ns = t0_ns
        self.dur_ns: Optional[int] = None
        self.args = args

    def __repr__(self) -> str:  # debugging aid only
        state = "open" if self.dur_ns is None else f"{self.dur_ns / 1e6:.3f}ms"
        return f"<Span {self.name} id={self.span_id} parent={self.parent_id} {state}>"


class _NullSpanContext:
    """The disabled-tracer fast path: one shared, stateless no-op."""

    __slots__ = ()

    def __enter__(self) -> None:
        return None

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpanContext()


class _SpanContext:
    __slots__ = ("_tracer", "_span", "_explicit_parent")

    def __init__(self, tracer: "Tracer", span: Span, explicit_parent: bool) -> None:
        self._tracer = tracer
        self._span = span
        self._explicit_parent = explicit_parent

    def __enter__(self) -> Span:
        self._tracer._push(self._span)
        return self._span

    def __exit__(self, *exc) -> bool:
        self._tracer._pop(self._span)
        return False


class Tracer:
    """Thread-safe span collector (see module docstring)."""

    def __init__(self) -> None:
        self.enabled = False
        self._lock = threading.Lock()
        self._finished: List[Span] = []
        self._local = threading.local()
        self._ids = itertools.count(1)
        self._pid = os.getpid()
        self._epoch_ns = time.perf_counter_ns()
        self._epoch_unix = time.time()
        self.dropped = 0  # spans discarded after a fork
        # request-span sampling: keep 1 in _sample_stride of sampled=True
        # spans (deterministic counter, no RNG — replays keep the same spans)
        self._sample_stride = 1
        self._sample_counter = itertools.count(0)
        self.sampled_out = 0

    # ----------------------------------------------------------- lifecycle
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        with self._lock:
            self._finished = []
            self._ids = itertools.count(1)
        self._local = threading.local()
        self._epoch_ns = time.perf_counter_ns()
        self._epoch_unix = time.time()
        self._sample_stride = 1
        self._sample_counter = itertools.count(0)
        self.sampled_out = 0

    def set_sample_rate(self, rate: float) -> None:
        """Keep roughly ``rate`` of ``sampled=True`` spans (1-in-N stride,
        ``N = round(1/rate)``).  ``rate >= 1`` keeps everything."""
        rate = float(rate)
        if not rate > 0.0:
            raise ValueError(f"sample rate must be > 0, got {rate}")
        self._sample_stride = max(1, round(1.0 / rate)) if rate < 1.0 else 1
        self._sample_counter = itertools.count(0)

    def _after_fork(self) -> None:
        # the child inherits the parent's buffer; it must not re-export it
        self.dropped += len(self._finished)
        self._finished = []
        self._local = threading.local()
        self._pid = os.getpid()
        self._lock = threading.Lock()

    # --------------------------------------------------------------- spans
    def _stack(self) -> List[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = []
            self._local.stack = st
        return st

    def current(self) -> Optional[Span]:
        """The innermost open span on *this* thread (None outside any)."""
        st = getattr(self._local, "stack", None)
        return st[-1] if st else None

    def span(
        self,
        name: str,
        cat: str = "tuning",
        *,
        parent: Optional[Span] = None,
        sampled: bool = False,
        **args: Any,
    ):
        """Context manager opening a child of ``parent`` (default: this
        thread's current span).  Returns a shared no-op while disabled.
        ``sampled=True`` marks a high-rate per-request span subject to
        :meth:`set_sample_rate` decimation."""
        if not self.enabled:
            return _NULL_SPAN
        if sampled and self._sample_stride > 1:
            if next(self._sample_counter) % self._sample_stride:
                self.sampled_out += 1
                return _NULL_SPAN
        explicit = parent is not None
        if not explicit:
            parent = self.current()
        sid = next(self._ids)  # itertools.count: atomic under the GIL
        s = Span(
            name=name,
            cat=cat,
            span_id=sid,
            parent_id=parent.span_id if parent is not None else None,
            pid=self._pid,
            tid=threading.get_ident(),
            t0_ns=time.perf_counter_ns() - self._epoch_ns,
            args=args or None,
        )
        return _SpanContext(self, s, explicit)

    def wrap(
        self,
        fn: Callable,
        name: str,
        cat: str = "tuning",
        **args: Any,
    ) -> Callable:
        """Wrap ``fn`` so it runs under a child span of the *submitting*
        thread's current span — the ``pool.submit(tracer.wrap(f, "compile"))``
        pattern.  A no-op passthrough while disabled."""
        if not self.enabled:
            return fn
        parent = self.current()

        def wrapped(*a, **kw):
            with self.span(name, cat, parent=parent, **args):
                return fn(*a, **kw)

        return wrapped

    def _push(self, s: Span) -> None:
        self._stack().append(s)

    def _pop(self, s: Span) -> None:
        s.dur_ns = (time.perf_counter_ns() - self._epoch_ns) - s.t0_ns
        st = self._stack()
        # tolerate exotic unwind orders (generators, exceptions): remove the
        # span wherever it sits rather than corrupting neighbours
        if st and st[-1] is s:
            st.pop()
        elif s in st:
            st.remove(s)
        with self._lock:
            self._finished.append(s)

    # -------------------------------------------------------------- export
    def finished(self) -> List[Span]:
        with self._lock:
            return list(self._finished)

    def to_chrome(self) -> dict:
        """The Chrome trace JSON object (``traceEvents`` list of ``ph: "X"``
        complete events; timestamps/durations in microseconds)."""
        events: List[dict] = []
        with self._lock:
            spans = list(self._finished)
        tids = sorted({s.tid for s in spans})
        tid_map = {t: i for i, t in enumerate(tids)}  # compact, stable tids
        for i in tid_map.values():
            events.append({
                "ph": "M", "pid": self._pid, "tid": i,
                "name": "thread_name", "args": {"name": f"worker-{i}"},
            })
        for s in spans:
            args = dict(s.args) if s.args else {}
            args["span_id"] = s.span_id
            if s.parent_id is not None:
                args["parent_id"] = s.parent_id
            events.append({
                "name": s.name,
                "cat": s.cat,
                "ph": "X",
                "ts": s.t0_ns / 1e3,
                "dur": (s.dur_ns or 0) / 1e3,
                "pid": s.pid,
                "tid": tid_map.get(s.tid, 0),
                "args": args,
            })
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"epoch_unix": self._epoch_unix, "pid": self._pid},
        }

    def export_chrome(self, path: str) -> int:
        """Write the Chrome trace JSON to ``path``; returns #spans."""
        blob = self.to_chrome()
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w", encoding="utf-8") as f:
            json.dump(blob, f)
        return sum(1 for e in blob["traceEvents"] if e.get("ph") == "X")


_TRACER = Tracer()
if hasattr(os, "register_at_fork"):  # not on every platform
    os.register_at_fork(after_in_child=_TRACER._after_fork)


def tracer() -> Tracer:
    """The process-wide tracer instance."""
    return _TRACER


def span(
    name: str,
    cat: str = "tuning",
    *,
    parent: Optional[Span] = None,
    sampled: bool = False,
    **args,
):
    """Open a span on the process tracer (no-op context while disabled)."""
    return _TRACER.span(name, cat, parent=parent, sampled=sampled, **args)


def set_sample_rate(rate: float) -> None:
    """Set the process tracer's request-span sample rate."""
    _TRACER.set_sample_rate(rate)


def current_span() -> Optional[Span]:
    """This thread's innermost open span — capture before handing work to a
    pool, pass as ``parent=`` inside the worker."""
    return _TRACER.current()


def export_chrome(path: str) -> int:
    return _TRACER.export_chrome(path)
