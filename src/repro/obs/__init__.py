"""repro.obs — zero-dependency observability for the tuning stack.

Four pieces, one switch:

* :mod:`repro.obs.trace` — nestable spans (search → round → compile /
  measure / commit), thread-pool and fork aware, exported as Chrome trace
  JSON (``chrome://tracing`` / Perfetto).
* :mod:`repro.obs.metrics` — process-level counters / gauges / fixed-bucket
  histograms; always on (increments are too cheap to gate).
* :mod:`repro.obs.events` — a durable JSONL stream of every tuning decision,
  with the fsync discipline of the pretune run journal.
* :mod:`repro.obs.log` — the ``REPRO_LOG``-controlled diagnostic logger
  library code uses instead of ad-hoc ``print``.

Observability is a **sidecar**: the tuning DB schema, committed records and
search trajectories are identical with it on or off.  Tracing and the event
stream are opt-in via :func:`configure` — the CLIs wire ``--obs-dir`` (or
the ``REPRO_OBS`` env var) to it — and every instrumentation site costs a
single attribute check while disabled.

    from repro import obs
    obs.configure("artifacts/obs")      # or: REPRO_OBS=artifacts/obs
    ... tune ...
    obs.shutdown()                      # writes trace.json + metrics.json
                                        # (events.jsonl streamed all along)

Serving at scale: ``REPRO_OBS_SAMPLE=0.01`` (or ``configure(...,
sample=0.01)``) decimates the high-rate per-request artifacts — serving
``request`` spans and ``explore_rep`` events — to 1-in-N with a
deterministic counter stride.  Structural spans and accounting events are
never sampled; the sink reports what it dropped in a close-time
``sampling_summary`` so :func:`completeness` still balances.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from . import events as events  # noqa: F401 (re-export)
from . import metrics as metrics  # noqa: F401
from . import trace as trace  # noqa: F401
from .events import EventSink, completeness, emit, read_events, validate_events
from .log import get_logger, set_level
from .metrics import counter, gauge, histogram, registry
from .trace import current_span, export_chrome, span, tracer

__all__ = [
    "configure",
    "configure_from_env",
    "shutdown",
    "enabled",
    "obs_dir",
    "span",
    "current_span",
    "tracer",
    "export_chrome",
    "emit",
    "read_events",
    "validate_events",
    "completeness",
    "EventSink",
    "counter",
    "gauge",
    "histogram",
    "registry",
    "get_logger",
    "set_level",
]

_OBS_DIR: Optional[str] = None

#: env var: sample rate in (0, 1] for per-request spans + events
ENV_OBS_SAMPLE = "REPRO_OBS_SAMPLE"

TRACE_FILE = "trace.json"
EVENTS_FILE = "events.jsonl"
METRICS_FILE = "metrics.json"


def enabled() -> bool:
    """Whether tracing + the event stream are active."""
    return _OBS_DIR is not None


def obs_dir() -> Optional[str]:
    return _OBS_DIR


def configure(directory: Optional[str], *, sample: Optional[float] = None) -> bool:
    """Enable tracing + events into ``directory`` (created if missing).

    ``None`` / empty disables (and flushes what was buffered).  Returns
    whether observability is enabled afterwards.  Idempotent for the same
    directory; a new directory re-points the sink and resets the tracer.
    ``sample`` (default: the ``REPRO_OBS_SAMPLE`` env var, else keep
    everything) decimates per-request spans/events to roughly that
    fraction."""
    global _OBS_DIR
    if not directory:
        if _OBS_DIR is not None:
            shutdown()
        return False
    directory = os.path.abspath(directory)
    if sample is None:
        raw = os.environ.get(ENV_OBS_SAMPLE)
        if raw:
            sample = float(raw)
    if _OBS_DIR == directory:
        if sample is not None:
            t = tracer()
            t.set_sample_rate(sample)
            s = events.sink()
            if s is not None:
                s.set_sample_rate(sample)
        return True
    if _OBS_DIR is not None:
        shutdown()
    os.makedirs(directory, exist_ok=True)
    _OBS_DIR = directory
    t = tracer()
    t.reset()
    t.enable()
    sink = EventSink(os.path.join(directory, EVENTS_FILE))
    if sample is not None:
        t.set_sample_rate(sample)
        sink.set_sample_rate(sample)
    events.set_sink(sink)
    return True


def configure_from_env() -> bool:
    """Opt in via ``REPRO_OBS=<dir>`` (how ``serve``/``train``/``pretune``
    pick it up without a flag); ``REPRO_OBS_SAMPLE`` tunes request-level
    sampling."""
    return configure(os.environ.get("REPRO_OBS") or None)


def shutdown() -> Optional[str]:
    """Flush artifacts (``trace.json``, ``metrics.json``), fsync and close
    the event stream, and disable.  Returns the directory written, or
    ``None`` if obs was off."""
    global _OBS_DIR
    d = _OBS_DIR
    if d is None:
        return None
    t = tracer()
    try:
        t.export_chrome(os.path.join(d, TRACE_FILE))
        with open(os.path.join(d, METRICS_FILE), "w", encoding="utf-8") as f:
            json.dump(registry().snapshot(), f, indent=1, sort_keys=True)
    finally:
        s = events.sink()
        if s is not None:
            s.close()
        t.disable()
        events.set_sink(None)
        _OBS_DIR = None
    return d
