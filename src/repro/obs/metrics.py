"""Process-level metrics registry: counters, gauges, fixed-bucket histograms.

The primitives (:class:`Counter`, :class:`Gauge`, :class:`Histogram`) are
plain thread-safe objects that components own directly — the executable
cache's hit/miss accounting and the breaker/quarantine state counters are
*built on* these rather than kept as parallel ad-hoc ints.  The process
:class:`MetricsRegistry` additionally get-or-creates metrics by name for
cross-cutting series that no single object owns (compile seconds, rep
seconds, ε-credit spend, retries/timeouts, drift events), and snapshots the
whole registry to one JSON-able dict for ``repro.tune report`` and the
``metrics.json`` artifact.

Everything here is always-on: an increment is one lock acquisition on ints,
cheap enough that no call site needs gating (the <2% disabled-overhead gate
in ``benchmarks/obs_overhead.py`` measures exactly this).
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, List, Optional, Sequence, Union

__all__ = [
    "Counter",
    "MirroredStats",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "registry",
    "counter",
    "gauge",
    "histogram",
    "TIME_BUCKETS",
]

# log-spaced seconds ladder: 1µs .. 100s — covers timer reps (µs–ms) through
# AOT compiles and whole searches (s)
TIME_BUCKETS = (
    1e-6, 3e-6, 1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3,
    1e-2, 3e-2, 1e-1, 3e-1, 1.0, 3.0, 10.0, 30.0, 100.0,
)


class Counter:
    """Monotonic counter.

    Number-like on read (``==``/``<``/``int()``/``bool()``) so it can
    replace a public int attribute (``CircuitBreaker.opens``,
    ``Quarantine.strikes``) without breaking existing comparisons."""

    __slots__ = ("_lock", "_value")

    def __init__(self, value: int = 0) -> None:
        self._lock = threading.Lock()
        self._value = int(value)

    def inc(self, n: Union[int, float] = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> Union[int, float]:
        with self._lock:
            return self._value

    def snapshot(self) -> Union[int, float]:
        return self.value

    def _cmp_value(self, other):
        if isinstance(other, Counter):
            return other.value
        if isinstance(other, (int, float)):
            return other
        return NotImplemented

    def __eq__(self, other):
        v = self._cmp_value(other)
        return NotImplemented if v is NotImplemented else self.value == v

    def __lt__(self, other):
        v = self._cmp_value(other)
        return NotImplemented if v is NotImplemented else self.value < v

    def __le__(self, other):
        v = self._cmp_value(other)
        return NotImplemented if v is NotImplemented else self.value <= v

    def __gt__(self, other):
        v = self._cmp_value(other)
        return NotImplemented if v is NotImplemented else self.value > v

    def __ge__(self, other):
        v = self._cmp_value(other)
        return NotImplemented if v is NotImplemented else self.value >= v

    # mutable, so identity hash (value-eq Counters are not dict-key equal)
    __hash__ = object.__hash__

    def __int__(self) -> int:
        return int(self.value)

    def __float__(self) -> float:
        return float(self.value)

    def __index__(self) -> int:
        return int(self.value)

    def __bool__(self) -> bool:
        return bool(self.value)

    def __repr__(self) -> str:
        return f"Counter({self.value})"


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("_lock", "_value")

    def __init__(self, value: float = 0.0) -> None:
        self._lock = threading.Lock()
        self._value = value

    def set(self, v: Union[int, float, str]) -> None:
        with self._lock:
            self._value = v

    def inc(self, n: Union[int, float] = 1) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: Union[int, float] = 1) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self):
        with self._lock:
            return self._value

    def snapshot(self):
        return self.value

    def __repr__(self) -> str:
        return f"Gauge({self.value})"


class Histogram:
    """Fixed-bucket histogram: ``counts[i]`` counts observations ``<=
    buckets[i]`` (last bucket is the +inf overflow), plus running sum/count
    so means survive the bucketing."""

    __slots__ = ("_lock", "buckets", "counts", "sum", "count", "min", "max")

    def __init__(self, buckets: Sequence[float] = TIME_BUCKETS) -> None:
        b = sorted(float(x) for x in buckets)
        if not b:
            raise ValueError("histogram needs at least one bucket bound")
        self._lock = threading.Lock()
        self.buckets: List[float] = b
        self.counts: List[int] = [0] * (len(b) + 1)
        self.sum = 0.0
        self.count = 0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, x: float) -> None:
        x = float(x)
        i = bisect.bisect_left(self.buckets, x)
        with self._lock:
            self.counts[i] += 1
            self.sum += x
            self.count += 1
            if x < self.min:
                self.min = x
            if x > self.max:
                self.max = x

    def snapshot(self) -> dict:
        with self._lock:
            out = {
                "count": self.count,
                "sum": self.sum,
                "buckets": list(self.buckets),
                "counts": list(self.counts),
            }
            if self.count:
                out["mean"] = self.sum / self.count
                out["min"] = self.min
                out["max"] = self.max
            return out

    def __repr__(self) -> str:
        return f"Histogram(count={self.count}, sum={self.sum:.6g})"


class MirroredStats(dict):
    """A stats dict whose numeric increments mirror into the process
    registry under ``<prefix>.<key>`` — existing ``stats["x"] += 1``
    bookkeeping (measurement engine, online tuner) is thereby re-implemented
    on top of the metrics layer without changing a single read site.

    Only *growth* of numeric values is mirrored (counter semantics);
    non-numeric entries (``mode`` strings) and resets pass through to the
    dict alone.

    The per-key :class:`Counter` objects are cached after the first
    increment so the serving hot path pays one per-counter lock, not a name
    format plus the registry's global lock, per request.  The cache is
    invalidated by registry generation so a test's ``registry().reset()``
    never leaves increments flowing into detached counters."""

    __slots__ = ("_prefix", "_mirrors", "_gen")

    def __init__(self, prefix: str, init: Optional[dict] = None) -> None:
        super().__init__(init or {})
        self._prefix = prefix
        self._mirrors: Dict[str, Counter] = {}
        self._gen = -1

    def __setitem__(self, key, value) -> None:
        old = self.get(key, 0)
        super().__setitem__(key, value)
        if (
            isinstance(value, (int, float))
            and isinstance(old, (int, float))
            and value > old
        ):
            gen = _REGISTRY._generation
            if self._gen != gen:
                self._mirrors = {}
                self._gen = gen
            c = self._mirrors.get(key)
            if c is None:
                c = self._mirrors[key] = counter(f"{self._prefix}.{key}")
            c.inc(value - old)


class MetricsRegistry:
    """Name → metric, get-or-create, one :meth:`snapshot` for all of them.

    Names are dotted (``compile.seconds``, ``measure.rep_seconds``,
    ``online.eps_credit_spent``) so the snapshot reads as a flat namespace.
    Asking for an existing name with a different type raises — silent
    shadowing would corrupt the series."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}
        # bumped on reset() so cached metric handles (MirroredStats mirrors)
        # know to re-resolve instead of incrementing dropped counters
        self._generation = 0

    def _get_or_create(self, name: str, cls, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = factory()
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {type(m).__name__}, "
                    f"not {cls.__name__}"
                )
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge, Gauge)

    def histogram(
        self, name: str, buckets: Optional[Sequence[float]] = None
    ) -> Histogram:
        return self._get_or_create(
            name, Histogram, lambda: Histogram(buckets or TIME_BUCKETS)
        )

    def snapshot(self) -> dict:
        with self._lock:
            items = list(self._metrics.items())
        return {name: m.snapshot() for name, m in sorted(items)}

    def reset(self) -> None:
        """Drop every metric (tests and benchmark isolation)."""
        with self._lock:
            self._metrics = {}
            self._generation += 1


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-wide registry."""
    return _REGISTRY


def counter(name: str) -> Counter:
    return _REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return _REGISTRY.gauge(name)


def histogram(name: str, buckets: Optional[Sequence[float]] = None) -> Histogram:
    return _REGISTRY.histogram(name, buckets)
