"""Structured JSONL event stream — every tuning decision, reconstructible.

One line per event, appended with the durability discipline of the pretune
``RunJournal`` scaled to a hot loop's event rate.  The ``RunJournal``
fsyncs per append because its appends are per-case, seconds apart; an
event stream emits hundreds of times per second inside the search loop it
observes, so the same guarantee is delivered at milestone granularity
instead of per line:

* events queue in memory and a daemon writer thread JSON-encodes, writes
  and flushes them on a :data:`DRAIN_INTERVAL_S` cadence — emitting costs
  the loop a stamp, a schema check and a queue append, nothing more (an
  eager wake per event was measured to cost several percent of tuning
  throughput in GIL ping-pong alone);
* durable milestones — ``db_commit``, ``search_end``, ``drift_reset``,
  ``breaker_transition`` (:data:`DURABLE_EVENTS`) — make the writer's next
  drain ``os.fsync`` (rate-limited to once per :data:`FSYNC_INTERVAL_S`
  seconds), so a ``SIGKILL`` can cost at most one drain interval's tail of
  *forensic* events — the tuning results themselves are durably owned by
  the ``TuningDB``/``RunJournal``, never by this stream;
* :meth:`EventSink.flush` / :meth:`EventSink.close` (``obs.shutdown()``)
  drain, flush + fsync whatever remains, and the directory is fsynced when
  the file is created.

:func:`read_events` tolerates the torn trailing line a crash can leave
either way.

Event vocabulary (``EVENT_SCHEMA`` maps type → required fields; the sink
stamps ``ts`` (unix seconds), ``type`` and ``pid`` on every event):

=========================  ====================================================
``search_start``           a measured search began for context ``name``
``search_end``             it finished: ``best_point``/``best_cost``/``evals``
``candidate_asked``        the optimizer asked for a (deduped) candidate
``candidate_committed``    measured to completion; its cost entered the search
``candidate_culled``       racing stopped it early (with its CI bounds)
``candidate_pruned``       roofline bound killed it before any repetition
``candidate_skipped``      build/measure failure (``reason`` says which)
``candidate_quarantined``  refused outright: the key is quarantined
``warm_start``             DB seeded the search (``kind``: exact | neighbor)
``db_commit``              the keep-better commit that actually stored
``drift_reset``            a drift detector triggered a re-search
``breaker_transition``     circuit breaker state change
``explore_rep``            one online explore repetition landed (high-rate)
``sampling_summary``       emitted at close: how many events sampling dropped
=========================  ====================================================

The invariant the acceptance gate (and ``tests/test_obs.py``) checks: within
one search, every ``candidate_asked`` is answered by **exactly one** terminal
event — committed + culled + pruned + skipped + quarantined = asked
(:func:`completeness`).

High-rate per-request forensics (:data:`SAMPLED_EVENTS` — currently
``explore_rep``) can be decimated with :meth:`EventSink.set_sample_rate`
(the ``REPRO_OBS_SAMPLE`` env var): a deterministic 1-in-N counter stride
keeps replays reproducible, dropped events are tallied per context, and
``close()`` emits one ``sampling_summary`` event carrying the tallies —
:func:`completeness` surfaces them as ``sampled_out`` per name, so the
account of what happened still balances under sampling.  Accounting events
(``candidate_asked`` / terminals) are never sampled.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Union

__all__ = [
    "EVENT_SCHEMA",
    "TERMINAL_EVENTS",
    "DURABLE_EVENTS",
    "SAMPLED_EVENTS",
    "EventSink",
    "read_events",
    "validate_events",
    "completeness",
]

EVENT_SCHEMA: Dict[str, frozenset] = {
    "search_start": frozenset({"name"}),
    "search_end": frozenset({"name", "best_point", "best_cost", "evals"}),
    "candidate_asked": frozenset({"name", "point", "round"}),
    "candidate_committed": frozenset({"name", "point", "cost"}),
    "candidate_culled": frozenset({"name", "point", "cost"}),
    "candidate_pruned": frozenset({"name", "point", "bound"}),
    "candidate_skipped": frozenset({"name", "point", "reason"}),
    "candidate_quarantined": frozenset({"name", "point"}),
    "warm_start": frozenset({"name", "kind"}),
    "db_commit": frozenset({"name", "point", "cost"}),
    "drift_reset": frozenset({"name", "level"}),
    "breaker_transition": frozenset({"from_state", "to_state"}),
    "explore_rep": frozenset({"name", "point", "cost"}),
    "sampling_summary": frozenset({"sampled_out"}),
}

TERMINAL_EVENTS = frozenset({
    "candidate_committed",
    "candidate_culled",
    "candidate_pruned",
    "candidate_skipped",
    "candidate_quarantined",
})

#: high-rate per-request forensic events subject to sink-side sampling.
#: Never includes accounting events: ``candidate_asked``/terminals must stay
#: exact for the :func:`completeness` identity.
SAMPLED_EVENTS = frozenset({
    "explore_rep",
})

#: milestones after which durable state changed (a commit landed, a search
#: concluded, a guard tripped): these make the writer's next drain
#: ``os.fsync``
DURABLE_EVENTS = frozenset({
    "search_end",
    "db_commit",
    "drift_reset",
    "breaker_transition",
})

#: writer-thread wake interval: a milestone-free stretch of events queues
#: at most this long before being encoded + pushed to the OS (the most a
#: SIGKILL can cost)
DRAIN_INTERVAL_S = 0.2

#: fsync rate limit: requested syncs coalesce to at most one per this many
#: seconds (close() always syncs), bounding the power-loss window without
#: paying an fsync per milestone in a hot tuning loop
FSYNC_INTERVAL_S = 1.0


def _jsonable(x: Any):
    """numpy scalars / arrays / anything exotic → JSON-safe."""
    for attr in ("item",):  # numpy scalar
        if hasattr(x, attr):
            try:
                return x.item()
            except Exception:
                pass
    if hasattr(x, "tolist"):
        try:
            return x.tolist()
        except Exception:
            pass
    return str(x)


#: one shared C-accelerated encoder — ``json.dumps(..., default=...)``
#: builds a fresh ``JSONEncoder`` per call, which costs more than the
#: encode itself on the small dicts a hot loop emits
_ENCODER = json.JSONEncoder(sort_keys=True, separators=(",", ":"),
                            default=_jsonable)


class EventSink:
    """Append-only JSONL sink (thread-safe).

    ``emit`` stamps + schema-checks the event and enqueues it; a daemon
    writer thread JSON-encodes and writes the queue every
    :data:`DRAIN_INTERVAL_S`, so the serialization cost stays off the
    instrumented loop.  :data:`DURABLE_EVENTS` make the writer's next
    drain ``os.fsync`` (rate-limited to once per
    :data:`FSYNC_INTERVAL_S`).  Order is preserved: there is one queue and
    every drain holds the one I/O lock.

    Holds the file open across drains (one ``open()`` per event would cost
    more than the search loop it observes); a forked child transparently
    reopens its own handle, restarts its own writer, and stamps its own
    pid."""

    def __init__(self, path: str) -> None:
        self.path = str(path)
        self._q: deque = deque()
        self._wake = threading.Event()
        self._io_lock = threading.Lock()
        self._state_lock = threading.Lock()
        self._writer: Optional[threading.Thread] = None
        self._closed = False
        self._sync_due = False
        self._fresh = not os.path.exists(self.path)
        self._pid = os.getpid()
        self._f = None
        self._last_sync = 0.0
        self.emitted = 0
        # deterministic 1-in-N sampling of SAMPLED_EVENTS (no RNG: replayed
        # workloads drop the same events); dropped events are tallied per
        # context name and reported once via a close-time sampling_summary
        self._sample_stride = 1
        self._sample_n = 0
        self.sampled_out = 0
        self._sampled_out_by_name: Dict[str, int] = {}
        self._summary_emitted = False
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)

    def set_sample_rate(self, rate: float) -> None:
        """Keep roughly ``rate`` of :data:`SAMPLED_EVENTS` (1-in-N stride,
        ``N = round(1/rate)``).  ``rate >= 1`` keeps everything."""
        rate = float(rate)
        if not rate > 0.0:
            raise ValueError(f"sample rate must be > 0, got {rate}")
        self._sample_stride = max(1, round(1.0 / rate)) if rate < 1.0 else 1
        self._sample_n = 0

    def emit(self, type: str, **fields: Any) -> dict:  # noqa: A002 - event type
        """Append one event; returns the stamped dict."""
        ev = dict(fields)
        ev["type"] = type
        ev["ts"] = time.time()
        ev["pid"] = os.getpid()
        required = EVENT_SCHEMA.get(type)
        if required is not None:
            missing = required - set(ev)
            if missing:
                raise ValueError(f"event {type!r} missing fields {sorted(missing)}")
        if self._sample_stride > 1 and type in SAMPLED_EVENTS:
            n = self._sample_n
            self._sample_n = n + 1
            if n % self._sample_stride:
                self.sampled_out += 1
                name = ev.get("name")
                if name is not None:
                    by = self._sampled_out_by_name
                    by[name] = by.get(name, 0) + 1
                return ev  # stamped + validated, deliberately not persisted
        self._q.append(ev)
        self.emitted += 1
        self._ensure_writer()
        if type in DURABLE_EVENTS:
            self._sync_due = True
        return ev

    # ------------------------------------------------------------- internals
    def _ensure_writer(self) -> None:
        w = self._writer
        if w is not None and w.is_alive() and self._pid == os.getpid():
            return
        with self._state_lock:
            w = self._writer
            if (w is None or not w.is_alive()) and not self._closed:
                # first use, or a forked child whose parent's writer thread
                # did not survive the fork
                self._writer = threading.Thread(
                    target=self._writer_loop, name="obs-events-writer",
                    daemon=True)
                self._writer.start()

    def _writer_loop(self) -> None:
        while not self._closed:
            self._wake.wait(timeout=DRAIN_INTERVAL_S)
            self._wake.clear()
            if self._q or self._sync_due:
                try:
                    self._drain()
                except Exception:
                    if self._closed:
                        return
                    raise

    def _drain(self) -> None:
        """Encode + write everything queued; flush; fsync when a durable
        event requested it (rate-limited)."""
        with self._io_lock:
            if self._closed:
                return
            pid = os.getpid()
            if self._f is None or pid != self._pid:
                if self._f is not None:  # post-fork: drop the inherited handle
                    try:
                        self._f.close()
                    except OSError:
                        pass
                self._f = open(self.path, "a", encoding="utf-8")
                self._pid = pid
            wrote = False
            while True:
                try:
                    ev = self._q.popleft()
                except IndexError:
                    break
                self._f.write(_ENCODER.encode(ev) + "\n")
                wrote = True
            sync = self._sync_due
            if not (wrote or sync):
                return
            self._f.flush()
            now = time.time()
            if sync and now - self._last_sync >= FSYNC_INTERVAL_S:
                os.fsync(self._f.fileno())
                self._last_sync = now
                self._sync_due = False
            if self._fresh:
                self._fsync_dir()
                self._fresh = False

    def flush(self) -> None:
        """Drain the queue, push buffered lines to the OS and fsync."""
        if self._closed:
            return
        self._drain()
        with self._io_lock:
            if self._f is not None:
                try:
                    os.fsync(self._f.fileno())
                    self._last_sync = time.time()
                    self._sync_due = False
                except (OSError, ValueError):
                    pass

    def close(self) -> None:
        """Drain + flush + fsync whatever is pending and release the
        handle (idempotent).  If sampling dropped events, one
        ``sampling_summary`` carrying the tallies is appended first."""
        if (self.sampled_out and not self._closed
                and not self._summary_emitted):
            self._summary_emitted = True  # before emit: close() may re-enter
            self.emit(
                "sampling_summary",
                sampled_out=self.sampled_out,
                per_name=dict(self._sampled_out_by_name),
                stride=self._sample_stride,
            )
        with self._state_lock:
            if self._closed:
                return
            w = self._writer
            self._writer = None
        try:
            self._drain()
        except (OSError, ValueError):
            pass
        self._closed = True
        self._wake.set()
        if w is not None and w.is_alive() and w is not threading.current_thread():
            w.join(timeout=2.0)
        with self._io_lock:
            if self._f is None:
                return
            try:
                self._f.flush()
                os.fsync(self._f.fileno())
            except (OSError, ValueError):
                pass
            try:
                self._f.close()
            except OSError:
                pass
            self._f = None

    def _fsync_dir(self) -> None:
        d = os.path.dirname(os.path.abspath(self.path))
        try:
            fd = os.open(d, os.O_RDONLY)
        except OSError:
            return  # platform without directory fds
        try:
            os.fsync(fd)
        except OSError:
            pass
        finally:
            os.close(fd)


def read_events(path: str) -> List[dict]:
    """All events in ``path`` in order; a torn/garbled trailing line (the
    crash case fsync discipline allows) ends the read instead of raising."""
    out: List[dict] = []
    if not os.path.exists(path):
        return out
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except ValueError:
                break  # torn trailing line: keep the readable prefix
            if isinstance(ev, dict):
                out.append(ev)
    return out


def validate_events(
    events: Union[str, Iterable[dict]], *, strict_types: bool = True
) -> List[str]:
    """Schema-check an event stream (path or parsed list); returns the list
    of problems (empty = valid).  ``strict_types=False`` lets unknown event
    types pass (forward compatibility), still checking the known ones."""
    if isinstance(events, str):
        events = read_events(events)
    problems: List[str] = []
    for i, ev in enumerate(events):
        t = ev.get("type")
        if t is None:
            problems.append(f"event {i}: no 'type'")
            continue
        for base in ("ts", "pid"):
            if base not in ev:
                problems.append(f"event {i} ({t}): missing {base!r}")
        required = EVENT_SCHEMA.get(t)
        if required is None:
            if strict_types:
                problems.append(f"event {i}: unknown type {t!r}")
            continue
        missing = required - set(ev)
        if missing:
            problems.append(f"event {i} ({t}): missing fields {sorted(missing)}")
    return problems


def completeness(events: Union[str, Iterable[dict]]) -> dict:
    """Candidate accounting per search ``name``: asked vs terminal events.

    Returns ``{name: {"asked": n, "committed": ..., "culled": ...,
    "pruned": ..., "skipped": ..., "quarantined": ...,
    "sampled_out": ..., "balanced": bool}}`` where ``balanced`` is the
    acceptance invariant (terminals sum == asked — sampling never touches
    accounting events, so the identity holds at any sample rate;
    ``sampled_out`` reports how many forensic events the sink dropped for
    that name, recovered from the close-time ``sampling_summary``)."""
    if isinstance(events, str):
        events = read_events(events)
    short = {
        "candidate_committed": "committed",
        "candidate_culled": "culled",
        "candidate_pruned": "pruned",
        "candidate_skipped": "skipped",
        "candidate_quarantined": "quarantined",
    }
    def _fresh() -> Dict[str, Any]:
        return {
            "asked": 0, "committed": 0, "culled": 0,
            "pruned": 0, "skipped": 0, "quarantined": 0, "sampled_out": 0,
        }

    acc: Dict[str, Dict[str, Any]] = {}
    for ev in events:
        t = ev.get("type")
        if t == "sampling_summary":
            for name, n in (ev.get("per_name") or {}).items():
                acc.setdefault(name, _fresh())["sampled_out"] += int(n)
            continue
        name = ev.get("name")
        if name is None or (t != "candidate_asked" and t not in TERMINAL_EVENTS):
            continue
        a = acc.setdefault(name, _fresh())
        if t == "candidate_asked":
            a["asked"] += 1
        else:
            a[short[t]] += 1
    for a in acc.values():
        terminal = sum(a[k] for k in
                       ("committed", "culled", "pruned", "skipped", "quarantined"))
        a["terminal"] = terminal
        # sequential (non-batch) searches emit terminal events without asked
        # events — only the batched ask/tell path owes the exact identity
        a["balanced"] = terminal == a["asked"] if a["asked"] else True
    return acc


# ------------------------------------------------------------ process sink
_SINK: Optional[EventSink] = None
_SINK_LOCK = threading.Lock()


def set_sink(sink: Optional[EventSink]) -> None:
    global _SINK
    with _SINK_LOCK:
        _SINK = sink


def sink() -> Optional[EventSink]:
    return _SINK


def emit(type: str, **fields: Any) -> None:  # noqa: A002 - event type
    """Emit on the process sink; no-op (and allocation-free on the common
    path) while no sink is configured."""
    s = _SINK
    if s is None:
        return
    s.emit(type, **fields)
