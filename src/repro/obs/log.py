"""``repro.obs.log`` — the framework's diagnostic logger.

Library code gets its channel with::

    from repro.obs.log import get_logger
    log = get_logger(__name__)          # -> logging.Logger "repro.core.costs"
    log.info("warm start from neighbor %s", point)

Diagnostics go to **stderr** (stdout belongs to the CLIs' human-readable
summaries), formatted ``[repro.<module>] message``.  The channel level is
controlled by ``REPRO_LOG``:

* ``debug`` — everything, including per-candidate eval lines,
* ``info``  — the default: warm starts, skips, quarantines, db notices,
* ``quiet`` — errors only.

The handler resolves ``sys.stderr`` at emit time (not at import), so
test harnesses that swap the stream (pytest ``capsys``) capture log output
like any other write.  ``logging``'s own propagation/levels still apply:
applications embedding the library can attach their own handlers to the
``"repro"`` logger and call :func:`set_level` (or mutate the logger) freely.
"""
from __future__ import annotations

import logging
import os
import sys
import threading

__all__ = ["get_logger", "set_level", "LEVELS"]

LEVELS = {
    "debug": logging.DEBUG,
    "info": logging.INFO,
    "quiet": logging.ERROR,
}

_ROOT_NAME = "repro"
_lock = threading.Lock()
_configured = False


class _LiveStderrHandler(logging.StreamHandler):
    """StreamHandler bound to *whatever* ``sys.stderr`` currently is."""

    def __init__(self) -> None:
        logging.Handler.__init__(self)

    @property
    def stream(self):  # type: ignore[override]
        return sys.stderr

    @stream.setter
    def stream(self, value) -> None:  # StreamHandler.setStream compatibility
        pass


def _ensure_configured() -> logging.Logger:
    global _configured
    root = logging.getLogger(_ROOT_NAME)
    if _configured:
        return root
    with _lock:
        if _configured:
            return root
        handler = _LiveStderrHandler()
        handler.setFormatter(logging.Formatter("[%(name)s] %(message)s"))
        root.addHandler(handler)
        root.propagate = False  # stderr once, not again via the root logger
        spec = os.environ.get("REPRO_LOG", "info").strip().lower()
        root.setLevel(LEVELS.get(spec, logging.INFO))
        _configured = True
    return root


def set_level(spec: str) -> None:
    """Set the framework channel level: ``debug`` | ``info`` | ``quiet``
    (or any :mod:`logging` level name)."""
    root = _ensure_configured()
    level = LEVELS.get(spec.strip().lower())
    if level is None:
        level = logging.getLevelName(spec.strip().upper())
        if not isinstance(level, int):
            raise ValueError(f"unknown log level {spec!r}")
    root.setLevel(level)


def get_logger(name: str) -> logging.Logger:
    """The diagnostic channel for ``name`` (module path), rooted under
    ``repro`` so one handler and one ``REPRO_LOG`` level govern them all."""
    _ensure_configured()
    if name == _ROOT_NAME or name.startswith(_ROOT_NAME + "."):
        return logging.getLogger(name)
    return logging.getLogger(f"{_ROOT_NAME}.{name}")
