"""Optimizer substrate: AdamW (dtype-configurable states), schedules, clipping.

Pure-JAX (no optax in this environment).  ``state_dtype='bfloat16'`` halves
optimizer memory — used by the 405B/480B dry-run cells (recorded in
EXPERIMENTS.md); fp32 is the default."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "cosine_schedule", "clip_by_global_norm", "global_norm"]


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable  # step -> lr  (or a float)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: Optional[str] = None  # None -> follow param dtype ("float32"/"bfloat16")
    clip_norm: float = 1.0

    def _sdt(self, p):
        return jnp.dtype(self.state_dtype) if self.state_dtype else p.dtype

    def init(self, params) -> dict:
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, self._sdt(p)), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, self._sdt(p)), params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        if self.clip_norm > 0:
            grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        else:
            gnorm = global_norm(grads)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
            mh = mf / c1
            vh = vf / c2
            step_ = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * step_
            return newp.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step}, {
            "grad_norm": gnorm,
            "lr": jnp.asarray(lr, jnp.float32),
        }
