"""Serving launcher: batched prefill + decode with a KV cache and
PATSMA-tuned decode fusion depth.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_7b --tiny \
        --batch 8 --prompt-len 32 --gen 64 --db tuned/serve.json

Decode is routed through a :class:`repro.runtime.ContextRouter`: each
(arch × batch size) is its own tuning context keyed by ``TuningKey``
fingerprint, an ε-fraction of decode chunks explores a candidate fusion
depth ``k``, and the rest exploit the best known.  Candidate variants are
AOT-compiled on a background pool (and every candidate is prewarmed before
the first token), so the token stream never stalls on XLA.  A
``DriftDetector`` watches the per-token exploit costs and re-tunes the
context mid-stream — at half budget, seeded at the deployed ``k`` — when
they degrade.

With ``--db`` the tuned fusion depth persists across launches: the second
process with the same (arch, batch) context skips tuning entirely and
decodes at the stored-best ``k`` from the first token.  ``--no-tune --db``
replays that stored best statically (no exploration, no drift handling);
``--no-tune`` without a DB record falls back to ``k=1``.

``--db`` is repeatable: extra paths are fleet shard DBs (``repro.tune
pretune --shard i/n`` outputs) folded read-only into the first at startup
with the fleet's keep-better resolver — serving a host straight off its
fleet's shards without a separate ``repro.tune db merge`` step.  Only the
first path is written back to.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import ChoiceDim, SearchSpace
from repro.models import ExecConfig, Model
from repro.runtime import ContextRouter
from repro.tuning import TuningDB, make_key

#: candidate decode fusion depths (tokens emitted per dispatched scan)
DECODE_KS = (1, 2, 4, 8)


def replay_decode_k(db, key, *, gen: int, default: int = 1) -> int:
    """Stored-best decode ``k`` for a context, for static (``--no-tune``)
    serving: an exact DB hit replays its point, otherwise ``default``.
    Clamped to the stream length."""
    k = default
    if db is not None and key is not None:
        rec = db.get(key)
        if rec is not None and "k" in rec.point:
            k = int(rec.point["k"])
    return max(1, min(k, gen))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="qwen2_7b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--no-tune", action="store_true")
    ap.add_argument("--db", type=str, default=None, action="append",
                    help="tuning DB path; persists the tuned decode k across "
                         "runs.  Repeatable: extra paths are fleet shard DBs "
                         "merged (keep-better) into the first at startup")
    ap.add_argument("--epsilon", type=float, default=0.25,
                    help="explored fraction of decode chunks while tuning")
    ap.add_argument("--objective", choices=("median", "p95", "p99"),
                    default="median",
                    help="statistic the decode-k search minimizes (p95/p99 "
                         "tune for tail latency; the drift detector watches "
                         "the same quantile)")
    ap.add_argument("--obs-dir", type=str, default=None,
                    help="write observability artifacts (events.jsonl, "
                         "trace.json, metrics.json) into this directory "
                         "(default: the REPRO_OBS env var, else off)")
    args = ap.parse_args()

    from repro import obs

    if args.obs_dir:
        obs.configure(args.obs_dir)
    else:
        obs.configure_from_env()
    try:
        with obs.span("serve", gen=args.gen):
            _serve(args)
    finally:
        obs.shutdown()


def _serve(args):

    cfg = configs.get_tiny(args.arch) if args.tiny else configs.get(args.arch)
    model = Model(cfg, ExecConfig(rec_chunk=4))
    params = model.init(jax.random.PRNGKey(0))
    B, P = args.batch, args.prompt_len
    max_len = P + args.gen
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab_size),
             "max_len": max_len}
    if cfg.is_encdec:
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.ctx_tokens, cfg.d_model))
    if cfg.family == "vlm":
        batch["ctx_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.ctx_tokens, cfg.d_model))

    t0 = time.perf_counter()
    hidden, states = model.prefill(params, batch)
    logits = model.logits(params, hidden[:, None])[:, 0]
    token = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    jax.block_until_ready(token)
    print(f"prefill {B}x{P}: {(time.perf_counter()-t0)*1e3:.0f} ms")

    def make_multi(k):
        @jax.jit
        def run(params, token, states, pos):
            def body(carry, _):
                token, states, pos = carry
                lg, states = model.decode_step(params, token, states, pos)
                nxt = jnp.argmax(lg, -1).astype(jnp.int32)[:, None]
                return (nxt, states, pos + 1), nxt
            (token, states, pos), toks = jax.lax.scan(
                body, (token, states, pos), None, length=k)
            return token, states, pos, toks
        return run

    # only depths that fit the stream are candidates: a k > --gen chunk can
    # never run whole, so it could never be measured and the search would
    # stall on it (short streams get their own space hash, hence their own
    # tuning context — a k=8-capable record says nothing about a 4-token job)
    ks = tuple(k for k in DECODE_KS if k <= args.gen) or (1,)
    space = SearchSpace([ChoiceDim("k", ks)])
    db = None
    if args.db:
        db = TuningDB(args.db[0])
        if len(args.db) > 1:
            from repro.tuning.fleet import merge_dbs

            stats = merge_dbs(db, [TuningDB(p, autosave=False) for p in args.db[1:]])
            print(f"merged {len(args.db) - 1} shard DB(s) into {args.db[0]}: {stats}")
    extra = {"arch": args.arch, "tiny": args.tiny, "batch": args.batch}
    key = make_key("serve/decode_k", space=space, extra=extra) if db else None
    pos = jnp.int32(P)
    tail_fns = {}  # final-chunk sizes (k > remaining): compiled on demand

    if args.no_tune:
        # static serving still honours the DB: replay the stored-best k
        k_static = replay_decode_k(db, key, gen=args.gen)
        if db is not None and k_static != 1:
            print(f"--no-tune: replaying stored decode k={k_static} from {args.db[0]}")
        fn_static = make_multi(k_static).lower(params, token, states, pos).compile()
        emitted = 0
        t0 = time.perf_counter()
        while emitted < args.gen:
            k = min(k_static, args.gen - emitted)
            fn = fn_static if k == k_static else tail_fns.setdefault(k, make_multi(k))
            token, states, pos, toks = fn(params, token, states, pos)
            jax.block_until_ready(toks)
            emitted += k
        wall = time.perf_counter() - t0
        print(f"decode: {emitted} tok/seq x {B} in {wall*1e3:.0f} ms "
              f"({B*emitted/wall:.0f} tok/s); static k={k_static}")
        return

    # adaptive serving: per-(arch, batch) decode-k context with background
    # candidate compiles and mid-stream drift re-tuning
    router = ContextRouter(db=db, jobs=max(1, len(DECODE_KS)))
    router.register(
        "serve/decode_k",
        space=lambda: space,
        build=lambda point: make_multi(point["k"]).lower(
            params, token, states, pos).compile(),
        defaults=lambda: {"k": 1},
        epsilon=args.epsilon,
        num_opt=3,
        max_iter=4,
        # tail objectives need a multi-rep stream per candidate (the p99 of
        # one rep is that rep); the median default keeps the classic
        # one-explore-one-measurement serving loop
        measure=None if args.objective == "median"
        else {"mode": "fixed", "repeats": 8, "objective": args.objective},
        drift={"window": 8, "min_samples": 4, "factor": 1.5},
        extra=extra,
    )
    tuner = router.tuner("serve/decode_k")
    # prewarm every candidate that fits the stream (on a DB hit, just the
    # stored best) so the first token needs zero in-band compiles
    if tuner.finished:
        points = [{"k": min(int(tuner.best_point["k"]), args.gen)}]
        print(f"tuning db hit: decode k={tuner.best_point['k']} (no online tuning)")
    else:
        points = [{"k": k} for k in ks]
    t0 = time.perf_counter()
    tuner.prewarm(points, wait=True)
    print(f"precompiled decode variants k={[p['k'] for p in points]} "
          f"in {(time.perf_counter() - t0) * 1e3:.0f} ms")

    emitted = 0
    t0 = time.perf_counter()
    while emitted < args.gen:
        rem = args.gen - emitted
        if rem < ks[-1]:
            # stream tail: not every candidate fits any more, so don't
            # consume a routing decision that might be unmeasurable — serve
            # the clamped best unmeasured (a shorter scan is a different
            # program, its cost would not describe the candidate's k)
            k = max(1, min(int(tuner.exploit_point().get("k", 1)), rem))
            fn = tuner.executable_for({"k": k}) if k in ks else None
            if fn is None:
                fn = tail_fns.setdefault(k, make_multi(k))
            token, states, pos, toks = fn(params, token, states, pos)
            jax.block_until_ready(toks)
            emitted += k
            continue
        decision = router.begin("serve/decode_k")
        k = int(decision.point["k"])  # always <= ks[-1] <= rem here
        tc = time.perf_counter()
        if decision.executable is not None:
            token, states, pos, toks = decision.executable(params, token, states, pos)
        else:  # cold exploit before the background build lands
            fn = tail_fns.setdefault(k, make_multi(k))
            token, states, pos, toks = fn(params, token, states, pos)
        jax.block_until_ready(toks)
        router.observe(decision, (time.perf_counter() - tc) / k)
        emitted += k
    wall = time.perf_counter() - t0
    rs = router.stats()
    print(f"decode: {emitted} tok/seq x {B} in {wall*1e3:.0f} ms "
          f"({B*emitted/wall:.0f} tok/s); tuned k={tuner.best_point.get('k')}")
    print(f"router: {rs['explores']} explore / {rs['exploits']} exploit chunks, "
          f"{rs['drift_resets']} drift re-tunes, "
          f"{rs['cache']['misses']} compiles ({rs['inband_builds']} in-band), "
          f"{rs['cache']['recompiles']} recompiles")


if __name__ == "__main__":
    main()
