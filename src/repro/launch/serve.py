"""Serving launcher: batched prefill + decode with a KV cache and
PATSMA-tuned decode fusion depth.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_7b --tiny \
        --batch 8 --prompt-len 32 --gen 64 --db tuned/serve.json

All candidate decode-``k`` variants are AOT-compiled concurrently before the
first token (XLA compilation releases the GIL), so online tuning never stalls
the token stream on a compile.  With ``--db`` the tuned fusion depth persists
across launches: the second process with the same (arch, batch) context skips
tuning entirely and decodes at the stored-best ``k`` from the first token.
"""
import argparse
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import Autotuning, CSA, ChoiceDim, SearchSpace
from repro.models import ExecConfig, Model
from repro.tuning import TuningDB, make_key


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="qwen2_7b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--no-tune", action="store_true")
    ap.add_argument("--db", type=str, default=None,
                    help="tuning DB path; persists the tuned decode k across runs")
    args = ap.parse_args()

    cfg = configs.get_tiny(args.arch) if args.tiny else configs.get(args.arch)
    model = Model(cfg, ExecConfig(rec_chunk=4))
    params = model.init(jax.random.PRNGKey(0))
    B, P = args.batch, args.prompt_len
    max_len = P + args.gen
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab_size),
             "max_len": max_len}
    if cfg.is_encdec:
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.ctx_tokens, cfg.d_model))
    if cfg.family == "vlm":
        batch["ctx_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.ctx_tokens, cfg.d_model))

    t0 = time.perf_counter()
    hidden, states = model.prefill(params, batch)
    logits = model.logits(params, hidden[:, None])[:, 0]
    token = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    jax.block_until_ready(token)
    print(f"prefill {B}x{P}: {(time.perf_counter()-t0)*1e3:.0f} ms")

    def make_multi(k):
        @jax.jit
        def run(params, token, states, pos):
            def body(carry, _):
                token, states, pos = carry
                lg, states = model.decode_step(params, token, states, pos)
                nxt = jnp.argmax(lg, -1).astype(jnp.int32)[:, None]
                return (nxt, states, pos + 1), nxt
            (token, states, pos), toks = jax.lax.scan(
                body, (token, states, pos), None, length=k)
            return token, states, pos, toks
        return run

    space = SearchSpace([ChoiceDim("k", (1, 2, 4, 8))])
    db = TuningDB(args.db) if args.db else None
    key = None
    if db is not None:
        key = make_key(
            "serve/decode_k", space=space,
            extra={"arch": args.arch, "tiny": args.tiny, "batch": args.batch},
        )
    at = Autotuning(space=space, ignore=1,
                    optimizer=CSA(1, num_opt=3, max_iter=4, seed=0), cache=True,
                    db=db, key=key)
    if at.finished and at.warm_started:
        print(f"tuning db hit: decode k={at.point['k']} (no online tuning)")
    fns = {}
    pos = jnp.int32(P)
    if not args.no_tune:
        # pre-compile every candidate fusion depth concurrently so the tuner's
        # first visit to each k costs a dict lookup, not a compile, and the
        # token stream never stalls; on a DB hit only the stored best is needed
        variants = [k for k in space.dims[0].values if k <= args.gen]
        if at.finished:
            # the stored best may exceed --gen (or any candidate value):
            # precompile exactly the k the first decode chunk will use
            variants = [min(at.point["k"], args.gen)]
        t0 = time.perf_counter()
        with ThreadPoolExecutor(max_workers=max(1, len(variants))) as pool:
            compiled = pool.map(
                lambda k: make_multi(k).lower(params, token, states, pos).compile(),
                variants,
            )
            fns = dict(zip(variants, compiled))
        print(
            f"precompiled decode variants k={variants} "
            f"in {(time.perf_counter() - t0) * 1e3:.0f} ms"
        )
    emitted = 0
    t0 = time.perf_counter()
    while emitted < args.gen:
        k = 1 if args.no_tune else at.point["k"]
        k = min(k, args.gen - emitted)
        fn = fns.setdefault(k, make_multi(k))
        tc = time.perf_counter()
        token, states, pos, toks = fn(params, token, states, pos)
        jax.block_until_ready(toks)
        if not args.no_tune:
            at.exec((time.perf_counter() - tc) / k)
        emitted += k
    wall = time.perf_counter() - t0
    print(f"decode: {emitted} tok/seq x {B} in {wall*1e3:.0f} ms "
          f"({B*emitted/wall:.0f} tok/s); tuned k={at.best_point.get('k')}")


if __name__ == "__main__":
    main()
