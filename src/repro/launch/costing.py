"""Dry-run cost accounting.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so a
scan-over-layers program under-reports FLOPs/bytes/collectives by ~n_layers.
This module corrects that exactly:

    total = reported(step) + Σ_stages (n_groups_s - 1) × probe(stage body_s)

where ``probe`` lowers ONE stage body in isolation (same shapes, same mesh,
same sharding rules, same remat policy; value-and-grad of the body for train
steps so the backward scan body is included) and reads its cost_analysis +
HLO collective bytes.  The RWKV chunk loop is unrolled in dry-run lowering
(``ExecConfig.rec_unroll``) so no nested while remains.  Validated against a
fully-unrolled lowering in tests/test_dryrun_small.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import collective_bytes, hlo_flops_bytes
from repro.parallel.api import ShardingRules, logical_spec, sharding_context
from repro.parallel.sharding import param_wanted, state_wanted, tree_shardings
from jax.sharding import NamedSharding, PartitionSpec

__all__ = ["CostTerms", "measure", "stage_probe", "corrected_cost"]


@dataclasses.dataclass
class CostTerms:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    coll_bytes: float = 0.0

    def __add__(self, o):
        return CostTerms(
            self.flops + o.flops,
            self.bytes_accessed + o.bytes_accessed,
            self.coll_bytes + o.coll_bytes,
        )

    def scaled(self, f: float):
        return CostTerms(self.flops * f, self.bytes_accessed * f, self.coll_bytes * f)

    def as_dict(self):
        return dataclasses.asdict(self)


def measure(compiled, hlo_text: Optional[str] = None) -> CostTerms:
    flops, nbytes = hlo_flops_bytes(compiled)
    text = hlo_text if hlo_text is not None else compiled.as_text()
    return CostTerms(flops, nbytes, float(collective_bytes(text)))


def _slice0(tree):
    """ShapeDtypeStruct tree: drop the leading (group-stack) dim."""
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), tree
    )


def stage_probe(
    model,
    si: int,
    mesh,
    rules: ShardingRules,
    *,
    B: int,
    S: int,
    mode: str,
    train: bool,
    ctx_tokens: int = 0,
    encoder: bool = False,
) -> CostTerms:
    """Lower one stage body (fwd, or fwd+bwd for train) and return its cost."""
    cfg = model.cfg
    stage_defs = [(("attn",), cfg.enc_layers)] if encoder else model.stage_defs
    kinds, ng = stage_defs[si]
    cdt = jnp.dtype(cfg.compute_dtype)

    params_tree = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    stages = params_tree["encoder"]["stages"] if encoder else params_tree["stages"]
    gp_spec = _slice0(stages[si])
    states = jax.eval_shape(
        lambda: model._init_states_for(stage_defs, B, S if mode != "decode" else S, mode)
    )
    gst_spec = _slice0(states[si])
    x_spec = jax.ShapeDtypeStruct((B, 1 if mode == "decode" else S, cfg.d_model), cdt)
    ctx_spec = (
        jax.ShapeDtypeStruct((B, ctx_tokens, cfg.d_model), cdt) if ctx_tokens else None
    )
    q_pos = jax.ShapeDtypeStruct((1 if mode == "decode" else S,), jnp.int32)
    causal = not encoder

    def run_body(gp, x, gst, q_pos, ctx):
        body = model.make_stage_body(kinds, q_pos=q_pos, ctx=ctx, mode=mode, causal=causal)
        (x2, aux), st = body((x, jnp.zeros((), jnp.float32)), (gp, gst))
        return x2, aux, st

    def grad_body(gp, x, gst, q_pos, ctx):
        def scalar(gp_, x_):
            x2, aux, _ = run_body(gp_, x_, gst, q_pos, ctx)
            return jnp.sum(x2.astype(jnp.float32)) + aux

        val, grads = jax.value_and_grad(scalar, argnums=(0, 1))(gp, x)
        return val, grads

    # shardings: params via the stage rules (prepend the stripped stack dim),
    # activations dp-sharded, states via state rules.
    def gp_wanted(path, shape):
        return param_wanted("stages/0/" + path, len(shape) + 1)[1:]

    def gst_wanted(path, shape):
        return state_wanted("0/" + path, len(shape) + 1,
                            tp_size=mesh.shape.get("model", 0))[1:]

    gp_sh = tree_shardings(mesh, rules, gp_spec, gp_wanted)
    gst_sh = tree_shardings(mesh, rules, gst_spec, gst_wanted)
    x_sh = NamedSharding(mesh, logical_spec(mesh, rules, x_spec.shape, ("dp", "sp", None)))
    pos_sh = NamedSharding(mesh, PartitionSpec())
    args = [gp_spec, x_spec, gst_spec, q_pos]
    shardings = [gp_sh, x_sh, gst_sh, pos_sh]
    if ctx_spec is not None:
        args.append(ctx_spec)
        shardings.append(
            NamedSharding(mesh, logical_spec(mesh, rules, ctx_spec.shape, ("dp", None, None)))
        )
        fwd_fn, grd_fn = run_body, grad_body
    else:
        fwd_fn = lambda gp, x, gst, q_pos: run_body(gp, x, gst, q_pos, None)
        grd_fn = lambda gp, x, gst, q_pos: grad_body(gp, x, gst, q_pos, None)

    # out_shardings matter: without them GSPMD may back-propagate a
    # replicated output layout through the whole body (measured 100x flops
    # inflation on MoE probes).
    st_sh = tree_shardings(mesh, rules, jax.eval_shape(fwd_fn, *args)[2], gst_wanted)
    aux_sh = NamedSharding(mesh, PartitionSpec())
    fwd_out_sh = (x_sh, aux_sh, st_sh)
    grd_out_sh = (aux_sh, (gp_sh, x_sh))

    def _measure(fn, out_sh):
        with sharding_context(mesh, rules):
            lowered = jax.jit(
                fn, in_shardings=tuple(shardings), out_shardings=out_sh
            ).lower(*args)
        return measure(lowered.compile())

    if not train:
        return _measure(fwd_fn, fwd_out_sh)
    g = _measure(grd_fn, grd_out_sh)
    if model.exec_cfg.remat in ("full", "dots"):
        # the scan's backward pass re-runs the (checkpointed) forward; a
        # straight-line grad program CSE's that recompute away, so add the
        # forward cost explicitly ("dots" recompute is bounded above by full).
        f = _measure(fwd_fn, fwd_out_sh)
        g = g + f
    return g


def attention_traffic(cfg, shape, dp: int, tp: int) -> dict:
    """Analytic per-chip HBM traffic of the attention score tensors.

    Used by §Perf iterations that substitute the Pallas flash kernel for the
    XLA attention path: the dry-run lowers XLA attention (Pallas cannot lower
    without a TPU), so the kernel's effect on the memory term is applied as
        bytes' = bytes - xla_scores + flash_io
    with the estimates below (documented in EXPERIMENTS.md §Perf):

      xla_scores: scores elems × 4 B × passes, passes = 6 (fwd) / 20 (train:
                  fwd + remat recompute + bwd chains), ×0.5 if causal;
      flash_io:   Q/K/V reads + O write only (the S² tile never leaves VMEM),
                  ×1 (fwd) / ×3.5 (train).
    Head sharding follows models.attention: KV heads if Kh % tp == 0, else
    the GQA group dim if g % tp == 0, else batch-only.
    """
    train = shape.kind == "train"
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    Skv_decode = shape.seq_len
    B_loc = B // dp if B % dp == 0 else B
    H, Kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = H // Kh
    hshard = tp if (Kh % tp == 0 or g % tp == 0) else 1
    passes = 20.0 if train else 6.0
    fl_mult = 3.5 if train else 1.0
    # MXU dot passes over the S² tile (QK + PV): fwd 2; train adds the remat
    # recompute (2) and the backward chain dQ/dK/dV/dP (~5).
    dot_passes = 9.0 if train else 2.0

    def inst(sq, skv, causal):
        frac = 0.5 if causal and sq == skv else 1.0
        elems = B_loc * (H / hshard) * sq * skv * frac
        xla = elems * 4.0 * passes
        flash = B_loc * (2 * H * sq + 2 * Kh * skv) * hd * 2.0 * fl_mult
        flops = dot_passes * elems * hd * 2.0
        return xla, flash, flops

    xla = flash = flops = 0.0
    skv_self = min(cfg.window, S) if cfg.window else S
    if shape.kind == "decode":
        skv_self = min(cfg.window, Skv_decode) if cfg.window else Skv_decode
    for kind in cfg.pattern:
        if kind in ("attn", "cross"):
            a, f, fl = inst(S, skv_self, causal=True)
            xla += a
            flash += f
            flops += fl
        if kind == "cross":
            a, f, fl = inst(S, cfg.ctx_tokens, causal=False)
            xla += a
            flash += f
            flops += fl
    if cfg.is_encdec and shape.kind != "decode":
        a, f, fl = inst(cfg.ctx_tokens, cfg.ctx_tokens, causal=False)
        xla += a * cfg.enc_layers
        flash += f * cfg.enc_layers
        flops += fl * cfg.enc_layers
    return {"xla_bytes": xla, "flash_bytes": flash, "flash_flops": flops}


def corrected_cost(model, step_cost: CostTerms, probes: dict) -> CostTerms:
    """total = step + Σ (ng-1) × probe (+ (enc_layers-1) × encoder probe)."""
    total = step_cost
    for si, (kinds, ng) in enumerate(model.stage_defs):
        if ng > 1 and si in probes:
            total = total + probes[si].scaled(ng - 1)
    if "encoder" in probes and model.cfg.enc_layers > 1:
        total = total + probes["encoder"].scaled(model.cfg.enc_layers - 1)
    return total
