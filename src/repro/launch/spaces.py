"""Launch-level search spaces: tune the whole launch, not just kernel tiles.

PATSMA's thesis is that execution parameters worth tuning live at every layer
of a parallel program.  This module registers the *launch* layer's knobs as
first-class :class:`~repro.core.space.SearchSpace`s behind the existing
``Autotuning``/``search=``/DB/measure stack, so launch configs get the same
fingerprinted commit/replay/warm-start treatment as kernel tiles:

  * **mesh axis assignment** — the dp × tp factorization of the device count
    handed to ``launch.mesh.make_mesh``/``default_rules`` (fsdp rides the dp
    axis, as ``default_rules`` wires it);
  * **pipeline microbatch count** — ``parallel/pipeline.py`` /
    ``train.make_train_step(microbatches=)``;
  * **collective chunking** — ``parallel.collectives.chunked_psum`` chunk
    size for the DP gradient reduction;
  * **remat policy** — ``ExecConfig.remat`` ("none" | "dots" | "full");
  * **a curated XLA flag subspace** — :data:`XLA_PRESETS`, applied
    *per-compile* via ``lowered.compile(compiler_options=...)`` (never by
    mutating ``XLA_FLAGS`` at import time).

The raw product space is intractable to measure point-by-point; declarative
validity predicates (:class:`~repro.core.space.Constraint`) collapse it
before any compile: device-count factorization, batch/heads divisibility by
mesh axes, microbatch divisibility, and analytic memory feasibility against
:class:`~repro.core.costs.HardwareSpec` HBM capacity.  The Autotuning driver
charges pruned points through ``skip(reason="constraint")`` at zero
compile/measure cost, and the prune counts flow through the obs completeness
identity (``asked == committed+culled+pruned+skipped+quarantined``).

Two measurement modes:

  * ``mode="model"`` — :func:`launch_cost_model`, a deterministic analytic
    step-time model (6ND compute, weight/activation HBM traffic, tp/dp
    collective terms with chunking + overlap credit).  Pure arithmetic: no
    devices, no compiles — the CI mode, byte-reproducible across hosts.
  * ``mode="dryrun"`` — lower + compile each candidate on the host-platform
    mesh via ``launch.dryrun.run_cell`` (with the candidate's compiler
    options) and charge the compiled roofline bound.  Real, slow; behind
    ``pretune --launch --cost runtime`` and ``benchmarks/launch_tuning.py
    --full``.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Optional

from repro.core.costs import TPU_V5E, HardwareSpec
from repro.core.space import ChoiceDim, Constraint, LogIntDim, SearchSpace
from repro.obs import events as _events
from repro.obs.log import get_logger

log = get_logger(__name__)

__all__ = [
    "XLA_PRESETS",
    "compiler_options_for",
    "launch_space",
    "default_launch_point",
    "launch_key",
    "launch_cost_model",
    "launch_memory_model",
    "tune_launch",
    "launch_cases",
    "apply_launch_point",
]

_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "int8": 1}

#: Curated per-compile XLA option bundles (the bayespec snippet in
#: SNIPPETS.md shows the env-var surface; here each preset is a
#: ``compiler_options`` dict passed to ``lowered.compile()`` so flags are
#: scoped to one executable, never the process).  ``tpu_flags`` apply on TPU
#: backends only — the host-platform CPU compiler rejects them, so
#: :func:`compiler_options_for` resolves to ``{}`` there and the preset's
#: effect is carried by the cost model's ``overlap``/``overhead`` terms.
XLA_PRESETS = {
    "default": dict(tpu_flags={}, overlap=0.0, overhead=0.0),
    "async-collectives": dict(
        tpu_flags={
            "xla_tpu_enable_async_all_gather": "true",
            "xla_enable_async_all_reduce": "true",
        },
        overlap=0.7,  # fraction of the DP reduction hidden under compute
        overhead=0.01,  # scheduler pressure on the compute stream
    ),
    "latency-hiding": dict(
        tpu_flags={"xla_latency_hiding_scheduler_rerun": "2"},
        overlap=0.5,
        overhead=0.005,
    ),
    "sync-conservative": dict(
        # fully synchronous schedule: no overlap, but also no scheduler
        # overhead — the safe baseline for debugging numerical drift
        tpu_flags={"xla_tpu_enable_async_collective_fusion": "false"},
        overlap=0.0,
        overhead=0.0,
    ),
}


def compiler_options_for(preset: str, backend: Optional[str] = None) -> dict:
    """The ``compiler_options`` dict for one preset on one backend.

    TPU-only flags vanish on other backends (CPU host-platform meshes,
    interpret mode) instead of failing the compile."""
    spec = XLA_PRESETS[preset]
    if backend == "tpu":
        return dict(spec["tpu_flags"])
    return {}


def _pow2s(lo: int, hi: int) -> list:
    return [lo * (2**k) for k in range(int(math.floor(math.log2(hi / lo))) + 1)]


def _tp_ok(cfg, tp: int) -> bool:
    """Can the model axis shard this config ``tp`` ways?

    Attention shards heads (KV heads, or the GQA group dim — mirroring
    models.attention / costing.attention_traffic); attention-free stacks
    (RWKV, RGLRU) shard ``d_model`` directly, which every layer needs
    divisible anyway."""
    if tp == 1:
        return True
    if cfg.d_model % tp:
        return False
    if any(k in ("attn", "cross") for k in cfg.pattern):
        group = cfg.n_heads // max(cfg.n_kv_heads, 1)
        return cfg.n_heads % tp == 0 and (
            cfg.n_kv_heads % tp == 0 or group % tp == 0
        )
    return True


def launch_memory_model(cfg, shape, n_devices: int, hw: HardwareSpec = TPU_V5E):
    """Analytic per-chip memory estimator for one launch point.

    Returns ``(weight_bytes_per_chip, act_bytes_fn)`` where ``act_bytes_fn``
    maps a decoded point to its resident activation bytes per chip.  Weights
    (+ grads + AdamW moments for train) shard over *all* chips — fsdp rides
    dp and tp shards the rest — so the weight term is constant across the
    dp×tp factorization; only activations are knob-controlled."""
    train = shape.kind == "train"
    pbytes = _BYTES.get(cfg.param_dtype, 4)
    # train residency: weights + grads + AdamW m,v (state_dtype=param_dtype)
    states = 4 if train else 1
    weight_bytes = cfg.param_count() * pbytes * states / n_devices
    cbytes = _BYTES.get(cfg.compute_dtype, 2)
    seq = shape.seq_len if shape.kind != "decode" else 1

    # resident checkpoints per layer: remat "full" keeps only the layer
    # boundary, "dots" a few intermediates, "none" every matmul operand
    depth = {"full": 1.0, "dots": 3.0, "none": 8.0}

    def act_bytes(point: dict) -> float:
        dp = point.get("dp", n_devices)
        tp = point.get("tp", 1)
        mb = point.get("microbatches", 1)
        remat = point.get("remat", "none")
        local_rows = max(shape.global_batch // max(dp, 1), 1)
        per_layer = (local_rows / max(mb, 1)) * seq * cfg.d_model * cbytes / tp
        if not train:
            return per_layer * 2.0  # double-buffered layer I/O, no bwd stash
        return per_layer * cfg.n_layers * depth.get(remat, 8.0)

    return weight_bytes, act_bytes


def launch_space(
    cfg,
    shape,
    n_devices: int,
    *,
    hw: HardwareSpec = TPU_V5E,
    max_microbatches: int = 16,
) -> SearchSpace:
    """The launch-knob :class:`SearchSpace` for one (config, shape, devices)
    context, with its validity predicates attached as declarative
    :class:`Constraint`s — evaluated by the Autotuning driver *before*
    compile, so illegal mesh factorizations cost nothing."""
    train = shape.kind == "train"
    dims = [
        LogIntDim("dp", 1, n_devices),
        LogIntDim("tp", 1, n_devices),
    ]
    if train:
        dims.append(LogIntDim("microbatches", 1, max_microbatches))
        dims.append(ChoiceDim("remat", ("none", "dots", "full")))
    dims.append(LogIntDim("coll_chunk_mb", 1, 64))
    dims.append(ChoiceDim("xla", tuple(XLA_PRESETS)))

    constraints = [
        Constraint(
            "device-factorization",
            lambda p: p["dp"] * p["tp"] == n_devices,
            describe=f"dp * tp == {n_devices} (every chip owns exactly one shard)",
        ),
        Constraint(
            "batch-divisible",
            lambda p: shape.global_batch % p["dp"] == 0,
            describe=f"global batch {shape.global_batch} % dp == 0",
        ),
        Constraint(
            "model-divisible",
            lambda p: _tp_ok(cfg, p["tp"]),
            describe=f"heads {cfg.n_heads}/{cfg.n_kv_heads} (or d_model) % tp == 0",
        ),
    ]
    if train:
        constraints.append(
            Constraint(
                "microbatch-divisible",
                lambda p: (shape.global_batch // p["dp"]) % p["microbatches"] == 0
                if shape.global_batch % p["dp"] == 0
                else False,
                describe="local batch % microbatches == 0",
            )
        )

    # memory feasibility: weights shard over all chips regardless of the
    # dp×tp split, so the predicate discriminates via activations.  If even
    # the leanest activation point overflows (or weights alone do), no point
    # in THIS space can fix it — more chips can, which is outside the space —
    # so the predicate abstains instead of declaring everything illegal (the
    # cost model still penalizes overflow smoothly).
    weight_bytes, act_bytes = launch_memory_model(cfg, shape, n_devices, hw)
    headroom = hw.hbm_bytes - weight_bytes
    lean = dict(dp=1, tp=n_devices, microbatches=max_microbatches, remat="full")
    lean["dp"] = max(d for d in _pow2s(1, n_devices) if shape.global_batch % d == 0)
    lean["tp"] = n_devices // lean["dp"]
    discriminates = headroom > 0 and act_bytes(lean) <= headroom
    if discriminates:
        constraints.append(
            Constraint(
                "memory-feasible",
                lambda p: act_bytes(p) <= headroom,
                describe=(
                    f"resident activations ≤ {headroom / 1e9:.2f} GB HBM "
                    f"headroom ({hw.name})"
                ),
            )
        )
    return SearchSpace(dims, constraints=constraints)


def default_launch_point(cfg, shape, n_devices: int, space: Optional[SearchSpace] = None) -> dict:
    """The untuned launch — what the zoo/dryrun defaults do today: widest
    legal dp, modest tp, one microbatch, ``default_exec``'s remat policy,
    one big all-reduce, stock flags.  Bumped along the memory knobs until
    the space's own feasibility predicate accepts it."""
    train = shape.kind == "train"
    tp = 1
    for cand in _pow2s(1, n_devices):
        if cand * cand > n_devices:
            break
        if n_devices % cand == 0 and _tp_ok(cfg, cand):
            tp = cand
    point: dict = {"dp": n_devices // tp, "tp": tp}
    if train:
        point["microbatches"] = 1
        point["remat"] = "full"  # default_exec: remat="full" for train
    point["coll_chunk_mb"] = 64  # one (near-)monolithic reduction
    point["xla"] = "default"
    if space is not None and space.check(point) is not None:
        local = shape.global_batch // point["dp"]
        for mb in _pow2s(1, 16):
            if local % mb:
                continue
            point["microbatches"] = mb
            if space.check(point) is None:
                break
    return point


def launch_key(
    arch: str,
    shape,
    n_devices: int,
    space: SearchSpace,
    *,
    mode: str = "model",
    hw: HardwareSpec = TPU_V5E,
):
    """Context fingerprint for one launch-tuning site.

    Launch contexts have **no array arguments** — the signature is empty and
    ``TuningKey.shapes()`` is None; the context lives in ``extra`` (shape
    name, device count) plus the space hash.  Model-mode keys pin
    ``backend="model"`` / the target hardware name so the deterministic
    records replay identically on any host; dryrun-mode keys use the real
    default device like every kernel key."""
    from repro.tuning import make_key

    kw: dict = {}
    if mode == "model":
        kw = dict(backend="model", device_kind=hw.name)
    return make_key(
        f"launch/{arch}",
        args=(),
        space=space,
        extra={"shape": shape.name, "devices": int(n_devices), "mode": mode},
        **kw,
    )


def launch_cost_model(
    cfg, shape, n_devices: int, hw: HardwareSpec = TPU_V5E
) -> Callable[[dict], float]:
    """Deterministic analytic step time (seconds) of one launch point.

    Terms (per chip): 6ND/2ND compute with remat recompute and preset
    scheduler overhead; HBM traffic of streamed weights + activation
    checkpoints; tp all-reduces (per-layer activation reductions, exposed);
    dp gradient reduce-scatter/all-gather with per-chunk dispatch latency
    and the preset's async overlap credit (which needs ≥2 chunks to bite —
    that is exactly the chunking/flags interaction worth tuning); microbatch
    loop overhead; and a smooth paging penalty when the estimated residency
    overflows HBM (for the degenerate spaces where the feasibility predicate
    abstains).  It is a *model* — monotone in the right directions and
    deterministic for CI — not a measurement; ``mode="dryrun"`` is the
    measured path."""
    train = shape.kind == "train"
    seq = shape.seq_len if shape.kind != "decode" else 1
    tokens = shape.global_batch * seq
    n_active = cfg.active_param_count()
    flops_global = (6 if train else 2) * n_active * tokens
    pbytes = _BYTES.get(cfg.param_dtype, 4)
    cbytes = _BYTES.get(cfg.compute_dtype, 2)
    weight_bytes, act_bytes = launch_memory_model(cfg, shape, n_devices, hw)
    recompute = {"none": 1.0, "dots": 7.0 / 6.0, "full": 4.0 / 3.0}
    act_passes = {"none": 2.0, "dots": 2.5, "full": 3.0}
    links = 4  # v5e 2D torus
    chunk_latency = 20e-6  # per-collective dispatch cost
    mb_latency = 50e-6  # per-microbatch loop/dispatch cost

    def cost(point: dict) -> float:
        dp = int(point.get("dp", n_devices))
        tp = int(point.get("tp", 1))
        mb = int(point.get("microbatches", 1))
        remat = point.get("remat", "none")
        preset = XLA_PRESETS[point.get("xla", "default")]
        local_tokens = tokens / dp

        compute_s = (
            flops_global / n_devices / hw.peak_flops
            * recompute.get(remat, 1.0)
            * (1.0 + preset["overhead"])
        )

        # HBM: stream weights (fwd + bwd + optimizer sweep for train) and
        # activation checkpoints (written fwd, read bwd, re-read on remat)
        weight_traffic = (cfg.param_count() * pbytes / n_devices) * (3.0 if train else 1.0)
        act_traffic = (
            local_tokens * cfg.d_model * cbytes * cfg.n_layers
            * act_passes.get(remat, 2.0) / tp
        )
        memory_s = (weight_traffic + act_traffic) / hw.hbm_bw

        # tp: two all-reduces per layer (attn out + mlp out) over the local
        # activation slab, doubled for the backward pass — latency-exposed
        coll_s = 0.0
        if tp > 1:
            tp_bytes = (
                2.0 * cfg.n_layers * local_tokens * cfg.d_model * cbytes
                * (2.0 if train else 1.0) * (tp - 1) / tp
            )
            tp_ops = 2.0 * cfg.n_layers * (2.0 if train else 1.0)
            tp_s = tp_bytes / (hw.ici_bw * links) + tp_ops * chunk_latency
            coll_s += tp_s * (1.0 - 0.5 * preset["overlap"])

        # dp: ring-style gradient reduction of the tp-sharded grads; chunking
        # adds dispatch latency but enables the async presets' overlap
        if train and dp > 1:
            dp_bytes = 2.0 * (dp - 1) / dp * (cfg.param_count() * pbytes / tp)
            chunk = float(point.get("coll_chunk_mb", 64)) * 1e6
            n_chunks = max(1, int(math.ceil(dp_bytes / chunk)))
            dp_s = dp_bytes / (hw.ici_bw * links) + n_chunks * chunk_latency
            overlap_eff = preset["overlap"] * (1.0 - 1.0 / n_chunks)
            coll_s += dp_s * (1.0 - overlap_eff)

        step = max(compute_s, memory_s) + coll_s + (mb - 1) * mb_latency

        resident = weight_bytes + act_bytes(point)
        if resident > hw.hbm_bytes:
            step *= resident / hw.hbm_bytes  # paging penalty (abstained spaces)
        return float(step)

    return cost


def apply_launch_point(point: dict, n_devices: int, backend: Optional[str] = None) -> dict:
    """Translate a decoded launch point into ``dryrun.run_cell`` kwargs."""
    kw: dict = {
        "mesh_spec": ((int(point["dp"]), int(point["tp"])), ("data", "model")),
        "microbatches": int(point.get("microbatches", 1)),
        "compiler_options": compiler_options_for(point.get("xla", "default"), backend),
    }
    if "remat" in point:
        kw["exec_overrides"] = {"remat": point["remat"]}
    return kw


def _dryrun_cost_fn(arch: str, shape, n_devices: int, *, tiny: bool = False):
    """mode="dryrun": compile each candidate on the host mesh, charge its
    roofline bound (max of compute/memory/collective time per chip)."""

    def cost(point: dict) -> float:
        import jax

        from repro.launch import dryrun

        kw = apply_launch_point(point, n_devices, jax.default_backend())
        r = dryrun.run_cell(
            arch, shape.name, tiny=tiny, probes=False, verbose=False, **kw
        )
        if r.get("status") != "ok":
            return float("inf")
        rf = r["roofline"]
        return float(max(rf["compute_s"], rf["memory_s"], rf["collective_s"]))

    return cost


def tune_launch(
    arch: str,
    shape_name: str,
    n_devices: int,
    *,
    db=None,
    mode: str = "model",
    num_opt: int = 3,
    max_iter: int = 8,
    seed: int = 0,
    search: Any = None,
    warm_start: bool = True,
    source: str = "pretune",
    hw: HardwareSpec = TPU_V5E,
    tiny: bool = False,
    stats: Optional[dict] = None,
    verbose: bool = False,
):
    """Tune the launch knobs of one (arch, shape) context; returns the
    :class:`~repro.tuning.TuningRecord` (committed to ``db`` when given).

    The default point is fed to the search via :meth:`Autotuning.note`
    before any round, so the committed best is ≤ the untuned launch by
    construction — tuning can only improve on the incumbent.  ``stats``
    (optional dict) is filled with space/prune/measure accounting:
    ``raw_size``, ``constrained_size``, ``pruned``, ``measured``,
    ``default_cost``, ``best_cost``, ``replayed``."""
    from repro import configs
    from repro.core import Autotuning
    from repro.tuning.warm_start import record_from

    cfg = configs.get(arch) if not tiny else configs.get_tiny(arch)
    shape = configs.SHAPES[shape_name]
    space = launch_space(cfg, shape, n_devices, hw=hw)
    key = launch_key(arch, shape, n_devices, space, mode=mode, hw=hw)
    cost_fn = (
        launch_cost_model(cfg, shape, n_devices, hw)
        if mode == "model"
        else _dryrun_cost_fn(arch, shape, n_devices, tiny=tiny)
    )
    default_pt = default_launch_point(cfg, shape, n_devices, space)
    if stats is None:
        stats = {}
    stats.update(
        raw_size=space.size(),
        constrained_size=space.constrained_size(),
        measured=0,
        default_point=dict(default_pt),
        default_cost=None,
        replayed=False,
    )

    at = Autotuning(
        space=space,
        search=search,
        num_opt=num_opt,
        max_iter=max_iter,
        seed=seed,
        cache=True,
        verbose=verbose,
        db=db,
        key=key,
        warm_start=warm_start,
        db_source=source,
    )
    if at.finished and at.warm_started:
        # exact fingerprint hit: replay, zero measurements
        stats["replayed"] = True
        stats["default_cost"] = float(cost_fn(default_pt))
        stats["best_cost"] = at.best_cost
        stats["pruned"] = 0
        return db.get(key) if db is not None else None

    # the incumbent (untuned default) joins the history out-of-band: commit
    # can only improve on it
    default_cost = float(cost_fn(default_pt))
    stats["default_cost"] = default_cost
    at.note(default_pt, default_cost)

    rnd = [0]

    def measure_batch(points):
        stats["measured"] += len(points)
        costs = [cost_fn(p) for p in points]
        if _events.sink() is not None:
            rnd[0] += 1
            sname = at.ctx_name()
            for p, c in zip(points, costs):
                _events.emit("candidate_asked", name=sname, point=dict(p),
                             round=rnd[0])
                if math.isfinite(c):
                    _events.emit("candidate_committed", name=sname,
                                 point=dict(p), cost=float(c))
                else:
                    _events.emit("candidate_skipped", name=sname,
                                 point=dict(p), reason="failed")
        return costs

    at.entire_exec_batch(measure_batch)
    stats["pruned"] = int(at.skip_reasons.get("constraint", 0))
    stats["constraint_violations"] = dict(at.constraint_violations)
    stats["best_point"] = dict(at.best_point)
    stats["best_cost"] = float(at.best_cost)
    if db is not None:
        rec = db.get(key)
        if rec is not None:
            return rec
    return record_from(at, key, source=source)


def launch_cases(smoke: bool = True) -> list:
    """(arch, shape_name) launch-tuning grid.  Smoke: the three zoo configs
    the benchmark reports; full: every arch on the train shape plus the two
    serving shapes for the smoke archs."""
    smoke_cases = [
        ("qwen2_7b", "train_4k"),
        ("recurrentgemma_2b", "train_4k"),
        ("moonshot_v1_16b_a3b", "train_4k"),
    ]
    if smoke:
        return smoke_cases
    from repro import configs

    cases = [(a, "train_4k") for a in configs.ARCH_IDS]
    cases += [(a, "prefill_32k") for a, _ in smoke_cases]
    cases += [(a, "decode_32k") for a, _ in smoke_cases]
    return cases
