import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.environ['REPRO_DRYRUN_DEVICES']}"
    )

"""§Perf hillclimbing: PATSMA (CSA, Entire-Execution mode, AnalyticCost)
searching the distributed-config space of one (arch x shape) cell — the
paper's own technique driving the roofline optimization.

Each candidate = a (sharding x remat x chunking x capacity) configuration;
its cost = the dominant roofline term of the freshly lowered cell (delta
method).  Every evaluation is logged to JSONL so EXPERIMENTS.md §Perf can
show the hypothesis -> change -> before/after trail.

The search loop itself lives in ``repro.runtime``: this launcher is glue
that builds an :class:`~repro.runtime.OnlineTuner` (with a
:class:`~repro.runtime.DriftDetector`, so a long-lived caller could keep
feeding it post-search costs and get automatic re-searches) and drives it
to completion with the analytic cost function.

    PYTHONPATH=src python -m repro.launch.hillclimb --arch llama3_405b \
        --shape train_4k --budget 10 --out results/hc_405b.jsonl
"""
import argparse
import json
import time

from repro.core import CSA, Autotuning, ChoiceDim, SearchSpace
from repro.launch.dryrun import run_cell
from repro.runtime import DriftDetector, OnlineTuner

# knob menus per shape kind
TRAIN_KNOBS = [
    ChoiceDim("attn_impl", ("xla", "flashcost")),  # flash kernel vs XLA attention
    ChoiceDim("remat", ("none", "full")),
    ChoiceDim("logits_chunk", (0, 4096, 16384)),
    ChoiceDim("sp", (False, True)),  # sequence-parallel activations
    ChoiceDim("fsdp", (True, False)),
]
MOE_KNOBS = [ChoiceDim("capacity_factor", (1.0, 1.25, 2.0))]
DECODE_KNOBS = [
    ChoiceDim("attn_impl", ("xla", "flashcost")),
    ChoiceDim("fsdp", (True, False)),
    ChoiceDim("logits_chunk", (0, 4096)),
]


def knob_space(cfg, shape_kind: str) -> SearchSpace:
    dims = list(TRAIN_KNOBS if shape_kind != "decode" else DECODE_KNOBS)
    if cfg.ffn == "moe" and shape_kind != "decode":
        dims += MOE_KNOBS
    return SearchSpace(dims)


def evaluate(arch: str, shape: str, knobs: dict, *, multi_pod=False, objective="bound"):
    exec_over = {}
    cfg_over = {}
    kw = {}
    for k, v in knobs.items():
        if k in ("remat", "logits_chunk", "scan_unroll", "rec_chunk", "attn_impl"):
            exec_over[k] = v
        elif k in ("capacity_factor",):
            cfg_over[k] = v
        elif k in ("fsdp", "sp", "microbatches"):
            kw[k] = v
    r = run_cell(
        arch, shape, multi_pod=multi_pod, exec_overrides=exec_over,
        cfg_overrides=cfg_over, verbose=False, **kw,
    )
    if r["status"] != "ok":
        return float("inf"), r
    rt = dict(r["roofline"])
    if exec_over.get("attn_impl") == "flashcost":
        # the surrogate lowering carries the kernel's true HBM/collective
        # traffic; re-add the kernel's MXU flops analytically (DESIGN §10)
        import dataclasses as _dc

        from repro import configs as _c
        from repro.launch import costing as _cost

        cfg = _c.get(arch)
        if cfg_over:
            cfg = _dc.replace(cfg, **cfg_over)
        shp = _c.SHAPES[shape]
        mesh_shape = r["mesh"]
        dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
        tp = mesh_shape.get("model", 1)
        adj = _cost.attention_traffic(cfg, shp, dp, tp)
        rt["flops"] = rt["flops"] + adj["flash_flops"]
        rt["compute_s"] = rt["flops"] / 197e12
        r["roofline"] = rt
        r["flash_flops_added"] = adj["flash_flops"]
    if objective == "bound":
        cost = max(rt["compute_s"], rt["memory_s"], rt["collective_s"])
    else:
        cost = rt[objective + "_s"]
    # HBM feasibility: argument bytes (params+opt+caches, exact under the
    # candidate shardings) must fit v5e's 16 GB.  Without this penalty CSA
    # happily "wins" by un-sharding weights (found in the first 405B sweep).
    HBM = 16e9
    args_b = r["memory"]["argument_bytes"]
    if args_b > 0.95 * HBM:
        r["infeasible"] = f"args {args_b/1e9:.1f} GB > HBM"
        cost = cost + 1e6 * (args_b / HBM)
    return cost, r


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--budget", type=int, default=10, help="CSA cost evaluations")
    ap.add_argument("--objective", default="bound",
                    choices=["bound", "compute", "memory", "collective"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--num-opt", type=int, default=3)
    args = ap.parse_args()

    from repro import configs

    cfg = configs.get(args.arch)
    shape_kind = configs.SHAPES[args.shape].kind
    space = knob_space(cfg, shape_kind)
    max_iter = max(2, args.budget // args.num_opt)
    at = Autotuning(
        space=space, ignore=0,
        search=CSA(len(space), num_opt=args.num_opt, max_iter=max_iter, seed=0),
        cache=True, verbose=True,
    )
    tuner = OnlineTuner(at, epsilon=1.0, drift=DriftDetector(window=4, min_samples=3))

    log = []

    def record(rec):
        log.append(rec)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")

    n = 0

    def cost_fn(knobs):
        nonlocal n
        t0 = time.time()
        cost, result = evaluate(args.arch, args.shape, knobs,
                                multi_pod=args.multi_pod, objective=args.objective)
        n += 1
        rec = {
            "eval": n, "knobs": knobs, "cost_s": cost,
            "roofline": result.get("roofline"), "memory": result.get("memory"),
            "status": result.get("status"), "elapsed_s": round(time.time() - t0, 1),
            "arch": args.arch, "shape": args.shape,
        }
        record(rec)
        print(f"[hc] eval {n}: {knobs} -> {cost*1e3:.1f} ms ({rec['elapsed_s']}s)")
        return cost

    tuner.drive(cost_fn)

    print(f"\n[hc] best: {at.best_point} -> {at.best_cost*1e3:.1f} ms "
          f"({at.num_evals} evals, cache hits included)")
    record({"final": True, "best_knobs": at.best_point, "best_cost_s": at.best_cost,
            "arch": args.arch, "shape": args.shape})


if __name__ == "__main__":
    main()
