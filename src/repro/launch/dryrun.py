import os


def _ensure_host_platform_devices(default: int = 512) -> None:
    """Set the host-platform device count, PRESERVING any other XLA_FLAGS the
    user (or the flag-tuning layer) already exported — this module used to
    clobber the whole variable.  Only an existing
    ``--xla_force_host_platform_device_count`` token is replaced; everything
    else is kept verbatim.  Must run before any jax import (jax locks the
    device count at first init).  Tests override via REPRO_DRYRUN_DEVICES."""
    n = int(os.environ.get("REPRO_DRYRUN_DEVICES") or default)
    kept = [
        f for f in os.environ.get("XLA_FLAGS", "").split()
        if not f.startswith("--xla_force_host_platform_device_count")
    ]
    kept.append(f"--xla_force_host_platform_device_count={n}")
    os.environ["XLA_FLAGS"] = " ".join(kept)


_ensure_host_platform_devices()

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production mesh; record memory analysis, cost analysis and the collective
schedule for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out results/
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.costs import TPU_V5E, RooflineTerms
from repro.launch import costing
from repro.launch.input_specs import input_specs
from repro.launch.mesh import default_rules, make_mesh, make_production_mesh
from repro.models import ExecConfig, Model
from repro.optim import AdamW
from repro.parallel.api import sharding_context
from repro.parallel.sharding import (
    batch_wanted,
    param_wanted,
    state_wanted,
    tree_shardings,
    replicated,
)
from repro.train import make_train_step
from jax.sharding import NamedSharding, PartitionSpec


def _opt_wanted(path, ndim):
    if path.startswith(("m/", "v/")):
        return param_wanted(path[2:], ndim)
    return ()


def _batch_wanted(path, ndim):
    name = path.split("/")[-1]
    return batch_wanted(name, ndim)


def default_exec(cfg, shape_kind: str, overrides: dict | None = None) -> ExecConfig:
    """Baseline execution config (the paper-faithful starting point; §Perf
    hillclimbs override fields via ``overrides``)."""
    kw = dict(
        attn_impl="xla",  # dry-run lowers the XLA path (Pallas is the TPU runtime path)
        scan_layers=True,
        scan_unroll=1,
        remat="full" if shape_kind == "train" else "none",
        logits_chunk=0,
        rec_chunk=128,
        rec_unroll=True,  # exact cost_analysis (no nested while)
    )
    kw.update(overrides or {})
    return ExecConfig(**kw)


def build_step(model, shape_kind: str, mesh, rules, *, microbatches: int = 1,
               logits_chunk: int = 0):
    """Returns (fn, example_args, in_shardings, out_shardings, donate)."""
    cfg = model.cfg
    params_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sh = tree_shardings(mesh, rules, params_spec, param_wanted)

    if shape_kind == "train":
        opt = AdamW(lr=3e-4, state_dtype=cfg.param_dtype)
        opt_spec = jax.eval_shape(opt.init, params_spec)
        opt_sh = tree_shardings(mesh, rules, opt_spec, _opt_wanted)
        step = make_train_step(model, opt, microbatches=microbatches, logits_chunk=logits_chunk)
        shape = None  # filled by caller
        return step, (params_spec, opt_spec), (params_sh, opt_sh), None

    if shape_kind == "prefill":
        def prefill(params, batch):
            hidden, states = model.prefill(params, batch)
            logits = model.logits(params, hidden[:, None])[:, 0]
            return logits, states

        return prefill, (params_spec,), (params_sh,), None

    def decode(params, token, states, pos):
        return model.decode_step(params, token, states, pos)

    return decode, (params_spec,), (params_sh,), None


def build_cell_program(cfg, exec_cfg, shape_name, mesh, rules, *, microbatches=1):
    """(fn, args, in_shardings, out_shardings, donate) for one cell."""
    from repro.parallel.api import logical_spec

    shape = configs.SHAPES[shape_name]
    model = Model(cfg, exec_cfg)
    specs = input_specs(model, shape_name)
    params_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sh = tree_shardings(mesh, rules, params_spec, param_wanted)

    if shape.kind == "train":
        opt = AdamW(lr=3e-4, state_dtype=cfg.param_dtype)
        opt_spec = jax.eval_shape(opt.init, params_spec)
        opt_sh = tree_shardings(mesh, rules, opt_spec, _opt_wanted)
        batch_sh = tree_shardings(mesh, rules, specs["batch"], _batch_wanted)
        fn = make_train_step(
            model, opt, microbatches=microbatches, logits_chunk=exec_cfg.logits_chunk
        )
        args = (params_spec, opt_spec, specs["batch"])
        in_sh = (params_sh, opt_sh, batch_sh)
        out_spec = jax.eval_shape(fn, *args)
        out_sh = (params_sh, opt_sh, replicated(mesh, out_spec[2]))
        donate = (0, 1)
    elif shape.kind == "prefill":
        def fn(params, batch):
            hidden, states = model.prefill(params, batch)
            logits = model.logits(params, hidden[:, None])[:, 0]
            return logits, states

        batch_sh = tree_shardings(mesh, rules, specs["batch"], _batch_wanted)
        args = (params_spec, specs["batch"])
        in_sh = (params_sh, batch_sh)
        out_spec = jax.eval_shape(fn, *args)
        logits_sh = NamedSharding(mesh, logical_spec(mesh, rules, out_spec[0].shape, ("dp", "tp")))
        states_sh = tree_shardings(
            mesh, rules, out_spec[1], lambda p, sh: state_wanted(p.split("/", 1)[-1], sh, tp_size=mesh.shape.get("model", 0))
        )
        out_sh = (logits_sh, states_sh)
        donate = ()
    else:  # decode
        def fn(params, token, states, pos):
            return model.decode_step(params, token, states, pos)

        token_sh = NamedSharding(mesh, logical_spec(mesh, rules, specs["token"].shape, ("dp", None)))
        states_sh = tree_shardings(
            mesh, rules, specs["states"], lambda p, sh: state_wanted(p.split("/", 1)[-1], sh, tp_size=mesh.shape.get("model", 0))
        )
        pos_sh = NamedSharding(mesh, PartitionSpec())
        args = (params_spec, specs["token"], specs["states"], specs["pos"])
        in_sh = (params_sh, token_sh, states_sh, pos_sh)
        out_spec = jax.eval_shape(fn, *args)
        logits_sh = NamedSharding(mesh, logical_spec(mesh, rules, out_spec[0].shape, ("dp", "tp")))
        out_sh = (logits_sh, states_sh)
        donate = (2,)
    return fn, args, in_sh, out_sh, donate


def _variant_cfg(cfg, k_groups: int, enc_layers: int | None = None):
    kw = dict(
        n_groups=k_groups,
        n_layers=len(cfg.group) * k_groups + len(cfg.tail),
    )
    if enc_layers is not None:
        kw["enc_layers"] = enc_layers
    return dataclasses.replace(cfg, **kw)


def _lower_cost(cfg, exec_cfg, shape_name, mesh, rules, microbatches):
    fn, args, in_sh, out_sh, donate = build_cell_program(
        cfg, exec_cfg, shape_name, mesh, rules, microbatches=microbatches
    )
    with sharding_context(mesh, rules):
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
    return costing.measure(lowered.compile())


def cost_by_delta(cfg, exec_cfg, shape_name, mesh, rules, microbatches) -> costing.CostTerms:
    """Exact-in-context while-loop-free cost: lower fully-unrolled variants
    with 2 and 4 layer groups, difference them for the per-group cost, and
    extrapolate to the real depth (EXPERIMENTS.md §Dry-run methodology).
    cost_analysis counts while bodies once, so unrolled variants are the only
    faithful accounting; deltas keep sharding context identical."""
    ec = dataclasses.replace(exec_cfg, scan_unroll=1_000_000, rec_unroll=True)
    ng = cfg.n_groups
    enc = cfg.enc_layers
    if ng <= 4 and enc <= 4:
        return _lower_cost(cfg, ec, shape_name, mesh, rules, microbatches)
    enc_small = min(enc, 2) if enc else 0
    c2 = _lower_cost(
        _variant_cfg(cfg, 2, enc_small or None), ec, shape_name, mesh, rules, microbatches
    )
    c4 = _lower_cost(
        _variant_cfg(cfg, 4, enc_small or None), ec, shape_name, mesh, rules, microbatches
    )
    per_group = (c4 + c2.scaled(-1.0)).scaled(0.5)
    total = c2 + per_group.scaled(ng - 2)
    if enc > 2:
        shape = configs.SHAPES[shape_name]
        if shape.kind != "decode":  # decode never runs the encoder
            e4 = _lower_cost(
                _variant_cfg(cfg, 2, 4), ec, shape_name, mesh, rules, microbatches
            )
            per_enc = (e4 + c2.scaled(-1.0)).scaled(0.5)
            total = total + per_enc.scaled(enc - 2)
    return total


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    tiny: bool = False,
    mesh_spec=None,
    exec_overrides: dict | None = None,
    cfg_overrides: dict | None = None,
    microbatches: int = 1,
    fsdp: bool = True,
    sp: bool = False,
    probes: bool = True,
    verbose: bool = True,
    compiler_options: dict | None = None,
) -> dict:
    t0 = time.time()
    cfg = configs.get_tiny(arch) if tiny else configs.get(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = configs.SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.subquadratic and not tiny:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch; long_500k runs for SSM/hybrid only (DESIGN §5)"}

    exec_cfg = default_exec(cfg, shape.kind, exec_overrides)
    model = Model(cfg, exec_cfg)
    if mesh_spec is not None:
        mesh = make_mesh(*mesh_spec)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(mesh, fsdp=fsdp, sp=sp)
    chips = int(mesh.size)

    # 1) the production program (scan-over-layers): proves compile + memory
    fn, args, in_sh, out_sh, donate = build_cell_program(
        cfg, exec_cfg, shape_name, mesh, rules, microbatches=microbatches
    )
    with sharding_context(mesh, rules):
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        # tuned XLA flags are applied per-compile (launch.spaces.XLA_PRESETS)
        # — never via the import-time XLA_FLAGS env hack
        if compiler_options:
            compiled = lowered.compile(compiler_options=dict(compiler_options))
        else:
            compiled = lowered.compile()
    mem = compiled.memory_analysis()
    step_cost = costing.measure(compiled)

    # 2) cost accounting via unrolled delta variants (exact; see cost_by_delta)
    if probes:
        total = cost_by_delta(cfg, exec_cfg, shape_name, mesh, rules, microbatches)
    else:
        total = step_cost

    hw = TPU_V5E
    terms = RooflineTerms(
        compute_s=total.flops / hw.peak_flops,
        memory_s=total.bytes_accessed / hw.hbm_bw,
        collective_s=total.coll_bytes / (hw.ici_bw * 4),
        flops=total.flops,
        bytes_accessed=total.bytes_accessed,
        coll_bytes=total.coll_bytes,
        chips=chips,
        hw=hw,
    )

    # model flops (6ND train / 2ND inference; N_active for MoE)
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    model_flops_global = (6 if shape.kind == "train" else 2) * n_active * tokens
    model_flops_per_chip = model_flops_global / chips

    result = {
        "arch": arch,
        "shape": shape_name,
        "status": "ok",
        "mesh": dict(zip(mesh.axis_names, (int(s) for s in mesh.devices.shape))),
        "chips": chips,
        "exec": dataclasses.asdict(exec_cfg),
        "fsdp": fsdp,
        "sp": sp,
        "microbatches": microbatches,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            # older jaxlib has no peak stat; args+outputs+temps is the upper
            # bound XLA itself reports for those versions
            "peak_bytes": getattr(
                mem,
                "peak_memory_in_bytes",
                mem.argument_size_in_bytes + mem.output_size_in_bytes + mem.temp_size_in_bytes,
            ),
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost_reported": step_cost.as_dict(),
        "cost_total_per_chip": total.as_dict(),
        "roofline": terms.as_dict(),
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flops_ratio": model_flops_per_chip / max(total.flops, 1.0),
        "params": cfg.param_count(),
        "active_params": n_active,
        "elapsed_s": round(time.time() - t0, 1),
    }
    if verbose:
        dom = terms.dominant
        print(
            f"[dryrun] {arch} × {shape_name} ({'multi' if multi_pod else 'single'}-pod, "
            f"{chips} chips): compile OK in {result['elapsed_s']}s\n"
            f"  mem/chip: args {mem.argument_size_in_bytes/1e9:.2f} GB, "
            f"temp {mem.temp_size_in_bytes/1e9:.2f} GB, "
            f"peak {result['memory']['peak_bytes']/1e9:.2f} GB\n"
            f"  roofline/chip: compute {terms.compute_s*1e3:.2f} ms | memory "
            f"{terms.memory_s*1e3:.2f} ms | collective {terms.collective_s*1e3:.2f} ms "
            f"-> {dom}-bound\n"
            f"  useful-flops ratio (6ND / HLO): {result['useful_flops_ratio']:.2f}"
        )
    return result


def _tuned_point(db, arch: str, shape_name: str, mesh_spec) -> dict | None:
    """Stored launch point for this cell, trying the dryrun-mode key first
    and falling back to the deterministic model-mode key (the records CI's
    `pretune --launch` commits)."""
    from repro.launch.spaces import launch_key, launch_space

    cfg = configs.get(arch)
    shape = configs.SHAPES[shape_name]
    if mesh_spec is not None:
        n = 1
        for s in mesh_spec[0]:
            n *= int(s)
    else:
        n = jax.device_count()
    space = launch_space(cfg, shape, n)
    for mode in ("dryrun", "model"):
        rec = db.get(launch_key(arch, shape, n, space, mode=mode))
        if rec is not None:
            return dict(rec.point)
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None, choices=list(configs.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--set", nargs="*", default=[], help="exec overrides k=v")
    ap.add_argument("--out", type=str, default=None, help="append JSONL here")
    ap.add_argument(
        "--mesh", type=str, default=None,
        help="override mesh shape, e.g. '4,4' (data,model) or '2,2,4' (pod,data,model)",
    )
    ap.add_argument(
        "--tune", action="store_true",
        help="apply each cell's tuned launch point from --db (launch.spaces) "
             "and report tuned vs default end-to-end step estimate",
    )
    ap.add_argument("--db", type=str, default=None,
                    help="tuning DB holding launch/<arch> records (with --tune)")
    args = ap.parse_args()

    mesh_spec = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
        axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
        mesh_spec = (dims, axes)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=")
        overrides[k] = (
            v if k in ("attn_impl", "remat") else (v == "True" if v in ("True", "False") else int(v))
        )

    cells = []
    if args.all:
        for a, s, runnable in configs.cells(include_skips=True):
            cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    tuned_db = None
    if args.tune:
        if not args.db:
            raise SystemExit("--tune needs --db <tuning db with launch records>")
        from repro.tuning import TuningDB

        tuned_db = TuningDB(args.db)

    def _bound(r):
        rf = r.get("roofline") or {}
        return max(rf.get("compute_s", 0), rf.get("memory_s", 0),
                   rf.get("collective_s", 0))

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    results = []
    for arch, shape in cells:
        for mp in meshes:
            cell_kw = dict(
                multi_pod=mp, tiny=args.tiny, mesh_spec=mesh_spec,
                exec_overrides=dict(overrides), microbatches=args.microbatches,
                fsdp=not args.no_fsdp, sp=args.sp, probes=not args.no_probes,
            )
            try:
                if tuned_db is not None:
                    point = _tuned_point(tuned_db, arch, shape, mesh_spec)
                    if point is None:
                        r = run_cell(arch, shape, **cell_kw)
                        r["launch_tuned"] = False
                    else:
                        from repro.launch.spaces import apply_launch_point

                        n = (point["dp"] * point["tp"])
                        tuned_kw = dict(cell_kw)
                        tuned_kw.update(apply_launch_point(
                            point, n, jax.default_backend()
                        ))
                        tuned_kw["exec_overrides"] = dict(
                            overrides, **tuned_kw.pop("exec_overrides", {})
                        )
                        r = run_cell(arch, shape, **tuned_kw)
                        base = run_cell(arch, shape, **dict(cell_kw, verbose=False))
                        r["launch_tuned"] = True
                        r["launch_point"] = dict(point)
                        r["step_bound_s"] = _bound(r)
                        r["default_step_bound_s"] = _bound(base)
                        if r["status"] == "ok" and base["status"] == "ok":
                            print(
                                f"[dryrun --tune] {arch} × {shape}: tuned "
                                f"{r['step_bound_s']*1e3:.2f} ms vs default "
                                f"{r['default_step_bound_s']*1e3:.2f} ms per step"
                            )
                else:
                    r = run_cell(arch, shape, **cell_kw)
            except Exception as e:
                traceback.print_exc()
                r = {"arch": arch, "shape": shape, "multi_pod": mp,
                     "status": "error", "error": repr(e)}
            r["multi_pod"] = mp
            results.append(r)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(r) + "\n")
    bad = [r for r in results if r["status"] == "error"]
    print(f"\n[dryrun] {len(results)} cells: "
          f"{sum(r['status']=='ok' for r in results)} ok, "
          f"{sum(r['status']=='skipped' for r in results)} skipped, {len(bad)} errors")
    if bad:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
