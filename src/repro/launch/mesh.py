"""Production mesh builders (functions, not module constants — importing this
module never touches jax device state)."""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes have no axis types yet
    AxisType = None

from repro.parallel.api import ShardingRules

__all__ = ["make_production_mesh", "make_mesh", "default_rules"]


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).  Multi-pod: 2 pods =
    512 chips (pod, data, model); the pod axis composes with data for
    hierarchical DP/FSDP (or acts as the pipeline axis, see parallel.pipeline)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes):
    return _make_mesh(tuple(shape), tuple(axes))


def default_rules(mesh, *, fsdp: bool = True, sp: bool = False) -> ShardingRules:
    """Logical-axis mapping for a mesh built by make_production_mesh (or any
    mesh with a 'data' and 'model' axis, optionally 'pod')."""
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    return ShardingRules(
        dp=dp,
        tp="model",
        sp="model" if sp else None,
        ep="model",
        fsdp=dp if fsdp else None,
    )
