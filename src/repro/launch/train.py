"""Training launcher.

Local (CPU / single host) mode runs the fault-tolerant driver end-to-end:

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_7b --tiny --steps 50

Cluster mode is the same program under a device mesh: pass --mesh to place
the (data, model) axes; on a real TPU pod slice, start one process per host
with jax.distributed.initialize() (env-driven) and the identical arguments —
the in/out shardings come from repro.parallel.sharding either way.
"""
import argparse
import json

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="qwen2_7b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--tune", action="store_true", help="PATSMA single-iteration mode")
    ap.add_argument("--runtime", type=str, default=None, choices=["adaptive"],
                    help="adaptive: keep tuning while training (epsilon-rationed "
                         "exploration + drift-triggered warm re-search)")
    ap.add_argument("--epsilon", type=float, default=1.0,
                    help="explored fraction of steps while a search is live "
                         "(adaptive runtime mode)")
    ap.add_argument("--db", type=str, default=None,
                    help="tuning DB path; warm-starts step knobs across runs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--distributed", action="store_true",
                    help="initialize jax.distributed from env (multi-host)")
    ap.add_argument("--obs-dir", type=str, default=None,
                    help="write observability artifacts (events.jsonl, "
                         "trace.json, metrics.json) into this directory "
                         "(default: the REPRO_OBS env var, else off)")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    from repro import obs

    if args.obs_dir:
        obs.configure(args.obs_dir)
    else:
        obs.configure_from_env()

    from repro.runtime import TrainJob

    job = TrainJob(
        arch=args.arch,
        tiny=args.tiny,
        steps=args.steps,
        global_batch=args.global_batch,
        seq_len=args.seq_len,
        lr=args.lr,
        seed=args.seed,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        tune=args.tune or args.runtime is not None,
        tune_db=args.db,
        runtime=args.runtime,
        tune_epsilon=args.epsilon,
    )
    try:
        with obs.span("train", steps=args.steps):
            hist = job.run()
    finally:
        obs.shutdown()
    print(json.dumps({
        "final_loss": hist["loss"][-1],
        "steps": len(hist["loss"]),
        "mean_step_s": sum(hist["step_time"]) / len(hist["step_time"]),
        "final_knobs": hist["final_knobs"],
        "watchdog_events": len(hist["watchdog_events"]),
        "resets": len(hist["resets"]),
    }, indent=2))


if __name__ == "__main__":
    main()
