"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation.  The dry-run lowers against these."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, ShapeSpec
from repro.models import ExecConfig, Model, ModelConfig

__all__ = ["train_inputs", "prefill_inputs", "decode_inputs", "input_specs"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _batch_specs(cfg: ModelConfig, B: int, S: int, with_labels: bool) -> dict:
    batch = {"tokens": _sds((B, S), jnp.int32)}
    if with_labels:
        batch["labels"] = _sds((B, S), jnp.int32)
    if cfg.is_encdec:
        batch["frames"] = _sds((B, cfg.ctx_tokens, cfg.d_model), cfg.compute_dtype)
    if cfg.family == "vlm":
        batch["ctx_embeds"] = _sds((B, cfg.ctx_tokens, cfg.d_model), cfg.compute_dtype)
    return batch


def train_inputs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    return _batch_specs(cfg, shape.global_batch, shape.seq_len, with_labels=True)


def prefill_inputs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    return _batch_specs(cfg, shape.global_batch, shape.seq_len, with_labels=False)


def decode_inputs(model: Model, shape: ShapeSpec):
    """(token, states, pos) specs; states via eval_shape of init_states —
    ring-buffer windows and recurrent states get their true (small) shapes."""
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    states = jax.eval_shape(lambda: model.init_states(B, S, mode="decode"))
    token = _sds((B, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    return token, states, pos


def input_specs(model: Model, shape_name: str):
    cfg = model.cfg
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return {"batch": train_inputs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_inputs(cfg, shape)}
    token, states, pos = decode_inputs(model, shape)
    return {"token": token, "states": states, "pos": pos}
