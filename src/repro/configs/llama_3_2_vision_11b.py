"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; cross-attn image layers every 5th (8 total)
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].  Vision frontend is a STUB:
input_specs supplies precomputed patch embeddings (B, 6400, 4096)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    group=("attn", "attn", "attn", "cross", "attn"),
    rope_theta=500_000.0,
    ctx_tokens=6400,
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b-tiny",
        family="vlm",
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        group=("attn", "attn", "attn", "cross", "attn"),
        n_groups=1,
        rope_theta=500_000.0,
        ctx_tokens=16,
        vocab_pad_multiple=16,
    )
