"""rwkv6-7b [ssm] — Finch: 32L d_model=4096 (attention-free, 64 heads of 64)
d_ff=14336 vocab=65536; data-dependent decay [arXiv:2404.05892; hf].
Sub-quadratic: runs the long_500k cell."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,
    n_kv_heads=64,
    d_head=64,
    d_ff=14336,
    vocab_size=65536,
    group=("rwkv",),
    norm="layernorm",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b-tiny",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        group=("rwkv",),
        norm="layernorm",
        vocab_pad_multiple=16,
    )
