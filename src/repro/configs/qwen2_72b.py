"""qwen2-72b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064; QKV bias [arXiv:2407.10671; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b-tiny",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        vocab_pad_multiple=16,
    )
