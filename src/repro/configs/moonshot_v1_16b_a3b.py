"""moonshot-v1-16b-a3b [moe] — kimi/moonlight: 48L d_model=2048 16H (kv=16)
MoE 64 experts top-6 (expert d_ff=1408) vocab=163840
[hf:moonshotai/Moonlight-16B-A3B; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    ffn="moe",
    n_experts=64,
    top_k=6,
    d_ff_expert=1408,
    rope_theta=50_000.0,
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b-tiny",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab_size=512,
        ffn="moe",
        n_experts=8,
        top_k=3,
        d_ff_expert=96,
        vocab_pad_multiple=16,
    )
