"""seamless-m4t-large-v2 [audio] — enc-dec, 24L each side, d_model=1024 16H
(kv=16) d_ff=8192 vocab=256206 [arXiv:2308.11596; hf].  Audio frontend is a
STUB: input_specs supplies precomputed frame embeddings (B, 4096, 1024).
vocab padded 256206 -> 256256 for 16-way TP (loss masks the pad)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    group=("cross",),
    norm="layernorm",
    ffn="gelu",
    enc_layers=24,
    ctx_tokens=4096,
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2-tiny",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=510,
        group=("cross",),
        norm="layernorm",
        ffn="gelu",
        enc_layers=2,
        ctx_tokens=16,
        vocab_pad_multiple=16,
    )
