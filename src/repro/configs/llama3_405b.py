"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 [arXiv:2407.21783; unverified].  The memory/collective stress
cell: bf16 params + bf16 Adam states (see DESIGN §6) under FSDP+TP."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500_000.0,
    param_dtype="bfloat16",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b-tiny",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=192,
        vocab_size=512,
        rope_theta=500_000.0,
        vocab_pad_multiple=16,
    )
