"""recurrentgemma-2b [hybrid] — Griffin 1:2: 26L d_model=2560 10H (MQA kv=1)
d_ff=7680, RG-LRU width 2560, local attn window 2048 [arXiv:2402.19427; hf].
Pattern (R,R,A)x8 + (R,R).  Sub-quadratic: runs the long_500k cell."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_head=256,
    d_ff=7680,
    vocab_size=256000,
    group=("rglru", "rglru", "attn"),
    tail=("rglru", "rglru"),
    ffn="geglu",
    window=2048,
    d_rnn=2560,
    conv_width=4,
    rope_theta=10_000.0,
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-tiny",
        family="hybrid",
        n_layers=5,
        d_model=64,
        n_heads=2,
        n_kv_heads=1,
        d_head=32,
        d_ff=128,
        vocab_size=512,
        group=("rglru", "rglru", "attn"),
        n_groups=1,
        tail=("rglru", "rglru"),
        ffn="geglu",
        window=8,
        d_rnn=64,
        conv_width=4,
        vocab_pad_multiple=16,
    )
