"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152; GQA + RoPE, LayerNorm + GELU MLP, biases [arXiv:2402.19173; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    qkv_bias=True,
    norm="layernorm",
    ffn="gelu",
    rope_theta=100_000.0,
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b-tiny",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        qkv_bias=True,
        norm="layernorm",
        ffn="gelu",
        rope_theta=100_000.0,
        vocab_pad_multiple=16,
    )
