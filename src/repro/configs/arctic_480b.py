"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) dense d_ff=4864,
MoE 128 experts top-2 (expert d_ff=4864) + dense residual
[hf:Snowflake/snowflake-arctic-base; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    ffn="moe",
    n_experts=128,
    top_k=2,
    d_ff_expert=4864,
    moe_dense_residual=True,
    rope_theta=10_000.0,
    param_dtype="bfloat16",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-tiny",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=512,
        ffn="moe",
        n_experts=8,
        top_k=2,
        d_ff_expert=96,
        moe_dense_residual=True,
        vocab_pad_multiple=16,
    )
